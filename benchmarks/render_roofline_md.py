"""Render EXPERIMENTS.md §Roofline markdown tables from the dry-run JSONs.

  PYTHONPATH=src python -m benchmarks.render_roofline_md
"""

from benchmarks.roofline_table import load_cells


def fmt(rec):
    def s(x):
        return f"{x:.3g}"
    fused = rec.get("memory_fused_s")
    return (f"| {rec['arch']} | {rec['shape']} | {s(rec['compute_s'])} "
            f"| {s(rec['memory_s'])} "
            f"| {s(fused) if fused is not None else '—'} "
            f"| {s(rec['collective_s'])} "
            f"| {rec['dominant']} "
            f"| {rec.get('useful_flops_fraction', 0):.2f} "
            f"| {rec.get('roofline_fraction', 0) * 100:.2f}% "
            f"| {rec.get('peak_memory_bytes', 0) / 2**30:.1f} |")


def main():
    print("| arch | shape | compute_s | memory_s | mem_fused_s "
          "| collective_s | dominant "
          "| useful_flops | roofline | peak GiB |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    order = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    cells = load_cells("single_pod")
    keys = sorted(cells, key=lambda k: (k.split("__")[0],
                                        order.index(k.split("__")[1])))
    skips = []
    errors = []
    for key in keys:
        rec = cells[key]
        if rec.get("status") == "skipped":
            skips.append(key)
            continue
        if rec.get("status") != "ok" or "dominant" not in rec:
            errors.append((key, rec.get("error", "no twin")))
            continue
        print(fmt(rec))
    if skips:
        print(f"\nSkipped cells (long_500k x full-attention archs, "
              f"DESIGN.md §Arch-applicability): {len(skips)}")
        for k in skips:
            print(f"  - {k}")
    if errors:
        print(f"\nErrors: {errors}")

    multi = load_cells("multi_pod")
    ok = sum(1 for r in multi.values() if r.get("status") == "ok")
    sk = sum(1 for r in multi.values() if r.get("status") == "skipped")
    print(f"\nMulti-pod (2,16,16): {ok} cells lowered+compiled OK, "
          f"{sk} skipped, {len(multi) - ok - sk} failed.")


if __name__ == "__main__":
    main()
