"""Paper Table 4: computation speedup from customized pipelining alone
(O1 -> O2), per kernel, next to the paper's measured numbers."""

from repro.core.costmodel import MACHSUITE_PROFILES, kernel_time
from repro.core.optlevel import OptLevel

PAPER_TABLE4 = {
    "aes": 1.4, "bfs": 1.4, "gemm": 10.5, "kmp": 7.0,
    "nw": 8.8, "sort": 1.8, "spmv": 10.9, "viterbi": 3.2,
}


def main():
    rows = []
    for name, prof in MACHSUITE_PROFILES.items():
        c1 = kernel_time(prof, OptLevel.O1)["compute_s"]
        c2 = kernel_time(prof, OptLevel.O2)["compute_s"]
        ours = c1 / c2
        paper = PAPER_TABLE4[name]
        rows.append((
            f"pipelining/{name}",
            c2 * 1e6,
            f"speedup={ours:.2f}x paper={paper}x "
            f"err={abs(ours - paper) / paper:.1%}",
        ))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
