"""Microbenchmark: paged decode attention — gather step vs gather-free
kernel — across a (max_seq, block_size, batch) grid.

Each cell builds a block pool with realistic occupancy (every slot holds
a random prefix of its reservation), then times two jitted formulations
of one decode-attention tick:

  gather — materialize the dense (B, nb*T, KV, D) view from the pool
           (``jnp.take``, what ``serving/paged.BlockPagingPlan.gather``
           does every tick) and run dense masked attention on it;
  kernel — ``repro.kernels.paged_attention`` walking the block tables
           directly (O(blocks touched) KV traffic).

Methodology follows the serving-ladder noise memo: jit compiles outside
the timed region, measurement rounds interleave the two variants (so
container drift cancels), and each variant's floor is the trimmed min
(mean of its 3 fastest rounds).  Never run this under concurrent load.

Rows are appended as JSONL to ``experiments/autotune/paged_attn_bench.jsonl``
(one row per cell x variant, with the analytic bytes estimate alongside
the measured floor) so the perf trajectory tooling can track the
kernel-vs-gather frontier over time.

CPU caveat: on this container the kernel runs in Pallas interpret mode —
every grid step is emulated with traced jax ops — so its WALL-CLOCK
carries a large constant emulation toll and gather wins the stopwatch;
the ``kv_bytes_est`` column is the hardware-relevant axis (the kernel
moves O(blocks touched), the gather step O(B * max_seq)).  This is
exactly why the serving autotuner *measures* the two and keeps gather on
a tie/loss instead of assuming the kernel wins: on a real TPU
(``interpret=False``) the bytes column is the stopwatch.

  PYTHONPATH=src python -m benchmarks.paged_attn_bench
"""

import json
import os
import time

TRAJ = os.path.join(os.path.dirname(__file__), "..", "experiments",
                    "autotune", "paged_attn_bench.jsonl")

# (max_seq, block_size, batch) cells; heads/dims fixed at a small GQA
# shape so the sweep isolates the KV-traffic axes the kernel changes.
GRID = [
    (64, 8, 4), (64, 16, 4),
    (256, 16, 4), (256, 16, 8),
    (512, 16, 8), (512, 32, 8),
]
H, KV, D = 4, 2, 32


def build_cell(max_seq: int, block: int, batch: int, seed: int = 0,
               kv_dtype: str = "bf16"):
    """Pool + tables + lengths with random prefix occupancy, plus the
    per-variant jitted callables.  ``kv_dtype`` int8/fp8 stores the pool
    quantized with per-block (x per-kv-head) absmax scales: the gather
    variant dequantizes the gathered view (what
    ``serving/paged.BlockPagingPlan.gather`` does), the kernel variant
    passes the (rows, KV) scale operands and dequantizes each streamed
    block in place."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.kernels.paged_attention.ops import paged_attention
    from repro.serving import kvquant

    quantized = kvquant.is_quantized(kv_dtype)
    rng = np.random.default_rng(seed)
    nb = -(-max_seq // block)
    rows = batch * nb + 1
    lengths = rng.integers(1, max_seq + 1, batch)
    tables = np.zeros((batch, nb), np.int32)
    free = list(range(1, rows))
    rng.shuffle(free)
    for b in range(batch):
        for j in range(-(-int(lengths[b]) // block)):
            tables[b, j] = free.pop()
    key = jax.random.PRNGKey(seed)
    kp, vp, q = (jax.random.normal(k, s, jnp.bfloat16) for k, s in zip(
        jax.random.split(key, 3),
        [(rows, block, KV, D), (rows, block, KV, D), (batch, H, D)]))
    tables = jnp.asarray(tables)
    lengths = jnp.asarray(lengths, jnp.int32)
    if quantized:
        ks = kvquant.block_scale(kp, (1, 3), kv_dtype)   # (rows,1,KV,1)
        vs = kvquant.block_scale(vp, (1, 3), kv_dtype)
        kp = kvquant.quantize(kp, ks, kv_dtype)
        vp = kvquant.quantize(vp, vs, kv_dtype)
        ks, vs = ks[:, 0, :, 0], vs[:, 0, :, 0]          # (rows, KV)
    else:
        ks = vs = None

    @jax.jit
    def gather_step(q, kp, vp, ks, vs, tables, lengths):
        flat = tables.reshape(-1)
        dk = jnp.take(kp, flat, axis=0)
        dv = jnp.take(vp, flat, axis=0)
        if quantized:
            sk = jnp.take(ks, flat, axis=0)[:, None, :, None]
            sv = jnp.take(vs, flat, axis=0)[:, None, :, None]
            dk = (dk.astype(jnp.float32) * sk).astype(q.dtype)
            dv = (dv.astype(jnp.float32) * sv).astype(q.dtype)
        dk = dk.reshape(batch, nb * block, KV, D)
        dv = dv.reshape(batch, nb * block, KV, D)
        qg = q.reshape(batch, KV, H // KV, D)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, dk) * (D ** -0.5)
        s = s.astype(jnp.float32)
        idx = jnp.arange(nb * block)
        s = jnp.where(idx[None, None, None, :]
                      < lengths[:, None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        o = jnp.einsum("bkgs,bskd->bkgd", p, dv)
        return o.reshape(batch, H, D)

    @jax.jit
    def kernel_step(q, kp, vp, ks, vs, tables, lengths):
        return paged_attention(q, kp, vp, tables, lengths,
                               k_scale=ks, v_scale=vs)

    args = (q, kp, vp, ks, vs, tables, lengths)
    itemsize = 1 if quantized else 2
    tb_store = 2 * KV * D * itemsize                      # k + v, stored
    tb_compute = 2 * KV * D * 2                           # dense bf16 view
    sb = 2 * KV * 4 if quantized else 0                   # k + v scales/row
    blocks = int(sum(-(-int(x) // block) for x in lengths))
    # gather: pool read (stored bytes + scales) + dense-view write and
    # attention read (compute bytes); kernel: stream only referenced
    # blocks (stored bytes + scales) + the appended token
    gather_est = (batch * nb * (block * tb_store + sb)
                  + 2 * batch * nb * block * tb_compute)
    kernel_est = blocks * (block * tb_store + sb) + batch * tb_store
    return {
        "gather": (gather_step, args, gather_est),
        "kernel": (kernel_step, args, kernel_est),
    }


def bench(rounds: int = 7, iters: int = 20,
          kv_dtypes=("bf16",)) -> list:
    import jax

    rows = []
    for max_seq, block, batch in GRID:
        for kvd in kv_dtypes:
            variants = build_cell(max_seq, block, batch, kv_dtype=kvd)
            # warmup: compile + first-run costs outside the timed region
            for fn, args, _ in variants.values():
                jax.block_until_ready(fn(*args))
            samples = {v: [] for v in variants}
            for _ in range(rounds):
                for v, (fn, args, _) in variants.items():   # interleaved
                    t0 = time.perf_counter()
                    for _ in range(iters):
                        out = fn(*args)
                    jax.block_until_ready(out)
                    samples[v].append((time.perf_counter() - t0) / iters)
            for v, (fn, args, est) in variants.items():
                floor = sum(sorted(samples[v])[:3]) / 3     # trimmed min
                rows.append({
                    "max_seq": max_seq, "block_size": block,
                    "batch": batch,
                    "heads": H, "kv_heads": KV, "head_dim": D,
                    "variant": v, "kv_dtype": kvd,
                    "wall_us": floor * 1e6,
                    "kv_bytes_est": int(est),
                })
    return rows


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--kv-dtype", default="bf16,int8",
                    help="comma list of pool stored dtypes to sweep "
                         "(bf16|int8|fp8); each cell x variant is "
                         "measured per dtype and the JSONL rows carry "
                         "kv_dtype + the dtype's bytes/tick estimate")
    ap.add_argument("--rounds", type=int, default=7)
    ap.add_argument("--iters", type=int, default=20)
    args = ap.parse_args(argv)
    dtypes = tuple(d.strip() for d in args.kv_dtype.split(",") if d.strip())

    rows = bench(rounds=args.rounds, iters=args.iters, kv_dtypes=dtypes)
    os.makedirs(os.path.dirname(TRAJ), exist_ok=True)
    with open(TRAJ, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    by_cell = {}
    for r in rows:
        by_cell.setdefault(
            (r["max_seq"], r["block_size"], r["batch"], r["kv_dtype"]),
            {})[r["variant"]] = r
    print("max_seq block batch kv_dtype | gather_us kernel_us speedup | "
          "gather_KB kernel_KB")
    for (ms, bl, ba, kvd), cell in sorted(by_cell.items()):
        g, k = cell["gather"], cell["kernel"]
        print(f"{ms:7d} {bl:5d} {ba:5d} {kvd:>8s} | {g['wall_us']:9.1f} "
              f"{k['wall_us']:9.1f} {g['wall_us'] / k['wall_us']:7.2f}x | "
              f"{g['kv_bytes_est'] / 1024:9.1f} "
              f"{k['kv_bytes_est'] / 1024:9.1f}")
    print(f"wrote {os.path.relpath(TRAJ)}")
    return rows


if __name__ == "__main__":
    main()
