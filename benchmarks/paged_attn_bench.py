"""Microbenchmark: paged decode attention — gather step vs gather-free
kernel — across a (max_seq, block_size, batch) grid.

Each cell builds a block pool with realistic occupancy (every slot holds
a random prefix of its reservation), then times two jitted formulations
of one decode-attention tick:

  gather — materialize the dense (B, nb*T, KV, D) view from the pool
           (``jnp.take``, what ``serving/paged.BlockPagingPlan.gather``
           does every tick) and run dense masked attention on it;
  kernel — ``repro.kernels.paged_attention`` walking the block tables
           directly (O(blocks touched) KV traffic).

Methodology follows the serving-ladder noise memo: jit compiles outside
the timed region, measurement rounds interleave the two variants (so
container drift cancels), and each variant's floor is the trimmed min
(mean of its 3 fastest rounds).  Never run this under concurrent load.

Rows are appended as JSONL to ``experiments/autotune/paged_attn_bench.jsonl``
(one row per cell x variant, with the analytic bytes estimate alongside
the measured floor) so the perf trajectory tooling can track the
kernel-vs-gather frontier over time.

CPU caveat: on this container the kernel runs in Pallas interpret mode —
every grid step is emulated with traced jax ops — so its WALL-CLOCK
carries a large constant emulation toll and gather wins the stopwatch;
the ``kv_bytes_est`` column is the hardware-relevant axis (the kernel
moves O(blocks touched), the gather step O(B * max_seq)).  This is
exactly why the serving autotuner *measures* the two and keeps gather on
a tie/loss instead of assuming the kernel wins: on a real TPU
(``interpret=False``) the bytes column is the stopwatch.

  PYTHONPATH=src python -m benchmarks.paged_attn_bench
"""

import json
import os
import time

TRAJ = os.path.join(os.path.dirname(__file__), "..", "experiments",
                    "autotune", "paged_attn_bench.jsonl")

# (max_seq, block_size, batch) cells; heads/dims fixed at a small GQA
# shape so the sweep isolates the KV-traffic axes the kernel changes.
GRID = [
    (64, 8, 4), (64, 16, 4),
    (256, 16, 4), (256, 16, 8),
    (512, 16, 8), (512, 32, 8),
]
H, KV, D = 4, 2, 32


def build_cell(max_seq: int, block: int, batch: int, seed: int = 0):
    """Pool + tables + lengths with random prefix occupancy, plus the
    per-variant jitted callables."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.kernels.paged_attention.ops import paged_attention

    rng = np.random.default_rng(seed)
    nb = -(-max_seq // block)
    rows = batch * nb + 1
    lengths = rng.integers(1, max_seq + 1, batch)
    tables = np.zeros((batch, nb), np.int32)
    free = list(range(1, rows))
    rng.shuffle(free)
    for b in range(batch):
        for j in range(-(-int(lengths[b]) // block)):
            tables[b, j] = free.pop()
    key = jax.random.PRNGKey(seed)
    kp, vp, q = (jax.random.normal(k, s, jnp.bfloat16) for k, s in zip(
        jax.random.split(key, 3),
        [(rows, block, KV, D), (rows, block, KV, D), (batch, H, D)]))
    tables = jnp.asarray(tables)
    lengths = jnp.asarray(lengths, jnp.int32)

    @jax.jit
    def gather_step(q, kp, vp, tables, lengths):
        flat = tables.reshape(-1)
        dk = jnp.take(kp, flat, axis=0).reshape(batch, nb * block, KV, D)
        dv = jnp.take(vp, flat, axis=0).reshape(batch, nb * block, KV, D)
        qg = q.reshape(batch, KV, H // KV, D)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, dk) * (D ** -0.5)
        s = s.astype(jnp.float32)
        idx = jnp.arange(nb * block)
        s = jnp.where(idx[None, None, None, :]
                      < lengths[:, None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        o = jnp.einsum("bkgs,bskd->bkgd", p, dv)
        return o.reshape(batch, H, D)

    @jax.jit
    def kernel_step(q, kp, vp, tables, lengths):
        return paged_attention(q, kp, vp, tables, lengths)

    args = (q, kp, vp, tables, lengths)
    token_bytes = 2 * KV * D * jnp.bfloat16.dtype.itemsize    # k + v
    blocks = int(sum(-(-int(x) // block) for x in lengths))
    return {
        "gather": (gather_step, args,
                   3 * batch * nb * block * token_bytes),
        "kernel": (kernel_step, args,
                   (blocks * block + batch) * token_bytes),
    }


def bench(rounds: int = 7, iters: int = 20) -> list:
    import jax

    rows = []
    for max_seq, block, batch in GRID:
        variants = build_cell(max_seq, block, batch)
        # warmup: compile + first-run costs outside the timed region
        for fn, args, _ in variants.values():
            jax.block_until_ready(fn(*args))
        samples = {v: [] for v in variants}
        for _ in range(rounds):
            for v, (fn, args, _) in variants.items():   # interleaved
                t0 = time.perf_counter()
                for _ in range(iters):
                    out = fn(*args)
                jax.block_until_ready(out)
                samples[v].append((time.perf_counter() - t0) / iters)
        for v, (fn, args, est) in variants.items():
            floor = sum(sorted(samples[v])[:3]) / 3       # trimmed min
            rows.append({
                "max_seq": max_seq, "block_size": block, "batch": batch,
                "heads": H, "kv_heads": KV, "head_dim": D,
                "variant": v, "wall_us": floor * 1e6,
                "kv_bytes_est": int(est),
            })
    return rows


def main():
    rows = bench()
    os.makedirs(os.path.dirname(TRAJ), exist_ok=True)
    with open(TRAJ, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    by_cell = {}
    for r in rows:
        by_cell.setdefault(
            (r["max_seq"], r["block_size"], r["batch"]), {})[
                r["variant"]] = r
    print("max_seq block batch | gather_us kernel_us speedup | "
          "gather_KB kernel_KB")
    for (ms, bl, ba), cell in sorted(by_cell.items()):
        g, k = cell["gather"], cell["kernel"]
        print(f"{ms:7d} {bl:5d} {ba:5d} | {g['wall_us']:9.1f} "
              f"{k['wall_us']:9.1f} {g['wall_us'] / k['wall_us']:7.2f}x | "
              f"{g['kv_bytes_est'] / 1024:9.1f} "
              f"{k['kv_bytes_est'] / 1024:9.1f}")
    print(f"wrote {os.path.relpath(TRAJ)}")
    return rows


if __name__ == "__main__":
    main()
