"""Paper Table 5: PCIe transfer time normalized to CPU runtime — the
communication-bound filter that rejects BFS and SPMV before refinement."""

from repro.core.costmodel import MACHSUITE_PROFILES, kernel_time
from repro.core.guideline import COMM_BOUND_THRESHOLD, comm_bound_filter
from repro.core.optlevel import OptLevel

PAPER_TABLE5 = {
    "aes": 2.2e-3, "bfs": 0.8, "gemm": 6.0e-4, "kmp": 5.9e-2,
    "nw": 1.5e-3, "sort": 4.9e-3, "spmv": 1.3, "viterbi": 1.4e-2,
}


def main():
    rows = []
    for name, prof in MACHSUITE_PROFILES.items():
        t = kernel_time(prof, OptLevel.O0)
        ratio = t["pcie_s"] / prof.cpu_time_s
        verdict = comm_bound_filter(t["pcie_s"], prof.cpu_time_s)
        rows.append((
            f"comm_filter/{name}",
            t["pcie_s"] * 1e6,
            f"pcie/cpu={ratio:.2e} paper={PAPER_TABLE5[name]:.2e} "
            f"{'REJECT' if verdict else 'accept'}"
            f" (threshold={COMM_BOUND_THRESHOLD})",
        ))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
