"""Paper Fig. 9: computation speedup vs PE duplication factor (1..128),
normalized to the 1-PE design.  BFS is absent (chain-dependent), SORT
scales sub-linearly (tree reduce) — exactly the paper's observations."""

from repro.core.costmodel import MACHSUITE_PROFILES, kernel_time
from repro.core.optlevel import OptLevel

PES = (1, 2, 4, 8, 16, 32, 64, 128)


def main():
    rows = []
    for name, prof in MACHSUITE_PROFILES.items():
        if prof.parallel_jobs == 0:
            rows.append((f"pe_scaling/{name}", 0.0,
                         "n/a (chain-dependent, paper Fig. 9 omits BFS)"))
            continue
        base = kernel_time(prof, OptLevel.O3, pe=1)["compute_s"]
        pts = []
        for pe in PES:
            if pe > prof.max_pe:
                pts.append(f"{pe}:resource-capped")
                continue
            c = kernel_time(prof, OptLevel.O3, pe=pe)["compute_s"]
            pts.append(f"{pe}:{base / c:.1f}x")
        rows.append((f"pe_scaling/{name}", base * 1e6, " ".join(pts)))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
