"""Paper Fig. 6: speedup sensitivity to the explicit-caching size
(2 KB / 64 KB / 1 MB / infinite), normalized per kernel."""

import math

from repro.core.costmodel import MACHSUITE_PROFILES, kernel_time
from repro.core.optlevel import OptLevel

SIZES = {"2KB": 2 * 1024, "64KB": 64 * 1024, "1MB": 1024 * 1024,
         "inf": float("inf")}


def main():
    rows = []
    for name, prof in MACHSUITE_PROFILES.items():
        ts = {}
        for label, size in SIZES.items():
            if math.isinf(size):
                # no burst-init overhead at all: one giant burst
                t = kernel_time(prof, OptLevel.O5,
                                cache_bytes=prof.bytes_in + prof.bytes_out
                                + 1)
            else:
                t = kernel_time(prof, OptLevel.O5, cache_bytes=size)
            ts[label] = t["system_s"]
        base = ts["64KB"]
        detail = " ".join(
            f"{k}={base / v:.3f}" for k, v in ts.items())
        rows.append((f"caching_size/{name}", base * 1e6,
                     f"normalized_speedup[{detail}]"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
