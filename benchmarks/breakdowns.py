"""Paper Figs. 3/7/11: execution-time breakdown (DRAM vs compute) before
each refinement iteration — the data-driven signal that picks the next
step.  Iter#1 sees O0, Iter#2 sees O1, Iter#3 sees O3."""

from repro.core.costmodel import MACHSUITE_PROFILES, kernel_time
from repro.core.guideline import recommend
from repro.core.optlevel import OptLevel

SNAPSHOTS = {
    "before_iter1(Fig3)": OptLevel.O0,
    "before_iter2(Fig7)": OptLevel.O1,
    "before_iter3(Fig11)": OptLevel.O3,
}


def main():
    rows = []
    for snap, lvl in SNAPSHOTS.items():
        for name, prof in MACHSUITE_PROFILES.items():
            t = kernel_time(prof, lvl)
            total = t["dram_s"] + t["compute_s"]
            dram_frac = t["dram_s"] / total if total else 0.0
            rec = recommend(level=lvl, compute_s=t["compute_s"],
                            memory_s=t["dram_s"], offload_s=t["pcie_s"],
                            baseline_s=prof.cpu_time_s)
            head = ("STOP" if rec.stop
                    else rec.step.value if rec.step else "done")
            rows.append((
                f"breakdown/{snap}/{name}",
                total * 1e6,
                f"dram={dram_frac:.0%} compute={1 - dram_frac:.0%} "
                f"next={head}",
            ))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
