"""The serving ladder — the paper's Table 1 analog for the decode engine.

Measures ``repro.serving.DecodeEngine`` at every OptLevel O0..O7 on one
fixed continuous-batching workload (smoke config) and renders the
per-level throughput/latency table to ``benchmarks/SERVING_LADDER.md``,
plus a JSONL trajectory compatible with the autotune tooling (every row
records its ``layout`` and ``devices`` placement cell).  The O6 rung
(paged KV blocks) runs at equal worst-case capacity here so the table
stays a pure speed comparison; its capacity win — more admitted
concurrency at equal memory on long-tail mixes — is measured separately
by :func:`capacity_demo` and rendered under the same table.  On >= 2
visible devices (``XLA_FLAGS=--xla_force_host_platform_device_count=N``)
every O3+ row shards — the O6 row then IS the layout x placement
composition cell (paged pool sharded on its BLOCK axis, same placement
as the O5 row so O5->O6 stays the pure block-indirection delta) — and
the ladder gains the ``O6pe1`` placement-ablation row (same paged pool,
replicated), measured by the same interleaved trimmed-min harness as
every other row.

  PYTHONPATH=src python -m benchmarks.serving_ladder

Methodology: wall-clock on a shared CPU container is noisy and the upper
rungs of the serving ladder are near-ties by design (PE duplication is
inert on one device; double buffering hides tens of microseconds of host
work per tick), so a naive one-engine-per-level sweep confounds the
ladder with jit-instance and process-warmup luck.  This harness builds
``INSTANCES`` independent engines per level (serpentine creation order),
warms every one up (jit compiles outside the timed region), interleaves
measurement rounds across all engines, and estimates each level's floor
as the trimmed min (mean of its 3 fastest runs).  Adjacent levels whose
difference is indistinguishable from round-to-round jitter under a
paired-delta test (median inside 1.5 MADs / 1%) are reported as TIES at
the pooled floor; a regression beyond noise is rendered as-is.  If an
inversion persists, extra rounds with fresh engine instances are run
(up to a cap) before giving up.

Each row also carries TTFT/ITL columns — single-request latency probes
on the idle warm engines (``serving_latency_probe``), trimmed-min over
the same interleaved rounds, through each engine's real prefill path —
and the ``O5c`` row ablates chunked prefill (``prefill_chunk=16``)
against the O5 row it modifies.

The O7 row (speculative decoding) additionally reports ``accept %`` and
``eff tok/step`` — the fraction of drafted tokens the target's argmax
accepted and the tokens emitted per slot per verify window.  With the
smoke zoo's random-weight drafter acceptance is near zero, so the row
reads as speculation's OVERHEAD floor (drafter forwards + a K+1-wide
verify that mostly emits one token); the acceptance column is what
turns it into a win when the drafter approximates the target.  Tokens
stay bit-identical regardless — greedy rejection guarantees it.

The harness also asserts the ladder's semantic contract: under greedy
sampling every level generates bit-identical tokens for every request.
"""

import json
import os
import time

# Keys 0..7 are the OptLevels; keys >= 90 are ablation rows (they were
# 7/8/9 before the ladder grew the O7 rung, which collided with level 7).
STAGES = {
    0: "naive: per-request B=1 decode calls + per-request cache rebuild",
    1: "+ data caching: persistent device cache, in-place slot zeroing",
    2: "+ pipelining: continuous batching, one fused step, sample-in-graph",
    3: "+ PE duplication: batch-axis sharding across devices",
    4: "+ double buffering: bookkeeping runs under the in-flight step",
    5: "+ scratchpad reorg: packed one-call zeroing of admitted slots",
    6: "+ paged scratchpad: KV block pool + per-request block tables",
    7: "+ speculative decoding: drafter proposes K=4, one verify forward",
    # Key 91 is not a level: on >= 2 devices (where the O6 row itself
    # runs the block-axis-sharded composition cell) it re-runs O6 pinned
    # to pe=1 — the placement ablation within the paged layout.
    91: "O6 placement ablation: same paged pool, replicated (pe=1)",
    # Key 92 is not a level either: the O6 attention-implementation
    # ablation — the same paged pool driven by the gather-free
    # block-table Pallas kernel (paged_attn=kernel) instead of the
    # per-tick dense gather.  Its bytes-moved column is the point:
    # O(blocks touched), not O(B * max_seq).
    92: "O6 attn ablation: gather-free block-table kernel "
        "(paged_attn=kernel)",
    # Key 93: the prefill ablation — the O5 engine with CHUNKED prefill
    # (prefill_chunk=16): prompts ride multi-token chunk dispatches
    # interleaved with decode instead of one decode tick per prompt
    # token.  Its column of interest is TTFT, not tok/s.
    93: "O5 prefill ablation: chunked prefill (prefill_chunk=16)",
    # Key 94: the pool-dtype ablation — the O6 engine storing int8
    # blocks with per-block absmax scales (kv_dtype=int8).  Its columns
    # of interest are `pool MB` and `KV bytes/tick` (roughly halved);
    # its token contract is the TOLERANCE contract, not bit-identity —
    # the `identical` column reports contract satisfaction.
    94: "O6 kv-dtype ablation: int8 block pool + per-block scales "
        "(kv_dtype=int8)",
}

# The drafter the O7 row pairs with the target (``model_zoo.
# DRAFTER_PAIRS`` validated at engine build) and its window size.
LADDER_DRAFT = {"draft_model": "smollm-360m", "draft_k": 4}

MD_PATH = os.path.join(os.path.dirname(__file__), "SERVING_LADDER.md")
TRAJ_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "autotune")


def ladder_variants(devices: int):
    """The measured (key, label, config) cells.  Keys 0..7 are the
    OptLevels at their default configs (the O7 row adds the
    ``LADDER_DRAFT`` drafter pairing — speculation needs one) — on >= 2
    devices every O3+ row shards, so O5->O6 compares MATCHED placements
    and the O6 row itself is the layout x placement composition cell
    (block-axis-sharded paged pool).  Key 92 (always present, adjacent
    to the O6 row it ablates) is the attention-implementation ablation:
    the same paged pool driven by the gather-free block-table kernel, so
    O6->O6k reads as the pure gather-elimination delta.  Key 93 is the
    prefill ablation: the O5 engine with chunked prefill
    (prefill_chunk=16), paired against the O5 row so O5->O5c reads as
    the pure chunked-prefill delta — its interesting column is TTFT, not
    tok/s.  Key 91, added only on multi-device runs, is the placement
    ablation: the same paged engine pinned to pe=1, isolating what
    sharding buys (or costs) within the paged layout."""
    from repro.core.optlevel import ALL_LEVELS, BestEffortConfig, OptLevel

    out = [(int(lvl), f"O{int(lvl)}",
            BestEffortConfig(level=lvl, **(LADDER_DRAFT
                                           if lvl == OptLevel.O7 else {})))
           for lvl in ALL_LEVELS]
    out.append((92, "O6k", BestEffortConfig(level=OptLevel.O6,
                                            paged_attn="kernel")))
    out.append((93, "O5c", BestEffortConfig(level=OptLevel.O5,
                                            prefill_chunk=16)))
    out.append((94, "O6q", BestEffortConfig(level=OptLevel.O6,
                                            kv_dtype="int8")))
    if devices > 1:
        out.append((91, "O6pe1", BestEffortConfig(level=OptLevel.O6, pe=1)))
    return out


def _traced_kernel_bytes(eng, workload) -> int:
    """One untimed replay that accumulates the kernel step's per-tick
    KV-bytes estimate (sum over slots of the blocks their tables
    reference, via ``PagedCacheManager.slot_lengths``) — the gather-free
    path's traffic depends on the live lengths, so it is measured off
    the actual schedule, not a formula.  Lengths are sampled BEFORE each
    step: the slots that will attend this tick, including ones that
    retire on it (their final, longest walk counts); on the cold-start
    tick, where admission happens inside the step, they are read back
    post-step instead.  Run AFTER the timed rounds (never under
    concurrent load)."""
    from repro.serving import Request

    mgr = eng.cache_mgr
    for p, n in workload:
        eng.submit(Request(prompt=list(p), max_new_tokens=n))
    total = ticks = 0
    for _ in range(10_000):
        lengths = mgr.slot_lengths(
            [s.pos if s.active else 0 for s in eng.slots])
        steps_before = eng.n_steps
        stepped = eng.step()
        if eng.n_steps > steps_before:
            if not any(lengths):         # cold start: admitted in-step;
                lengths = mgr.slot_lengths(     # pos already advanced
                    [s.pos - 1 if s.active else 0 for s in eng.slots])
            total += mgr.plan.kernel_bytes_per_tick(lengths)
            ticks += 1
        if not stepped and not eng.queue:
            break
    return total // max(1, ticks)


def measure_ladder(arch: str = "qwen3-8b", *, batch_size: int = 4,
                   max_seq: int = 48, n_requests: int = 16,
                   max_new: int = 8, instances: int = 2, rounds: int = 8,
                   max_extra_rounds: int = 24, policy: str = "fcfs",
                   vocab: int = 0, seed: int = 0) -> list:
    """Returns one row dict per measured variant: wall_s, tok_per_s,
    ticks, tokens, identical (vs O0), layout/devices, plus the workload
    identity."""
    import jax

    from repro.autotune.measurement import (run_serving_workload,
                                            serving_latency_probe,
                                            serving_smoke_config,
                                            serving_workload)
    from repro.models import get_model
    from repro.serving import DecodeEngine

    cfg = serving_smoke_config(arch, vocab)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    workload = serving_workload(cfg.vocab, max_seq=max_seq,
                                n_requests=n_requests, max_new=max_new,
                                seed=seed)
    variants = ladder_variants(jax.device_count())
    by_key = {k: (label, vcfg) for k, label, vcfg in variants}
    keys = [k for k, _, _ in variants]

    def run(eng):
        wall, _, gen, _ = run_serving_workload(eng, workload)
        return wall, gen

    generated = {}        # key -> token lists (must agree per key too)
    engines = []          # [(key, engine)]
    kv_capacity = {}      # key -> persistent cache capacity (tokens)
    devices_used = {}     # key -> placement device count
    layouts = {}          # key -> cache layout name
    attn_impls = {}       # key -> paged attention impl (None: contiguous)
    state_impls = {}      # key -> recurrent-state impl ("rows" | "none")
    degrades = {}         # key -> recorded degrade reason (or None)
    prefill_modes = {}    # key -> "chunked" | "token"
    kv_dtypes = {}        # key -> pool stored dtype ("bf16" contiguous)
    pool_mb = {}          # key -> paged pool MB (None: contiguous)
    probe_len = max(1, min(24, max_seq - max_new))

    def add_instance(k):
        _, vcfg = by_key[k]
        eng = DecodeEngine(
            model, params, batch_size=batch_size, max_seq=max_seq,
            config=vcfg, policy=policy)
        _, gen = run(eng)                          # warmup: jit compiles
        assert generated.setdefault(k, gen) == gen, (
            f"variant {k}: instances disagree")
        # Untimed warmup probe so the timed latency probes never carry a
        # first-touch compile (the chunked-prefill step traces here).
        serving_latency_probe(eng, cfg.vocab, prompt_len=probe_len,
                              max_new=max_new, seed=seed + 17)
        kv_capacity[k] = eng.cache_mgr.capacity_tokens
        devices_used[k] = eng.placement.n_devices
        layouts[k] = eng.layout.name
        attn_impls[k] = getattr(eng.layout, "attn_impl", None)
        state_impls[k] = getattr(eng.layout, "state_impl", "none")
        degrades[k] = eng.degrade_reason
        prefill_modes[k] = eng.prefill_mode
        kv_dtypes[k] = getattr(eng.layout, "kv_dtype", "bf16")
        geo = getattr(eng.cache_mgr, "geometry", None)
        pool_mb[k] = geo.get("pool_mb") if geo else None
        engines.append((k, eng))
        return eng

    # Serpentine creation order: engine construction order measurably
    # biases performance (allocator state drifts over process lifetime),
    # so instance 0 is built O0->O6, instance 1 O6->O0, and so on — no
    # variant systematically inherits the worst allocator state.
    for i in range(instances):
        order = keys if i % 2 == 0 else list(reversed(keys))
        for k in order:
            add_instance(k)

    samples = {k: [] for k in keys}
    round_best = {k: [] for k in keys}   # per-round minima
    ttft_samples = {k: [] for k in keys}
    itl_samples = {k: [] for k in keys}
    ticks = {}

    def one_round():
        this_round = {}
        for k, eng in engines:
            t_before = eng.n_steps
            wall, gen = run(eng)
            assert gen == generated[k], f"variant {k}: nondeterminism"
            samples[k].append(wall)
            this_round[k] = min(this_round.get(k, wall), wall)
            ticks[k] = eng.n_steps - t_before
            # Latency probe on the now-idle warm engine: TTFT/ITL through
            # the REAL prefill path (chunked where the config says so),
            # single unloaded request — NOT wall-clock under load.  Rides
            # the same interleaved rounds so process drift cancels.
            ttft, itl, _ = serving_latency_probe(
                eng, cfg.vocab, prompt_len=probe_len, max_new=max_new,
                seed=seed + 17)
            ttft_samples[k].append(ttft)
            itl_samples[k].append(itl)
        for k, w in this_round.items():
            round_best[k].append(w)

    for _ in range(rounds):
        one_round()

    noise_ties = []

    def floors():
        # Trimmed min — mean of the 3 fastest samples — not the raw min:
        # on a shared container one transient quiet period can hand a
        # single level an unrepresentatively lucky sample that a raw min
        # never takes back; the trimmed floor needs the luck to repeat.
        # And on one device PE duplication is inert: the O3 engine
        # resolves to the *identical* configuration as O2 (no mesh, same
        # shared compiled step, same host loop), so the two levels sample
        # the same distribution and share one measurement pool —
        # different floors for identical machine behavior would just be
        # split-sample noise.
        pool = dict(samples)
        if jax.device_count() == 1:
            merged = sorted(samples[2] + samples[3])
            pool[2] = pool[3] = merged
        est = {k: sum(sorted(v)[:3]) / min(3, len(v))
               for k, v in pool.items()}

        # Adjacent variants whose measured difference is statistically
        # indistinguishable from round-to-round jitter are TIES: compare
        # the PAIRED per-round minima (same process epoch, so drift
        # cancels) and, when the median delta is inside the noise band
        # (1.5 MADs, floored at 1%), give both variants the pooled floor.
        # A real regression (beyond noise) is left standing and renders
        # as non-monotone — the harness never papers over mechanism.
        # The ablation rows are NOT paired positionally: O6k (attn impl)
        # and O6pe1 (placement) ablate the O6 row itself, so each is
        # paired against key 6, never against the other ablation; O5c
        # (chunked prefill) ablates the O5 row.
        tie_baseline = {91: 6, 92: 6, 93: 5, 94: 6}
        noise_ties.clear()
        for i in range(1, len(keys)):
            k = keys[i]
            prev = tie_baseline.get(k, keys[i - 1])
            if est[k] <= est[prev]:
                continue
            n = min(len(round_best[k]), len(round_best[prev]))
            deltas = sorted(round_best[k][j] - round_best[prev][j]
                            for j in range(n))
            med = deltas[n // 2]
            mad = sorted(abs(d - med) for d in deltas)[n // 2]
            if med <= max(1.5 * mad, 0.01 * est[prev]):
                merged = sorted(pool[k] + pool[prev])
                tie = sum(merged[:3]) / min(3, len(merged))
                est[k] = est[prev] = tie
                noise_ties.append((prev, k))
        return est

    best = floors()
    extra = 0
    # Inversion escalation covers the MECHANISM rungs O0..O5 only: an
    # inversion there after the initial rounds is instance luck and more
    # instances converge it away.  O5->O6 (and the O6+pe composition row)
    # is excluded — the paged rung pays a real gather/scatter toll at
    # equal capacity, so "slower than O5" is the expected reading, not
    # luck, and chasing it would burn every extra round (and ~2 fresh jit
    # compiles per round) for nothing; the rendered table explains the
    # regression instead.
    mono_top = min(5, len(keys) - 1)
    while extra < max_extra_rounds and any(
            best[k] > best[k - 1] for k in range(1, mono_top + 1)):
        for k in range(1, mono_top + 1):
            if best[k] > best[k - 1]:
                add_instance(k)
                add_instance(k - 1)
        one_round()
        best = floors()
        extra += 1

    # Per-tick KV-cache bytes estimate (the gather-vs-kernel delta the
    # O6k row exists to show).  Contiguous rungs: dense attention streams
    # the whole (B, max_seq) cache each tick.  Paged gather: the dense
    # view is materialized AND read (plan.gather_bytes_per_tick).  Paged
    # kernel: O(blocks touched), measured off a replay of the actual
    # schedule.  Computed after the timed rounds so the replay can't
    # perturb them.
    first_eng = {}
    for k, eng in engines:
        first_eng.setdefault(k, eng)
    # Speculation telemetry (O7 row): counters accumulate over the same
    # deterministic workload every round, so the rate is the workload's.
    spec_stats = {k: first_eng[k].spec_stats for k in keys}
    tb = first_eng[6].cache_mgr.geometry["token_bytes"]
    kv_bytes = {}
    for k in keys:
        eng = first_eng[k]
        if eng.layout.name == "contiguous":
            kv_bytes[k] = batch_size * max_seq * tb
        elif getattr(eng.layout, "attn_impl", "gather") == "kernel":
            kv_bytes[k] = _traced_kernel_bytes(eng, workload)
        else:
            kv_bytes[k] = eng.cache_mgr.plan.gather_bytes_per_tick()

    # Latency floors use the same trimmed-min estimator as the
    # throughput column: each probe is one unloaded request through the
    # engine's real prefill path, sampled once per engine per round.
    ttft_est = {k: sum(sorted(v)[:3]) / min(3, len(v))
                for k, v in ttft_samples.items()}
    itl_est = {k: sum(sorted(v)[:3]) / min(3, len(v))
               for k, v in itl_samples.items()}

    from repro.serving.kvquant import token_agreement, tolerance_contract

    tokens = sum(len(g) for g in generated[0])
    tie_partner = {k: p for p, k in noise_ties}
    row_level = {91: 6, 92: 6, 93: 5, 94: 6}
    rows = []
    for i, k in enumerate(keys):
        stage = STAGES[k]
        if k == 92 and attn_impls[k] != "kernel":
            # A family without a paged decode step degrades the kernel
            # row to gather — say so instead of mislabeling the cell.
            stage += (" — DEGRADED to gather (this family has no paged "
                      "decode step)")
        if k == 93 and prefill_modes[k] != "chunked":
            stage += (" — DEGRADED to token prefill (this family has no "
                      "prefill step)")
        if k == 7 and spec_stats[k]["spec_mode"] != "draft":
            stage += (" — DEGRADED to plain decode (this cell cannot "
                      "speculate)")
        # The ladder's token contract is per-row: bf16 rows must be
        # bit-identical to O0; a narrow-pool row is held to its dtype's
        # tolerance contract instead (the `identical` column then reports
        # contract SATISFACTION, and `agreement` the measured fraction).
        if kv_dtypes[k] == "bf16":
            identical = generated[k] == generated[0]
            agreement = None
        else:
            tc = tolerance_contract(kv_dtypes[k])
            agreement = token_agreement(generated[0], generated[k])
            identical = agreement >= tc["min_agreement"]
        rows.append({
            "level": row_level.get(k, k),
            "label": by_key[k][0],
            "stage": stage,
            "wall_s": best[k],
            "tok_per_s": tokens / best[k],
            "tick_ms": best[k] / ticks[k] * 1e3,
            "ticks": ticks[k],
            "tokens": tokens,
            "speedup_vs_o0": best[0] / best[k],
            "identical": identical,
            "kv_dtype": kv_dtypes[k],
            "agreement": agreement,
            "pool_mb": pool_mb[k],
            # the baseline this row pooled floors with (each ablation row
            # ties against the O6 row it ablates, not its table neighbor)
            "noise_tie_with": (by_key[tie_partner[k]][0]
                               if k in tie_partner else None),
            "extra_rounds": extra,
            "kv_capacity": kv_capacity[k],
            "layout": layouts[k],
            "devices": devices_used[k],
            "paged_attn": attn_impls[k],
            "state_impl": state_impls[k],
            "degrade_reason": degrades[k],
            "kv_bytes_per_tick": int(kv_bytes[k]),
            "prefill_mode": prefill_modes[k],
            "ttft_ms": ttft_est[k] * 1e3,
            "itl_ms": itl_est[k] * 1e3,
            "spec_mode": spec_stats[k]["spec_mode"],
            "draft_k": spec_stats[k]["draft_k"],
            "accept_rate": spec_stats[k]["accept_rate"],
            "eff_tok_per_step": spec_stats[k]["eff_tok_per_step"],
        })
    return rows


def capacity_demo(arch: str = "qwen3-8b", *, memory_slots: int = 4,
                  max_seq: int = 48, slots_paged: int = 8,
                  block_size: int = 8, n_requests: int = 24,
                  max_new: int = 6, seed: int = 0) -> dict:
    """The paged rung's actual win, measured: at EQUAL KV memory
    (``memory_slots x max_seq`` token positions), the contiguous cache
    admits at most ``memory_slots`` concurrent requests no matter how
    short they are, while the paged pool admits as many as their actual
    reservations pack — more concurrency (and fewer ticks) on long-tail
    prompt mixes.  Greedy tokens must stay identical between the two
    engines (slot placement and batch composition never change *what* is
    computed).

    The QUANTIZED row compounds the win: at the same pool BYTES the
    int8 pool holds ~2x the blocks (1-byte cells + per-block scales vs
    2-byte bf16 cells), so it admits ~2x the paged engine's concurrency
    on the same mix.  Its tokens are held to the int8 tolerance
    contract against the contiguous baseline, not bit-identity.

    Timing follows the ladder harness's rules, not a hand-rolled
    stopwatch: jit compiles (the O6 engine always builds its own step —
    pool geometry is part of the program) and the deterministic run shape
    (peak concurrency, ticks) are captured on an untimed warmup pass, and
    the tok/s column is the best of interleaved re-runs on the
    already-warm engines, so neither side's number carries compile time
    or a one-sided quiet period."""
    import jax

    from repro.autotune.measurement import (run_serving_workload,
                                            serving_smoke_config,
                                            serving_workload)
    from repro.core.optlevel import BestEffortConfig, OptLevel
    from repro.models import get_model
    from repro.serving import DecodeEngine, Request

    rounds = 3
    cfg = serving_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    workload = serving_workload(cfg.vocab, max_seq=max_seq,
                                n_requests=n_requests, max_new=max_new,
                                seed=seed)
    pool_blocks = memory_slots * max_seq // block_size   # same token memory

    def warmup_tracked(eng):
        """Untimed first pass: compiles, and records the run's
        deterministic shape (peak concurrency, ticks, generations)."""
        rids = [eng.submit(Request(prompt=list(p), max_new_tokens=n))
                for p, n in workload]
        peak = 0
        for _ in range(10_000):
            stepped = eng.step()
            peak = max(peak, sum(s.active for s in eng.slots))
            if not stepped and not eng.queue:
                break
        by_rid = {r.rid: r.generated for r in eng.finished}
        gen = [by_rid[rid] for rid in rids]
        return {"peak_concurrency": peak, "ticks": eng.n_steps,
                "gen": gen, "tokens": sum(len(g) for g in gen)}

    eng_c = DecodeEngine(
        model, params, batch_size=memory_slots, max_seq=max_seq,
        config=BestEffortConfig(level=OptLevel.O5))
    eng_p = DecodeEngine(
        model, params, batch_size=slots_paged, max_seq=max_seq,
        config=BestEffortConfig(level=OptLevel.O6,
                                kv_block_size=block_size,
                                kv_pool_blocks=pool_blocks))
    contig, paged = warmup_tracked(eng_c), warmup_tracked(eng_p)
    assert paged["gen"] == contig["gen"], "capacity demo changed tokens"

    # Quantized pool at the SAME pool BYTES as the bf16 pool: the bytes
    # the 1-byte cells save (minus the per-block scale overhead) are
    # spent on more blocks, and the slot count doubles so the extra
    # blocks can actually become admitted concurrency.
    from repro.serving.kvquant import token_agreement, tolerance_contract
    from repro.serving.paged import BlockPagingPlan

    wide_plan = eng_p.cache_mgr.plan
    nplan = BlockPagingPlan(model, slots_paged, max_seq, block_size,
                            pool_blocks, kv_dtype="int8")
    wide_bb = block_size * wide_plan.token_bytes \
        + wide_plan.scale_bytes_per_block
    narrow_bb = block_size * nplan.token_bytes + nplan.scale_bytes_per_block
    q_blocks = pool_blocks * wide_bb // narrow_bb
    eng_q = DecodeEngine(
        model, params, batch_size=slots_paged * 2, max_seq=max_seq,
        config=BestEffortConfig(level=OptLevel.O6,
                                kv_block_size=block_size,
                                kv_pool_blocks=q_blocks,
                                kv_dtype="int8"))
    quant = warmup_tracked(eng_q)
    tc = tolerance_contract("int8")
    agreement = token_agreement(contig["gen"], quant["gen"])
    assert agreement >= tc["min_agreement"], (
        f"capacity demo int8 agreement {agreement:.3f} below the "
        f"{tc['min_agreement']} tolerance contract")

    contig["wall_s"] = paged["wall_s"] = quant["wall_s"] = float("inf")
    for _ in range(rounds):                       # interleaved best-of-K
        for rec, eng in ((contig, eng_c), (paged, eng_p), (quant, eng_q)):
            wall, _, gen, _ = run_serving_workload(eng, workload)
            assert gen == rec["gen"], "capacity demo nondeterminism"
            rec["wall_s"] = min(rec["wall_s"], wall)
    quant["pool_blocks"] = q_blocks
    quant["agreement"] = agreement
    return {
        "arch": arch,
        "kv_memory_tokens": memory_slots * max_seq,
        "block_size": block_size,
        "pool_blocks": pool_blocks,
        "n_requests": n_requests,
        "contiguous": {k: v for k, v in contig.items() if k != "gen"},
        "paged": {k: v for k, v in paged.items() if k != "gen"},
        "quantized": {k: v for k, v in quant.items() if k != "gen"},
        "identical": True,
    }


def capacity_demo_state(arch: str = "zamba2-2.7b", *, memory_slots: int = 4,
                        max_seq: int = 256, slots_paged: int = 12,
                        block_size: int = 8, n_requests: int = 12,
                        max_new: int = 6, seed: int = 0) -> dict:
    """The paged rung's capacity story for a RECURRENT family, at equal
    TOTAL cache bytes (attention KV blocks + state rows, leaf-summed off
    the real device trees — no formula).

    Hybrid (and enc-dec self-attention) families win the same way
    transformers do: recurrent state is O(1) per slot, so at a long
    ``max_seq`` almost the whole contiguous budget is worst-case
    attention KV, and the paged engine re-spends it as block-packed
    short reservations plus one cheap state row per extra slot — more
    admitted concurrency on short-prompt mixes.  Pure-state families
    (rwkv6, mamba2) have NO per-position cache at all: capacity is one
    row per slot whichever layout holds it, so at equal bytes the paged
    pool admits exactly ``contig_rows - 1`` slots (the constant NULL
    row is the entire overhead, amortized away at scale) — the table
    reports that parity honestly; the O6 rung's value for them is the
    uniform full-rung mechanism (kernel step, NULL-row chunk parking,
    defrag), not bytes.

    Greedy tokens must stay identical across layouts and batch sizes —
    slot placement never changes what is computed."""
    import jax

    from repro.autotune.measurement import (serving_smoke_config,
                                            serving_workload)
    from repro.core.optlevel import BestEffortConfig, OptLevel
    from repro.models import get_model
    from repro.serving import DecodeEngine, PagedCacheManager, Request

    cfg = serving_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    # short prompts (4x shorter than the engine's max_seq would draw):
    # the long-tail mix where block packing beats worst-case slabs
    workload = serving_workload(cfg.vocab, max_seq=max_seq // 4,
                                n_requests=n_requests, max_new=max_new,
                                seed=seed)

    def drain(eng):
        rids = [eng.submit(Request(prompt=list(p), max_new_tokens=n))
                for p, n in workload]
        peak = 0
        for _ in range(10_000):
            stepped = eng.step()
            peak = max(peak, sum(s.active for s in eng.slots))
            if not stepped and not eng.queue:
                break
        by_rid = {r.rid: r.generated for r in eng.finished}
        return {"peak_concurrency": peak, "ticks": eng.n_steps,
                "gen": [by_rid[rid] for rid in rids]}

    eng_c = DecodeEngine(model, params, batch_size=memory_slots,
                         max_seq=max_seq,
                         config=BestEffortConfig(level=OptLevel.O5))
    contig_bytes = sum(l.size * l.dtype.itemsize
                       for l in jax.tree.leaves(eng_c.cache_mgr.cache))

    # probe manager: per-block and per-state-row byte costs of THIS
    # family's cache tree (geometry, not guesswork)
    g = PagedCacheManager(model, 2, max_seq, block_size=block_size).geometry
    block_bytes = block_size * g["token_bytes"] + g["scale_bytes_per_block"]
    row_bytes = g["state_row_bytes"]
    if g["token_bytes"] == 0:
        # pure state: no block leaves to page; equal bytes buys
        # contig_rows - 1 slots (the NULL row is the whole overhead)
        slots = max(1, contig_bytes // max(1, row_bytes) - 1)
        pcfg = BestEffortConfig(level=OptLevel.O6, kv_block_size=block_size)
        note = "state only: parity minus the constant NULL row"
    else:
        # spend the contiguous budget on (slots_paged + NULL) state rows,
        # then pack the remainder with KV blocks (one row is the NULL
        # block, not allocatable)
        state_total = (slots_paged + 1) * row_bytes
        blocks = (contig_bytes - state_total) // block_bytes - 1
        slots = slots_paged
        pcfg = BestEffortConfig(level=OptLevel.O6, kv_block_size=block_size,
                                kv_pool_blocks=int(blocks))
        note = "mixed pools: block tables + one state row per slot"
    eng_p = DecodeEngine(model, params, batch_size=int(slots),
                         max_seq=max_seq, config=pcfg)
    paged_bytes = eng_p.cache_mgr.geometry["pool_bytes"]
    assert paged_bytes <= contig_bytes, (arch, paged_bytes, contig_bytes)

    contig, paged = drain(eng_c), drain(eng_p)
    assert paged["gen"] == contig["gen"], (
        f"{arch} state capacity demo changed tokens")
    return {
        "arch": arch, "family": cfg.family,
        "contig_bytes": int(contig_bytes), "paged_bytes": int(paged_bytes),
        "contig_slots": memory_slots, "paged_slots": int(slots),
        "state_impl": eng_p.layout.state_impl, "note": note,
        "contiguous": {k: v for k, v in contig.items() if k != "gen"},
        "paged": {k: v for k, v in paged.items() if k != "gen"},
        "identical": True,
    }


# The family x rung support matrix SERVING_LADDER.md and README render:
# static truth about which mechanism each family runs at each rung,
# asserted by the differential-fuzz suite (tests/test_serving.py).
FAMILY_RUNG_MATRIX = [
    ("dense / moe / vlm", "qwen3-8b", "yes", "gather + kernel",
     "— (every leaf block-paged)", "contiguous + paged", "yes"),
    ("ssm (rwkv6)", "rwkv6-3b", "yes", "gather + kernel", "rows",
     "paged only (NULL-row parking)", "no vocab-compatible drafter"),
    ("mamba (mamba2)", "mamba2-2.7b", "yes", "gather + kernel", "rows",
     "paged only (NULL-row parking)", "no vocab-compatible drafter"),
    ("hybrid (zamba2)", "zamba2-2.7b", "yes",
     "gather + kernel (shared-attn KV blocks)", "rows (conv/ssm state)",
     "paged only (NULL-row parking)", "no vocab-compatible drafter"),
    ("enc-dec (whisper)", "whisper-base", "yes",
     "gather + kernel (self-attn KV blocks)", "rows (cross KV, read-only)",
     "contiguous + paged", "no vocab-compatible drafter"),
]


def render_md(rows, arch: str, capacity: dict = None,
              state_capacity: list = None) -> str:
    lines = [
        "# The serving ladder (paper Table 1 analog for the decode engine)",
        "",
        f"Generated by `python -m benchmarks.serving_ladder` — the",
        f"`repro.serving` engine built at every OptLevel on the `{arch}`",
        "smoke config, decoding one fixed continuous-batching workload",
        f"({rows[0]['tokens']} tokens across mixed-length requests).",
        "Best-of-interleaved-rounds wall clock; see the module docstring",
        "for the methodology.  Greedy sampling: every level must generate",
        "bit-identical tokens (the serving analog of MachSuite's O0..O5",
        "output-equivalence matrix).",
        "",
        "| level | serving stage (paper step) | tok/s | tick (ms) | "
        "wall (s) | speedup vs O0 | TTFT (ms) | ITL (ms) | "
        "KV capacity (tok) | pool MB | KV bytes/tick | devices | "
        "accept % | eff tok/step | identical tokens |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        kb = r.get("kv_bytes_per_tick")
        kb = f"{kb / 1024:.1f}K" if kb else "-"
        ttft = r.get("ttft_ms")
        itl = r.get("itl_ms")
        spec = r.get("spec_mode") == "draft"
        acc = f"{r['accept_rate'] * 100:.0f}%" if spec else "-"
        eff = f"{r['eff_tok_per_step']:.2f}" if spec else "-"
        pmb = r.get("pool_mb")
        pmb = f"{pmb:.2f}" if pmb is not None else "-"
        # bf16 rows report bit-identity; narrow-pool rows report their
        # tolerance-contract status with the measured token agreement
        if r.get("kv_dtype", "bf16") == "bf16":
            ident = "yes" if r["identical"] else "NO"
        else:
            ident = (f"{'tol ok' if r['identical'] else 'TOL FAIL'} "
                     f"({r['agreement']:.2f})")
        lines.append(
            f"| {r['label']} | {r['stage']} | {r['tok_per_s']:.0f} "
            f"| {r['tick_ms']:.3f} | {r['wall_s']:.4f} "
            f"| {r['speedup_vs_o0']:.2f}x "
            f"| {ttft:.2f} | {itl:.3f} "
            f"| {r.get('kv_capacity', '-')} "
            f"| {pmb} "
            f"| {kb} "
            f"| {r.get('devices', 1)} "
            f"| {acc} | {eff} "
            f"| {ident} |")
    # The monotonicity contract covers the mechanism rungs O0..O5 only —
    # the O6 capacity rung (and the O6+pe composition row) may
    # legitimately pay a gather/scatter toll (the note below explains
    # it), matching the harness's mono_top.
    mtop = min(5, len(rows) - 1)
    mono = all(rows[i]["tok_per_s"] >= rows[i - 1]["tok_per_s"]
               for i in range(1, mtop + 1))
    ties = [f"{r['noise_tie_with']}={r['label']}"
            for r in rows if r.get("noise_tie_with")]
    lines += [
        "",
        f"tok/s monotone non-decreasing O0->O{mtop}: "
        f"{'yes' if mono else 'NO'}; "
        f"ladder token contract (bf16 rows bit-identical, narrow-pool "
        f"rows within their tolerance contract): "
        f"{'yes' if all(r['identical'] for r in rows) else 'NO'}."
        + (f"  Ties within measurement noise (paired-delta test): "
           f"{', '.join(ties)}." if ties else ""),
    ]
    lines += [
        "",
        "TTFT/ITL are single-request latency probes on the idle warm",
        "engines (trimmed min across the interleaved rounds), measured",
        "through each engine's real prefill path — NOT wall-clock under",
        "load.  The `O5c` row is the O5 engine with chunked prefill",
        "(`prefill_chunk=16`): a prompt costs ceil(P/16) chunk ticks",
        "before its first token instead of P one-token ticks, which is",
        "the TTFT column's delta; greedy tokens stay bit-identical.",
    ]
    if any(r.get("spec_mode") == "draft" for r in rows):
        lines += [
            "",
            "The O7 row is speculative decoding: a small drafter",
            f"(`{LADDER_DRAFT['draft_model']}`) proposes",
            f"K={LADDER_DRAFT['draft_k']} tokens per slot per tick and the",
            "target verifies the whole window in ONE batched forward,",
            "accepting exactly its own argmax prefix (greedy rejection) —",
            "so tokens stay bit-identical to O5/O6 by construction.  The",
            "`accept %` / `eff tok/step` columns are the mechanism's",
            "telemetry: effective tokens per verify window is",
            "1 + accept x K.  On the smoke zoo the drafter's weights are",
            "random, acceptance is near zero, and the row shows the",
            "overhead floor; the autotuner (`--serve`, `draft_k=auto`)",
            "races K in {0,2,4,8} and keeps speculation only when it",
            "actually wins.",
        ]
    if max(r["level"] for r in rows) >= 6:
        lines += [
            "",
            "O6 runs this speed table at EQUAL worst-case capacity"
            " (auto-sized pool), so any delta vs O5 is the pure"
            " gather/scatter toll of block indirection; the rung's win is"
            " the capacity table below.  The `O6k` row is the same paged"
            " pool driven by the gather-free block-table Pallas kernel"
            " (`paged_attn=kernel`): no dense view is ever materialized,"
            " which is what the `KV bytes/tick` column shows — the gather"
            " step stages O(B x max_seq) KV bytes per tick (3x the dense"
            " view: pool read, dense write, attention read) while the"
            " kernel touches only the blocks each slot's table references"
            " (measured off a replay of the actual schedule).  The"
            " autotuner (`--serve`, `paged_attn=auto`) measures both and"
            " keeps the winner — gather on tie/loss.",
            "",
            "The `O6q` row is the same paged engine storing INT8 blocks"
            " with per-block (x per-kv-head) absmax scales"
            " (`kv_dtype=int8`): the `pool MB` column roughly halves at"
            " the same token capacity — capacity the pool can spend on"
            " ~2x the admitted concurrency at equal memory (quantized"
            " row of the capacity table below).  Quantized rungs trade"
            " the ladder's bit-identity contract for a TOLERANCE"
            " contract (`serving.kvquant.tolerance_contract`): the"
            " `identical tokens` column reports the measured greedy-token"
            " agreement against O0 and whether it clears the contract"
            " floor.  The autotuner (`--serve`, `kv_dtype=auto`) races"
            " bf16 vs int8 at equal pool memory and keeps narrow only"
            " when it wins.",
            "",
            "## Layout x placement matrix",
            "",
            "Cache layout (contiguous vs paged, `serving/layout.py`) and",
            "device placement (replicated vs PE-sharded,",
            "`parallel/sharding.PlacementPlan`) are orthogonal layers —",
            "every combination compiles a decode step, and greedy tokens",
            "are bit-identical across all four cells (dist-tier oracle in",
            "`tests/test_distributed.py`):",
            "",
            "| | replicated (pe=1 or 1 device) "
            "| PE-sharded (pe>1, >=2 devices) |",
            "|---|---|---|",
            "| contiguous (O0-O5) | process-wide shared jitted step "
            "| per-engine step; cache + tokens sharded on the batch axis |",
            "| paged (O6) | per-engine step (pool geometry is part of the "
            "program); gather -> decode -> scatter "
            "| per-engine step; pool sharded on the BLOCK axis (rows "
            "padded to a device multiple), block tables replicated, "
            "gathered dense view re-sharded onto the batch axis |",
            "| paged (O6, `paged_attn=kernel`) | per-engine step; the "
            "gather-free block-table Pallas kernel reads the pool "
            "directly (no dense view, no scatter — the current token's "
            "K/V is appended in place) "
            "| per-engine step; pool sharded on the BLOCK axis, "
            "replicated in-graph around the kernel call, written pool "
            "re-sharded by out_shardings |",
            "",
            "On a multi-device run every O3+ row shards (the `devices` "
            "column shows the placement each engine actually landed "
            "on), so the O6 row is the composed sharded-paged cell at "
            "the SAME placement as O5, and the table gains the `O6pe1` "
            "placement-ablation row — the same paged pool replicated — "
            "measured by the same interleaved trimmed-min harness.",
        ]
    if capacity:
        c, p = capacity["contiguous"], capacity["paged"]
        q = capacity.get("quantized")
        lines += [
            "",
            "## Capacity at equal KV memory (the O6 rung's actual win)",
            "",
            f"Same long-tail workload ({capacity['n_requests']} requests), "
            f"same KV memory ({capacity['kv_memory_tokens']} token "
            f"positions = {capacity['pool_blocks']} blocks of "
            f"{capacity['block_size']}):",
            "",
            "| cache | peak concurrent requests | ticks to drain | tok/s |",
            "|---|---|---|---|",
            f"| contiguous (O5, B x max_seq slots) "
            f"| {c['peak_concurrency']} | {c['ticks']} "
            f"| {c['tokens'] / c['wall_s']:.0f} |",
            f"| paged (O6, block tables) | {p['peak_concurrency']} "
            f"| {p['ticks']} | {p['tokens'] / p['wall_s']:.0f} |",
        ]
        if q:
            lines += [
                f"| paged int8 (O6, kv_dtype=int8, same pool BYTES = "
                f"{q['pool_blocks']} blocks) | {q['peak_concurrency']} "
                f"| {q['ticks']} | {q['tokens'] / q['wall_s']:.0f} |",
            ]
        lines += [
            "",
            "Greedy tokens identical between the contiguous and paged "
            f"engines: {'yes' if capacity['identical'] else 'NO'}."
            + (f"  The int8 pool holds the same bytes in ~2x the blocks "
               f"({q['pool_blocks']} vs {capacity['pool_blocks']}); its "
               f"tokens meet the int8 tolerance contract (agreement "
               f"{q['agreement']:.2f})." if q else ""),
        ]
    if state_capacity:
        lines += [
            "",
            "## Capacity at equal cache bytes — recurrent families",
            "",
            "Same short-prompt mix, equal TOTAL cache bytes (attention",
            "KV + recurrent state, leaf-summed off the device trees).",
            "Hybrid re-spends the contiguous worst-case KV slabs as",
            "block-packed reservations plus one O(1) state row per extra",
            "slot; pure-state families have no per-position cache, so",
            "equal bytes is slot parity minus the one constant NULL row",
            "(their O6 value is the uniform full-rung mechanism —",
            "kernel step, NULL-row chunk parking, defrag — not bytes):",
            "",
            "| family (arch) | cache bytes | contiguous slots -> peak | "
            "paged slots -> peak | pools |",
            "|---|---|---|---|---|",
        ]
        for sc in state_capacity:
            lines.append(
                f"| {sc['family']} (`{sc['arch']}`) "
                f"| {sc['contig_bytes'] / 1024:.0f}K "
                f"(paged uses {sc['paged_bytes'] / 1024:.0f}K) "
                f"| {sc['contig_slots']} -> "
                f"{sc['contiguous']['peak_concurrency']} "
                f"| {sc['paged_slots']} -> "
                f"{sc['paged']['peak_concurrency']} "
                f"| {sc['note']} |")
        lines += [
            "",
            "Greedy tokens identical across layouts for every family "
            "row: "
            f"{'yes' if all(s['identical'] for s in state_capacity) else 'NO'}.",
        ]
    lines += [
        "",
        "## Family x rung support matrix",
        "",
        "What each model family actually runs at each rung (recorded at",
        "engine build as `attn_impl` / `state_impl` / `degrade_reason`,",
        "asserted by the per-family differential fuzz in",
        "`tests/test_serving.py`):",
        "",
        "| family | arch | O0-O5 contiguous | O6 attention | O6 state | "
        "chunked prefill | O7 speculative |",
        "|---|---|---|---|---|---|---|",
    ]
    for fam, a, o05, attn, state, chunk, spec in FAMILY_RUNG_MATRIX:
        lines.append(f"| {fam} | `{a}` | {o05} | {attn} | {state} "
                     f"| {chunk} | {spec} |")
    return "\n".join(lines)


def write_trajectory(rows, arch: str, out_dir: str = None) -> str:
    """Mirror the rows as a JSONL file next to the autotune trajectories
    so one set of tools reads both."""
    d = out_dir or TRAJ_DIR
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"serving_ladder__{arch}.jsonl")
    with open(path, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    return path


def _preserved_traffic_section(path: str) -> str:
    """The open-loop traffic harness (`benchmarks/traffic_harness.py`)
    owns a marker-delimited section of this file; a ladder rewrite must
    carry it over, not clobber it."""
    from benchmarks.traffic_harness import TRAFFIC_BEGIN, TRAFFIC_END
    if not os.path.exists(path):
        return ""
    text = open(path).read()
    if TRAFFIC_BEGIN not in text or TRAFFIC_END not in text:
        return ""
    return (TRAFFIC_BEGIN
            + text.split(TRAFFIC_BEGIN, 1)[1].split(TRAFFIC_END, 1)[0]
            + TRAFFIC_END)


STATE_CAPACITY_ARCHS = ("rwkv6-3b", "mamba2-2.7b", "zamba2-2.7b")


def main(arch: str = "qwen3-8b", write_md: bool = True, **kw):
    t0 = time.time()
    rows = measure_ladder(arch, **kw)
    capacity = capacity_demo(arch)
    state_caps = [capacity_demo_state(a) for a in STATE_CAPACITY_ARCHS]
    if write_md:
        traffic = _preserved_traffic_section(MD_PATH)
        with open(MD_PATH, "w") as f:
            f.write(render_md(rows, arch, capacity, state_caps) + "\n")
            if traffic:
                f.write("\n" + traffic + "\n")
        write_trajectory(rows, arch)
    out = [(f"serving_ladder_{r['label']}", r["wall_s"] * 1e6,
            f"{r['tok_per_s']:.0f}tok/s {r['speedup_vs_o0']:.2f}x "
            f"{r['layout']}"
            f"{'/' + r['paged_attn'] if r.get('paged_attn') else ''}"
            f"x{r['devices']}dev "
            f"kv={r['kv_bytes_per_tick'] // 1024}K/tick "
            f"ttft={r['ttft_ms']:.1f}ms itl={r['itl_ms']:.2f}ms "
            f"prefill={r['prefill_mode']} "
            + (f"spec=K{r['draft_k']} accept={r['accept_rate']:.2f} "
               f"eff={r['eff_tok_per_step']:.2f} "
               if r.get("spec_mode") == "draft" else "")
            + f"identical={r['identical']}") for r in rows]
    cc = capacity["contiguous"]["peak_concurrency"]
    cp = capacity["paged"]["peak_concurrency"]
    out.append(("serving_capacity_paged_vs_contig", cp * 1e6 / max(cc, 1),
                f"peak concurrency {cp} vs {cc} at equal KV memory"))
    if capacity.get("quantized"):
        cq = capacity["quantized"]["peak_concurrency"]
        out.append(("serving_capacity_int8_vs_paged",
                    cq * 1e6 / max(cp, 1),
                    f"peak concurrency {cq} vs {cp} at equal pool bytes "
                    f"(agreement "
                    f"{capacity['quantized']['agreement']:.2f})"))
    for sc in state_caps:
        sp = sc["paged"]["peak_concurrency"]
        scc = sc["contiguous"]["peak_concurrency"]
        out.append((f"serving_capacity_state_{sc['arch']}",
                    sp * 1e6 / max(scc, 1),
                    f"{sc['family']}: peak concurrency {sp} vs {scc} at "
                    f"equal cache bytes ({sc['note']})"))
    out.append(("serving_ladder_wall", (time.time() - t0) * 1e6,
                f"{len(rows)} levels x best-of-interleaved ({arch})"))
    return out


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.3f},{derived}")
    print(f"wrote {MD_PATH}")
