"""Benchmark driver: one section per paper table/figure + the roofline
table.  Prints ``name,us_per_call,derived`` CSV rows (and a summary).

  PYTHONPATH=src python -m benchmarks.run [--skip-measured]
"""

import argparse
import sys
import time

from benchmarks import (autotune_table, breakdowns, caching_size,
                        comm_filter, machsuite_steps, pe_scaling,
                        pipelining_table, resources, roofline_table,
                        serving_ladder)

SECTIONS = [
    ("machsuite_steps (Fig.1/12)", machsuite_steps),
    ("pipelining (Table 4)", pipelining_table),
    ("caching_size (Fig.6)", caching_size),
    ("pe_scaling (Fig.9)", pe_scaling),
    ("comm_filter (Table 5)", comm_filter),
    ("breakdowns (Fig.3/7/11)", breakdowns),
    ("resources (Table 6)", resources),
    ("autotune (closed-loop Table 4)", autotune_table),
    ("serving_ladder (Table 1 analog, measured)", serving_ladder),
    ("roofline (EXPERIMENTS §Roofline)", roofline_table),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-measured", action="store_true",
                    help="model-only machsuite rows (fast)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    n = 0
    t0 = time.time()
    for title, mod in SECTIONS:
        print(f"# --- {title}", flush=True)
        if mod is machsuite_steps:
            rows = mod.main(measure=not args.skip_measured)
        elif mod is serving_ladder and args.skip_measured:
            # inherently measured (real decoding, minutes): model-only runs
            # skip it and keep the checked-in SERVING_LADDER.md untouched
            print("# serving_ladder skipped (--skip-measured)")
            continue
        else:
            rows = mod.main()
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived}")
            n += 1
    print(f"# {n} rows in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
