"""Open-loop traffic harness: goodput-under-SLO curves for the serving
engine behind the async front end (`repro.launch.server`).

    PYTHONPATH=src python -m benchmarks.traffic_harness --arch qwen3-8b \
        --rates 2,5,10 --requests 24 --pattern poisson

For each arrival rate the harness replays a deterministic Poisson (or
bursty) trace at the `AsyncServer` — arrivals never wait for
completions — and records p50/p99 TTFT, p50/p99 per-token latency
(TPOT), and GOODPUT: finished requests that met both SLOs, per second.
Rows land in `experiments/traffic/traffic__<arch>.jsonl` and render as
a marker-delimited section of `benchmarks/SERVING_LADDER.md`, alongside
(never replacing) the closed-loop trimmed-min ladder.

Measurement honesty, per the ROADMAP noise memo: wall-clock under
concurrent load is noisy on this container, so these curves are for
SHAPE — how latency and goodput bend as the offered rate crosses the
engine's capacity — not for absolute speed claims; the interleaved
trimmed-min ladder remains the authoritative speed table.  The knee is
robust to noise: below capacity TTFT is flat, above it the queue grows
without bound and p99 TTFT explodes.

`--smoke` runs a tiny 3-rate sweep and then ASSERTS the written JSONL
carries every required field (the CI fast-tier contract).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax

from repro.configs import ARCH_NAMES, get_smoke
from repro.core.optlevel import BestEffortConfig, OptLevel
from repro.launch.server import latency_metrics, make_trace, serve_trace
from repro.models import get_model
from repro.serving import DecodeEngine

MD_PATH = os.path.join(os.path.dirname(__file__), "SERVING_LADDER.md")
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "traffic")
TRAFFIC_BEGIN = "<!-- traffic:begin -->"
TRAFFIC_END = "<!-- traffic:end -->"

# Every JSONL row must carry these (the CI smoke asserts it): the
# goodput-under-SLO curve is unusable if any percentile column goes
# missing silently.
REQUIRED_FIELDS = (
    "arch", "rate_rps", "pattern", "policy", "level",
    "ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "tpot_p99_s",
    "goodput_rps", "goodput_frac", "throughput_rps", "tok_per_s",
)


def build_engine(arch: str, *, level: int = 5, batch: int = 3,
                 max_seq: int = 48, policy: str = "fcfs",
                 kv_block: int = 8, prefill_chunk: int = 0,
                 seed: int = 0) -> DecodeEngine:
    cfg = get_smoke(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return DecodeEngine(
        model, params, batch_size=batch, max_seq=max_seq, policy=policy,
        config=BestEffortConfig(level=OptLevel(level),
                                kv_block_size=kv_block,
                                prefill_chunk=prefill_chunk))


def sweep(arch: str, rates, *, pattern: str = "poisson",
          n_requests: int = 24, level: int = 5, batch: int = 3,
          max_seq: int = 48, policy: str = "fcfs", seed: int = 0,
          ttft_slo_s: float = 0.5, tpot_slo_s: float = 0.1,
          prefill_chunk: int = 0) -> list:
    """One engine, one rate point at a time (drained between points, so
    nothing leaks across); speculation telemetry comes from the WINDOWED
    snapshot — per rate point, not lifetime — which is what the
    `spec_stats_window` API exists for."""
    engine = build_engine(arch, level=level, batch=batch, max_seq=max_seq,
                          policy=policy, prefill_chunk=prefill_chunk,
                          seed=seed)
    # Warm the jitted step outside the measured replays: the first tick
    # pays compile, which would otherwise land entirely on rate point 1
    # as fake TTFT.
    warm = make_trace(n_requests=2, rate=100.0, seed=seed + 999,
                      vocab=engine.model.cfg.vocab, prompt_len=(2, 5),
                      max_new=(2, 4))
    serve_trace(engine, warm, time_scale=0.0)
    engine.spec_stats_window(reset=True)

    rows = []
    for rate in rates:
        trace = make_trace(n_requests=n_requests, rate=rate, seed=seed,
                           pattern=pattern,
                           vocab=engine.model.cfg.vocab,
                           prompt_len=(2, 10),
                           max_new=(3, min(12, max_seq // 3)))
        res = serve_trace(engine, trace)
        m = latency_metrics(res["finished"], makespan_s=res["makespan_s"],
                            ttft_slo_s=ttft_slo_s, tpot_slo_s=tpot_slo_s)
        spec = engine.spec_stats_window(reset=True)
        row = {
            "arch": arch, "rate_rps": float(rate), "pattern": pattern,
            "policy": policy, "level": int(level), "batch": batch,
            "max_seq": max_seq, "ticks": res["ticks"], "seed": seed,
            **m,
            "spec_mode": spec["spec_mode"],
            "spec_accept_rate": spec["accept_rate"],
            "spec_eff_tok_per_step": spec["eff_tok_per_step"],
        }
        rows.append(row)
        print(f"[traffic] {arch} O{level}/{policy} {pattern} "
              f"rate={rate:g}/s: goodput={m['goodput_rps']:.2f}/s "
              f"({m['goodput_frac'] * 100:.0f}%) "
              f"ttft p50/p99={m['ttft_p50_s'] * 1e3:.0f}/"
              f"{m['ttft_p99_s'] * 1e3:.0f}ms "
              f"tpot p50/p99={m['tpot_p50_s'] * 1e3:.1f}/"
              f"{m['tpot_p99_s'] * 1e3:.1f}ms")
    return rows


def write_jsonl(rows, arch: str, out_dir: str = None) -> str:
    d = out_dir or OUT_DIR
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"traffic__{arch}.jsonl")
    with open(path, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    return path


def render_section(rows, arch: str) -> str:
    """The SERVING_LADDER.md traffic section, between the markers the
    closed-loop ladder's writer preserves."""
    lines = [
        TRAFFIC_BEGIN,
        "",
        "## Open-loop traffic: goodput under SLO",
        "",
        f"Arrival-rate sweep through the asyncio front end "
        f"(`repro.launch.server`), {rows[0]['pattern']} arrivals, "
        f"policy `{rows[0]['policy']}`, O{rows[0]['level']} engine "
        f"(`{arch}` smoke weights).  SLOs: TTFT <= "
        f"{rows[0]['slo_ttft_s'] * 1e3:.0f}ms, per-token <= "
        f"{rows[0]['slo_tpot_s'] * 1e3:.0f}ms.  Goodput counts only "
        "requests meeting BOTH — raw throughput rewards a server that "
        "strands its tail.  Per the noise memo these curves are for "
        "SHAPE (where the knee is), not absolute speed; the trimmed-min "
        "closed-loop ladder above stays the speed table.",
        "",
        "| rate req/s | TTFT p50/p99 ms | TPOT p50/p99 ms "
        "| goodput req/s | good % | tok/s |",
        "|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['rate_rps']:g} "
            f"| {r['ttft_p50_s'] * 1e3:.0f} / {r['ttft_p99_s'] * 1e3:.0f} "
            f"| {r['tpot_p50_s'] * 1e3:.1f} / {r['tpot_p99_s'] * 1e3:.1f} "
            f"| {r['goodput_rps']:.2f} "
            f"| {r['goodput_frac'] * 100:.0f}% "
            f"| {r['tok_per_s']:.0f} |")
    lines += [
        "",
        f"Rows mirrored to `experiments/traffic/traffic__{arch}.jsonl` "
        "(one JSON object per rate point; regenerate with "
        "`python -m benchmarks.traffic_harness`).",
        "",
        TRAFFIC_END,
    ]
    return "\n".join(lines)


def upsert_section(section: str, md_path: str = None) -> str:
    """Insert or replace the marker-delimited traffic section, leaving
    the rest of SERVING_LADDER.md (the closed-loop ladder) untouched.
    Creates a stub file when the ladder has not been rendered yet."""
    path = md_path or MD_PATH
    if os.path.exists(path):
        text = open(path).read()
    else:
        text = "# Serving ladder\n\n(closed-loop ladder not rendered yet)\n"
    if TRAFFIC_BEGIN in text and TRAFFIC_END in text:
        head = text.split(TRAFFIC_BEGIN)[0].rstrip("\n")
        tail = text.split(TRAFFIC_END, 1)[1].lstrip("\n")
        text = head + "\n\n" + section + ("\n\n" + tail if tail else "\n")
    else:
        text = text.rstrip("\n") + "\n\n" + section + "\n"
    with open(path, "w") as f:
        f.write(text)
    return path


def check_jsonl(path: str) -> None:
    """The CI contract: every row carries every required field."""
    rows = [json.loads(line) for line in open(path)]
    assert rows, f"{path} is empty"
    for r in rows:
        missing = [k for k in REQUIRED_FIELDS if k not in r]
        assert not missing, f"JSONL row missing fields {missing}: {r}"
    rates = {r["rate_rps"] for r in rows}
    assert len(rates) >= 3, \
        f"goodput curve needs >= 3 arrival rates (got {sorted(rates)})"
    print(f"[traffic] JSONL check OK: {len(rows)} rows, "
          f"{len(rates)} rates, all {len(REQUIRED_FIELDS)} fields present")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=ARCH_NAMES)
    ap.add_argument("--rates", default="2,5,10",
                    help="comma-separated arrival rates (req/s)")
    ap.add_argument("--pattern", default="poisson",
                    choices=("poisson", "bursty"))
    ap.add_argument("--requests", type=int, default=24,
                    help="requests per rate point")
    ap.add_argument("--level", type=int, default=5, choices=range(8))
    ap.add_argument("--batch", type=int, default=3)
    ap.add_argument("--max-seq", type=int, default=48)
    ap.add_argument("--policy", default="fcfs",
                    choices=("fcfs", "spf", "deadline"))
    ap.add_argument("--prefill-chunk", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ttft-slo-ms", type=float, default=500.0)
    ap.add_argument("--tpot-slo-ms", type=float, default=100.0)
    ap.add_argument("--no-md", action="store_true",
                    help="skip the SERVING_LADDER.md section update")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep + assert the JSONL contract (CI)")
    args = ap.parse_args(argv)

    rates = [float(x) for x in args.rates.split(",") if x]
    n_requests = args.requests
    if args.smoke:
        rates = rates[:3] if len(rates) >= 3 else [5.0, 20.0, 80.0]
        n_requests = min(n_requests, 8)
    if len(rates) < 3:
        raise SystemExit("need >= 3 rates for a goodput curve")

    t0 = time.time()
    rows = sweep(args.arch, rates, pattern=args.pattern,
                 n_requests=n_requests, level=args.level,
                 batch=args.batch, max_seq=args.max_seq,
                 policy=args.policy, seed=args.seed,
                 ttft_slo_s=args.ttft_slo_ms / 1e3,
                 tpot_slo_s=args.tpot_slo_ms / 1e3,
                 prefill_chunk=args.prefill_chunk)
    path = write_jsonl(rows, args.arch)
    print(f"[traffic] wrote {path} ({time.time() - t0:.1f}s)")
    if not args.no_md:
        md = upsert_section(render_section(rows, args.arch))
        print(f"[traffic] updated {md}")
    if args.smoke:
        check_jsonl(path)
    return rows


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
