"""Paper Table 6: resource consumption per strategy (BRAM block math of
§5.2/§6 on the Virtex-7: 18 Kb blocks, <=36-bit native width).

The block arithmetic lives in ``core.costmodel.bram_blocks`` — the same
model the tuner's resource feedback (``costmodel.fit_resources``) uses to
shrink knobs on a conflict instead of stopping the walk."""

from repro.core.costmodel import bram_blocks
from repro.core.hw import FPGA_2012


def main():
    hw = FPGA_2012
    cache = 64 * 1024
    rows = []
    rows.append(("resources/caching/64KB_buffer",
                 bram_blocks(cache, 32),
                 f"blocks of {hw.bram_blocks} "
                 f"({bram_blocks(cache, 32) / hw.bram_blocks:.1%})"))
    rows.append(("resources/double_buffering/3x64KB",
                 3 * bram_blocks(cache, 32),
                 "3x caching (paper: 'merely costs 3x BRAM')"))
    for width in (64, 128, 256, 512):
        blocks = bram_blocks(cache, width)
        rows.append((
            f"resources/scratchpad_reorg/width{width}",
            blocks,
            f"{width}-bit 64KB buffer; paper: 8 blocks@256b, 15@512b "
            f"per buffer minimum -> width x PE trade-off",
        ))
    # the paper's 128-PE feasibility check (§5.2)
    pe, width = 128, 256
    need = 3 * pe * bram_blocks(cache // pe, width)
    rows.append((
        "resources/128PE_x_256bit_x_3buf",
        need,
        f"{'OVER' if need > hw.bram_blocks else 'fits'} "
        f"{hw.bram_blocks}-block fabric (paper: must trade PEs vs width)",
    ))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
