"""Paper Table 6: resource consumption per strategy (BRAM block math of
§5.2/§6 on the Virtex-7: 18 Kb blocks, <=36-bit native width)."""

import math

from repro.core.hw import FPGA_2012


def bram_blocks(capacity_bytes: int, width_bits: int) -> int:
    """Blocks to build a ``width_bits``-wide buffer of given capacity.

    A block supplies <=36 bits of width; wider words gang ceil(w/36)
    blocks; total must also cover capacity."""
    hw = FPGA_2012
    by_width = math.ceil(width_bits / hw.bram_block_max_width)
    by_cap = math.ceil(capacity_bytes * 8 / hw.bram_block_bits)
    return max(by_width, by_cap)


def main():
    hw = FPGA_2012
    cache = 64 * 1024
    rows = []
    rows.append(("resources/caching/64KB_buffer",
                 bram_blocks(cache, 32),
                 f"blocks of {hw.bram_blocks} "
                 f"({bram_blocks(cache, 32) / hw.bram_blocks:.1%})"))
    rows.append(("resources/double_buffering/3x64KB",
                 3 * bram_blocks(cache, 32),
                 "3x caching (paper: 'merely costs 3x BRAM')"))
    for width in (64, 128, 256, 512):
        blocks = bram_blocks(cache, width)
        rows.append((
            f"resources/scratchpad_reorg/width{width}",
            blocks,
            f"{width}-bit 64KB buffer; paper: 8 blocks@256b, 15@512b "
            f"per buffer minimum -> width x PE trade-off",
        ))
    # the paper's 128-PE feasibility check (§5.2)
    pe, width = 128, 256
    need = 3 * pe * bram_blocks(cache // pe, width)
    rows.append((
        "resources/128PE_x_256bit_x_3buf",
        need,
        f"{'OVER' if need > hw.bram_blocks else 'fits'} "
        f"{hw.bram_blocks}-block fabric (paper: must trade PEs vs width)",
    ))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
