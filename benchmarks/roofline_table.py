"""EXPERIMENTS.md §Roofline generator: reads the dry-run JSON records and
emits one row per (arch x shape) cell — the three roofline terms, the
dominant bottleneck, useful-flops fraction, and roofline fraction."""

import json
import os

EXP_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")


def load_cells(mesh: str = "single_pod"):
    d = os.path.join(EXP_DIR, mesh)
    cells = {}
    if not os.path.isdir(d):
        return cells
    for name in sorted(os.listdir(d)):
        if name.endswith(".json"):
            with open(os.path.join(d, name)) as f:
                cells[name[:-5]] = json.load(f)
    return cells


def main():
    rows = []
    for mesh in ("single_pod", "multi_pod"):
        for cell, rec in load_cells(mesh).items():
            status = rec.get("status")
            if status == "skipped":
                rows.append((f"roofline/{mesh}/{cell}", 0.0,
                             "SKIP " + rec.get("reason", "")[:60]))
                continue
            if status != "ok":
                rows.append((f"roofline/{mesh}/{cell}", -1.0,
                             "ERROR " + str(rec.get("error"))[:80]))
                continue
            if "dominant" not in rec:
                rows.append((f"roofline/{mesh}/{cell}",
                             0.0, "compiled (no twin terms on this mesh)"))
                continue
            rows.append((
                f"roofline/{mesh}/{cell}",
                rec["step_time_s"] * 1e6,
                f"compute={rec['compute_s']:.3g}s "
                f"memory={rec['memory_s']:.3g}s "
                f"collective={rec['collective_s']:.3g}s "
                f"dominant={rec['dominant']} "
                f"useful_flops={rec.get('useful_flops_fraction', 0):.2f} "
                f"roofline_frac={rec.get('roofline_fraction', 0):.4f}",
            ))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
