"""Microbenchmark: speculative decoding — the O7 draft/verify loop swept
across a (drafter, draft_k, batch, workload mix) grid.

Each cell builds a full O7 ``DecodeEngine`` (paged cache, greedy
sampler) and drains the fixed continuous-batching workload
(``autotune.measurement.serving_workload``), timing wall-clock per run.
Three drafter variants bracket the mechanism:

  K=0   — speculation off: the O6-equivalent hot path (the incumbent
          every K must beat);
  zoo   — the real pairing (``smollm-360m`` proposes for the target).
          On the smoke zoo both models have RANDOM weights, so
          acceptance is ~0 and this row is speculation's overhead
          floor: K drafter forwards + one (K+1)-wide verify that
          mostly emits a single token;
  self  — the target drafts for itself: acceptance is exactly 1.0 by
          construction, so this row is the mechanism's ceiling — every
          verify window emits K+1 tokens (window effects aside) and the
          tick count collapses by ~1/(K+1).

Real deployments live between the two rows, at the drafter's actual
acceptance; the serving autotuner (``--serve``, ``draft_k="auto"``)
measures exactly that and keeps speculation only when it wins.  Greedy
rejection keeps every cell bit-identical to K=0 — asserted per cell.

Methodology follows the serving-ladder noise memo: jit compiles outside
the timed region (one warmup drain per engine), measurement rounds
interleave every variant in the cell (container drift cancels), and
each variant's floor is the trimmed min (mean of its 3 fastest rounds).
Never run this under concurrent load.

Rows are appended as JSONL to
``experiments/autotune/spec_decode_bench.jsonl`` (one row per cell x
variant, acceptance telemetry alongside the measured floor) so the perf
trajectory tooling can track the speculation frontier over time.

  PYTHONPATH=src python -m benchmarks.spec_decode_bench
"""

import json
import os
import time

TRAJ = os.path.join(os.path.dirname(__file__), "..", "experiments",
                    "autotune", "spec_decode_bench.jsonl")

ARCH = "qwen3-8b"
DRAFT = "smollm-360m"
DRAFT_KS = (2, 4, 8)

# (mix, batch) cells.  The mixes move the prefill/decode balance the
# spec loop must live with: decode_heavy is where speculation can win
# (long generations amortize the verify window); prefill_heavy stresses
# the prompt-rides-the-verify-window path instead.
MIXES = {
    "decode_heavy": dict(max_seq=48, max_new=12, n_requests=10),
    "prefill_heavy": dict(max_seq=48, max_new=3, n_requests=10),
}
BATCHES = (2, 4)


def build_cell(mix: str, batch: int, seed: int = 0):
    """One (mix, batch) cell: the shared workload plus an engine per
    variant — ``("off", 0)`` then ``(drafter, K)`` for both drafter
    variants at every K."""
    import jax

    from repro.autotune.measurement import (serving_smoke_config,
                                            serving_workload)
    from repro.core.optlevel import BestEffortConfig, OptLevel
    from repro.models import get_model
    from repro.models.model_zoo import compatible_drafter
    from repro.serving import DecodeEngine

    cfg = serving_smoke_config(ARCH)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    dcfg = compatible_drafter(cfg, DRAFT)
    draft_api = get_model(dcfg)
    draft_params = draft_api.init(jax.random.PRNGKey(seed + 1))
    workload = serving_workload(cfg.vocab, seed=seed,
                                n_requests=MIXES[mix]["n_requests"],
                                max_seq=MIXES[mix]["max_seq"],
                                max_new=MIXES[mix]["max_new"])

    def engine(k: int, api=None, ps=None):
        return DecodeEngine(
            model, params, batch_size=batch,
            max_seq=MIXES[mix]["max_seq"],
            config=BestEffortConfig(level=OptLevel.O7, kv_block_size=8,
                                    draft_model=DRAFT, draft_k=k),
            draft_model=api, draft_params=ps)

    variants = {("off", 0): engine(0)}
    for k in DRAFT_KS:
        variants[("zoo", k)] = engine(k, draft_api, draft_params)
        variants[("self", k)] = engine(k, model, params)
    return workload, variants


def bench(rounds: int = 5, seed: int = 0) -> list:
    from repro.autotune.measurement import run_serving_workload

    rows = []
    for mix in MIXES:
        for batch in BATCHES:
            workload, variants = build_cell(mix, batch, seed)
            generated = None
            samples = {v: [] for v in variants}
            ticks = {}
            for v, eng in variants.items():     # warmup: jit compiles
                _, _, gen, _ = run_serving_workload(eng, workload)
                if generated is None:
                    generated = gen
                assert gen == generated, (
                    f"{mix}/B{batch}/{v}: speculation changed greedy "
                    f"tokens")
            for _ in range(rounds):
                for v, eng in variants.items():           # interleaved
                    t0 = eng.n_steps
                    wall, _, gen, _ = run_serving_workload(eng, workload)
                    assert gen == generated, "nondeterminism"
                    samples[v].append(wall)
                    ticks[v] = eng.n_steps - t0
            tokens = sum(len(g) for g in generated)
            for (drafter, k), eng in variants.items():
                floor = sum(sorted(samples[(drafter, k)])[:3]) / 3
                st = eng.spec_stats
                rows.append({
                    "arch": ARCH, "mix": mix, "batch": batch,
                    "max_seq": MIXES[mix]["max_seq"],
                    "max_new": MIXES[mix]["max_new"],
                    "requests": MIXES[mix]["n_requests"],
                    "drafter": drafter,
                    "draft_model": (None if drafter == "off" else
                                    ARCH if drafter == "self" else DRAFT),
                    "draft_k": k, "spec_mode": st["spec_mode"],
                    "wall_s": floor, "tok_per_s": tokens / floor,
                    "ticks": ticks[(drafter, k)], "tokens": tokens,
                    "accept_rate": st["accept_rate"],
                    "eff_tok_per_step": st["eff_tok_per_step"],
                    "identical": True,      # asserted at warmup
                })
    return rows


def main():
    rows = bench()
    os.makedirs(os.path.dirname(TRAJ), exist_ok=True)
    with open(TRAJ, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    print("mix            batch drafter K | wall_ms tok/s  ticks | "
          "accept eff_tok | vs K=0")
    base = {}
    for r in rows:
        if r["drafter"] == "off":
            base[(r["mix"], r["batch"])] = r["wall_s"]
    for r in rows:
        b = base[(r["mix"], r["batch"])]
        print(f"{r['mix']:14s} {r['batch']:5d} {r['drafter']:7s} "
              f"{r['draft_k']:d} | {r['wall_s'] * 1e3:7.1f} "
              f"{r['tok_per_s']:6.0f} {r['ticks']:5d} | "
              f"{r['accept_rate']:6.2f} {r['eff_tok_per_step']:7.2f} | "
              f"{b / r['wall_s']:5.2f}x")
    print(f"wrote {os.path.relpath(TRAJ)}")
    return rows


if __name__ == "__main__":
    main()
