"""Paper Fig. 1 + Fig. 12: cumulative speedup of the five refinement steps.

Two views:
  * ``model``  — the analytic FPGA model at the paper's full input sizes
    (the faithful-reproduction numbers EXPERIMENTS.md compares to the
    paper's 42~29030x / 34.4x claims);
  * ``measured`` — wall-clock of the *JAX ladder implementations* on this
    container's CPU at reduced sizes (shows the same structural transforms
    speed up real executions too, not only the model).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.costmodel import MACHSUITE_PROFILES, refinement_curve
from repro.core.optlevel import OptLevel
from repro.machsuite import KERNELS

MEASURE_SCALES = {
    "aes": 2048 / 64e6, "bfs": 32 / 4096, "gemm": 32 / 1024,
    "kmp": 8192 / 128e6, "nw": 1 / 4096, "sort": 64 / 262144 / 16,
    "spmv": 1 / 64, "viterbi": 1 / 62500,
}
# O0 is element-at-a-time under jit -- measure it only where it is not
# pathologically slow to compile/run on CPU.
MEASURE_LEVELS = (OptLevel.O1, OptLevel.O2, OptLevel.O3, OptLevel.O4,
                  OptLevel.O5)


def model_rows():
    rows = []
    for name, prof in MACHSUITE_PROFILES.items():
        curve = refinement_curve(prof)
        base = curve[0]["system_s"]
        for lvl in range(6):
            t = curve[lvl]
            rows.append((
                f"model/{name}/O{lvl}",
                t["system_s"] * 1e6,
                f"speedup_vs_naive={base / t['system_s']:.1f}x "
                f"vs_cpu={t['speedup_vs_cpu']:.3g}x",
            ))
    return rows


def _time(fn, *args, reps=3, **kw):
    out = fn(*args, **kw)
    np.asarray(out if not isinstance(out, tuple) else out[0])  # sync
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    np.asarray(out if not isinstance(out, tuple) else out[0])
    return (time.perf_counter() - t0) / reps


def measured_rows():
    rows = []
    rng = np.random.default_rng(0)
    for name, mod in KERNELS.items():
        inp = mod.make_inputs(rng, MEASURE_SCALES[name])
        base = None
        for lvl in MEASURE_LEVELS:
            try:
                dt = _time(lambda: np.asarray(mod.run(lvl, **inp)))
            except Exception as e:   # noqa: BLE001
                rows.append((f"measured/{name}/O{int(lvl)}", -1, repr(e)))
                continue
            if base is None:
                base = dt
            rows.append((
                f"measured/{name}/O{int(lvl)}",
                dt * 1e6,
                f"speedup_vs_O1={base / dt:.2f}x",
            ))
    return rows


def main(measure: bool = True):
    rows = model_rows()
    if measure:
        rows += measured_rows()
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
