from repro.kernels.tiled_matmul.ops import matmul  # noqa: F401
