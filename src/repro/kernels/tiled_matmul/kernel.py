"""Blocked matmul Pallas kernel — the paper's Fig. 4 ladder on the MXU.

The five refinement steps map onto kernel structure like this (DESIGN.md §2):

  O0  no tiling: one grid step, whole operands as the "block" (the naive
      compute-against-HBM architecture; only legal for small shapes)
  O1  explicit data caching: (bm, bk) x (bk, bn) BlockSpec tiles staged in
      VMEM, one output tile per grid step, K walked whole
  O2  customized pipelining: K split into bk-blocks on the innermost grid
      dim with an f32 VMEM accumulator — the Mosaic grid pipeliner overlaps
      DMA-in / MXU / DMA-out across steps (the II=1 analog)
  O3  PE duplication: (M, N) tile grid marked "parallel" dimension
      semantics (tiles land on independent compute units / cores)
  O4  double buffering: Mosaic multiple-buffers grid streams automatically;
      the programmer-visible knob is block sizing so TWO in-flight copies of
      every stream fit VMEM — ops.py halves blocks at O4 (paper §6: shrink
      the cache, keep the overlap)
  O5  scratchpad reorganization: bf16 operand staging (2 values per 32-bit
      lane word) with f32 accumulation scratch

All variants share this one kernel body; ops.py picks grid/specs per level.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel_noacc(a_ref, b_ref, o_ref):
    """O0/O1: single K-pass per output tile, no carried accumulator."""
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def _matmul_kernel_acc(a_ref, b_ref, o_ref, acc_ref):
    """O2+: K on the innermost grid dim, f32 accumulator in VMEM scratch."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "split_k", "parallel_mn",
                     "interpret"),
)
def matmul_pallas(a, b, *, bm: int, bn: int, bk: int, split_k: bool,
                  parallel_mn: bool, interpret: bool = True):
    """Blocked a @ b.  a: (M, K), b: (K, N) -> (M, N) float32.

    ``split_k=False`` -> O1 structure (K whole per tile);
    ``split_k=True``  -> O2+ structure (K blocked + VMEM accumulator).
    ``parallel_mn``   -> O3+: mark the (M, N) tile grid parallel.
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (a.shape, b.shape,
                                                         (bm, bn, bk))
    out_shape = jax.ShapeDtypeStruct((M, N), jnp.float32)

    if not split_k:
        grid = (M // bm, N // bn)
        sem = ("parallel", "parallel") if parallel_mn else None
        kw = {}
        if sem and not interpret:
            kw["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=sem)
        return pl.pallas_call(
            _matmul_kernel_noacc,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
                pl.BlockSpec((K, bn), lambda i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=out_shape,
            interpret=interpret,
            **kw,
        )(a, b)

    grid = (M // bm, N // bn, K // bk)
    sem = (("parallel", "parallel", "arbitrary") if parallel_mn
           else ("arbitrary", "arbitrary", "arbitrary"))
    kw = {}
    if not interpret:
        kw["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=sem)
    return pl.pallas_call(
        _matmul_kernel_acc,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        **kw,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul_whole(a, b, *, interpret: bool = True):
    """O0: one grid step, whole operands — no explicit caching."""
    M, K = a.shape
    _, N = b.shape
    return pl.pallas_call(
        _matmul_kernel_noacc,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((M, K), lambda i: (0, 0)),
            pl.BlockSpec((K, N), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((M, N), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(a, b)
