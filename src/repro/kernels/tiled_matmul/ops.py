"""Public wrapper: the best-effort ladder for the TPU matmul kernel.

``matmul(a, b, level)`` dispatches per OptLevel (see kernel.py header).
Block sizes follow the paper's guidance: MXU-aligned (multiples of 128 on
real shapes; the helpers degrade gracefully for small test shapes), with a
VMEM budget feedback rule at O4 (two in-flight buffers per stream must fit
— the "shrink the cache size" feedback of paper §6).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.hw import TPU_V5E
from repro.core.optlevel import OptLevel
from repro.kernels.tiled_matmul.kernel import matmul_pallas, matmul_whole

# VMEM working budget per core we allow kernels to claim (half of 128 MB,
# leaving room for the pipeline's metadata/semaphores).
VMEM_BUDGET = TPU_V5E.vmem_bytes // 2


def _fit(dim: int, want: int) -> int:
    """Largest divisor of ``dim`` that is <= want (prefers want itself)."""
    want = min(dim, want)
    for c in range(want, 0, -1):
        if dim % c == 0:
            return c
    return 1


def pick_blocks(M: int, N: int, K: int, *, level: OptLevel,
                elem_bytes: int = 4) -> tuple:
    """(bm, bn, bk) per the ladder's resource rules."""
    bm = _fit(M, 256)
    bn = _fit(N, 256)
    bk = _fit(K, 512)
    n_buf = 2 if level >= OptLevel.O4 else 1   # double buffering in flight
    while n_buf * elem_bytes * (bm * bk + bk * bn + bm * bn) > VMEM_BUDGET:
        # shrink the largest contributor first (paper: shrink cache size,
        # spare BRAM for other strategies)
        if bk >= max(bm, bn) and bk > 1:
            bk = _fit(K, bk // 2)
        elif bm >= bn and bm > 1:
            bm = _fit(M, bm // 2)
        elif bn > 1:
            bn = _fit(N, bn // 2)
        else:
            break
    return bm, bn, bk


def matmul(a, b, level: OptLevel = OptLevel.O5, *, interpret: bool = True,
           blocks: tuple = None):
    """Best-effort blocked matmul.  Returns float32 (M, N)."""
    level = OptLevel(level)
    M, K = a.shape
    _, N = b.shape

    if level == OptLevel.O0:
        return matmul_whole(a, b, interpret=interpret)

    if level >= OptLevel.O5:          # scratchpad reorg: bf16 lane packing
        a = a.astype(jnp.bfloat16)
        b = b.astype(jnp.bfloat16)
        elem = 2
    else:
        a = a.astype(jnp.float32)
        b = b.astype(jnp.float32)
        elem = 4

    bm, bn, bk = blocks or pick_blocks(M, N, K, level=level, elem_bytes=elem)
    if level == OptLevel.O1:
        return matmul_pallas(a, b, bm=bm, bn=bn, bk=K, split_k=False,
                             parallel_mn=False, interpret=interpret)
    return matmul_pallas(
        a, b, bm=bm, bn=bn, bk=bk, split_k=True,
        parallel_mn=(level >= OptLevel.O3), interpret=interpret)
