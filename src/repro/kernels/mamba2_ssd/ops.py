"""Public wrapper for the SSD kernel (drop-in for models.mamba2.ssd_chunked)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.mamba2_ssd.kernel import ssd_pallas


def ssd(xh, dt, A, Bs, Cs, *, init_state=None, chunk: int = 128,
        interpret: bool = True):
    """xh: (B, S, H, P); dt: (B, S, H) post-softplus; A: (H,) negative;
    Bs, Cs: (B, S, N).  Returns (y, final_state (B,H,P,N) f32)."""
    B, S, H, P = xh.shape
    N = Bs.shape[-1]
    s0 = (init_state if init_state is not None
          else jnp.zeros((B, H, P, N), jnp.float32)).astype(jnp.float32)
    return ssd_pallas(xh, dt, jnp.asarray(A, jnp.float32), Bs, Cs, s0,
                      chunk=chunk, interpret=interpret)
