"""Pure-jnp sequential oracle for the SSD kernel."""

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, Bs, Cs, s0):
    """Step-by-step recurrence.  x: (B,S,H,P); dt: (B,S,H); A: (H,);
    Bs, Cs: (B,S,N); s0: (B,H,P,N) f32."""
    def step(state, inp):
        x_t, dt_t, B_t, C_t = inp            # (B,H,P),(B,H),(B,N)
        la = dt_t.astype(jnp.float32) * A    # (B,H)
        decay = jnp.exp(la)
        upd = jnp.einsum("bhp,bn,bh->bhpn", x_t.astype(jnp.float32),
                         B_t.astype(jnp.float32), dt_t.astype(jnp.float32))
        state = state * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, C_t.astype(jnp.float32))
        return state, y

    tm = lambda t: jnp.moveaxis(t, 1, 0)
    final, ys = jax.lax.scan(step, s0, (tm(x), tm(dt), tm(Bs), tm(Cs)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final
