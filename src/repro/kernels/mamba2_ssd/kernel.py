"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

One grid step = one (batch, chunk) cell: stages x (Q, H, P), dt (Q, H),
B/C (Q, N) in VMEM, computes the intra-chunk dense block on the MXU, and
carries the (H, P, N) SSM state across the sequential chunk dim in VMEM
scratch.  Matches ``repro.models.mamba2.ssd_chunked``'s math f32-for-f32.

VMEM sizing (the explicit-data-caching design choice): with the zamba2
config (H=80, P=64, N=64) the state is 80*64*64*4 B = 1.25 MB, one chunk
of x at Q=256 is 256*80*64*4 B = 5 MB — comfortably inside the 64 MB
working budget with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, s0_ref,
                y_ref, sf_ref, state_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    x_c = x_ref[0].astype(jnp.float32)            # (Q, H, P)
    dt_c = dt_ref[0].astype(jnp.float32)          # (Q, H)
    A = a_ref[0].astype(jnp.float32)              # (1, H) negative
    B_c = b_ref[0].astype(jnp.float32)            # (Q, N)
    C_c = c_ref[0].astype(jnp.float32)            # (Q, N)

    la = dt_c * A                                 # (Q, H), <= 0
    cum = jnp.cumsum(la, axis=0)                  # (Q, H)

    seg = cum[:, None, :] - cum[None, :, :]       # (Q, Q, H)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where((ii >= jj)[..., None], jnp.exp(seg), 0.0)  # (Q, Q, H)
    CB = jax.lax.dot_general(C_c, B_c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    xdt = x_c * dt_c[..., None]                   # (Q, H, P)
    y_diag = jnp.einsum("ij,ijh,jhp->ihp", CB, L, xdt)

    state = state_ref[...]                        # (H, P, N)
    out_decay = jnp.exp(cum)                      # (Q, H)
    y_off = jnp.einsum("in,hpn,ih->ihp", C_c, state, out_decay)
    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)

    decay_states = jnp.exp(cum[-1:, :] - cum)     # (Q, H)
    st_c = jnp.einsum("jn,jh,jhp->hpn", B_c, decay_states, xdt)
    chunk_decay = jnp.exp(cum[-1, :])             # (H,)
    state_ref[...] = state * chunk_decay[:, None, None] + st_c

    @pl.when(ci == pl.num_programs(1) - 1)
    def _done():
        sf_ref[0] = state_ref[...].astype(sf_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(x, dt, A, Bs, Cs, s0, *, chunk: int = 128,
               interpret: bool = True):
    """x: (B, S, H, P); dt: (B, S, H); A: (H,); Bs, Cs: (B, S, N);
    s0: (B, H, P, N) f32.

    Returns (y (B,S,H,P) same dtype as x, final_state (B,H,P,N) f32).
    """
    Bsz, S, H, P = x.shape
    N = Bs.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    grid = (Bsz, S // chunk)
    A2 = jnp.broadcast_to(A[None, :], (Bsz, H))

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    kw = {}
    if not interpret:
        kw["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))

    y, sf = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, H), lambda b, c: (b, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, H, P, N), lambda b, c: (b, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
        **kw,
    )(x, dt, A2, Bs, Cs, s0)
    return y, sf
