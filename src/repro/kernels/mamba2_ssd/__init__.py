from repro.kernels.mamba2_ssd.ops import ssd  # noqa: F401
