"""Block-table-aware paged decode attention — the gather-free O6 step.

The paged serving rung's original step re-materializes a dense
``(B, max_seq, ...)`` view of every KV leaf from the block pool on every
decode tick (``serving/paged.BlockPagingPlan.gather``) just so dense
attention can read it — O(B * max_seq) HBM traffic per generated token.
This kernel is the *explicit data caching* / *scratchpad reorganization*
answer: it consumes the pool, the block tables and the per-slot lengths
directly, so the only KV bytes moved are the blocks each slot's table
actually references.

Ladder mapping: streaming K/V one physical block at a time with
VMEM-resident ``(m, l, acc)`` online-softmax state is the same blocked
discipline as ``kernels/flash_attention`` (explicit caching +
pipelining); the (batch, kv-head) grid dims are PE duplication.  GQA is
handled by the grid, not by materializing repeated K/V: each kv-head
program attends its ``G = H // KV`` query heads against one shared
``(T, D)`` block slice.

Grid: ``(B, KV, 2 * nb)`` with the block walk innermost (sequential).
The walk is TWO passes over the slot's block list, phase = j // nb:

  phase 0 — online-softmax statistics: running row-max ``m`` (exact)
            and rescaled denominator ``l``;
  phase 1 — the weighted-value accumulation, with the probabilities
            rounded to the query dtype before the PV product.

The two-pass structure is what makes the serving ladder's bit-identity
contract *hold in practice*: the dense decode path computes bf16 scores
(einsum output dtype), masks/softmaxes in f32, then rounds the
probabilities back to bf16 before the PV einsum.  Phase 1 applies the
same roundings in the same order (scores -> dt, probs -> dt, one final
output round), so kernel-path logits track the gather-path logits to
reduction-order noise (~1e-7) instead of bf16-rounding noise (~1e-2) —
greedy argmax cannot realistically flip.  The extra K stream per tick is
still O(blocks touched), nowhere near the gather step's dense copy.

The block tables and lengths ride in as scalar-prefetch operands so the
``BlockSpec`` index maps can turn a *logical* block index ``j % nb``
into the *physical* pool row ``tables[b, j % nb]`` before the DMA is
issued — the indirection happens in the index map, never as a gathered
copy.

Masking uses -1e30 like the flash kernel: position ``idx = jj*T + t`` is
valid iff ``idx < lengths[b]``.  Blocks entirely past ``lengths[b]`` are
skipped (their table entries may be the NULL block; its DMA is cheap and
its values are never read).  Callers guarantee ``lengths >= 1`` (the
engine writes position ``p`` before attending, so the length is
``p + 1``); the ``1e-30`` guard only protects the skipped-slot case.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dequant(raw, s, dt):
    """Per-block dequant, bit-matching ``serving.kvquant.dequantize``
    (kept inline so the kernel package stays import-free of serving):
    f32 multiply by the block's absmax scale, ONE round to the compute
    dtype, then the f32 widening every score path applies anyway."""
    return (raw.astype(jnp.float32) * s).astype(dt).astype(jnp.float32)


def _scores(q_ref, k_ref, jj, length, *, scale, block_size, ks=None):
    """Masked f32 scores for one (G, T) block, with the SAME rounding
    discipline as the dense decode path: the qk product and the scale
    multiply are rounded to the query dtype (the dense path's einsum
    output dtype) before the f32 mask/softmax.  ``ks`` (narrow pools)
    is this block's scalar K scale; the dequant rounds to the query
    dtype first — the exact bits the gather path's dense view holds."""
    dt = q_ref.dtype
    q = q_ref[0].astype(jnp.float32)                # (G, D)
    if ks is None:
        k = k_ref[0, :, 0].astype(jnp.float32)      # (T, D)
    else:
        k = _dequant(k_ref[0, :, 0], ks, dt)        # (T, D)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)         # (G, T)
    s = (s.astype(dt) * scale).astype(dt).astype(jnp.float32)
    idx = jj * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    return jnp.where(idx < length, s, NEG_INF)


def _paged_attn_kernel(tables_ref, lens_ref, *refs, scale: float,
                       block_size: int, n_blocks: int,
                       quantized: bool = False):
    if quantized:
        (kscale_ref, vscale_ref, q_ref, k_ref, v_ref, o_ref,
         m_ref, l_ref, acc_ref) = refs
    else:
        kscale_ref = vscale_ref = None
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
    b = pl.program_id(0)
    h = pl.program_id(1)
    j = pl.program_id(2)
    jj = j % n_blocks                # logical block within the pass
    phase = j // n_blocks            # 0: (m, l) stats; 1: PV accumulate

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lens_ref[b]
    # Narrow pools: this block's scalar scales, read from the SMEM
    # scalar-prefetch operands through the same table indirection the
    # BlockSpec DMA uses.
    row = tables_ref[b, jj]
    ks = kscale_ref[row, h] if quantized else None
    vs = vscale_ref[row, h] if quantized else None

    # Skip blocks entirely past this slot's valid prefix (no compute;
    # the NULL-block rows inactive table tails point at are never read).
    in_range = jj * block_size < length

    @pl.when((phase == 0) & in_range)
    def _stats():
        s = _scores(q_ref, k_ref, jj, length, scale=scale,
                    block_size=block_size, ks=ks)
        m_prev = m_ref[...]                          # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new

    @pl.when((phase == 1) & in_range)
    def _accumulate():
        s = _scores(q_ref, k_ref, jj, length, scale=scale,
                    block_size=block_size, ks=ks)
        if quantized:
            v = _dequant(v_ref[0, :, 0], vs, q_ref.dtype)   # (T, D)
        else:
            v = v_ref[0, :, 0].astype(jnp.float32)          # (T, D)
        p = jnp.exp(s - m_ref[...]) / jnp.maximum(l_ref[...], 1e-30)
        # Round the probabilities to the query dtype — the dense path's
        # ``softmax(s).astype(dt)`` — so the PV product sees identical
        # inputs to the gather step's einsum.
        p = p.astype(q_ref.dtype).astype(jnp.float32)
        acc_ref[...] += jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(2) - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _paged_prefill_kernel(tables_ref, lens_ref, *refs, scale: float,
                          block_size: int, n_blocks: int, q_len: int,
                          quantized: bool = False):
    """Multi-query (qlen > 1) variant of ``_paged_attn_kernel``.

    The q block carries ``G * Q`` rows (g-major: row r is query position
    ``r % Q`` of query head ``r // Q``), and the causal mask is per ROW:
    query position ``qi`` attends kv positions ``idx <= start + qi``,
    i.e. ``idx < length - (Q - 1 - qi)`` with ``length = start + Q``.
    With Q == 1 every expression degenerates to the decode kernel's —
    same block layout, same mask, same rounding sites — so qlen==1 is
    bit-identical to ``_paged_attn_kernel`` (locked by a kernel test).

    Row safety: every row's limit is ``start + qi + 1 >= 1``, so logical
    block 0 (walked first) always contributes at least one valid score
    per row — ``m`` is real before any fully-masked block is seen, and a
    fully-masked block then contributes ``exp(-1e30 - m) == 0``.
    """
    if quantized:
        (kscale_ref, vscale_ref, q_ref, k_ref, v_ref, o_ref,
         m_ref, l_ref, acc_ref) = refs
    else:
        kscale_ref = vscale_ref = None
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
    b = pl.program_id(0)
    h = pl.program_id(1)
    j = pl.program_id(2)
    jj = j % n_blocks
    phase = j // n_blocks

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lens_ref[b]
    in_range = jj * block_size < length
    row = tables_ref[b, jj]
    ks = kscale_ref[row, h] if quantized else None
    vs = vscale_ref[row, h] if quantized else None

    def scores():
        s = _scores(q_ref, k_ref, jj, length, scale=scale,
                    block_size=block_size, ks=ks)       # (G*Q, T)
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % q_len
        idx = jj * block_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        return jnp.where(idx < length - (q_len - 1 - qi), s, NEG_INF)

    @pl.when((phase == 0) & in_range)
    def _stats():
        s = scores()
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new

    @pl.when((phase == 1) & in_range)
    def _accumulate():
        s = scores()
        if quantized:
            v = _dequant(v_ref[0, :, 0], vs, q_ref.dtype)
        else:
            v = v_ref[0, :, 0].astype(jnp.float32)
        p = jnp.exp(s - m_ref[...]) / jnp.maximum(l_ref[...], 1e-30)
        p = p.astype(q_ref.dtype).astype(jnp.float32)
        acc_ref[...] += jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(2) - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _grid_args(quantized: bool, nb: int):
    """(num_scalar_prefetch, q/kv/out index maps) for the two scalar
    arities: unquantized kernels prefetch (tables, lengths); narrow
    pools add the (R, KV) f32 K/V scale matrices, read in-kernel through
    the same table indirection the BlockSpec DMA uses."""
    if quantized:
        q_map = lambda b, h, j, tbl, lens, ks, vs: (b, h, 0)   # noqa: E731
        kv_map = lambda b, h, j, tbl, lens, ks, vs: (           # noqa: E731
            tbl[b, j % nb], 0, h, 0)
        return 4, q_map, kv_map
    q_map = lambda b, h, j, tbl, lens: (b, h, 0)               # noqa: E731
    kv_map = lambda b, h, j, tbl, lens: (                       # noqa: E731
        tbl[b, j % nb], 0, h, 0)
    return 2, q_map, kv_map


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_attention_pallas(q, k_pool, v_pool, tables, lengths,
                                   k_scale=None, v_scale=None, *,
                                   interpret: bool = True):
    """q: (B, Q, H, D) — Q query tokens per slot, causally masked against
    a paged KV prefix whose last Q positions ARE those tokens;
    k_pool/v_pool: (R, T, KV, D); tables: (B, nb); lengths: (B,) int32 =
    start + Q valid positions per slot (the chunk's K/V already
    appended); k_scale/v_scale: (R, KV) f32 per-block absmax scales when
    the pool is narrow.  Returns (B, Q, H, D) in q's dtype."""
    B, Q, H, D = q.shape
    R, T, KV, Dk = k_pool.shape
    assert Dk == D and v_pool.shape == k_pool.shape, (q.shape, k_pool.shape)
    assert H % KV == 0, (H, KV)
    G = H // KV
    nb = tables.shape[1]
    assert tables.shape == (B, nb) and lengths.shape == (B,), (
        tables.shape, lengths.shape)
    quantized = k_scale is not None
    if quantized:
        assert k_scale.shape == (R, KV) and v_scale.shape == (R, KV), (
            k_scale.shape, v_scale.shape)
    scale = 1.0 / (D ** 0.5)

    # g-major row layout: (B, Q, H, D) -> (B, H*Q, D); kv-head h's block
    # is rows [h*G*Q, (h+1)*G*Q) — row r is (head h*G + r//Q, query r%Q).
    qr = q.transpose(0, 2, 1, 3).reshape(B, H * Q, D)

    n_prefetch, q_map, kv_map = _grid_args(quantized, nb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_prefetch,
        grid=(B, KV, 2 * nb),
        in_specs=[
            pl.BlockSpec((1, G * Q, D), q_map),
            pl.BlockSpec((1, T, 1, D), kv_map),
            pl.BlockSpec((1, T, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, G * Q, D), q_map),
        scratch_shapes=[
            pltpu.VMEM((G * Q, 1), jnp.float32),
            pltpu.VMEM((G * Q, 1), jnp.float32),
            pltpu.VMEM((G * Q, D), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_prefill_kernel, scale=scale,
                               block_size=T, n_blocks=nb, q_len=Q,
                               quantized=quantized)
    kw = {}
    if not interpret:
        kw["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    operands = ((tables, lengths, k_scale, v_scale) if quantized
                else (tables, lengths))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H * Q, D), q.dtype),
        interpret=interpret,
        **kw,
    )(*operands, qr, k_pool, v_pool)
    return out.reshape(B, H, Q, D).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_pallas(q, k_pool, v_pool, tables, lengths,
                           k_scale=None, v_scale=None, *,
                           interpret: bool = True):
    """q: (B, H, D); k_pool/v_pool: (R, T, KV, D); tables: (B, nb) int32
    physical pool rows per logical block; lengths: (B,) int32 valid
    positions per slot; k_scale/v_scale: (R, KV) f32 per-block absmax
    scales when the pool is narrow.  Returns (B, H, D) in q's dtype."""
    B, H, D = q.shape
    R, T, KV, Dk = k_pool.shape
    assert Dk == D and v_pool.shape == k_pool.shape, (q.shape, k_pool.shape)
    assert H % KV == 0, (H, KV)
    G = H // KV
    nb = tables.shape[1]
    assert tables.shape == (B, nb) and lengths.shape == (B,), (
        tables.shape, lengths.shape)
    quantized = k_scale is not None
    if quantized:
        assert k_scale.shape == (R, KV) and v_scale.shape == (R, KV), (
            k_scale.shape, v_scale.shape)
    scale = 1.0 / (D ** 0.5)

    n_prefetch, q_map, kv_map = _grid_args(quantized, nb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_prefetch,
        grid=(B, KV, 2 * nb),
        in_specs=[
            # q heads for kv-head h: rows h*G .. h*G+G-1
            pl.BlockSpec((1, G, D), q_map),
            # ONE physical pool block, selected through the table
            pl.BlockSpec((1, T, 1, D), kv_map),
            pl.BlockSpec((1, T, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, G, D), q_map),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_attn_kernel, scale=scale,
                               block_size=T, n_blocks=nb,
                               quantized=quantized)
    kw = {}
    if not interpret:
        kw["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    operands = ((tables, lengths, k_scale, v_scale) if quantized
                else (tables, lengths))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
        **kw,
    )(*operands, q, k_pool, v_pool)
