from repro.kernels.paged_attention.ops import (  # noqa: F401
    paged_attention, paged_prefill_attention)
