"""Public wrapper: block-table-aware paged decode attention.

Unlike the flash wrapper there is no GQA repeat here at all: the kernel
grid is (batch, kv-head, block), so each kv-head's ``G`` query heads
share one streamed ``(T, D)`` block slice and the pool is never copied
``H / Hkv`` times.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import (
    paged_attention_pallas, paged_prefill_attention_pallas)


def _check_scales(k_pool, k_scale, v_scale):
    if (k_scale is None) != (v_scale is None):
        raise ValueError("k_scale and v_scale must be passed together")
    if k_scale is not None:
        R, _T, KV, _D = k_pool.shape
        want = (R, KV)
        if tuple(k_scale.shape) != want or tuple(v_scale.shape) != want:
            raise ValueError(f"scale shape mismatch: want {want}, got "
                             f"k {k_scale.shape}, v {v_scale.shape}")


def paged_attention(q, k_pool, v_pool, tables, lengths, *,
                    k_scale=None, v_scale=None, interpret: bool = True):
    """Decode attention straight off a paged KV block pool.

    q: (B, H, D) — one query token per slot.
    k_pool, v_pool: (R, T, KV, D) — the physical block pool (row 0 is
        the NULL block; its contents are write-garbage by design).
    tables: (B, nb) int — physical pool row of each logical block.
    lengths: (B,) int — valid positions per slot (the engine passes
        ``positions + 1``: the current token's K/V is already appended).
    k_scale, v_scale: (R, KV) f32 — per-block absmax scales when the
        pool stores a narrow dtype (int8/fp8); each streamed block is
        dequantized in-kernel at the gather path's exact rounding site.

    Returns (B, H, D) in q's dtype.  Every block the table references
    inside ``lengths[b]`` must be a real (non-NULL) block — the
    allocator's up-front reservation guarantees it.
    """
    B, H, D = q.shape
    R, T, KV, Dk = k_pool.shape
    if H % KV != 0:
        raise ValueError(f"H={H} must be a multiple of KV={KV}")
    if Dk != D or v_pool.shape != k_pool.shape:
        raise ValueError(f"pool/query shape mismatch: q {q.shape}, "
                         f"k {k_pool.shape}, v {v_pool.shape}")
    _check_scales(k_pool, k_scale, v_scale)
    return paged_attention_pallas(
        q, k_pool, v_pool, tables.astype(jnp.int32),
        lengths.astype(jnp.int32), k_scale, v_scale, interpret=interpret)


def paged_prefill_attention(q, k_pool, v_pool, tables, lengths, *,
                            k_scale=None, v_scale=None,
                            interpret: bool = True):
    """Multi-token (qlen > 1) prefill attention off the paged pool — the
    chunked-prefill / speculative-decoding query mode.

    q: (B, Q, H, D) — Q consecutive query tokens per slot, causally
        masked: query position qi attends kv positions <= start + qi.
    k_pool, v_pool: (R, T, KV, D) — the chunk's K/V must already be
        appended at positions [start, start + Q).
    tables: (B, nb) int — physical pool row of each logical block.
    lengths: (B,) int — ``start + Q`` valid positions per slot.
    k_scale, v_scale: (R, KV) f32 — per-block scales for narrow pools.

    Returns (B, Q, H, D) in q's dtype.  Q == 1 is bit-identical to
    :func:`paged_attention` (same block layout, masks, and roundings).
    """
    B, Q, H, D = q.shape
    R, T, KV, Dk = k_pool.shape
    if H % KV != 0:
        raise ValueError(f"H={H} must be a multiple of KV={KV}")
    if Dk != D or v_pool.shape != k_pool.shape:
        raise ValueError(f"pool/query shape mismatch: q {q.shape}, "
                         f"k {k_pool.shape}, v {v_pool.shape}")
    _check_scales(k_pool, k_scale, v_scale)
    return paged_prefill_attention_pallas(
        q, k_pool, v_pool, tables.astype(jnp.int32),
        lengths.astype(jnp.int32), k_scale, v_scale, interpret=interpret)
