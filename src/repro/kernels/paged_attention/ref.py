"""Pure-jnp oracle: gather to a dense view, then masked softmax.

This IS the semantics of the serving engine's gather step
(``serving/paged.BlockPagingPlan.gather`` followed by dense masked
decode attention) — the reference the kernel is diffed against, and the
reference the gather/scatter round-trip property test pins.  Positions
``>= lengths[b]`` (stale block contents, NULL-block garbage, the padded
tail of the last block) are masked to -1e30 before the softmax exactly
like the dense path, so nothing unmasked can differ.
"""

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pool, v_pool, tables, lengths):
    """q: (B, H, D); k_pool/v_pool: (R, T, KV, D); tables: (B, nb);
    lengths: (B,) valid positions per slot (callers keep >= 1)."""
    B, H, D = q.shape
    _, T, KV, _ = k_pool.shape
    nb = tables.shape[1]
    G = H // KV

    dk = k_pool[tables].reshape(B, nb * T, KV, D).astype(jnp.float32)
    dv = v_pool[tables].reshape(B, nb * T, KV, D).astype(jnp.float32)
    qg = q.reshape(B, KV, G, D).astype(jnp.float32)

    s = jnp.einsum("bkgd,bskd->bkgs", qg, dk) / (D ** 0.5)
    idx = jnp.arange(nb * T)
    s = jnp.where(idx[None, None, None, :] < lengths[:, None, None, None],
                  s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, dv)
    return o.reshape(B, H, D).astype(q.dtype)


def paged_prefill_attention_ref(q, k_pool, v_pool, tables, lengths):
    """Multi-query oracle: q (B, Q, H, D); lengths = start + Q.  Query
    position qi attends kv positions <= start + qi (per-row causal mask
    over the same gathered dense view)."""
    B, Q, H, D = q.shape
    _, T, KV, _ = k_pool.shape
    nb = tables.shape[1]
    G = H // KV

    dk = k_pool[tables].reshape(B, nb * T, KV, D).astype(jnp.float32)
    dv = v_pool[tables].reshape(B, nb * T, KV, D).astype(jnp.float32)
    qg = q.reshape(B, Q, KV, G, D).astype(jnp.float32)

    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, dk) / (D ** 0.5)
    idx = jnp.arange(nb * T)
    # row qi's limit: start + qi + 1 == lengths - (Q - 1 - qi)
    limit = (lengths[:, None] - (Q - 1 - jnp.arange(Q))[None, :])
    s = jnp.where(idx[None, None, None, None, :]
                  < limit[:, :, None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", p, dv)
    return o.reshape(B, Q, H, D).astype(q.dtype)
