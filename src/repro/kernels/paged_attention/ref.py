"""Pure-jnp oracle: gather to a dense view, then masked softmax.

This IS the semantics of the serving engine's gather step
(``serving/paged.BlockPagingPlan.gather`` followed by dense masked
decode attention) — the reference the kernel is diffed against, and the
reference the gather/scatter round-trip property test pins.  Positions
``>= lengths[b]`` (stale block contents, NULL-block garbage, the padded
tail of the last block) are masked to -1e30 before the softmax exactly
like the dense path, so nothing unmasked can differ.
"""

import jax
import jax.numpy as jnp


def _dense_view(pool, scale, tables, compute_dtype):
    """Gathered (B, nb, T, KV, D) view; narrow pools dequantize each
    block with its (R, KV) scale through the shared rounding site (f32
    multiply, one round to the compute dtype — the exact expression of
    ``serving.kvquant.dequantize``)."""
    g = pool[tables]                                 # (B, nb, T, KV, D)
    if scale is not None:
        s = scale[tables][:, :, None, :, None]       # (B, nb, 1, KV, 1)
        g = (g.astype(jnp.float32) * s).astype(compute_dtype)
    return g


def paged_attention_ref(q, k_pool, v_pool, tables, lengths,
                        k_scale=None, v_scale=None):
    """q: (B, H, D); k_pool/v_pool: (R, T, KV, D); tables: (B, nb);
    lengths: (B,) valid positions per slot (callers keep >= 1);
    k_scale/v_scale: (R, KV) f32 per-block scales for narrow pools."""
    B, H, D = q.shape
    _, T, KV, _ = k_pool.shape
    nb = tables.shape[1]
    G = H // KV

    dk = _dense_view(k_pool, k_scale, tables, q.dtype).reshape(
        B, nb * T, KV, D).astype(jnp.float32)
    dv = _dense_view(v_pool, v_scale, tables, q.dtype).reshape(
        B, nb * T, KV, D).astype(jnp.float32)
    qg = q.reshape(B, KV, G, D).astype(jnp.float32)

    s = jnp.einsum("bkgd,bskd->bkgs", qg, dk) / (D ** 0.5)
    idx = jnp.arange(nb * T)
    s = jnp.where(idx[None, None, None, :] < lengths[:, None, None, None],
                  s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, dv)
    return o.reshape(B, H, D).astype(q.dtype)


def paged_prefill_attention_ref(q, k_pool, v_pool, tables, lengths,
                                k_scale=None, v_scale=None):
    """Multi-query oracle: q (B, Q, H, D); lengths = start + Q.  Query
    position qi attends kv positions <= start + qi (per-row causal mask
    over the same gathered — and, for narrow pools, dequantized —
    dense view)."""
    B, Q, H, D = q.shape
    _, T, KV, _ = k_pool.shape
    nb = tables.shape[1]
    G = H // KV

    dk = _dense_view(k_pool, k_scale, tables, q.dtype).reshape(
        B, nb * T, KV, D).astype(jnp.float32)
    dv = _dense_view(v_pool, v_scale, tables, q.dtype).reshape(
        B, nb * T, KV, D).astype(jnp.float32)
    qg = q.reshape(B, Q, KV, G, D).astype(jnp.float32)

    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, dk) / (D ** 0.5)
    idx = jnp.arange(nb * T)
    # row qi's limit: start + qi + 1 == lengths - (Q - 1 - qi)
    limit = (lengths[:, None] - (Q - 1 - jnp.arange(Q))[None, :])
    s = jnp.where(idx[None, None, None, None, :]
                  < limit[:, :, None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", p, dv)
    return o.reshape(B, Q, H, D).astype(q.dtype)
