"""Public wrapper: GQA-aware flash attention entry point."""

from __future__ import annotations

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (B, S, H, D); k, v: (B, S_kv, Hkv, D) with H % Hkv == 0 and
    S_kv >= S.

    Returns (B, S, H, D).  GQA is resolved on the kernel grid (each q
    stream's block-index map points at its kv group's stream) — K/V are
    flattened to (B*Hkv, S_kv, D) as-is, never repeated to H first, so
    GQA models stop copying KV ``H/Hkv``x before every call.  With
    S_kv > S the causal mask shifts by ``S_kv - S`` (chunked prefill:
    the last S kv positions ARE the queries).
    """
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    assert H % Hkv == 0, (H, Hkv)

    def to_flat(t):
        _, s, h, _ = t.shape
        return t.transpose(0, 2, 1, 3).reshape(B * h, s, D)

    out = flash_attention_pallas(
        to_flat(q), to_flat(k), to_flat(v), causal=causal,
        block_q=block_q, block_k=block_k, interpret=interpret,
        n_heads=H, n_kv_heads=Hkv)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
