"""Public wrapper: GQA-aware flash attention entry point."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (B, S, H, D); k, v: (B, S, Hkv, D) with H % Hkv == 0.

    Returns (B, S, H, D).  KV heads are repeated to H (the wrapper's job;
    the kernel sees flat (B*H, S, D) streams).
    """
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    assert H % Hkv == 0, (H, Hkv)
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    to_flat = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    out = flash_attention_pallas(
        to_flat(q), to_flat(k), to_flat(v), causal=causal,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
