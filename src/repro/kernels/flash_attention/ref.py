"""Pure-jnp oracle: dense softmax attention."""

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q, k, v: (BH, S, D)."""
    BH, S, D = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
