"""Blocked causal attention (FlashAttention-style) Pallas TPU kernel.

Ladder mapping: the (block_q x block_k) tiling is the *explicit data
caching* step applied to attention (the O(S^2) score matrix never
materializes in HBM); the sequential k-block grid dim with VMEM-resident
(m, l, acc) running stats is the *customized pipelining* step (Mosaic
overlaps the k-block DMA with the MXU work); (batch*heads, q-blocks) are
*parallel* grid dims (PE duplication).

Grid: (B*H, S/block_q, S/block_k), k innermost (sequential).
Scratch (VMEM, per (bh, qi) stream): m (bq, 1), l (bq, 1), acc (bq, D).
Masked logits use -1e30; with ascending k-blocks every causal row sees its
diagonal block before any fully-masked block, so exp underflows to exact 0
and no NaN guard is needed (documented in tests).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                 *, scale: float, causal: bool, block_q: int, block_k: int,
                 offset: int = 0):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _block():
        q = q_ref[0].astype(jnp.float32)          # (bq, D)
        k = k_ref[0].astype(jnp.float32)          # (bk, D)
        v = v_ref[0].astype(jnp.float32)          # (bk, D)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)

        if causal:
            # query row r sits at absolute kv position r + offset (the
            # chunked-prefill case: S_kv = prefix + S_q, offset = S_kv -
            # S_q; offset == 0 is the classic square mask).
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(cols <= rows + offset, s, NEG_INF)

        m_prev = m_ref[...]                       # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                    # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)           # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip blocks strictly above the (offset) diagonal
        @pl.when(ki * block_k <= qi * block_q + block_q - 1 + offset)
        def _():
            _block()
    else:
        _block()

    @pl.when(ki == pl.num_programs(2) - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret",
                     "n_heads", "n_kv_heads"),
)
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True, n_heads: int = 0,
                           n_kv_heads: int = 0):
    """q: (B*H, S, D) -> (B*H, S, D), same dtype as q.

    GQA runs on the grid, not on copied data: with ``n_heads`` /
    ``n_kv_heads`` given, k and v are the UN-repeated (B*Hkv, S_kv, D)
    streams and each q stream's k-block index map points at its kv
    group's stream (``(b // H) * Hkv + (b % H) // G``) — the kernel body
    is untouched, so the output is bit-identical to feeding it repeated
    K/V, without ever materializing the H/Hkv copies.  Defaulting both
    to 0 keeps the legacy H == Hkv contract.

    ``S_kv >= S_q`` is allowed (the chunked-prefill query mode): the
    causal mask shifts by ``offset = S_kv - S_q``, i.e. query row r
    attends kv positions ``<= r + offset`` — with S_kv == S_q this is
    the classic square causal mask, unchanged.
    """
    BH, S, D = q.shape
    Skv = k.shape[1]
    H = n_heads or BH
    Hkv = n_kv_heads or H
    assert H % Hkv == 0 and BH % H == 0, (BH, H, Hkv)
    group = H // Hkv
    BHkv = (BH // H) * Hkv
    assert Skv >= S, (S, Skv)
    assert k.shape == v.shape == (BHkv, Skv, D), (q.shape, k.shape, v.shape)
    block_q = min(block_q, S)
    block_k = min(block_k, Skv)
    assert S % block_q == 0 and Skv % block_k == 0, (S, Skv, block_q, block_k)
    scale = 1.0 / (D ** 0.5)

    grid = (BH, S // block_q, Skv // block_k)
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, offset=Skv - S)

    def kv_stream(b):
        return (b // H) * Hkv + (b % H) // group

    kw = {}
    if not interpret:
        kw["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda b, i, j: (kv_stream(b), j, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda b, i, j: (kv_stream(b), j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        # NaN guard for rectangular causal: offset >= 0 keeps every query
        # row's diagonal block in range, so l > 0 always holds here too.
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
        **kw,
    )(q, k, v)
