"""Public wrapper: (B, S, H, N) layout -> kernel's flat (B*H, S, N)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.rwkv6_wkv.kernel import wkv_pallas


def wkv(r, k, v, lw, u, *, init_state=None, chunk: int = 128,
        interpret: bool = True):
    """Drop-in for ``models.rwkv6.wkv_chunked``.

    r,k,v,lw: (B, S, H, N); u: (H, N).
    Returns (y (B,S,H,N), final_state (B,H,N,N) f32).
    """
    B, S, H, N = r.shape
    flat = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, N)
    u_f = jnp.broadcast_to(u, (B, H, N)).reshape(B * H, N)
    s0 = (init_state if init_state is not None
          else jnp.zeros((B, H, N, N), jnp.float32))
    s0_f = s0.reshape(B * H, N, N).astype(jnp.float32)

    y, sf = wkv_pallas(flat(r), flat(k), flat(v), flat(lw), u_f, s0_f,
                       chunk=chunk, interpret=interpret)
    y = y.reshape(B, H, S, N).transpose(0, 2, 1, 3)
    return y, sf.reshape(B, H, N, N)
