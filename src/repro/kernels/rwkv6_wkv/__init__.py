from repro.kernels.rwkv6_wkv.ops import wkv  # noqa: F401
