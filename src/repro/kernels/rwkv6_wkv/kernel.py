"""RWKV-6 chunked WKV recurrence as a Pallas TPU kernel.

The recurrence  S_t = diag(w_t) S_{t-1} + k_t^T v_t,  y_t = r_t (S_{t-1} +
u k_t^T v_t)  is evaluated chunk-by-chunk: each grid step stages one
(Q, N) chunk of r/k/v/log-decay in VMEM (explicit data caching), computes
the intra-chunk part as dense (Q,Q)/(Q,N) MXU matmuls, and carries the
(N, N) state in VMEM scratch across the sequential chunk dim (the
load-compute-store rotation over a *recurrence*).  (B*H) is the parallel
grid dim.

Matches ``repro.models.rwkv6.wkv_chunked`` exactly (same clamp convention:
lw is log-decay already clamped to [-LW_CLAMP, 0] by the caller).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                y_ref, sf_ref, state_ref, *, chunk: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    r_c = r_ref[0].astype(jnp.float32)            # (Q, N)
    k_c = k_ref[0].astype(jnp.float32)
    v_c = v_ref[0].astype(jnp.float32)
    lw_c = lw_ref[0].astype(jnp.float32)          # log-decay, <= 0
    u = u_ref[0].astype(jnp.float32)              # (1, N) bonus

    cum = jnp.cumsum(lw_c, axis=0)                # (Q, N)
    ri = r_c * jnp.exp(cum - lw_c)                # r_i * exp(cum_{i-1})
    kj = k_c * jnp.exp(-cum)

    # A[i, j] = <ri_i, kj_j> for j < i (strictly causal)
    A = jax.lax.dot_general(ri, kj, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.where(jj < ii, A, 0.0)

    diag = jnp.sum(r_c * u * k_c, axis=1, keepdims=True)         # (Q, 1)
    state = state_ref[...]                                       # (N, N)
    y = (jax.lax.dot_general(A, v_c, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
         + diag * v_c
         + jax.lax.dot_general(ri, state, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32))
    y_ref[0] = y.astype(y_ref.dtype)

    # state update to end of chunk
    decay_k = jnp.exp(cum[-1:] - cum)                            # (Q, N)
    st_c = jax.lax.dot_general(k_c * decay_k, v_c,
                               (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (N, N)
    total_decay = jnp.exp(cum[-1])                               # (N,)
    state_ref[...] = state * total_decay[:, None] + st_c

    @pl.when(c == pl.num_programs(1) - 1)
    def _done():
        sf_ref[0] = state_ref[...].astype(sf_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_pallas(r, k, v, lw, u, s0, *, chunk: int = 128,
               interpret: bool = True):
    """r,k,v,lw: (BH, S, N); u: (BH, N); s0: (BH, N, N) f32.

    Returns (y (BH, S, N) same dtype as r, final_state (BH, N, N) f32).
    """
    BH, S, N = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    grid = (BH, S // chunk)

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    kw = {}
    if not interpret:
        kw["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))

    y, sf = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N), lambda b, c: (b, 0)),
            pl.BlockSpec((1, N, N), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N, N), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, N), r.dtype),
            jax.ShapeDtypeStruct((BH, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
        **kw,
    )(r, k, v, lw, u, s0)
    return y, sf
