"""Pure-jnp sequential oracle for the WKV kernel (flat (BH, ...) layout)."""

import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, lw, u, s0):
    """r,k,v,lw: (BH, S, N); u: (BH, N); s0: (BH, N, N) f32."""
    def step(state, inp):
        r_t, k_t, v_t, lw_t = inp                 # (BH, N)
        kv = jnp.einsum("bc,bn->bcn", k_t.astype(jnp.float32),
                        v_t.astype(jnp.float32))
        y = jnp.einsum("bc,bcn->bn", r_t.astype(jnp.float32),
                       state + u.astype(jnp.float32)[..., None] * kv)
        state = state * jnp.exp(lw_t.astype(jnp.float32))[..., None] + kv
        return state, y

    tm = lambda t: jnp.moveaxis(t, 1, 0)
    final, ys = jax.lax.scan(step, s0, (tm(r), tm(k), tm(v), tm(lw)))
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), final
