"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel is a subpackage with the repo-standard triple:

  kernel.py — ``pl.pallas_call`` + explicit ``BlockSpec`` VMEM tiling
  ops.py    — the jit'd public wrapper (shape plumbing, level knobs)
  ref.py    — the pure-jnp oracle the tests assert against

The container is CPU-only: kernels target TPU (BlockSpec shapes chosen for
VMEM/MXU) and are validated in ``interpret=True`` mode, which executes the
kernel body on CPU.

Kernels:

  tiled_matmul    — the paper's Fig. 4 ladder transplanted to a TPU matmul:
                    block staging (O1), grid software pipelining (O2),
                    parallel tile grid (O3), double-buffer-aware block
                    sizing (O4), bf16 lane packing w/ f32 accum (O5)
  flash_attention — blocked causal attention (online softmax), the
                    data-caching + pipelining steps applied to attention
  rwkv6_wkv       — RWKV-6 chunked WKV recurrence (state in VMEM scratch,
                    chunk grid = the load-compute-store rotation)
  mamba2_ssd      — Mamba-2 SSD chunked scan, same structure
"""

from repro.kernels.tiled_matmul import ops as tiled_matmul  # noqa: F401
from repro.kernels.flash_attention import ops as flash_attention  # noqa: F401
from repro.kernels.rwkv6_wkv import ops as rwkv6_wkv  # noqa: F401
from repro.kernels.mamba2_ssd import ops as mamba2_ssd  # noqa: F401
