"""AdamW + schedule + clipping, pure JAX, shard-transparent.

Moments live in the same sharding as their params (the sharder maps the
moment tree with the param axes), so optimizer memory scales down with
FSDP x TP exactly like MaxText-class frameworks.  ``moment_dtype=bfloat16``
is the beyond-paper memory lever used in §Perf (scratchpad-reorganization
applied to optimizer state).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init_state(cfg: AdamWConfig, params) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(z, params),
        "nu": jax.tree.map(z, params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_spec(cfg: AdamWConfig, param_shapes) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    z = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return {
        "mu": jax.tree.map(z, param_shapes),
        "nu": jax.tree.map(z, param_shapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def state_axes(param_axes_tree) -> dict:
    """Logical axes tree matching ``init_state`` (for the sharder)."""
    return {
        "mu": param_axes_tree,
        "nu": param_axes_tree,
        "step": (),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), tree), norm


def update(cfg: AdamWConfig, grads, state, params):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g32
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
        mhat = mu32 / bc1
        nhat = nu32 / bc2
        step_ = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * step_).astype(p.dtype),
                mu32.astype(mdt), nu32.astype(mdt))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
