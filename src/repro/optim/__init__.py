from repro.optim.adamw import (
    AdamWConfig,
    clip_by_global_norm,
    global_norm,
    init_state,
    schedule,
    state_axes,
    state_spec,
    update,
)
