"""SORT (merge sort) — paper Table 3: 64 MB integer array.

Per the paper (§2.2), the FPGA's goal is every 1 MB chunk sorted; the CPU
merges the rest (tree-reduce parallelism dies off after a few layers).
Output here: the array with every chunk independently sorted.

  O0  insertion sort per chunk, element-at-a-time against the full buffer
  O1  chunks staged; in-scratchpad insertion sort
  O2  + pipelined sorting network: bitonic stages, each stage one
      vectorized compare-exchange pass (the II=1 pipeline analog)
  O3  + PE duplication across chunks (vmap)
  O4  + 3-slot rotation over chunks
  O5  kept == O4 (32-bit keys already word-wide; paper: SORT's scratchpad
      gain comes from caching-size choice, fixed at 1 MB — Fig. 6 note)
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.costmodel import MACHSUITE_PROFILES
from repro.machsuite.common import OptLevel, rotate3

PROFILE = MACHSUITE_PROFILES["sort"]


def oracle(data: np.ndarray, chunk: int) -> np.ndarray:
    d = np.asarray(data).reshape(-1, chunk)
    return np.sort(d, axis=1).reshape(-1)


def _insertion_sort(buf):
    n = buf.shape[0]

    def outer(i, buf):
        key = buf[i]

        def cond(state):
            j, b = state
            return (j >= 0) & (b[jnp.maximum(j, 0)] > key)

        def shift(state):
            j, b = state
            return j - 1, b.at[j + 1].set(b[j])

        j, buf = jax.lax.while_loop(cond, shift, (i - 1, buf))
        return buf.at[j + 1].set(key)

    return jax.lax.fori_loop(1, n, outer, buf)


def _bitonic_sort(buf):
    """Power-of-two bitonic network; stages are static Python loops, each
    stage one vectorized compare-exchange (the hardware pipeline)."""
    n = buf.shape[0]
    assert (n & (n - 1)) == 0, f"bitonic needs power-of-two, got {n}"
    idx = jnp.arange(n)
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            partner = idx ^ j
            up = (idx & k) == 0
            a = buf
            b = buf[partner]
            lo = jnp.minimum(a, b)
            hi = jnp.maximum(a, b)
            first = idx < partner
            buf = jnp.where(first == up, lo, hi)
            j //= 2
        k *= 2
    return buf


def _run_o0(data, chunk):
    n_chunks = data.shape[0] // chunk

    def body(c, buf):
        seg = jax.lax.dynamic_slice(buf, (c * chunk,), (chunk,))
        seg = _insertion_sort(seg)
        return jax.lax.dynamic_update_slice(buf, seg, (c * chunk,))

    return jax.lax.fori_loop(0, n_chunks, body, data)


def _run_o1(data, chunk):
    chunks = data.reshape(-1, chunk)
    _, out = jax.lax.scan(
        lambda _, c: (None, _insertion_sort(c)), None, chunks)
    return out.reshape(-1)


def _run_o2(data, chunk):
    chunks = data.reshape(-1, chunk)
    _, out = jax.lax.scan(
        lambda _, c: (None, _bitonic_sort(c)), None, chunks)
    return out.reshape(-1)


def _run_o3(data, chunk):
    chunks = data.reshape(-1, chunk)
    return jax.vmap(_bitonic_sort)(chunks).reshape(-1)


def _run_o4(data, chunk):
    chunks = data.reshape(-1, chunk)
    n = chunks.shape[0]
    bufs0 = {
        "slots": jnp.zeros((3, chunk), chunks.dtype),
        "out": jnp.zeros_like(chunks),
    }

    def body(i, slot, bufs):
        t = jnp.minimum(i, n - 1)
        slots = jax.lax.dynamic_update_index_in_dim(
            bufs["slots"], chunks[t], slot, 0)
        c = (i - 1) % 3
        s = _bitonic_sort(slots[c])
        out = jax.lax.cond(
            i >= 1,
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, s, jnp.maximum(i - 1, 0), 0),
            lambda o: o, bufs["out"])
        return {"slots": slots, "out": out}

    return rotate3(body, n + 1, bufs0)["out"].reshape(-1)


def run(level: OptLevel, data, chunk: int) -> jax.Array:
    data = jnp.asarray(data, jnp.int32)
    level = OptLevel(level)
    if level == OptLevel.O0:
        return _run_o0(data, chunk)
    if level == OptLevel.O1:
        return _run_o1(data, chunk)
    if level == OptLevel.O2:
        return _run_o2(data, chunk)
    if level == OptLevel.O3:
        return _run_o3(data, chunk)
    return _run_o4(data, chunk)


def make_inputs(rng: np.random.Generator, scale: float = 1.0) -> dict:
    # paper: 64 MB of int32 = 16M elements, 1 MB (256K-element) chunks
    chunk = 1 << max(4, int(np.log2(262_144 * scale)))
    n_chunks = max(2, int(64 * min(1.0, scale * 32)))
    return {
        "data": rng.integers(-2**31, 2**31 - 1, n_chunks * chunk,
                             dtype=np.int32),
        "chunk": chunk,
    }
