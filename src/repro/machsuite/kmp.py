"""KMP string matching — paper Table 3: 128 MB string, 16 B substring.

Output: the number of occurrences (the paper notes KMP's output is "merely
an integer", which is why double buffering gains nothing for it).

  O0  character scan with the classic failure-function backtrack
      (while-loop inside the scan body = the un-pipelined inner loop)
  O1  text staged in chunks; same backtracking automaton per chunk
  O2  + the match loop compiled to a DFA: one table lookup per character,
      II=1 (the paper's "pipeline pragma" step — KMP gains 7.0x, Table 4)
  O3  + PE duplication: text split across PE chunks with (m-1)-overlap,
      each PE counts matches *starting* in its span (vmap)
  O4  + 3-slot rotation over chunks (paper: ~no gain for KMP — Fig. 12)
  O5  + chunk staging in packed uint32 words (char->int reorg; KMP is a
      top gainer for scratchpad reorg in the paper: byte-typed buffers)
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.costmodel import MACHSUITE_PROFILES
from repro.machsuite.common import (OptLevel, pack_u8_to_u32, rotate3,
                                    unpack_u32_to_u8)

PROFILE = MACHSUITE_PROFILES["kmp"]

PE_NUM = 8
ALPHABET = 256


def failure_fn(pattern: np.ndarray) -> np.ndarray:
    """Classic KMP failure (longest proper prefix-suffix) table."""
    p = np.asarray(pattern, np.uint8)
    m = len(p)
    fail = np.zeros(m, np.int32)
    k = 0
    for i in range(1, m):
        while k > 0 and p[i] != p[k]:
            k = fail[k - 1]
        if p[i] == p[k]:
            k += 1
        fail[i] = k
    return fail


def dfa_table(pattern: np.ndarray) -> np.ndarray:
    """(m+1, 256) next-state table: state = chars of pattern matched."""
    p = np.asarray(pattern, np.uint8)
    m = len(p)
    fail = failure_fn(p)
    dfa = np.zeros((m + 1, ALPHABET), np.int32)
    for s in range(m + 1):
        for c in range(ALPHABET):
            if s < m and c == p[s]:
                dfa[s, c] = s + 1
            elif s == 0:
                dfa[s, c] = 0
            else:
                # follow failure links from the longest border
                k = fail[s - 1] if s <= m else 0
                dfa[s, c] = dfa[k, c]
    # state m (full match) continues from its border, same as other rows
    return dfa


def oracle(text: np.ndarray, pattern: np.ndarray) -> np.ndarray:
    t = np.asarray(text, np.uint8)
    p = np.asarray(pattern, np.uint8)
    m = len(p)
    if len(t) < m:
        return np.int32(0)
    windows = np.lib.stride_tricks.sliding_window_view(t, m)
    return np.int32((windows == p).all(axis=1).sum())


# ---------------------------------------------------------------------------
# levels
# ---------------------------------------------------------------------------

def _scan_backtrack(text, pat_j, fail_j):
    """O0/O1 inner automaton: per-char backtracking while-loop."""
    m = pat_j.shape[0]

    def step(carry, c):
        j, count = carry

        def cond(j):
            return (j > 0) & (pat_j[j] != c)

        j = jax.lax.while_loop(cond, lambda j: fail_j[j - 1], j)
        j = jnp.where(pat_j[j] == c, j + 1, j)
        matched = j == m
        count = count + matched.astype(jnp.int32)
        j = jnp.where(matched, fail_j[m - 1], j)
        return (j, count), None

    (j, count), _ = jax.lax.scan(step, (jnp.int32(0), jnp.int32(0)), text)
    return count, j


def _run_o0(text, pat_j, fail_j):
    count, _ = _scan_backtrack(text, pat_j, fail_j)
    return count


def _chunks(text, n_chunks):
    return text.reshape(n_chunks, -1)


def _run_o1(text, pat_j, fail_j, n_chunks):
    chunks = _chunks(text, n_chunks)

    def per_chunk(carry, chunk):
        j, count = carry

        def step(c2, ch):
            jj, cnt = c2

            def cond(j):
                return (j > 0) & (pat_j[j] != ch)

            jj = jax.lax.while_loop(cond, lambda j: fail_j[j - 1], jj)
            jj = jnp.where(pat_j[jj] == ch, jj + 1, jj)
            matched = jj == pat_j.shape[0]
            cnt = cnt + matched.astype(jnp.int32)
            jj = jnp.where(matched, fail_j[pat_j.shape[0] - 1], jj)
            return (jj, cnt), None

        (j, count), _ = jax.lax.scan(step, (j, count), chunk)
        return (j, count), None

    (j, count), _ = jax.lax.scan(per_chunk, (jnp.int32(0), jnp.int32(0)),
                                 chunks)
    return count


def _dfa_count(chunk, dfa_j, m, start_state=0):
    """II=1 automaton: one lookup per char. Returns per-position match flag
    sum and the final state."""
    def step(s, c):
        s2 = dfa_j[s, c]
        return s2, (s2 == m).astype(jnp.int32)

    final, hits = jax.lax.scan(step, jnp.int32(start_state), chunk)
    return jnp.sum(hits), final


def _run_o2(text, dfa_j, m, n_chunks):
    chunks = _chunks(text, n_chunks)

    def per_chunk(carry, chunk):
        s, count = carry

        def step(s, c):
            s2 = dfa_j[s, c]
            return s2, (s2 == m).astype(jnp.int32)

        s, hits = jax.lax.scan(step, s, chunk)
        return (s, count + jnp.sum(hits)), None

    (s, count), _ = jax.lax.scan(per_chunk, (jnp.int32(0), jnp.int32(0)),
                                 chunks)
    return count


def _pe_split(text, m):
    """Split text into PE_NUM spans + (m-1)-char halo from the next span."""
    T = text.shape[0]
    assert T % PE_NUM == 0, (T, PE_NUM)
    span = T // PE_NUM
    padded = jnp.concatenate([text, jnp.zeros((m - 1,), text.dtype)])
    idx = jnp.arange(span + m - 1)[None, :] + (
        jnp.arange(PE_NUM) * span)[:, None]
    return padded[idx], span


def _run_o3(text, dfa_j, m):
    ext, span = _pe_split(text, m)
    T = text.shape[0]

    def per_pe(chunk, pe):
        def step(s, c):
            s2 = dfa_j[s, c]
            return s2, (s2 == m).astype(jnp.int32)

        _, hits = jax.lax.scan(step, jnp.int32(0), chunk)
        # count matches whose *start* is inside this PE's span AND whose
        # end is inside the real text (halo padding must not count):
        # match ending at local e starts at e-m+1
        pos = jnp.arange(chunk.shape[0])
        ok = (pos - (m - 1) < span) & (pe * span + pos < T)
        return jnp.sum(hits * ok)

    return jnp.sum(
        jax.vmap(per_pe)(ext, jnp.arange(PE_NUM))).astype(jnp.int32)


def _run_o4(text, dfa_j, m, *, packed=False):
    ext, span = _pe_split(text, m)   # (PE, span+m-1)
    n = ext.shape[0]
    width = ext.shape[1]
    pad = (-width) % 4
    ext_p = jnp.pad(ext, ((0, 0), (0, pad)))
    staged = pack_u8_to_u32(ext_p) if packed else ext_p

    T = text.shape[0]

    def compute(chunk, pe):
        u8 = unpack_u32_to_u8(chunk) if packed else chunk
        u8 = u8[:width]

        def step(s, c):
            s2 = dfa_j[s, c]
            return s2, (s2 == m).astype(jnp.int32)

        _, hits = jax.lax.scan(step, jnp.int32(0), u8)
        pos = jnp.arange(width)
        ok = (pos - (m - 1) < span) & (pe * span + pos < T)
        return jnp.sum(hits * ok)

    bufs0 = {
        "slots": jnp.zeros((3,) + staged.shape[1:], staged.dtype),
        "count": jnp.zeros((), jnp.int32),
    }

    def body(i, slot, bufs):
        t = jnp.minimum(i, n - 1)
        slots = jax.lax.dynamic_update_index_in_dim(
            bufs["slots"], staged[t], slot, 0)
        c = (i - 1) % 3
        add = jnp.where(i >= 1, compute(slots[c], jnp.maximum(i - 1, 0)), 0)
        return {"slots": slots, "count": bufs["count"] + add}

    return rotate3(body, n + 1, bufs0)["count"]


def run(level: OptLevel, text, pattern, n_chunks: int = 8) -> jax.Array:
    pattern = np.asarray(pattern, np.uint8)
    m = len(pattern)
    text = jnp.asarray(text, jnp.uint8)
    level = OptLevel(level)
    if level == OptLevel.O0:
        return _run_o0(text, jnp.asarray(pattern), jnp.asarray(failure_fn(pattern)))
    if level == OptLevel.O1:
        return _run_o1(text, jnp.asarray(pattern), jnp.asarray(failure_fn(pattern)),
                       n_chunks)
    dfa_j = jnp.asarray(dfa_table(pattern))
    if level == OptLevel.O2:
        return _run_o2(text, dfa_j, m, n_chunks)
    if level == OptLevel.O3:
        return _run_o3(text, dfa_j, m)
    if level == OptLevel.O4:
        return _run_o4(text, dfa_j, m, packed=False)
    return _run_o4(text, dfa_j, m, packed=True)


def make_inputs(rng: np.random.Generator, scale: float = 1.0) -> dict:
    n = max(PE_NUM * 64, int(128e6 * scale) // (PE_NUM * 8) * (PE_NUM * 8))
    # small alphabet => plenty of matches to count
    text = rng.integers(0, 4, n, dtype=np.uint8)
    pattern = rng.integers(0, 4, 16, dtype=np.uint8)
    return {"text": text, "pattern": pattern}
