"""Viterbi — paper Table 3: 1M chains of 128 observations (64-state HMM).

MachSuite convention: negative-log-space, minimization.  Output: the
min-cost (float32) of the best path per chain.  The paper notes Viterbi's
pipeline II is limited by the float add/min chain per stage (3.2x, Table 4)
unlike NW's single-cycle integer cells.

  O0  per-chain, per-step, per-state scalar loops
  O1  chains staged in batches; same scalar DP
  O2  + vectorized state update: one (S x S) min-plus contraction per step
  O3  + PE duplication across chains (vmap)
  O4  + 3-slot rotation over chain batches
  O5  kept == O4 (float64-wide words already; paper: limited gain)
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.costmodel import MACHSUITE_PROFILES
from repro.machsuite.common import OptLevel, rotate3

PROFILE = MACHSUITE_PROFILES["viterbi"]

BATCH = 8


def oracle(obs: np.ndarray, init: np.ndarray, trans: np.ndarray,
           emit: np.ndarray) -> np.ndarray:
    obs = np.asarray(obs)
    n_chains, T = obs.shape
    out = np.zeros(n_chains, np.float32)
    for c in range(n_chains):
        llh = init + emit[:, obs[c, 0]]
        for t in range(1, T):
            llh = (llh[:, None] + trans).min(axis=0) + emit[:, obs[c, t]]
        out[c] = llh.min()
    return out.astype(np.float32)


def _chain_scalar(obs_c, init, trans, emit):
    """O0/O1: explicit per-state loops (the un-pipelined nest)."""
    S = init.shape[0]
    llh0 = init + emit[:, obs_c[0]]

    def step(llh, o_t):
        def per_state(s, new):
            def per_prev(r, best):
                return jnp.minimum(best, llh[r] + trans[r, s])
            v = jax.lax.fori_loop(0, S, per_prev, jnp.float32(jnp.inf))
            return new.at[s].set(v + emit[s, o_t])
        new = jax.lax.fori_loop(0, S, per_state, jnp.zeros_like(llh))
        return new, None

    llh, _ = jax.lax.scan(step, llh0, obs_c[1:])

    def reduce_min(s, best):
        return jnp.minimum(best, llh[s])

    return jax.lax.fori_loop(0, S, reduce_min, jnp.float32(jnp.inf))


def _chain_vector(obs_c, init, trans, emit):
    """O2+: min-plus contraction, all states in parallel per step."""
    llh0 = init + emit[:, obs_c[0]]

    def step(llh, o_t):
        new = jnp.min(llh[:, None] + trans, axis=0) + emit[:, o_t]
        return new, None

    llh, _ = jax.lax.scan(step, llh0, obs_c[1:])
    return jnp.min(llh)


def _run_sequential(obs, init, trans, emit, per_chain, batched):
    if not batched:
        _, out = jax.lax.scan(
            lambda _, o: (None, per_chain(o, init, trans, emit)), None, obs)
        return out
    ob = obs.reshape(-1, BATCH, obs.shape[1])

    def per_batch(_, o):
        _, out = jax.lax.scan(
            lambda _, oc: (None, per_chain(oc, init, trans, emit)), None, o)
        return None, out

    _, out = jax.lax.scan(per_batch, None, ob)
    return out.reshape(-1)


def _run_o3(obs, init, trans, emit):
    ob = obs.reshape(-1, BATCH, obs.shape[1])

    def per_batch(_, o):
        return None, jax.vmap(
            lambda oc: _chain_vector(oc, init, trans, emit))(o)

    _, out = jax.lax.scan(per_batch, None, ob)
    return out.reshape(-1)


def _run_o4(obs, init, trans, emit):
    ob = obs.reshape(-1, BATCH, obs.shape[1])
    n = ob.shape[0]
    bufs0 = {
        "slots": jnp.zeros((3,) + ob.shape[1:], ob.dtype),
        "out": jnp.zeros((n, BATCH), jnp.float32),
    }

    def body(i, slot, bufs):
        t = jnp.minimum(i, n - 1)
        slots = jax.lax.dynamic_update_index_in_dim(
            bufs["slots"], ob[t], slot, 0)
        c = (i - 1) % 3
        vals = jax.vmap(
            lambda oc: _chain_vector(oc, init, trans, emit))(slots[c])
        out = jax.lax.cond(
            i >= 1,
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, vals, jnp.maximum(i - 1, 0), 0),
            lambda o: o, bufs["out"])
        return {"slots": slots, "out": out}

    return rotate3(body, n + 1, bufs0)["out"].reshape(-1)


def run(level: OptLevel, obs, init, trans, emit) -> jax.Array:
    obs = jnp.asarray(obs, jnp.int32)
    init = jnp.asarray(init, jnp.float32)
    trans = jnp.asarray(trans, jnp.float32)
    emit = jnp.asarray(emit, jnp.float32)
    level = OptLevel(level)
    if level == OptLevel.O0:
        return _run_sequential(obs, init, trans, emit, _chain_scalar, False)
    if level == OptLevel.O1:
        return _run_sequential(obs, init, trans, emit, _chain_scalar, True)
    if level == OptLevel.O2:
        return _run_sequential(obs, init, trans, emit, _chain_vector, True)
    if level == OptLevel.O3:
        return _run_o3(obs, init, trans, emit)
    return _run_o4(obs, init, trans, emit)


def make_inputs(rng: np.random.Generator, scale: float = 1.0) -> dict:
    n_chains = max(BATCH, int(1e6 * scale) // BATCH * BATCH)
    T = 128 if scale >= 1.0 else max(4, int(128 * min(1.0, scale * 64)))
    S, M = 64, 64
    if scale < 1.0:
        S, M = 8, 16
    return {
        "obs": rng.integers(0, M, (n_chains, T), dtype=np.int32),
        "init": -np.log(rng.dirichlet(np.ones(S))).astype(np.float32),
        "trans": -np.log(rng.dirichlet(np.ones(S), S)).astype(np.float32),
        "emit": -np.log(rng.dirichlet(np.ones(M), S)).astype(np.float32),
    }
