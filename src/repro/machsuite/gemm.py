"""GEMM (N^3 algorithm) — paper Table 3: two 1024x1024 double matrices.

Ladder (paper §3.2 data-tiling example):

  O0  element-at-a-time triple loop against the full operands
  O1  explicit tiling: (TI, TK)x(TK, TJ) tiles staged, inner k-loop scalar
  O2  + pipelined tile compute (the tile contraction as one MXU-shaped dot)
  O3  + PE duplication: all tiles of a block-row computed in parallel (vmap)
  O4  + 3-slot rotation over the k tile loop (Fig. 4c)
  O5  scratchpad reorg: inputs already max-width words (paper: limited gain
      for wide types — kept identical to O4)

Float note: accumulation order differs across levels, so tests compare with
allclose against a float64 numpy oracle.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.costmodel import MACHSUITE_PROFILES
from repro.machsuite.common import OptLevel, rotate3

PROFILE = MACHSUITE_PROFILES["gemm"]

TILE = 16   # staging tile (kept small so smoke inputs divide evenly)


def oracle(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return (np.asarray(a, np.float64) @ np.asarray(b, np.float64)).astype(
        np.float32)


def _run_o0(a, b):
    n, k = a.shape
    m = b.shape[1]

    def body(idx, c):
        i, j = idx // m, idx % m
        row = jax.lax.dynamic_slice(a, (i, 0), (1, k))
        col = jax.lax.dynamic_slice(b, (0, j), (k, 1))

        def inner(p, acc):
            return acc + row[0, p] * col[p, 0]

        v = jax.lax.fori_loop(0, k, inner, jnp.float32(0))
        return c.at[i, j].set(v)

    return jax.lax.fori_loop(0, n * m, body, jnp.zeros((n, m), jnp.float32))


def _tiles(a, b):
    n, k = a.shape
    m = b.shape[1]
    assert n % TILE == 0 and m % TILE == 0 and k % TILE == 0, (n, k, m)
    return n // TILE, k // TILE, m // TILE


def _run_o1(a, b):
    nt, kt, mt = _tiles(a, b)

    def tile_body(ti, tj, tk, acc):
        at = jax.lax.dynamic_slice(a, (ti * TILE, tk * TILE), (TILE, TILE))
        bt = jax.lax.dynamic_slice(b, (tk * TILE, tj * TILE), (TILE, TILE))

        def cell(idx, acc):
            i, j = idx // TILE, idx % TILE

            def inner(p, s):
                return s + at[i, p] * bt[p, j]

            v = jax.lax.fori_loop(0, TILE, inner, jnp.float32(0))
            return acc.at[i, j].add(v)

        return jax.lax.fori_loop(0, TILE * TILE, cell, acc)

    def out_tile(idx, c):
        ti, tj = idx // mt, idx % mt
        acc = jax.lax.fori_loop(
            0, kt, lambda tk, acc: tile_body(ti, tj, tk, acc),
            jnp.zeros((TILE, TILE), jnp.float32))
        return jax.lax.dynamic_update_slice(c, acc, (ti * TILE, tj * TILE))

    return jax.lax.fori_loop(0, nt * mt, out_tile,
                             jnp.zeros((a.shape[0], b.shape[1]), jnp.float32))


def _tile_view(a, b):
    nt, kt, mt = _tiles(a, b)
    at = a.reshape(nt, TILE, kt, TILE).transpose(0, 2, 1, 3)  # (nt,kt,T,T)
    bt = b.reshape(kt, TILE, mt, TILE).transpose(0, 2, 1, 3)  # (kt,mt,T,T)
    return at, bt, (nt, kt, mt)


def _run_o2(a, b):
    at, bt, (nt, kt, mt) = _tile_view(a, b)

    def out_tile(ti, tj):
        def k_step(acc, tk):
            return acc + at[ti, tk] @ bt[tk, tj], None
        acc, _ = jax.lax.scan(k_step, jnp.zeros((TILE, TILE), jnp.float32),
                              jnp.arange(kt))
        return acc

    def row(c, ti):
        def col(c, tj):
            return c, out_tile(ti, tj)
        _, tiles = jax.lax.scan(col, None, jnp.arange(mt))
        return c, tiles

    _, out = jax.lax.scan(row, None, jnp.arange(nt))   # (nt, mt, T, T)
    return out.transpose(0, 2, 1, 3).reshape(a.shape[0], b.shape[1])


def _run_o3(a, b):
    at, bt, (nt, kt, mt) = _tile_view(a, b)

    def out_tile(ti, tj):
        def k_step(acc, tk):
            return acc + at[ti, tk] @ bt[tk, tj], None
        acc, _ = jax.lax.scan(k_step, jnp.zeros((TILE, TILE), jnp.float32),
                              jnp.arange(kt))
        return acc

    pe_grid = jax.vmap(jax.vmap(out_tile, in_axes=(None, 0)),
                       in_axes=(0, None))
    out = pe_grid(jnp.arange(nt), jnp.arange(mt))      # (nt, mt, T, T)
    return out.transpose(0, 2, 1, 3).reshape(a.shape[0], b.shape[1])


def _run_o4(a, b):
    """3-slot rotation over the k tile stream for every output tile."""
    at, bt, (nt, kt, mt) = _tile_view(a, b)

    def out_tile(ti, tj):
        bufs0 = {
            "a": jnp.zeros((3, TILE, TILE), jnp.float32),
            "b": jnp.zeros((3, TILE, TILE), jnp.float32),
            "acc": jnp.zeros((TILE, TILE), jnp.float32),
        }

        def body(i, slot, bufs):
            tk = jnp.minimum(i, kt - 1)
            a_s = jax.lax.dynamic_update_index_in_dim(
                bufs["a"], at[ti, tk], slot, 0)
            b_s = jax.lax.dynamic_update_index_in_dim(
                bufs["b"], bt[tk, tj], slot, 0)
            c = (i - 1) % 3
            contrib = a_s[c] @ b_s[c]
            acc = bufs["acc"] + jnp.where(i >= 1, 1.0, 0.0) * contrib
            return {"a": a_s, "b": b_s, "acc": acc}

        return rotate3(body, kt + 1, bufs0)["acc"]

    pe_grid = jax.vmap(jax.vmap(out_tile, in_axes=(None, 0)),
                       in_axes=(0, None))
    out = pe_grid(jnp.arange(nt), jnp.arange(mt))
    return out.transpose(0, 2, 1, 3).reshape(a.shape[0], b.shape[1])


def run(level: OptLevel, a, b) -> jax.Array:
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    level = OptLevel(level)
    if level == OptLevel.O0:
        return _run_o0(a, b)
    if level == OptLevel.O1:
        return _run_o1(a, b)
    if level == OptLevel.O2:
        return _run_o2(a, b)
    if level == OptLevel.O3:
        return _run_o3(a, b)
    return _run_o4(a, b)   # O4 == O5 (scratchpad reorg: no-op for f32/f64)


def make_inputs(rng: np.random.Generator, scale: float = 1.0) -> dict:
    n = max(TILE, int(1024 * scale) // TILE * TILE)
    return {
        "a": rng.standard_normal((n, n), np.float32),
        "b": rng.standard_normal((n, n), np.float32),
    }
