"""Shared helpers for the MachSuite level ladder."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.optlevel import OptLevel, Step

__all__ = ["OptLevel", "Step", "has", "rotate3", "pack_u8_to_u32",
           "unpack_u32_to_u8"]


def has(level: OptLevel, step: Step) -> bool:
    return level.has(step)


def rotate3(body, n_iters: int, init_bufs):
    """Paper Fig. 4(c): explicit 3-slot load/compute/store rotation.

    ``body(i, slot, bufs) -> bufs`` performs the load/compute/store trio for
    phase ``i`` against buffer group ``slot`` (= i % 3).  Numerically the
    rotation is an identity scheduling transform — XLA overlaps the slots on
    real hardware; here the structure is what's faithful.
    """
    def step_fn(bufs, i):
        slot = i % 3
        return body(i, slot, bufs), None

    bufs, _ = jax.lax.scan(step_fn, init_bufs, jnp.arange(n_iters))
    return bufs


def pack_u8_to_u32(x_u8: jax.Array) -> jax.Array:
    """Pack a (..., 4k) uint8 array into (..., k) uint32 little-endian words
    — the paper's ap_uint<W> wide scratchpad word (§5.2)."""
    assert x_u8.shape[-1] % 4 == 0, x_u8.shape
    x = x_u8.reshape(*x_u8.shape[:-1], -1, 4).astype(jnp.uint32)
    return (x[..., 0] | (x[..., 1] << 8) | (x[..., 2] << 16)
            | (x[..., 3] << 24))


def unpack_u32_to_u8(x_u32: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_u8_to_u32`."""
    parts = [(x_u32 >> (8 * i)) & 0xFF for i in range(4)]
    out = jnp.stack(parts, axis=-1).astype(jnp.uint8)
    return out.reshape(*x_u32.shape[:-1], -1)
