"""AES-256 ECB — the paper's Fig. 2/4 walkthrough kernel.

Table 3: 256-bit key, 64 MB data.  The level ladder below transplants the
paper's exact code walk (Fig. 4a-d) to JAX:

  O0  block-at-a-time against the full buffer (per-block dynamic_slice =
      the naive per-access DRAM architecture of Fig. 2)
  O1  batch staging: scan over BATCH_SIZE slabs, blocks still sequential
  O2  + vectorize each block's 16 byte-lanes; blocks pipelined via scan
  O3  + all blocks of a batch encrypted in parallel (PE per block group)
  O4  + explicit 3-slot load/compute/store rotation (Fig. 4c)
  O5  + batch slabs staged as packed uint32 wide words (Fig. 4d)

The S-box is *derived* (GF(2^8) inverse + affine), not transcribed, and the
whole cipher is pinned by the FIPS-197 appendix C.3 test vector in the tests.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.costmodel import MACHSUITE_PROFILES
from repro.machsuite.common import (OptLevel, Step, pack_u8_to_u32, rotate3,
                                    unpack_u32_to_u8)

PROFILE = MACHSUITE_PROFILES["aes"]

N_ROUNDS = 14                      # AES-256
BLOCK = 16
BATCH_BLOCKS = 64                  # paper BATCH_SIZE = 1 KB slabs
BATCH_BYTES = BATCH_BLOCKS * BLOCK
PE_NUM = 8                         # paper Fig. 4(b) duplication factor


# ---------------------------------------------------------------------------
# Tables (host-side, derived from first principles)
# ---------------------------------------------------------------------------

def _gf_mul(a: int, b: int) -> int:
    p = 0
    for _ in range(8):
        if b & 1:
            p ^= a
        hi = a & 0x80
        a = (a << 1) & 0xFF
        if hi:
            a ^= 0x1B
        b >>= 1
    return p


def _make_sbox() -> np.ndarray:
    inv = np.zeros(256, np.uint8)
    for x in range(1, 256):
        for y in range(1, 256):
            if _gf_mul(x, y) == 1:
                inv[x] = y
                break
    rotl = lambda v, n: ((v << n) | (v >> (8 - n))) & 0xFF
    sbox = np.zeros(256, np.uint8)
    for x in range(256):
        b = int(inv[x])
        sbox[x] = b ^ rotl(b, 1) ^ rotl(b, 2) ^ rotl(b, 3) ^ rotl(b, 4) ^ 0x63
    return sbox


SBOX = _make_sbox()

# ShiftRows on the FIPS state layout (flat index = r + 4c):
# out[r + 4c] = in[r + 4*((c + r) % 4)]
SHIFT_PERM = np.array(
    [r + 4 * ((c + r) % 4) for c in range(4) for r in range(4)], np.int32
)


def expand_key(key: np.ndarray) -> np.ndarray:
    """FIPS-197 key expansion for AES-256 -> (15, 16) round keys (uint8)."""
    key = np.asarray(key, np.uint8)
    assert key.shape == (32,), key.shape
    Nk, Nr = 8, N_ROUNDS
    w = np.zeros((4 * (Nr + 1), 4), np.uint8)
    w[:Nk] = key.reshape(Nk, 4)
    rcon = 1
    for i in range(Nk, 4 * (Nr + 1)):
        t = w[i - 1].copy()
        if i % Nk == 0:
            t = np.roll(t, -1)
            t = SBOX[t]
            t[0] ^= rcon
            rcon = _gf_mul(rcon, 2)
        elif i % Nk == 4:
            t = SBOX[t]
        w[i] = w[i - Nk] ^ t
    return w.reshape(Nr + 1, 16)


# ---------------------------------------------------------------------------
# numpy oracle
# ---------------------------------------------------------------------------

def _xtime_np(x):
    return (((x.astype(np.uint16) << 1) & 0xFF)
            ^ (((x >> 7) & 1) * 0x1B)).astype(np.uint8)


def _mix_columns_np(s):
    """s: (..., 16) uint8, columns are consecutive 4-byte groups."""
    c = s.reshape(*s.shape[:-1], 4, 4)
    a0, a1, a2, a3 = c[..., 0], c[..., 1], c[..., 2], c[..., 3]
    x0, x1, x2, x3 = map(_xtime_np, (a0, a1, a2, a3))
    b0 = x0 ^ (x1 ^ a1) ^ a2 ^ a3
    b1 = a0 ^ x1 ^ (x2 ^ a2) ^ a3
    b2 = a0 ^ a1 ^ x2 ^ (x3 ^ a3)
    b3 = (x0 ^ a0) ^ a1 ^ a2 ^ x3
    return np.stack([b0, b1, b2, b3], axis=-1).reshape(s.shape)


def encrypt_blocks_np(blocks: np.ndarray, round_keys: np.ndarray) -> np.ndarray:
    """blocks: (B, 16) uint8; round_keys: (15, 16)."""
    s = blocks ^ round_keys[0]
    for r in range(1, N_ROUNDS):
        s = SBOX[s]
        s = s[..., SHIFT_PERM]
        s = _mix_columns_np(s)
        s = s ^ round_keys[r]
    s = SBOX[s]
    s = s[..., SHIFT_PERM]
    return s ^ round_keys[N_ROUNDS]


def oracle(data: np.ndarray, key: np.ndarray) -> np.ndarray:
    rk = expand_key(key)
    blocks = np.asarray(data, np.uint8).reshape(-1, 16)
    return encrypt_blocks_np(blocks, rk).reshape(-1)


# ---------------------------------------------------------------------------
# JAX implementation, per level
# ---------------------------------------------------------------------------

_SBOX_J = jnp.asarray(SBOX)
_PERM_J = jnp.asarray(SHIFT_PERM)


def _xtime(x):
    return ((x << 1) & 0xFF) ^ (((x >> 7) & 1) * jnp.uint8(0x1B))


def _mix_columns(s):
    c = s.reshape(*s.shape[:-1], 4, 4)
    a0, a1, a2, a3 = c[..., 0], c[..., 1], c[..., 2], c[..., 3]
    x0, x1, x2, x3 = map(_xtime, (a0, a1, a2, a3))
    b0 = x0 ^ (x1 ^ a1) ^ a2 ^ a3
    b1 = a0 ^ x1 ^ (x2 ^ a2) ^ a3
    b2 = a0 ^ a1 ^ x2 ^ (x3 ^ a3)
    b3 = (x0 ^ a0) ^ a1 ^ a2 ^ x3
    return jnp.stack([b0, b1, b2, b3], axis=-1).reshape(s.shape)


def encrypt_blocks(blocks: jax.Array, round_keys: jax.Array) -> jax.Array:
    """Fully vectorized rounds over (..., 16) uint8 blocks."""
    s = blocks ^ round_keys[0]

    def round_fn(r, s):
        s = _SBOX_J[s]
        s = s[..., _PERM_J]
        s = _mix_columns(s)
        return s ^ round_keys[r]

    s = jax.lax.fori_loop(1, N_ROUNDS, round_fn, s)
    s = _SBOX_J[s]
    s = s[..., _PERM_J]
    return s ^ round_keys[N_ROUNDS]


def _encrypt_block_bytewise(blk: jax.Array, round_keys: jax.Array):
    """O0/O1 compute: one 16-byte block, byte loops explicit (fori over the
    16 lanes for SubBytes/AddRoundKey — the un-pipelined inner loop)."""
    def sub_ark(s, rk):
        def body(i, acc):
            b = _SBOX_J[s[i]]
            return acc.at[i].set(b ^ rk[i])
        return jax.lax.fori_loop(0, BLOCK, body, jnp.zeros_like(s))

    s = blk ^ round_keys[0]

    def round_fn(r, s):
        s = sub_ark(s, jnp.zeros_like(round_keys[r]))   # SubBytes
        s = s[_PERM_J]
        s = _mix_columns(s)
        return s ^ round_keys[r]

    s = jax.lax.fori_loop(1, N_ROUNDS, round_fn, s)
    s = _SBOX_J[s][_PERM_J]
    return s ^ round_keys[N_ROUNDS]


def _run_o0(data, rk):
    n_blocks = data.shape[0] // BLOCK

    def body(i, buf):
        blk = jax.lax.dynamic_slice(buf, (i * BLOCK,), (BLOCK,))
        enc = _encrypt_block_bytewise(blk, rk)
        return jax.lax.dynamic_update_slice(buf, enc, (i * BLOCK,))

    return jax.lax.fori_loop(0, n_blocks, body, data)


def _run_o1(data, rk):
    slabs = data.reshape(-1, BATCH_BYTES)

    def per_slab(slab):
        def body(i, buf):
            blk = jax.lax.dynamic_slice(buf, (i * BLOCK,), (BLOCK,))
            enc = _encrypt_block_bytewise(blk, rk)
            return jax.lax.dynamic_update_slice(buf, enc, (i * BLOCK,))
        return jax.lax.fori_loop(0, BATCH_BLOCKS, body, slab)

    _, out = jax.lax.scan(lambda _, s: (None, per_slab(s)), None, slabs)
    return out.reshape(-1)


def _run_o2(data, rk):
    slabs = data.reshape(-1, BATCH_BLOCKS, BLOCK)

    def per_slab(slab):
        _, out = jax.lax.scan(
            lambda _, blk: (None, encrypt_blocks(blk, rk)), None, slab
        )
        return out

    _, out = jax.lax.scan(lambda _, s: (None, per_slab(s)), None, slabs)
    return out.reshape(-1)


def _run_o3(data, rk):
    slabs = data.reshape(-1, PE_NUM, BATCH_BLOCKS // PE_NUM, BLOCK)

    def per_slab(slab):                    # (PE, blocks/PE, 16)
        return jax.vmap(lambda chunk: encrypt_blocks(chunk, rk))(slab)

    _, out = jax.lax.scan(lambda _, s: (None, per_slab(s)), None, slabs)
    return out.reshape(-1)


def _run_o4(data, rk, *, packed=False):
    """Fig. 4(c): 3-slot rotation.  Phase i loads slab i into slot i%3,
    computes slot (i-1)%3, stores slot (i-2)%3."""
    slabs = data.reshape(-1, BATCH_BYTES)
    n = slabs.shape[0]

    if packed:                              # O5: wide-word staging buffers
        slabs = pack_u8_to_u32(slabs)

    def compute(slab):
        u8 = unpack_u32_to_u8(slab) if packed else slab
        enc = jax.vmap(lambda chunk: encrypt_blocks(chunk, rk))(
            u8.reshape(PE_NUM, -1, BLOCK)
        ).reshape(-1)
        return pack_u8_to_u32(enc) if packed else enc

    bufs0 = {
        "slots": jnp.zeros((3,) + slabs.shape[1:], slabs.dtype),
        "out": jnp.zeros_like(slabs),
    }

    def body(i, slot, bufs):
        slots = bufs["slots"]
        # load phase-i input into slot
        slots = jax.lax.dynamic_update_index_in_dim(
            slots, slabs[jnp.minimum(i, n - 1)], slot, 0)
        # compute slot (i-1)%3, store slot content computed at (i-1)
        c = (i - 1) % 3
        computed = compute(slots[c])
        out = jax.lax.cond(
            i >= 1,
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, computed, jnp.maximum(i - 1, 0), 0),
            lambda o: o,
            bufs["out"],
        )
        return {"slots": slots, "out": out}

    bufs = rotate3(body, n + 1, bufs0)
    out = bufs["out"]
    if packed:
        out = unpack_u32_to_u8(out)
    return out.reshape(-1)


def run(level: OptLevel, data, key) -> jax.Array:
    """Encrypt ``data`` (uint8, len % BATCH_BYTES == 0) at one opt level."""
    rk = jnp.asarray(expand_key(np.asarray(key)))
    data = jnp.asarray(data, jnp.uint8)
    level = OptLevel(level)
    if level == OptLevel.O0:
        return _run_o0(data, rk)
    if level == OptLevel.O1:
        return _run_o1(data, rk)
    if level == OptLevel.O2:
        return _run_o2(data, rk)
    if level == OptLevel.O3:
        return _run_o3(data, rk)
    if level == OptLevel.O4:
        return _run_o4(data, rk, packed=False)
    return _run_o4(data, rk, packed=True)


def make_inputs(rng: np.random.Generator, scale: float = 1.0) -> dict:
    n = max(BATCH_BYTES, int(64e6 * scale) // BATCH_BYTES * BATCH_BYTES)
    return {
        "data": rng.integers(0, 256, n, dtype=np.uint8),
        "key": rng.integers(0, 256, 32, dtype=np.uint8),
    }
