"""MachSuite kernels in JAX — the paper's faithful benchmark substrate.

Each kernel module exposes:

  make_inputs(rng, scale) -> dict      scaled-down inputs (scale=1.0 is the
                                       paper's Table 3 size; tests use <<1)
  oracle(**inputs) -> array            pure-numpy reference
  run(level, **inputs) -> array        JAX implementation whose *structure*
                                       follows the paper's refinement ladder
                                       (O0 naive .. O5 scratchpad-reorg);
                                       every level is output-identical
  PROFILE                              the analytic-model profile
                                       (core.costmodel.MACHSUITE_PROFILES)

The level variants are the paper's Fig. 4 code walk transplanted to JAX:
  O0  element-at-a-time compute against "DRAM" (per-element dynamic_slice)
  O1  explicit data caching: batch/tile staging, then compute per element
  O2  customized pipelining: vectorized/scanned inner loops (II -> 1)
  O3  PE duplication: vmap over independent jobs (where they exist)
  O4  double buffering: explicit 3-slot load/compute/store rotation
  O5  scratchpad reorganization: packed wide-word staging buffers
"""

from repro.machsuite import aes, bfs, gemm, kmp, nw, sort, spmv, viterbi

KERNELS = {
    "aes": aes,
    "bfs": bfs,
    "gemm": gemm,
    "kmp": kmp,
    "nw": nw,
    "sort": sort,
    "spmv": spmv,
    "viterbi": viterbi,
}

KERNEL_NAMES = tuple(KERNELS)
