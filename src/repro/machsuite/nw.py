"""Needleman-Wunsch — paper Table 3: 64K pairs of 128-nucleotide sequences.

Scoring follows MachSuite: MATCH +1, MISMATCH -1, GAP -1.  Output: the
global-alignment score per pair (int32).

  O0  per-pair row-by-row DP, cell-at-a-time (the un-pipelined nest)
  O1  pairs staged in batches; same sequential per-pair DP
  O2  + anti-diagonal wavefront: all cells of a diagonal in parallel —
      the paper's II=1 pipeline for 2-D DP (NW gains 8.8x, Table 4)
  O3  + PE duplication across pairs (vmap — NW is "fully parallel jobs")
  O4  + 3-slot rotation over pair batches
  O5  + 2-bit nucleotide codes staged in packed uint32 words (byte-typed
      buffers make NW/AES/KMP the big scratchpad-reorg winners)
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.costmodel import MACHSUITE_PROFILES
from repro.machsuite.common import (OptLevel, pack_u8_to_u32, rotate3,
                                    unpack_u32_to_u8)

PROFILE = MACHSUITE_PROFILES["nw"]

MATCH, MISMATCH, GAP = 1, -1, -1
BATCH = 16


def oracle(seq_a: np.ndarray, seq_b: np.ndarray) -> np.ndarray:
    a = np.asarray(seq_a)
    b = np.asarray(seq_b)
    n_pairs, L = a.shape
    out = np.zeros(n_pairs, np.int32)
    for p in range(n_pairs):
        prev = np.arange(L + 1, dtype=np.int64) * GAP
        for i in range(1, L + 1):
            cur = np.empty(L + 1, np.int64)
            cur[0] = i * GAP
            sub = np.where(b[p] == a[p, i - 1], MATCH, MISMATCH)
            for j in range(1, L + 1):
                cur[j] = max(prev[j - 1] + sub[j - 1],
                             prev[j] + GAP, cur[j - 1] + GAP)
            prev = cur
        out[p] = prev[L]
    return out


# ---------------------------------------------------------------------------
# per-pair DP kernels
# ---------------------------------------------------------------------------

def _dp_rowwise_cells(a, b):
    """O0/O1: scan rows; each row scanned cell-at-a-time (j-dependency
    serializes — the un-pipelined inner loop)."""
    L = a.shape[0]
    row0 = jnp.arange(L + 1, dtype=jnp.int32) * GAP

    def row(prev, i):
        sub = jnp.where(b == a[i], MATCH, MISMATCH)

        def cell(left, j):
            diag = prev[j] + sub[j]
            up = prev[j + 1] + GAP
            v = jnp.maximum(jnp.maximum(diag, up), left + GAP)
            return v, v

        _, vals = jax.lax.scan(cell, (i + 1) * GAP, jnp.arange(L))
        cur = jnp.concatenate([jnp.array([(i + 1) * GAP], jnp.int32), vals])
        return cur, None

    last, _ = jax.lax.scan(row, row0, jnp.arange(L))
    return last[L]


def _dp_wavefront(a, b):
    """O2+: anti-diagonal sweep — every cell on a diagonal is independent.

    diag[d][k] = M[i, j] with i = k, j = d - k (1-based incl. borders).
    We carry two previous diagonals of length L+1 (padded)."""
    L = a.shape[0]
    size = L + 1

    # borders: M[i,0] = i*GAP ; M[0,j] = j*GAP
    d0 = jnp.zeros((size,), jnp.int32)                       # diagonal d=0
    d1 = jnp.full((size,), GAP, jnp.int32)                   # d=1: (0,1),(1,0)

    idx = jnp.arange(size)

    def diag_step(carry, d):
        dm2, dm1 = carry
        i = idx                      # candidate row index on diagonal d
        j = d - i
        valid = (i >= 1) & (j >= 1) & (i <= L) & (j <= L)
        ai = a[jnp.clip(i - 1, 0, L - 1)]
        bj = b[jnp.clip(j - 1, 0, L - 1)]
        sub = jnp.where(ai == bj, MATCH, MISMATCH)
        # M[i-1, j-1] lives on dm2 at row i-1; M[i-1, j] on dm1 at i-1;
        # M[i, j-1] on dm1 at i.
        diag = dm2[jnp.clip(i - 1, 0, L)] + sub
        up = dm1[jnp.clip(i - 1, 0, L)] + GAP
        left = dm1[i] + GAP
        v = jnp.maximum(jnp.maximum(diag, up), left)
        border = jnp.where(i == 0, j * GAP, i * GAP)   # i==0 or j==0 cells
        cur = jnp.where(valid, v, border).astype(jnp.int32)
        return (dm1, cur), None

    (_, dlast), _ = jax.lax.scan(diag_step, (d0, d1),
                                 jnp.arange(2, 2 * L + 1))
    return dlast[L]        # cell (L, L) sits at row L of diagonal 2L


# ---------------------------------------------------------------------------
# levels
# ---------------------------------------------------------------------------

def _run_sequential(seq_a, seq_b, per_pair, batched: bool):
    if not batched:
        _, out = jax.lax.scan(
            lambda _, ab: (None, per_pair(ab[0], ab[1])), None,
            (seq_a, seq_b))
        return out
    a_b = seq_a.reshape(-1, BATCH, seq_a.shape[1])
    b_b = seq_b.reshape(-1, BATCH, seq_b.shape[1])

    def per_batch(_, ab):
        a, b = ab
        _, out = jax.lax.scan(
            lambda _, p: (None, per_pair(p[0], p[1])), None, (a, b))
        return None, out

    _, out = jax.lax.scan(per_batch, None, (a_b, b_b))
    return out.reshape(-1)


def _run_o3(seq_a, seq_b):
    a_b = seq_a.reshape(-1, BATCH, seq_a.shape[1])
    b_b = seq_b.reshape(-1, BATCH, seq_b.shape[1])

    def per_batch(_, ab):
        return None, jax.vmap(_dp_wavefront)(ab[0], ab[1])

    _, out = jax.lax.scan(per_batch, None, (a_b, b_b))
    return out.reshape(-1)


def _run_o4(seq_a, seq_b, *, packed=False):
    L = seq_a.shape[1]
    a_b = seq_a.reshape(-1, BATCH, L)
    b_b = seq_b.reshape(-1, BATCH, L)
    n = a_b.shape[0]
    if packed:
        pad = (-L) % 4
        a_st = pack_u8_to_u32(jnp.pad(a_b, ((0, 0), (0, 0), (0, pad))))
        b_st = pack_u8_to_u32(jnp.pad(b_b, ((0, 0), (0, 0), (0, pad))))
    else:
        a_st, b_st = a_b, b_b

    def compute(a_slab, b_slab):
        if packed:
            a_u8 = unpack_u32_to_u8(a_slab)[:, :L]
            b_u8 = unpack_u32_to_u8(b_slab)[:, :L]
        else:
            a_u8, b_u8 = a_slab, b_slab
        return jax.vmap(_dp_wavefront)(a_u8, b_u8)

    bufs0 = {
        "a": jnp.zeros((3,) + a_st.shape[1:], a_st.dtype),
        "b": jnp.zeros((3,) + b_st.shape[1:], b_st.dtype),
        "out": jnp.zeros((n, BATCH), jnp.int32),
    }

    def body(i, slot, bufs):
        t = jnp.minimum(i, n - 1)
        a_s = jax.lax.dynamic_update_index_in_dim(bufs["a"], a_st[t], slot, 0)
        b_s = jax.lax.dynamic_update_index_in_dim(bufs["b"], b_st[t], slot, 0)
        c = (i - 1) % 3
        scores = compute(a_s[c], b_s[c])
        out = jax.lax.cond(
            i >= 1,
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, scores, jnp.maximum(i - 1, 0), 0),
            lambda o: o, bufs["out"])
        return {"a": a_s, "b": b_s, "out": out}

    return rotate3(body, n + 1, bufs0)["out"].reshape(-1)


def run(level: OptLevel, seq_a, seq_b) -> jax.Array:
    seq_a = jnp.asarray(seq_a, jnp.uint8)
    seq_b = jnp.asarray(seq_b, jnp.uint8)
    level = OptLevel(level)
    if level == OptLevel.O0:
        return _run_sequential(seq_a, seq_b, _dp_rowwise_cells, batched=False)
    if level == OptLevel.O1:
        return _run_sequential(seq_a, seq_b, _dp_rowwise_cells, batched=True)
    if level == OptLevel.O2:
        return _run_sequential(seq_a, seq_b, _dp_wavefront, batched=True)
    if level == OptLevel.O3:
        return _run_o3(seq_a, seq_b)
    if level == OptLevel.O4:
        return _run_o4(seq_a, seq_b, packed=False)
    return _run_o4(seq_a, seq_b, packed=True)


def make_inputs(rng: np.random.Generator, scale: float = 1.0) -> dict:
    n_pairs = max(BATCH, int(65536 * scale) // BATCH * BATCH)
    L = 128 if scale >= 1.0 else max(8, int(128 * min(1.0, scale * 16)))
    return {
        "seq_a": rng.integers(0, 4, (n_pairs, L), dtype=np.uint8),
        "seq_b": rng.integers(0, 4, (n_pairs, L), dtype=np.uint8),
    }
