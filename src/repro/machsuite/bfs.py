"""BFS (queue-based) — paper Table 3: 4K nodes, 64K edges.

The paper's problem child: chain-dependent (no PE duplication, no double
buffering — §4.2/§5.1) and PCIe-bound (Table 5: 0.8 -> rejected by the
communication filter).  The ladder stops structurally at O2:

  O0  faithful queue-based scalar BFS: pop one node per while-iteration,
      walk its adjacency list element-at-a-time
  O1  level-synchronous with edge relaxation in staged tiles
  O2  + fully vectorized per-level relaxation (gather/scatter-min)
  O3..O5  == O2 (inapplicable; the dependence chain is the kernel)

Output: hop distance per node, -1 if unreachable.
"""

from __future__ import annotations

import collections

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.costmodel import MACHSUITE_PROFILES
from repro.machsuite.common import OptLevel

PROFILE = MACHSUITE_PROFILES["bfs"]

INF = np.int32(2**30)
EDGE_TILE = 256


def oracle(offsets: np.ndarray, neighbors: np.ndarray, edge_src: np.ndarray,
           source: int) -> np.ndarray:
    n = len(offsets) - 1
    dist = np.full(n, -1, np.int32)
    dist[source] = 0
    q = collections.deque([int(source)])
    while q:
        u = q.popleft()
        for v in neighbors[offsets[u]:offsets[u + 1]]:
            if dist[v] < 0:
                dist[v] = dist[u] + 1
                q.append(int(v))
    return dist


def _finish(dist):
    return jnp.where(dist >= INF, -1, dist).astype(jnp.int32)


def _run_o0(offsets, neighbors, source):
    """Queue in a fixed-size array; one pop per outer while iteration."""
    n = offsets.shape[0] - 1
    dist0 = jnp.full((n,), INF, jnp.int32).at[source].set(0)
    queue0 = jnp.zeros((n,), jnp.int32).at[0].set(source)

    def cond(state):
        _, _, head, tail = state
        return head < tail

    def body(state):
        dist, queue, head, tail = state
        u = queue[head]
        start, stop = offsets[u], offsets[u + 1]

        def edge_cond(es):
            return es[0] < stop

        def edge_body(es):
            e, dist, queue, tail = es
            v = neighbors[e]
            fresh = dist[v] >= INF
            dist = dist.at[v].min(dist[u] + 1)
            queue = jnp.where(fresh, queue.at[tail].set(v), queue)
            tail = tail + fresh.astype(jnp.int32)
            return (e + 1, dist, queue, tail)

        _, dist, queue, tail = jax.lax.while_loop(
            edge_cond, edge_body, (start, dist, queue, tail))
        return dist, queue, head + 1, tail

    dist, *_ = jax.lax.while_loop(
        cond, body, (dist0, queue0, jnp.int32(0), jnp.int32(1)))
    return _finish(dist)


def _relax_tiles(dist, level, edge_src, edge_dst, n_tiles):
    """One BFS level: relax edges tile-by-tile (O1 staging)."""
    src_t = edge_src.reshape(n_tiles, -1)
    dst_t = edge_dst.reshape(n_tiles, -1)

    def tile(dist, sd):
        s, d = sd
        on_frontier = dist[s] == level
        cand = jnp.where(on_frontier, level + 1, INF)
        return dist.at[d].min(cand), None

    dist, _ = jax.lax.scan(tile, dist, (src_t, dst_t))
    return dist


def _run_levelsync(offsets, neighbors, edge_src, source, *, n_tiles):
    n = offsets.shape[0] - 1
    dist0 = jnp.full((n,), INF, jnp.int32).at[source].set(0)

    def cond(state):
        dist, level, changed = state
        return changed & (level < n)

    def body(state):
        dist, level, _ = state
        if n_tiles == 1:
            on_frontier = dist[edge_src] == level
            cand = jnp.where(on_frontier, level + 1, INF)
            new = dist.at[neighbors].min(cand)
        else:
            new = _relax_tiles(dist, level, edge_src, neighbors, n_tiles)
        changed = jnp.any(new != dist)
        return new, level + 1, changed

    dist, *_ = jax.lax.while_loop(
        cond, body, (dist0, jnp.int32(0), jnp.bool_(True)))
    return _finish(dist)


def run(level: OptLevel, offsets, neighbors, edge_src, source) -> jax.Array:
    offsets = jnp.asarray(offsets, jnp.int32)
    neighbors = jnp.asarray(neighbors, jnp.int32)
    edge_src = jnp.asarray(edge_src, jnp.int32)
    source = jnp.asarray(source, jnp.int32)
    level = OptLevel(level)
    if level == OptLevel.O0:
        return _run_o0(offsets, neighbors, source)
    if level == OptLevel.O1:
        n_tiles = max(1, neighbors.shape[0] // EDGE_TILE)
        return _run_levelsync(offsets, neighbors, edge_src, source,
                              n_tiles=n_tiles)
    # O2..O5: vectorized level-synchronous relaxation (PE duplication and
    # double buffering are inapplicable — paper §4.2/§5.1)
    return _run_levelsync(offsets, neighbors, edge_src, source, n_tiles=1)


def make_inputs(rng: np.random.Generator, scale: float = 1.0) -> dict:
    n = max(16, int(4096 * scale))
    e = max(4 * n, int(65536 * scale))
    e = (e // EDGE_TILE) * EDGE_TILE if e >= EDGE_TILE else e
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    offsets = np.zeros(n + 1, np.int64)
    np.add.at(offsets[1:], src, 1)
    offsets = np.cumsum(offsets)
    return {
        "offsets": offsets.astype(np.int32),
        "neighbors": dst.astype(np.int32),
        "edge_src": src.astype(np.int32),
        "source": np.int32(0),
    }
