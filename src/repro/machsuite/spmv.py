"""SPMV (ELLPACK) — paper Table 3: 4096x512 data/index matrices.

y[i] = sum_l vals[i, l] * x[cols[i, l]].

The paper rejects SPMV as communication-bound (Table 5, PCIe/CPU = 1.3) —
the ladder is still implemented, mirroring what a programmer would build
before the filter stops them.

  O0  per-(row, lane) scalar accumulation against the full operands
  O1  row tiles staged; per-element loops inside the tile
  O2  + vectorized tile compute (gather + row-sum, the II=1 pipeline)
  O3  + tiles in parallel (vmap)
  O4  + 3-slot rotation over row tiles
  O5  kept == O4 (operands already wide words; paper §5.2: limited gain)
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.costmodel import MACHSUITE_PROFILES
from repro.machsuite.common import OptLevel, rotate3

PROFILE = MACHSUITE_PROFILES["spmv"]

TILE_ROWS = 64


def oracle(vals: np.ndarray, cols: np.ndarray, x: np.ndarray) -> np.ndarray:
    v = np.asarray(vals, np.float64)
    return (v * np.asarray(x, np.float64)[cols]).sum(axis=1).astype(np.float32)


def _run_o0(vals, cols, x):
    n, l = vals.shape

    def body(idx, y):
        i, j = idx // l, idx % l
        c = jax.lax.dynamic_slice(cols, (i, j), (1, 1))[0, 0]
        v = jax.lax.dynamic_slice(vals, (i, j), (1, 1))[0, 0]
        return y.at[i].add(v * x[c])

    return jax.lax.fori_loop(0, n * l, body, jnp.zeros((n,), jnp.float32))


def _run_o1(vals, cols, x):
    n, l = vals.shape
    nt = n // TILE_ROWS

    def tile(t, y):
        vt = jax.lax.dynamic_slice(vals, (t * TILE_ROWS, 0), (TILE_ROWS, l))
        ct = jax.lax.dynamic_slice(cols, (t * TILE_ROWS, 0), (TILE_ROWS, l))

        def cell(idx, acc):
            i, j = idx // l, idx % l
            return acc.at[i].add(vt[i, j] * x[ct[i, j]])

        yt = jax.lax.fori_loop(0, TILE_ROWS * l, cell,
                               jnp.zeros((TILE_ROWS,), jnp.float32))
        return jax.lax.dynamic_update_slice(y, yt, (t * TILE_ROWS,))

    return jax.lax.fori_loop(0, nt, tile, jnp.zeros((n,), jnp.float32))


def _tile_compute(vt, ct, x):
    return jnp.sum(vt * x[ct], axis=1)


def _run_o2(vals, cols, x):
    vt = vals.reshape(-1, TILE_ROWS, vals.shape[1])
    ct = cols.reshape(-1, TILE_ROWS, cols.shape[1])
    _, out = jax.lax.scan(
        lambda _, vc: (None, _tile_compute(vc[0], vc[1], x)), None, (vt, ct))
    return out.reshape(-1)


def _run_o3(vals, cols, x):
    vt = vals.reshape(-1, TILE_ROWS, vals.shape[1])
    ct = cols.reshape(-1, TILE_ROWS, cols.shape[1])
    return jax.vmap(lambda v, c: _tile_compute(v, c, x))(vt, ct).reshape(-1)


def _run_o4(vals, cols, x):
    vt = vals.reshape(-1, TILE_ROWS, vals.shape[1])
    ct = cols.reshape(-1, TILE_ROWS, cols.shape[1])
    nt = vt.shape[0]
    bufs0 = {
        "v": jnp.zeros((3,) + vt.shape[1:], vt.dtype),
        "c": jnp.zeros((3,) + ct.shape[1:], ct.dtype),
        "y": jnp.zeros((nt, TILE_ROWS), jnp.float32),
    }

    def body(i, slot, bufs):
        t = jnp.minimum(i, nt - 1)
        v_s = jax.lax.dynamic_update_index_in_dim(bufs["v"], vt[t], slot, 0)
        c_s = jax.lax.dynamic_update_index_in_dim(bufs["c"], ct[t], slot, 0)
        c = (i - 1) % 3
        yt = _tile_compute(v_s[c], c_s[c], x)
        y = jax.lax.cond(
            i >= 1,
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, yt, jnp.maximum(i - 1, 0), 0),
            lambda o: o, bufs["y"])
        return {"v": v_s, "c": c_s, "y": y}

    return rotate3(body, nt + 1, bufs0)["y"].reshape(-1)


def run(level: OptLevel, vals, cols, x) -> jax.Array:
    vals = jnp.asarray(vals, jnp.float32)
    cols = jnp.asarray(cols, jnp.int32)
    x = jnp.asarray(x, jnp.float32)
    level = OptLevel(level)
    if level == OptLevel.O0:
        return _run_o0(vals, cols, x)
    if level == OptLevel.O1:
        return _run_o1(vals, cols, x)
    if level == OptLevel.O2:
        return _run_o2(vals, cols, x)
    if level == OptLevel.O3:
        return _run_o3(vals, cols, x)
    return _run_o4(vals, cols, x)


def make_inputs(rng: np.random.Generator, scale: float = 1.0) -> dict:
    n = max(TILE_ROWS, int(4096 * scale) // TILE_ROWS * TILE_ROWS)
    l = max(8, int(512 * scale))
    return {
        "vals": rng.standard_normal((n, l), np.float32),
        "cols": rng.integers(0, n, (n, l), dtype=np.int32),
        "x": rng.standard_normal((n,), np.float32),
    }
