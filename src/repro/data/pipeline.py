"""Synthetic token pipeline: deterministic, sharded, double-buffered.

Three properties matter at scale and are all tested:

  * **Deterministic seek** — ``batch_at(step)`` is a pure function of
    (seed, step), so a restarted job resumes with bitwise-identical batches
    (the checkpoint/restart property test relies on this).
  * **Sharded placement** — batches are built shard-by-shard via
    ``jax.make_array_from_callback`` against the step's NamedSharding, so
    no host ever materializes the global batch (1000+-node posture).
  * **Double-buffered prefetch** — a background thread keeps ``depth``
    batches in flight (the paper's double-buffering step applied to the
    host->device stream).

The synthetic distribution is a mixture of Zipf-ish unigram draws and
shifted-copy spans, enough structure for the loss to move during the
example training runs.
"""

from __future__ import annotations

import queue
import threading

import numpy as np
import jax
import jax.numpy as jnp


class SyntheticLM:
    """Deterministic synthetic LM token stream."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 *, seed: int = 0, frontend: str = "none",
                 d_model: int = 0, n_prefix: int = 0,
                 emb_dtype=jnp.bfloat16):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.frontend = frontend
        self.d_model = d_model
        self.n_prefix = n_prefix
        self.emb_dtype = emb_dtype
        # Zipf-ish unigram table, fixed by seed.
        r = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._p = p / p.sum()
        self._perm = r.permutation(vocab)

    # -- pure batch functions -------------------------------------------------
    def _tokens_at(self, step: int, lo: int, hi: int) -> np.ndarray:
        """Rows [lo, hi) of the global batch for ``step`` (pure)."""
        out = np.empty((hi - lo, self.seq_len), np.int32)
        for i, row in enumerate(range(lo, hi)):
            r = np.random.default_rng(
                (self.seed * 1_000_003 + step) * 131_071 + row)
            toks = self._perm[
                r.choice(self.vocab, self.seq_len, p=self._p)]
            # splice in a shifted-copy span (learnable structure)
            span = self.seq_len // 4
            if span >= 2:
                start = int(r.integers(0, self.seq_len - 2 * span + 1))
                toks[start + span: start + 2 * span] = \
                    toks[start: start + span]
            out[i] = toks
        return out

    def batch_at(self, step: int, *, sharding=None) -> dict:
        """Build the full batch for ``step``; sharded if given a sharding."""
        B, S = self.global_batch, self.seq_len
        if sharding is not None:
            tokens = jax.make_array_from_callback(
                (B, S), sharding, lambda idx: self._tokens_at(
                    step, *_row_range(idx, B)))
        else:
            tokens = jnp.asarray(self._tokens_at(step, 0, B))
        batch = {"tokens": tokens, "labels": _shift_labels(tokens)}
        if self.frontend == "audio_frames":
            batch["frames"] = self._frames(step, (B, S, self.d_model))
        elif self.frontend == "vision_patches":
            batch["patches"] = self._frames(step, (B, self.n_prefix,
                                                   self.d_model))
        return batch

    def _frames(self, step: int, shape) -> jax.Array:
        key = jax.random.PRNGKey(self.seed * 7_919 + step)
        return (jax.random.normal(key, shape) * 0.02).astype(self.emb_dtype)


def _row_range(idx, B):
    sl = idx[0]
    rng = range(*sl.indices(B))
    return rng.start, rng.stop


def _shift_labels(tokens):
    """Next-token labels: labels[i] = tokens[i+1]; last column wraps to 0."""
    if isinstance(tokens, np.ndarray):
        lab = np.concatenate(
            [tokens[:, 1:], np.zeros_like(tokens[:, :1])], axis=1)
        return lab
    return jnp.concatenate(
        [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)


class Prefetcher:
    """Background-thread double buffering of ``dataset.batch_at(step)``.

    ``depth=2`` is the paper's double-buffer; ``depth=3`` its 3-slot
    rotation.  ``get(step)`` returns batches strictly in order.
    """

    def __init__(self, dataset: SyntheticLM, *, start_step: int = 0,
                 depth: int = 2, sharding=None):
        self.dataset = dataset
        self.sharding = sharding
        self.depth = depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._next
        while not self._stop.is_set():
            batch = self.dataset.batch_at(step, sharding=self.sharding)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self, expect_step: int = None) -> dict:
        step, batch = self._q.get()
        if expect_step is not None and step != expect_step:
            raise RuntimeError(
                f"prefetcher out of sync: got {step}, want {expect_step}")
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


def make_pipeline(cfg, shape, *, seed: int = 0, start_step: int = 0,
                  depth: int = 2, sharding=None) -> Prefetcher:
    """Pipeline for one (arch, shape) cell (matches ``input_specs``)."""
    frontend = ("audio_frames" if cfg.family == "audio"
                else "vision_patches" if cfg.family == "vlm" else "none")
    seq = shape.seq_len - (cfg.n_prefix if cfg.family == "vlm" else 0)
    ds = SyntheticLM(cfg.vocab, seq, shape.global_batch, seed=seed,
                     frontend=frontend, d_model=cfg.d_model,
                     n_prefix=cfg.n_prefix,
                     emb_dtype=jnp.dtype(cfg.compute_dtype))
    return Prefetcher(ds, start_step=start_step, depth=depth,
                      sharding=sharding)
