from repro.data.pipeline import (SyntheticLM, Prefetcher,  # noqa: F401
                                 make_pipeline)
