"""End-to-end serving driver: slot-based continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --batch 4 --max-seq 64 --requests 8

On a real fleet the same driver builds the production mesh and the sharded
``serve_step`` from ``launch/steps.py``; on this container it runs the
reduced smoke config on the host device.
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs import ARCH_NAMES, get_config, get_smoke
from repro.models import get_model
from repro.serving import DecodeEngine, Request


def serve_demo(cfg, *, batch_size: int, max_seq: int, n_requests: int,
               seed: int = 0, prompt_len=(2, 12), max_new=(4, 16)) -> dict:
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    engine = DecodeEngine(model, params, batch_size=batch_size,
                          max_seq=max_seq)

    rng = np.random.default_rng(seed)
    for _ in range(n_requests):
        plen = int(rng.integers(*prompt_len))
        new = int(rng.integers(*max_new))
        prompt = rng.integers(1, cfg.vocab, plen).tolist()
        engine.submit(Request(prompt=prompt, max_new_tokens=new))

    t0 = time.time()
    finished = engine.run()
    wall = time.time() - t0
    total_new = sum(len(r.generated) for r in finished)
    return {
        "finished": finished,
        "ticks": engine.n_steps,
        "wall_s": wall,
        "tokens": total_new,
        "tok_per_s": total_new / wall if wall > 0 else 0.0,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    out = serve_demo(cfg, batch_size=args.batch, max_seq=args.max_seq,
                     n_requests=args.requests, seed=args.seed)
    for r in out["finished"][:4]:
        print(f"[serve] req {r.rid}: prompt[{r.n_prompt}] -> "
              f"{r.generated}")
    print(f"[serve] {len(out['finished'])} requests, {out['tokens']} new "
          f"tokens in {out['ticks']} ticks / {out['wall_s']:.2f}s "
          f"({out['tok_per_s']:.1f} tok/s batched)")


if __name__ == "__main__":
    main()
