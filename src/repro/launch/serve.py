"""End-to-end serving driver: slot-based continuous batching at any rung
of the best-effort ladder.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --batch 4 --max-seq 64 --requests 8 --level 5 --policy spf

On a real fleet the same driver builds the production mesh and the sharded
``serve_step`` from ``launch/steps.py``; on this container it runs the
reduced smoke config on the host device.  ``--level`` selects the
OptLevel the engine is built at (see ``repro.serving``; 6 = paged KV
blocks, 7 = speculative decoding — pair it with ``--draft``); walk all
eight with ``python -m repro.autotune --serve``.

Layout x placement: ``--pe`` sets the PE-duplication degree — on >= 2
devices an O3+ engine shards (the contiguous cache on its batch axis;
at ``--level 6`` the paged pool on its BLOCK axis).  Force host devices
with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``;
``--expect-devices`` turns the reported placement into an exit code for
CI smoke jobs.
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs import ARCH_NAMES, get_config, get_smoke
from repro.core.optlevel import BestEffortConfig, OptLevel
from repro.models import get_model
from repro.serving import DecodeEngine, Request, SamplerConfig


def serve_demo(cfg, *, batch_size: int, max_seq: int, n_requests: int,
               seed: int = 0, prompt_len=(2, 12), max_new=(4, 16),
               level: OptLevel = OptLevel.O5, policy: str = "fcfs",
               sampler: SamplerConfig = None, pe: int = 8,
               kv_block_size: int = 16, kv_pool_blocks: int = 0,
               paged_attn: str = "gather", prefill_chunk: int = 0,
               draft_model: str = "", draft_k: int = 4,
               kv_dtype: str = "bf16") -> dict:
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    engine = DecodeEngine(model, params, batch_size=batch_size,
                          max_seq=max_seq,
                          config=BestEffortConfig(
                              level=level, pe=pe,
                              kv_block_size=kv_block_size,
                              kv_pool_blocks=kv_pool_blocks,
                              paged_attn=paged_attn,
                              prefill_chunk=prefill_chunk,
                              draft_model=draft_model,
                              draft_k=draft_k,
                              kv_dtype=kv_dtype),
                          policy=policy, sampler=sampler)

    rng = np.random.default_rng(seed)
    for _ in range(n_requests):
        plen = int(rng.integers(*prompt_len))
        new = int(rng.integers(*max_new))
        prompt = rng.integers(1, cfg.vocab, plen).tolist()
        engine.submit(Request(prompt=prompt, max_new_tokens=new))

    t0 = time.time()
    finished = engine.run()
    wall = time.time() - t0
    total_new = sum(len(r.generated) for r in finished)
    return {
        "finished": finished,
        "ticks": engine.n_steps,
        "wall_s": wall,
        "tokens": total_new,
        "tok_per_s": total_new / wall if wall > 0 else 0.0,
        "layout": engine.layout.name,
        "devices": engine.placement.n_devices,
        "paged_attn": getattr(engine.layout, "attn_impl", None),
        "state_impl": getattr(engine.layout, "state_impl", "none"),
        "degrade_reason": engine.degrade_reason,
        "kv_dtype": getattr(engine.layout, "kv_dtype", "bf16"),
        "prefill_mode": engine.prefill_mode,
        "spec_mode": engine.spec_mode,
        "spec": engine.spec_stats,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--level", type=int, default=5, choices=range(8),
                    help="OptLevel to build the engine at (0=naive, "
                         "6=paged KV blocks, 7=speculative decoding — "
                         "needs --draft)")
    ap.add_argument("--policy", default="fcfs",
                    choices=("fcfs", "spf", "deadline"),
                    help="admission policy: fcfs, spf (shortest-prompt-"
                         "first with aging), or deadline (EDF on "
                         "Request.deadline_s — the open-loop traffic "
                         "front end's SLO policy)")
    ap.add_argument("--sampler", default="greedy",
                    choices=("greedy", "temperature", "top_k"))
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--pe", type=int, default=8,
                    help="PE duplication degree (O3+): shard degree over "
                         "visible devices; degrades, never fails")
    ap.add_argument("--kv-block", type=int, default=16,
                    help="O6 paged-cache block size in tokens")
    ap.add_argument("--kv-pool-blocks", type=int, default=0,
                    help="O6 pool size in blocks (0 = auto)")
    ap.add_argument("--paged-attn", default="gather",
                    choices=("gather", "kernel"),
                    help="O6 attention implementation: gather "
                         "re-materializes the dense KV view per tick; "
                         "kernel runs the gather-free block-table "
                         "Pallas kernel on the raw pool (families "
                         "without a paged decode step fall back)")
    ap.add_argument("--kv-dtype", default="bf16",
                    choices=("bf16", "int8", "fp8"),
                    help="O6 pool STORED dtype: int8/fp8 store narrow "
                         "blocks with per-block absmax scales (~2x "
                         "capacity at equal pool memory; tokens track "
                         "the bf16 rung within the tolerance contract, "
                         "not bit-exactly)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: consume prompts in chunks of "
                         "this many tokens, one chunk per tick, "
                         "interleaved with decode (0 = legacy one-token-"
                         "per-tick prestaged path; families without a "
                         "prefill step degrade; greedy tokens identical "
                         "either way)")
    ap.add_argument("--draft", default="", dest="draft_model",
                    help="O7 drafter arch (e.g. smollm-360m): proposes "
                         "--draft-k tokens per slot per tick for the "
                         "target to verify in one batched forward; must "
                         "share the target's vocab (resolved at the same "
                         "smoke/full scale).  Empty disables speculation "
                         "(O7 then behaves exactly like O6)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="speculation window: drafted tokens per slot "
                         "per verify step (0 disables; greedy tokens "
                         "identical for every K)")
    ap.add_argument("--expect-devices", type=int, default=0,
                    help="exit 1 unless the engine's placement landed on "
                         "exactly this many devices (CI smoke)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    sampler = SamplerConfig(kind=args.sampler, temperature=args.temperature,
                            top_k=args.top_k, seed=args.seed)
    out = serve_demo(cfg, batch_size=args.batch, max_seq=args.max_seq,
                     n_requests=args.requests, seed=args.seed,
                     level=OptLevel(args.level), policy=args.policy,
                     sampler=sampler, pe=args.pe,
                     kv_block_size=args.kv_block,
                     kv_pool_blocks=args.kv_pool_blocks,
                     paged_attn=args.paged_attn,
                     prefill_chunk=args.prefill_chunk,
                     draft_model=args.draft_model, draft_k=args.draft_k,
                     kv_dtype=args.kv_dtype)
    for r in out["finished"][:4]:
        print(f"[serve] req {r.rid}: prompt[{r.n_prompt}] -> "
              f"{r.generated}")
    attn = f"/{out['paged_attn']}" if out["paged_attn"] else ""
    if out.get("state_impl", "none") != "none":
        attn += f"/state={out['state_impl']}"
    if out.get("kv_dtype", "bf16") != "bf16":
        attn += f"/kv={out['kv_dtype']}"
    if args.prefill_chunk:
        attn += f"/prefill={out['prefill_mode']}({args.prefill_chunk})"
    if out["spec_mode"] == "draft":
        st = out["spec"]
        attn += (f"/spec=K{st['draft_k']}({args.draft_model},"
                 f"accept={st['accept_rate']:.2f},"
                 f"eff={st['eff_tok_per_step']:.2f})")
    elif args.level >= 7:
        attn += "/spec=off"
    print(f"[serve] O{args.level}/{args.policy} "
          f"[{out['layout']}{attn} x {out['devices']} device(s)]: "
          f"{len(out['finished'])} requests, {out['tokens']} new "
          f"tokens in {out['ticks']} ticks / {out['wall_s']:.2f}s "
          f"({out['tok_per_s']:.1f} tok/s batched)")
    if out.get("degrade_reason"):
        print(f"[serve] degraded: {out['degrade_reason']}")
    if args.expect_devices and out["devices"] != args.expect_devices:
        raise SystemExit(
            f"placement landed on {out['devices']} device(s), expected "
            f"{args.expect_devices} (XLA_FLAGS / --pe / batch "
            f"divisibility?)")


if __name__ == "__main__":
    main()
