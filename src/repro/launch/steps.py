"""Step construction: train_step / serve_step with full sharding plumbing.

Shared by the real drivers (``launch/train.py``, ``launch/serve.py``), the
multi-pod dry-run (``launch/dryrun.py``) and the tests.  Everything here is
mesh-parametric: pass any mesh (production 16x16 / 2x16x16 or a tiny host
mesh) and the same code lowers.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import get_model, input_specs, decode_input_specs
from repro.models.layers import param_shapes
from repro.optim import adamw
from repro.parallel.sharding import Sharder, make_rules, use_sharder


@dataclasses.dataclass
class TrainArtifacts:
    cfg: ArchConfig
    model: Any
    sharder: Sharder
    step_fn: Any                 # (params, opt, batch) -> (params, opt, metrics)
    param_specs: Any             # ShapeDtypeStruct tree
    opt_specs: Any
    batch_specs: Any
    in_shardings: tuple
    out_shardings: tuple
    donate: tuple = (0, 1)

    def jit(self):
        return jax.jit(self.step_fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate)

    def lower(self):
        with self.sharder.mesh, use_sharder(self.sharder):
            return self.jit().lower(self.param_specs, self.opt_specs,
                                    self.batch_specs)


@dataclasses.dataclass
class ServeArtifacts:
    cfg: ArchConfig
    model: Any
    sharder: Sharder
    step_fn: Any                 # (params, cache, tokens, pos) -> (tok, cache)
    param_specs: Any
    cache_specs: Any
    token_spec: Any
    pos_spec: Any
    in_shardings: tuple
    out_shardings: tuple
    donate: tuple = (1,)

    def jit(self):
        return jax.jit(self.step_fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate)

    def lower(self):
        with self.sharder.mesh, use_sharder(self.sharder):
            return self.jit().lower(self.param_specs, self.cache_specs,
                                    self.token_spec, self.pos_spec)


def _batch_axes(specs: dict) -> dict:
    out = {}
    for k, s in specs.items():
        out[k] = ("batch",) + (None,) * (len(s.shape) - 1)
    return out


def _cast_params(cfg: ArchConfig, params):
    """§Perf knob (``cast_params_once``): cast f32 params to the compute
    dtype ONCE per step while still FSDP-sharded, so the implicit
    all-gathers move half the bytes and per-layer ``astype`` casts become
    no-ops.  Gradients flow through the cast, accumulating in f32 (classic
    mixed precision: f32 master weights live in params/optimizer)."""
    if not cfg.cast_params_once:
        return params
    ct = jnp.dtype(cfg.compute_dtype)

    def cast(x):
        return x.astype(ct) if x.dtype == jnp.float32 else x

    return jax.tree.map(cast, params)


def make_sharder(cfg: ArchConfig, mesh) -> Sharder:
    return Sharder(mesh, make_rules(mesh, fsdp_over_pod=cfg.fsdp_over_pod))


def build_train(cfg: ArchConfig, shape: ShapeConfig, mesh,
                adamw_cfg: Optional[adamw.AdamWConfig] = None,
                ) -> TrainArtifacts:
    model = get_model(cfg)
    acfg = adamw_cfg or adamw.AdamWConfig()
    sharder = make_sharder(cfg, mesh)

    p_specs = param_shapes(model.defs(), jnp.dtype(cfg.param_dtype))
    o_specs = adamw.state_spec(acfg, p_specs)
    b_specs = input_specs(cfg, shape)

    axes = model.axes()
    p_sh = sharder.tree_shardings(axes, p_specs)
    o_sh = sharder.tree_shardings(adamw.state_axes(axes), o_specs)
    b_sh = sharder.tree_shardings(_batch_axes(b_specs), b_specs)
    scalar = NamedSharding(mesh, P())
    m_sh = {"loss": scalar, "grad_norm": scalar, "lr": scalar}

    def loss_fn(p, b):
        return model.loss(_cast_params(cfg, p), b)

    def train_step(params, opt, batch):
        M = cfg.microbatch
        if M and M > 1:
            # Gradient accumulation: scan over M microbatches, f32 grad
            # accumulator.  Bounds activation memory to one microbatch
            # (the explicit-data-caching step applied to the batch dim).
            def split(x):
                xs = x.reshape((M, x.shape[0] // M) + x.shape[1:])
                return sharder.constrain(
                    xs, None, "batch", *((None,) * (x.ndim - 1)))

            micro = jax.tree.map(split, batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def mb(carry, b):
                acc, loss_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, b)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / M, acc, grads)
                return (acc, loss_acc + loss / M), None

            (grads, loss), _ = jax.lax.scan(mb, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g, p: g.astype(p.dtype),
                                 grads, params)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_p, new_opt, metrics = adamw.update(acfg, grads, opt, params)
        metrics["loss"] = loss
        return new_p, new_opt, metrics

    return TrainArtifacts(
        cfg=cfg, model=model, sharder=sharder, step_fn=train_step,
        param_specs=p_specs, opt_specs=o_specs, batch_specs=b_specs,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, m_sh),
    )


def build_serve(cfg: ArchConfig, shape: ShapeConfig, mesh) -> ServeArtifacts:
    model = get_model(cfg)
    sharder = make_sharder(cfg, mesh)

    p_specs = param_shapes(model.defs(), jnp.dtype(cfg.param_dtype))
    c_specs, t_spec, pos_spec = decode_input_specs(cfg, shape)

    p_sh = sharder.tree_shardings(model.axes(), p_specs)
    c_sh = sharder.tree_shardings(model.cache_axes(), c_specs)
    t_sh = sharder.named(("batch", None), t_spec.shape)
    pos_sh = sharder.named(("batch",), pos_spec.shape)

    def serve_step(params, cache, tokens, positions):
        logits, new_cache = model.decode_step(_cast_params(cfg, params),
                                              cache, tokens, positions)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, new_cache

    return ServeArtifacts(
        cfg=cfg, model=model, sharder=sharder, step_fn=serve_step,
        param_specs=p_specs, cache_specs=c_specs, token_spec=t_spec,
        pos_spec=pos_spec,
        in_shardings=(p_sh, c_sh, t_sh, pos_sh),
        out_shardings=(t_sh, c_sh),
    )


def build_prefill(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """Prefill = forward pass over the full prompt (loss-free), the
    inference-prefill lowering for the ``prefill_32k`` cells."""
    model = get_model(cfg)
    sharder = make_sharder(cfg, mesh)
    p_specs = param_shapes(model.defs(), jnp.dtype(cfg.param_dtype))
    b_specs = input_specs(cfg, shape)
    p_sh = sharder.tree_shardings(model.axes(), p_specs)
    b_sh = sharder.tree_shardings(_batch_axes(b_specs), b_specs)

    def prefill_step(params, batch):
        # Forward only; reuse the loss graph without the backward pass.
        return model.loss(_cast_params(cfg, params), batch)

    art = TrainArtifacts(
        cfg=cfg, model=model, sharder=sharder, step_fn=prefill_step,
        param_specs=p_specs, opt_specs=None, batch_specs=b_specs,
        in_shardings=(p_sh, b_sh),
        out_shardings=NamedSharding(mesh, P()),
        donate=(),
    )

    def lower():
        with sharder.mesh, use_sharder(sharder):
            return jax.jit(prefill_step, in_shardings=art.in_shardings,
                           out_shardings=art.out_shardings).lower(
                               p_specs, b_specs)

    art.lower = lower
    return art
