"""Async traffic front end: serve REQUESTS, not tick loops.

Everything below ``launch/`` so far drives :class:`~repro.serving.engine.
DecodeEngine` from a closed synchronous loop — build a fixed request
list, call ``run()``, read ``finished``.  That shape cannot absorb
open-loop traffic (arrivals do not wait for the batch to drain), cannot
stream tokens back per request, and measures nothing a serving SLO is
written against.  :class:`AsyncServer` closes the gap:

  * ``submit()`` enqueues a request into the ENGINE's scheduler queue
    (the scheduler IS the ingress — admission order equals submission
    order, exactly like the synchronous path) and returns a
    :class:`RequestHandle` carrying a per-token ``stream``
    (``asyncio.Queue``), a ``done`` future resolving to the finished
    :class:`~repro.serving.scheduler.Request`, and an optional
    synchronous ``on_token`` callback.
  * A single background task ticks ``engine.step()`` continuously,
    yielding to the event loop between ticks so arrival coroutines
    interleave with decoding; when idle it parks on an event instead of
    spinning.  Everything runs on ONE thread — the engine's host
    bookkeeping is not thread-safe and does not need to be.
  * Greedy tokens are BIT-IDENTICAL to the synchronous
    ``submit()``/``run()`` path for the same admission order: the server
    never reorders the scheduler, it only publishes what the tick loop
    already produced.

The module also owns the OPEN-LOOP measurement vocabulary the traffic
harness and the autotuner's traffic mode share (``benchmarks`` must not
be imported from ``src``):

  * :func:`make_trace` — deterministic Poisson / bursty arrival traces.
  * :func:`replay_trace` / :func:`serve_trace` — fire a trace at a
    server open-loop (arrivals never wait for completions) and collect
    per-request latency records.
  * :func:`latency_metrics` — p50/p99 TTFT, per-token latency (TPOT),
    and goodput-under-SLO: finished requests that met BOTH the TTFT and
    per-token SLOs, per second of replay — the deployment objective the
    ROADMAP's "millions of users" claim is actually written against.
"""

from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.serving.engine import DecodeEngine
from repro.serving.scheduler import Request

_END = object()          # stream sentinel: request finished


@dataclasses.dataclass
class TokenEvent:
    """One streamed token: its request, value, index in the completion,
    and the publish timestamp (``time.monotonic``)."""
    rid: int
    token: int
    index: int
    t_s: float


class RequestHandle:
    """The caller's view of one in-flight request."""

    def __init__(self, request: Request, loop: asyncio.AbstractEventLoop,
                 on_token: Optional[Callable] = None):
        self.request = request
        self.stream: asyncio.Queue = asyncio.Queue()
        self.done: asyncio.Future = loop.create_future()
        self.on_token = on_token
        self._published = 0

    @property
    def rid(self) -> int:
        return self.request.rid

    async def tokens(self):
        """Async-iterate the streamed :class:`TokenEvent`\\ s until the
        request finishes."""
        while True:
            ev = await self.stream.get()
            if ev is _END:
                return
            yield ev


class AsyncServer:
    """Open-loop front end over a :class:`DecodeEngine`.

    One background task owns the tick loop; ``submit()`` may be called
    from any coroutine on the same event loop.  Use as an async context
    manager, or ``start()``/``stop()`` explicitly::

        async with AsyncServer(engine) as server:
            h = server.submit([1, 2, 3], max_new_tokens=8)
            async for ev in h.tokens():
                ...
            req = await h.done
    """

    def __init__(self, engine: DecodeEngine, *, max_ticks: int = 0):
        self.engine = engine
        # 0 = unbounded; a positive budget bounds a stuck server the way
        # DecodeEngine.run's budget bounds a stuck drain.
        self.max_ticks = int(max_ticks)
        self.ticks = 0
        self._handles: dict = {}        # rid -> RequestHandle
        self._task: Optional[asyncio.Task] = None
        self._wake: Optional[asyncio.Event] = None
        self._stopping = False

    # -- lifecycle ----------------------------------------------------------
    async def start(self) -> "AsyncServer":
        if self._task is not None:
            raise RuntimeError("server already started")
        self._wake = asyncio.Event()
        self._task = asyncio.get_running_loop().create_task(self._loop())
        return self

    async def stop(self) -> None:
        """Stop ticking.  Outstanding handles get their futures failed —
        a stopped server never resolves silently."""
        self._stopping = True
        if self._wake is not None:
            self._wake.set()
        if self._task is not None:
            await self._task
            self._task = None
        for h in list(self._handles.values()):
            if not h.done.done():
                h.done.set_exception(
                    RuntimeError(f"server stopped with request "
                                 f"{h.rid} unfinished"))
                h.stream.put_nowait(_END)
        self._handles.clear()

    async def __aenter__(self) -> "AsyncServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- submission ---------------------------------------------------------
    def submit(self, prompt, *, max_new_tokens: int = 16,
               eos_id: Optional[int] = None,
               deadline_s: Optional[float] = None,
               on_token: Optional[Callable] = None) -> RequestHandle:
        """Enqueue a request; returns its :class:`RequestHandle`.

        Raises ``ValueError`` exactly like the synchronous
        ``engine.submit`` (static max_seq validation plus the paged
        pool's never-fits submit gate)."""
        if self._task is None or self._stopping:
            raise RuntimeError("server is not running")
        req = Request(prompt=list(prompt), max_new_tokens=max_new_tokens,
                      eos_id=eos_id, deadline_s=deadline_s)
        self.engine.submit(req)         # validates; stamps arrival_s
        handle = RequestHandle(req, asyncio.get_running_loop(),
                               on_token=on_token)
        if req.done:
            # Degenerate (max_new_tokens <= 0): retired at submit with an
            # empty completion — resolve immediately, nothing will tick.
            handle.done.set_result(req)
            handle.stream.put_nowait(_END)
            return handle
        self._handles[req.rid] = handle
        self._wake.set()
        return handle

    async def drain(self) -> None:
        """Wait until every submitted request has finished."""
        pending = [h.done for h in self._handles.values()]
        if pending:
            await asyncio.gather(*pending)

    # -- the tick loop ------------------------------------------------------
    def _publish(self) -> None:
        """Diff each tracked request's ``generated`` against what was
        already streamed and publish the new tokens; resolve finished
        requests.  Reading ``generated`` (not device buffers) keeps this
        correct under the O4 overlapped engine, whose finalize trails
        the dispatch frontier — a token is published the tick its
        bookkeeping lands, bit-identical to the sync path."""
        now = time.monotonic()
        for rid in list(self._handles):
            h = self._handles[rid]
            r = h.request
            gen = r.generated
            while h._published < len(gen):
                ev = TokenEvent(rid=rid, token=gen[h._published],
                                index=h._published, t_s=now)
                h._published += 1
                h.stream.put_nowait(ev)
                if h.on_token is not None:
                    h.on_token(ev)
            if r.done:
                del self._handles[rid]
                h.stream.put_nowait(_END)
                if not h.done.done():
                    h.done.set_result(r)

    async def _loop(self) -> None:
        engine = self.engine
        while not self._stopping:
            if self.max_ticks and self.ticks >= self.max_ticks:
                # Mirror DecodeEngine.run's budget contract: mark the
                # survivors truncated and FAIL their futures — a waiter
                # blocked on `await handle.done` must not hang forever.
                for h in list(self._handles.values()):
                    h.request.truncated = True
                    if not h.done.done():
                        h.done.set_exception(RuntimeError(
                            f"server tick budget ({self.max_ticks}) "
                            f"exhausted with request {h.rid} unfinished"))
                    h.stream.put_nowait(_END)
                self._handles.clear()
                break
            progressed = engine.step()
            if progressed:
                self.ticks += 1
            self._publish()
            if progressed or engine.queue:
                # Yield WITHOUT sleeping: arrival coroutines scheduled
                # for "now" run between ticks, the engine never idles.
                await asyncio.sleep(0)
            else:
                # Idle: park until the next submission (or stop()).
                self._wake.clear()
                await self._wake.wait()


# ---------------------------------------------------------------------------
# Open-loop traces + replay + metrics (shared by benchmarks + autotune).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TraceItem:
    """One arrival in an open-loop trace: fire at ``at_s`` (seconds from
    replay start) regardless of what the server has finished."""
    at_s: float
    prompt: list
    max_new_tokens: int
    eos_id: Optional[int] = None
    deadline_s: Optional[float] = None      # relative to arrival


def make_trace(*, n_requests: int, rate: float, seed: int = 0,
               pattern: str = "poisson", vocab: int = 128,
               prompt_len=(2, 12), max_new=(4, 16),
               burst: int = 8, burst_idle_factor: float = 4.0,
               deadline_slack_s: Optional[float] = None) -> list:
    """Deterministic open-loop arrival trace at ``rate`` requests/s.

    ``poisson``: i.i.d. exponential inter-arrivals (the classic open-loop
    model).  ``bursty``: arrivals clump in bursts of ~``burst`` (geometric
    size) separated by idle gaps ``burst_idle_factor`` x longer than the
    intra-burst spacing, mean rate preserved — the pattern that exposes
    admission-policy starvation (a burst of shorts convoys a long).
    ``deadline_slack_s`` attaches per-request completion deadlines
    (arrival + slack) for the "deadline" policy.
    """
    if pattern not in ("poisson", "bursty"):
        raise ValueError(f"unknown trace pattern {pattern!r}")
    if rate <= 0:
        raise ValueError(f"rate must be > 0 (got {rate})")
    rng = np.random.default_rng(seed)
    mean_gap = 1.0 / rate
    if pattern == "poisson":
        gaps = rng.exponential(mean_gap, n_requests)
    else:
        # Burst sizes ~ Geometric(1/burst); short gaps inside a burst,
        # one long gap between bursts, scaled so the MEAN gap (and thus
        # the offered rate) matches the poisson trace.
        short = mean_gap / burst_idle_factor
        gaps, left = [], 0
        while len(gaps) < n_requests:
            if left == 0:
                left = int(rng.geometric(1.0 / burst))
                n_long = max(1, n_requests // burst)
                long_total = mean_gap * n_requests - short * (
                    n_requests - n_long)
                gaps.append(rng.exponential(
                    max(long_total / n_long, short)))
            else:
                gaps.append(short)
            left -= 1
        gaps = np.asarray(gaps[:n_requests])
    at = np.cumsum(gaps)
    items = []
    for k in range(n_requests):
        plen = int(rng.integers(*prompt_len))
        items.append(TraceItem(
            at_s=float(at[k]),
            prompt=rng.integers(1, vocab, plen).tolist(),
            max_new_tokens=int(rng.integers(*max_new)),
            deadline_s=deadline_slack_s))
    return items


async def replay_trace(server: AsyncServer, trace: list, *,
                       time_scale: float = 1.0) -> list:
    """Fire ``trace`` at ``server`` OPEN-LOOP — each arrival waits for
    its timestamp (scaled by ``time_scale``), never for completions —
    then await every request and return the finished ``Request``s (in
    submission order).  ``time_scale < 1`` compresses the trace."""
    t0 = time.monotonic()
    handles = []
    for item in trace:
        delay = item.at_s * time_scale - (time.monotonic() - t0)
        if delay > 0:
            await asyncio.sleep(delay)
        deadline = (time.monotonic() + item.deadline_s
                    if item.deadline_s is not None else None)
        handles.append(server.submit(
            item.prompt, max_new_tokens=item.max_new_tokens,
            eos_id=item.eos_id, deadline_s=deadline))
    return list(await asyncio.gather(*(h.done for h in handles)))


def serve_trace(engine: DecodeEngine, trace: list, *,
                time_scale: float = 1.0, max_ticks: int = 0) -> dict:
    """Synchronous convenience: spin up an :class:`AsyncServer` on a
    fresh event loop, replay ``trace``, tear down.  Returns
    ``{"finished": [...], "makespan_s": float, "ticks": int}``."""

    async def _run():
        t0 = time.monotonic()
        async with AsyncServer(engine, max_ticks=max_ticks) as server:
            finished = await replay_trace(server, trace,
                                          time_scale=time_scale)
            return {"finished": finished,
                    "makespan_s": time.monotonic() - t0,
                    "ticks": server.ticks}

    return asyncio.run(_run())


def _pct(xs: list, q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def latency_metrics(finished: list, *, makespan_s: float,
                    ttft_slo_s: float = 0.5,
                    tpot_slo_s: float = 0.1) -> dict:
    """Open-loop serving metrics over finished ``Request``s.

    TTFT = first token - arrival (queueing + prefill); TPOT = mean
    per-token latency after the first.  ``goodput_rps`` counts only
    requests meeting BOTH SLOs (and, when a request carries a
    ``deadline_s``, finishing by it), per second of replay — the number
    a capacity plan is written against, where raw throughput rewards a
    server that strands its tail.
    """
    ttfts = [r.ttft_s for r in finished if r.ttft_s is not None]
    tpots = [r.tpot_s for r in finished if r.tpot_s is not None]
    tokens = sum(len(r.generated) for r in finished)

    def _good(r) -> bool:
        if r.truncated or r.ttft_s is None:
            return False
        if r.ttft_s > ttft_slo_s:
            return False
        if r.tpot_s is not None and r.tpot_s > tpot_slo_s:
            return False
        if r.deadline_s is not None and r.finish_s is not None:
            return r.finish_s <= r.deadline_s
        return True

    good = sum(1 for r in finished if _good(r))
    span = max(makespan_s, 1e-9)
    return {
        "requests": len(finished),
        "tokens": tokens,
        "makespan_s": makespan_s,
        "throughput_rps": len(finished) / span,
        "tok_per_s": tokens / span,
        "ttft_p50_s": _pct(ttfts, 50),
        "ttft_p99_s": _pct(ttfts, 99),
        "tpot_p50_s": _pct(tpots, 50),
        "tpot_p99_s": _pct(tpots, 99),
        "slo_ttft_s": ttft_slo_s,
        "slo_tpot_s": tpot_slo_s,
        "good_requests": good,
        "goodput_rps": good / span,
        "goodput_frac": good / len(finished) if finished else 0.0,
    }
