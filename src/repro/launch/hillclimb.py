import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Perf hillclimbing harness (EXPERIMENTS.md §Perf).

Lowers one (arch x shape) cell on the production mesh with explicit config
overrides, extracts the three roofline terms (via the unrolled cost twin),
and prints HLO forensics (top collectives, op census, remat duplication)
so each hypothesis -> change -> measure cycle is one command:

  PYTHONPATH=src python -m repro.launch.hillclimb --arch qwen3-8b \
      --shape train_4k --tag baseline
  PYTHONPATH=src python -m repro.launch.hillclimb --arch qwen3-8b \
      --shape train_4k --tag bf16params --set param_dtype=bfloat16

Results append to experiments/perf/<arch>__<shape>.jsonl.
"""

import argparse
import dataclasses
import json
import time

import jax

from repro.autotune.measurement import roofline_terms
from repro.configs import SHAPES, get_config, model_flops
from repro.core import hlo_stats
from repro.core.analyzer import extract_cost
from repro.core.hw import TPU_V5E
from repro.launch import dryrun, steps
from repro.launch.mesh import make_production_mesh, mesh_chips

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "perf")


def parse_value(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("true", "True"):
        return True
    if v in ("false", "False"):
        return False
    return v


def apply_overrides(cfg, overrides: dict):
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def measure(arch: str, shape_name: str, overrides: dict, *,
            multi_pod: bool = False, forensics: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    cfg = apply_overrides(get_config(arch), overrides)
    shape = SHAPES[shape_name]

    t0 = time.time()
    art = dryrun._build(cfg, shape, mesh)
    lowered = art.lower()
    compiled = lowered.compile()
    mem = compiled.memory_analysis()

    # twin terms (true trip counts), derived via the shared measurement API
    tw = dryrun.cost_twin(cfg, shape, mesh)
    coll_total = sum(tw["coll"].values())
    rec = {
        "arch": arch, "shape": shape_name, "overrides": overrides,
        "chips": chips,
        "flops_per_device": tw["flops"],
        "bytes_per_device": tw["bytes"],
        "fused_bytes_per_device": tw["fused_bytes"],
        "collective_bytes_per_device": coll_total,
        "collective_breakdown": tw["coll"],
        "model_flops": model_flops(cfg, shape),
        "peak_temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "wall_s": round(time.time() - t0, 1),
    }
    rec.update(roofline_terms(
        tw["flops"], tw["bytes"], coll_total,
        chips=chips, model_flops=rec["model_flops"],
        fused_bytes_per_device=tw["fused_bytes"], spec=TPU_V5E))

    if forensics:
        # forensics on the 2-unit unrolled twin (true per-layer picture)
        c1, c2, K = dryrun.twin_cfgs(cfg)
        art2 = dryrun._build(c2, shape, mesh)
        txt = art2.lower().compile().as_text()
        stats = hlo_stats.parse_hlo(txt)
        rec["forensics"] = {
            "collectives_2unit": {
                k: {"bytes": v.operand_bytes, "count": v.count}
                for k, v in stats.collectives.items()},
            "top_collectives_2unit": [
                {"op": op, "bytes": b, "shape": sh}
                for op, b, sh in hlo_stats.top_collectives(txt, 12)],
            "bytes_by_opcode_2unit": [
                {"op": op, "GiB": round(b / 2**30, 2), "count": c}
                for op, b, c in hlo_stats.bytes_by_opcode(txt, 12)],
            "heavy_ops_2unit": hlo_stats.remat_duplication(stats.op_census),
            "reshape_transpose_2unit": stats.reshape_transpose_count,
            "instructions_2unit": stats.instruction_count,
        }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", action="append", default=[],
                    metavar="key=value")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-forensics", action="store_true")
    args = ap.parse_args()

    overrides = {}
    for kv in getattr(args, "set"):
        k, v = kv.split("=", 1)
        overrides[k] = parse_value(v)

    rec = measure(args.arch, args.shape, overrides,
                  multi_pod=args.multi_pod,
                  forensics=not args.no_forensics)
    rec["tag"] = args.tag

    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{args.arch}__{args.shape}.jsonl")
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")

    print(json.dumps({k: v for k, v in rec.items()
                      if k != "forensics"}, indent=1))
    if "forensics" in rec:
        print("--- forensics (2-unit twin) ---")
        print(json.dumps(rec["forensics"], indent=1))


if __name__ == "__main__":
    main()
