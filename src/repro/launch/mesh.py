"""Production mesh construction (functions only — importing this module
never touches jax device state)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (host) devices exist — tests/examples."""
    if pod:
        shape, axes = (pod, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
