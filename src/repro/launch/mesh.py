"""Production mesh construction (functions only — importing this module
never touches jax device state)."""

from __future__ import annotations

import inspect

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """Version-compat shim: ``jax.sharding.AxisType`` + the ``axis_types``
    kwarg of ``jax.make_mesh`` only exist in newer JAX.  On older installs
    (e.g. 0.4.x) every mesh axis is implicitly Auto, so omitting the kwarg
    is semantically identical."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (host) devices exist — tests/examples."""
    if pod:
        shape, axes = (pod, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
