import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real step function (train_step for train
shapes, prefill/serve steps for inference shapes) against ShapeDtypeStruct
stand-ins on the production mesh, compiles it, prints memory/cost analysis,
parses the collective schedule out of the optimized HLO, and writes one JSON
record under ``experiments/dryrun/<mesh>/``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both [--force]
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import (ARCH_NAMES, applicable_shapes, get_config,
                           model_flops, SHAPES)
from repro.core.analyzer import extract_cost, roofline_from_compiled
from repro.core import hlo_stats
from repro.launch import steps
from repro.launch.mesh import make_production_mesh, mesh_chips

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def cell_path(mesh_name: str, arch: str, shape: str, out_dir: str = None) -> str:
    d = os.path.join(out_dir or OUT_DIR, mesh_name)
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch}__{shape}.json")


def _build(cfg, shape, mesh):
    if shape.kind == "train":
        return steps.build_train(cfg, shape, mesh)
    if shape.kind == "prefill":
        return steps.build_prefill(cfg, shape, mesh)
    return steps.build_serve(cfg, shape, mesh)


# ---------------------------------------------------------------------------
# Cost twin: XLA's cost_analysis counts a while-loop body ONCE regardless of
# trip count (verified in this container; see models/loops.py), so the
# scanned production program under-reports flops/bytes/collectives.  We
# therefore lower an *unrolled* twin at 1 and 2 layer-units and extrapolate
# linearly in unit count — exact for homogeneous stacks.  The scanned
# lowering remains the artifact that proves compilability + memory.
# ---------------------------------------------------------------------------

def twin_cfgs(cfg):
    """(cfg_1unit, cfg_2unit, K_units).  A 'unit' is one decoder layer;
    for zamba2 one group (6 mamba + shared app); for whisper one
    enc+dec layer pair."""
    cfg = dataclasses.replace(cfg, microbatch=0)  # pure rescheduling
    if cfg.family == "hybrid":
        mk = lambda g: dataclasses.replace(
            cfg, n_layers=g * cfg.attn_every, unroll_layers=True)
        return mk(1), mk(2), cfg.n_layers // cfg.attn_every
    if cfg.family == "audio":
        mk = lambda L: dataclasses.replace(
            cfg, n_layers=L, n_enc_layers=L, unroll_layers=True)
        return mk(1), mk(2), cfg.n_layers
    mk = lambda L: dataclasses.replace(cfg, n_layers=L, unroll_layers=True)
    return mk(1), mk(2), cfg.n_layers


def _twin_costs(cfg, shape, mesh):
    art = _build(cfg, shape, mesh)
    compiled = art.lower().compile()
    cost = extract_cost(compiled)
    txt = compiled.as_text()
    stats = hlo_stats.parse_hlo(txt)
    return {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "fused_bytes": float(hlo_stats.fused_bytes(txt)),
        "coll": {k: float(v.operand_bytes)
                 for k, v in stats.collectives.items()},
    }


def cost_twin(cfg, shape, mesh) -> dict:
    c1_cfg, c2_cfg, K = twin_cfgs(cfg)
    c1 = _twin_costs(c1_cfg, shape, mesh)
    c2 = _twin_costs(c2_cfg, shape, mesh)

    def extrap(a, b):
        return max(0.0, a + (K - 1) * (b - a))

    keys = set(c1["coll"]) | set(c2["coll"])
    coll = {k: extrap(c1["coll"].get(k, 0.0), c2["coll"].get(k, 0.0))
            for k in keys}
    return {
        "flops": extrap(c1["flops"], c2["flops"]),
        "bytes": extrap(c1["bytes"], c2["bytes"]),
        "fused_bytes": extrap(c1["fused_bytes"], c2["fused_bytes"]),
        "coll": coll,
        "units": K,
    }


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             *, verbose: bool = True, twin: bool = True,
             overrides: dict = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    chips = mesh_chips(mesh)
    t0 = time.time()

    art = _build(cfg, shape, mesh)
    lowered = art.lower()
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if verbose:
        print(f"  memory_analysis: {mem}")
        ck = {k: cost.get(k) for k in ("flops", "bytes accessed")} \
            if hasattr(cost, "get") else cost
        print(f"  cost_analysis (scanned; while bodies count once): {ck}")

    rf = roofline_from_compiled(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=chips, model_flops=model_flops(cfg, shape),
    )
    rec = rf.to_dict()
    rec["scanned_flops_per_device"] = rec["flops_per_device"]
    rec["scanned_bytes_per_device"] = rec["bytes_per_device"]

    if twin:
        t1 = time.time()
        tw = cost_twin(cfg, shape, mesh)
        from repro.autotune.measurement import roofline_terms
        # Floor by the scanned program (while bodies count once, so the
        # scanned values are a strict lower bound — guards tiny-decode
        # cells where the 1->2-unit delta is within CPU fusion noise).
        tw["flops"] = max(tw["flops"], rec["scanned_flops_per_device"])
        tw["bytes"] = max(tw["bytes"], rec["scanned_bytes_per_device"])
        rec.update({
            "flops_per_device": tw["flops"],
            "bytes_per_device": tw["bytes"],
            "fused_bytes_per_device": tw["fused_bytes"],
            "collective_bytes_per_device": sum(tw["coll"].values()),
            "collective_breakdown": tw["coll"],
            "twin_units": tw["units"],
            "twin_s": round(time.time() - t1, 1),
        })
        rec.update(roofline_terms(
            tw["flops"], tw["bytes"], sum(tw["coll"].values()),
            chips=chips, model_flops=rec["model_flops"],
            fused_bytes_per_device=tw["fused_bytes"]))

    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        "sharding_degradations": sorted(
            {f"{l}:{d}:{m}->{p}" for (l, d, m, p)
             in art.sharder.degradations}),
    })
    return rec


def run(archs, shapes, meshes, *, force=False, overrides=None,
        out_dir=None):
    results = {}
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi_pod"))
        for arch in archs:
            cfg = get_config(arch)
            app = {s.name for s in applicable_shapes(cfg)}
            for shape_name in shapes:
                path = cell_path(mesh_name, arch, shape_name, out_dir)
                key = f"{mesh_name}/{arch}/{shape_name}"
                if shape_name not in app:
                    rec = {"status": "skipped",
                           "reason": "full-attention arch: long_500k "
                                     "needs sub-quadratic attention "
                                     "(DESIGN.md §Arch-applicability)"}
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=2)
                    print(f"SKIP {key}")
                    continue
                if os.path.exists(path) and not force:
                    with open(path) as f:
                        old = json.load(f)
                    if old.get("status") == "ok":
                        print(f"CACHED {key}")
                        results[key] = old
                        continue
                print(f"RUN  {key} ...", flush=True)
                try:
                    # Roofline table is single-pod (per the brief); the
                    # multi-pod pass proves the `pod` axis lowers/compiles.
                    rec = run_cell(arch, shape_name, mesh, mesh_name,
                                   twin=(mesh_name == "single_pod"),
                                   overrides=overrides)
                    print(f"OK   {key}: dominant={rec['dominant']} "
                          f"step_time={rec['step_time_s']:.4f}s "
                          f"compile={rec['compile_s']}s", flush=True)
                except Exception as e:  # noqa: BLE001 - report, keep going
                    rec = {"status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    print(f"FAIL {key}: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                results[key] = rec
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single_pod", "multi_pod", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    metavar="key=value",
                    help="ArchConfig overrides applied to every cell")
    ap.add_argument("--out", default=None,
                    help="alternate output dir (e.g. dryrun_optimized)")
    args = ap.parse_args()

    overrides = {}
    for kv in getattr(args, "set"):
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                pass
        if v in ("true", "True"):
            v = True
        elif v in ("false", "False"):
            v = False
        overrides[k] = v

    assert jax.device_count() == 512, (
        f"dry-run needs 512 host devices, got {jax.device_count()} — "
        "XLA_FLAGS must be set before any jax import")

    archs = list(ARCH_NAMES) if args.arch == "all" else args.arch.split(",")
    shapes = (list(SHAPES) if args.shape == "all"
              else args.shape.split(","))
    meshes = (["single_pod", "multi_pod"] if args.mesh == "both"
              else [args.mesh])
    out_dir = (os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", args.out) if args.out else None)
    res = run(archs, shapes, meshes, force=args.force, overrides=overrides,
              out_dir=out_dir)
    n_ok = sum(1 for r in res.values() if r.get("status") == "ok")
    print(f"\n{n_ok}/{len(res)} cells OK")


if __name__ == "__main__":
    main()
