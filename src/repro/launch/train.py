"""End-to-end training driver.

Wires every subsystem: config -> mesh -> sharded step (steps.py) ->
deterministic sharded data pipeline (double-buffered prefetch) -> AdamW ->
async sharded checkpointing -> resilient step loop (retry / restore /
straggler accounting).  The same driver runs the production cells (on a
real fleet) and the reduced smoke configs (this container):

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt /tmp/ck

Distributed-optimization knobs (the paper's O4/O5 analogs at the fleet
level): ``--overlap-grad-sync`` applies the cross-pod gradient reduction
one step late (hiding DCN latency under compute), ``--compress-grads``
int8-compresses that reduction with error feedback.  Both change the
update schedule, not the substrate — see runtime/overlap.py.
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config, get_smoke, SHAPES
from repro.configs.base import ShapeConfig
from repro.checkpoint import CheckpointManager
from repro.data.pipeline import make_pipeline
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.runtime import (CompressedReducer, DelayedGradSync,
                           ResilientRunner)
from repro.parallel.sharding import use_sharder


def build_state(art, rng):
    """Init params/opt on the artifact's shardings."""
    with art.sharder.mesh, use_sharder(art.sharder):
        params = jax.jit(
            art.model.init, out_shardings=art.in_shardings[0])(rng)
        opt = jax.jit(
            lambda p: adamw.init_state(adamw.AdamWConfig(), p),
            out_shardings=art.in_shardings[1])(params)
    return params, opt


def train(cfg, shape, *, steps: int = 20, ckpt_dir: str = None,
          ckpt_every: int = 10, seed: int = 0, mesh=None,
          overlap_grad_sync: bool = False, compress_grads: bool = False,
          log_every: int = 1, resume: bool = True) -> dict:
    mesh = mesh if mesh is not None else make_host_mesh()
    art = steps_lib.build_train(cfg, shape, mesh)
    step_jit = None
    with art.sharder.mesh, use_sharder(art.sharder):
        step_jit = art.jit()

    # ---- gradient-sync pipeline knobs (multi-pod only) --------------------
    has_pod = "pod" in mesh.axis_names
    if (overlap_grad_sync or compress_grads) and not has_pod:
        print("[train] no pod axis in mesh; overlap/compression knobs "
              "are no-ops on this mesh")

    rng = jax.random.PRNGKey(seed)
    params, opt = build_state(art, rng)

    mgr = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
    start_step = 0
    if mgr is not None and resume:
        restored = mgr.restore_latest(
            {"params": art.param_specs, "opt": art.opt_specs},
            shardings={"params": art.in_shardings[0],
                       "opt": art.in_shardings[1]})
        if restored is not None:
            tree, start_step, _ = restored
            params, opt = tree["params"], tree["opt"]
            print(f"[train] restored checkpoint at step {start_step}")

    batch_shard = art.in_shardings[2]["tokens"]
    pipe = make_pipeline(cfg, shape, seed=seed, start_step=start_step,
                         sharding=batch_shard
                         if jax.device_count() > 1 else None)

    losses = []

    def one_step(state, step):
        params, opt = state
        batch = pipe.get(step)
        with art.sharder.mesh:
            params, opt, metrics = step_jit(params, opt, batch)
        if step % log_every == 0:
            loss = float(metrics["loss"])
            losses.append((step, loss))
            print(f"[train] step {step:5d} loss {loss:.4f}")
        return params, opt

    def save(state, step):
        if mgr is not None:
            mgr.save_async({"params": state[0], "opt": state[1]}, step=step)

    def restore():
        if mgr is None:
            return None
        mgr.wait()
        restored = mgr.restore_latest(
            {"params": art.param_specs, "opt": art.opt_specs},
            shardings={"params": art.in_shardings[0],
                       "opt": art.in_shardings[1]})
        if restored is None:
            return None
        tree, step, _ = restored
        nonlocal_pipe_reset(step)
        return (tree["params"], tree["opt"]), step

    def nonlocal_pipe_reset(step):
        nonlocal pipe
        pipe.close()
        pipe = make_pipeline(cfg, shape, seed=seed, start_step=step,
                             sharding=batch_shard
                             if jax.device_count() > 1 else None)

    runner = ResilientRunner(one_step, save_fn=save, restore_fn=restore,
                             every=ckpt_every)
    t0 = time.time()
    (params, opt), end_step = runner.run(
        (params, opt), start_step=start_step, n_steps=steps)
    wall = time.time() - t0
    if mgr is not None:
        mgr.save_async({"params": params, "opt": opt}, step=end_step)
        mgr.close()
    pipe.close()
    return {
        "losses": losses,
        "steps": end_step - start_step,
        "wall_s": wall,
        "events": runner.events,
        "params": params,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ARCH_NAMES)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shape (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--overlap-grad-sync", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    if args.smoke:
        cfg = get_smoke(args.arch)
        shape = ShapeConfig("smoke_train", args.seq, args.batch, "train")
    else:
        cfg = get_config(args.arch)
        shape = SHAPES[args.shape]

    out = train(cfg, shape, steps=args.steps, ckpt_dir=args.ckpt,
                ckpt_every=args.ckpt_every, seed=args.seed,
                overlap_grad_sync=args.overlap_grad_sync,
                compress_grads=args.compress_grads)
    first = out["losses"][0][1] if out["losses"] else float("nan")
    last = out["losses"][-1][1] if out["losses"] else float("nan")
    print(f"[train] {out['steps']} steps in {out['wall_s']:.1f}s   "
          f"loss {first:.4f} -> {last:.4f}")


if __name__ == "__main__":
    main()
