"""Closed-loop best-effort autotuner.

Automates the paper's measure -> guideline -> transform -> re-measure cycle
end-to-end (``python -m repro.autotune --kernel gemm``), over either the
analytic MachSuite cost model or the lowered-HLO cost twin of an LM config.
See ``autotune.measurement`` for the shared measurement API and
``autotune.tuner`` for the loop itself.
"""

from repro.autotune.measurement import (
    CostTwinBackend,
    CumulativeLadderState,
    KernelModelBackend,
    LM_STEP_OVERRIDES,
    Measurement,
    ServingBackend,
    roofline_terms,
)
from repro.autotune.trajectory import (
    read_trajectory,
    render_rounds,
    render_summary,
    trajectory_path,
    write_trajectory,
)
from repro.autotune.tuner import TuneResult, TuneRound, autotune

__all__ = [
    "CostTwinBackend",
    "CumulativeLadderState",
    "KernelModelBackend",
    "ServingBackend",
    "LM_STEP_OVERRIDES",
    "Measurement",
    "TuneResult",
    "TuneRound",
    "autotune",
    "read_trajectory",
    "render_rounds",
    "render_summary",
    "roofline_terms",
    "trajectory_path",
    "write_trajectory",
]
