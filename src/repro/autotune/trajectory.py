"""Per-round JSONL trajectories + table rendering for tuning runs.

One line per round, schema = ``TuneRound.to_dict()`` plus run identity
(target / mode / rejected).  The same files are read back by
``benchmarks/autotune_table.py`` to render the paper's Table 4 analog, and
their shape matches the records ``launch/hillclimb.py`` appends, so one set
of plotting/rendering tools serves both harnesses.
"""

from __future__ import annotations

import json
import os

DEFAULT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "experiments", "autotune")


def trajectory_path(target: str, out_dir: str = None) -> str:
    d = out_dir or DEFAULT_DIR
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, target.replace("/", "__") + ".jsonl")


def write_trajectory(result, out_dir: str = None, path: str = None) -> str:
    """Write one run's rounds as JSONL (overwrites prior runs of the same
    target: a trajectory is a complete walk, not an append-only log)."""
    path = path or trajectory_path(result.target, out_dir)
    with open(path, "w") as f:
        for rec in result.to_records():
            f.write(json.dumps(rec) + "\n")
    return path


def read_trajectory(path: str) -> list:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def render_rounds(records: list) -> str:
    """Markdown table of one trajectory (per-round diagnosis + effect)."""
    lines = [
        "| round | state | step applied | dominant | total (s) | "
        "speedup | guideline |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in records:
        m = r["measurement"]
        lines.append(
            f"| {r['round']} | {r['label']} | {r['applied_step'] or '-'} "
            f"| {m['dominant']} | {m['total_s']:.3e} "
            f"| {r['speedup_vs_start']:.1f}x | {r['recommendation']} |")
    return "\n".join(lines)


def render_summary(results: list) -> str:
    """Markdown summary across targets — the paper's Table 4 analog:
    per-kernel chosen steps + modeled speedups + filter verdict."""
    lines = [
        "| target | verdict | rounds | steps chosen (in order) | "
        "final | speedup vs naive |",
        "|---|---|---|---|---|---|",
    ]
    for res in results:
        verdict = "REJECT (comm-bound)" if res.rejected else "accept"
        steps = " -> ".join(res.steps_taken) or "-"
        lines.append(
            f"| {res.target} | {verdict} | {len(res.rounds)} | {steps} "
            f"| {res.final_label} | {res.final_speedup:.1f}x |")
    return "\n".join(lines)
