"""Closed-loop best-effort autotuner (the paper's procedure, unattended).

The paper's human drives three iterations of *measure the breakdown -> read
the guideline -> apply one transformation -> re-measure*.  This module closes
that loop: given any measurement backend (``autotune.measurement``), it walks
the candidate space until the guideline says stop, the comm-bound filter
rejects the kernel, or no candidate improves the modeled time.

Two exploration modes:

  * greedy (default) — exactly the paper: one guideline-recommended step per
    round.  Deterministic, minimal measurements.
  * frontier (AutoDSE-style, opt-in) — each round measures every *minimal*
    candidate move the backend offers and keeps the best, so a mis-ranked
    guideline suggestion cannot trap the search.  For independent-knob
    backends (the LM cost twin) that is every remaining step; for the
    cumulative FPGA ladder the only minimal move is the next level, so the
    frontier degrades to a measured one-level-at-a-time walk that stops as
    soon as a level fails to improve.  The guideline still provides the
    stop condition and the diagnosis that is logged.
"""

from __future__ import annotations

import dataclasses

from repro.autotune.measurement import Measurement
from repro.core.guideline import Recommendation, recommend


@dataclasses.dataclass
class TuneRound:
    """One measure->diagnose(->explore) round."""

    round: int
    label: str                   # state label measured this round ("O2")
    applied_step: str            # step taken to reach this state ("" round 0)
    measurement: Measurement
    recommendation: str
    stop: bool
    speedup_vs_start: float
    candidates: list = dataclasses.field(default_factory=list)
    # frontier mode: [(candidate label, total_s), ...] measured this round

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["measurement"] = self.measurement.to_dict()
        return d


@dataclasses.dataclass
class TuneResult:
    target: str
    mode: str                    # greedy | frontier
    rounds: list                 # [TuneRound]
    rejected: bool               # comm-bound filter fired (paper Table 5)

    @property
    def final(self) -> TuneRound:
        return self.rounds[-1]

    @property
    def final_label(self) -> str:
        return self.final.label

    @property
    def final_total_s(self) -> float:
        return self.final.measurement.total_s

    @property
    def final_speedup(self) -> float:
        return self.final.speedup_vs_start

    @property
    def steps_taken(self) -> list:
        return [r.applied_step for r in self.rounds if r.applied_step]

    def to_records(self) -> list:
        """JSONL-ready per-round records (see ``autotune.trajectory``)."""
        out = []
        for r in self.rounds:
            rec = r.to_dict()
            rec.update(target=self.target, mode=self.mode,
                       rejected=self.rejected)
            out.append(rec)
        return out


def _diagnose(backend, state, m: Measurement) -> Recommendation:
    return recommend(
        applied=backend.applied(state),
        compute_s=m.compute_s,
        memory_s=m.memory_s,
        collective_s=m.collective_s,
        offload_s=m.offload_s,
        baseline_s=m.baseline_s,
        # Surfaces with a non-paper ladder (serving: O6 paged scratchpad)
        # declare their step universe; everything else gets the paper's
        # five and stops at O5 exactly as before.
        steps=getattr(backend, "step_universe", None),
    )


def autotune(backend, *, frontier: bool = False, ladder: bool = False,
             max_rounds: int = 12) -> TuneResult:
    """Run the closed loop to completion.

    Stops when the guideline stops (all steps applied / comm-bound reject),
    when ``max_rounds`` is exhausted, or — in frontier mode — when no
    remaining candidate improves ``total_s`` (AutoDSE's bottleneck-guided
    pruning: exploring past a non-improving frontier is wasted synthesis).

    ``ladder=True`` walks the backend's cumulative ladder one minimal move
    at a time, measuring *every* rung to the top — the paper's full-walk
    mode (Fig. 12's bar groups): the guideline's diagnosis is still logged
    per round, but a non-improving rung does not end the walk, so the
    result is the complete O0..O5 measurement curve, ties included.
    """
    state = backend.initial_state()
    m = backend.measure(state)
    t_start = m.total_s
    rounds = []
    applied_step = ""
    rejected = False

    for i in range(max_rounds):
        rec = _diagnose(backend, state, m)
        round_ = TuneRound(
            round=i,
            label=backend.describe(state),
            applied_step=applied_step,
            measurement=m,
            recommendation=str(rec),
            stop=rec.stop,
            speedup_vs_start=t_start / m.total_s if m.total_s else 0.0,
        )
        rounds.append(round_)
        if rec.stop or rec.step is None:
            rejected = rec.stop and "communication-bound" in rec.reason
            break

        if ladder:
            cands = backend.candidate_steps(state)
            if not cands:
                round_.stop = True
                break
            step = cands[0]
            state = backend.apply(state, step)
            m = backend.measure(state)
        elif frontier:
            cands = []
            for step in backend.candidate_steps(state):
                cand_state = backend.apply(state, step)
                cand_m = backend.measure(cand_state)
                cands.append((step, cand_state, cand_m))
            round_.candidates = [
                (backend.describe(s), cm.total_s) for _, s, cm in cands]
            best = min(cands, key=lambda c: c[2].total_s)
            if best[2].total_s >= m.total_s:
                round_.recommendation += (
                    " | frontier: no candidate improves; stop")
                round_.stop = True
                break
            step, state, m = best
        else:
            step = rec.step
            state = backend.apply(state, step)
            m = backend.measure(state)
        applied_step = step.value
    else:
        # max_rounds exhausted without a stop verdict: log the final state.
        rec = _diagnose(backend, state, m)
        rounds.append(TuneRound(
            round=max_rounds,
            label=backend.describe(state),
            applied_step=applied_step,
            measurement=m,
            recommendation=str(rec),
            stop=True,
            speedup_vs_start=t_start / m.total_s if m.total_s else 0.0,
        ))

    return TuneResult(
        target=backend.name,
        mode=("ladder" if ladder else "frontier" if frontier else "greedy"),
        rounds=rounds,
        rejected=rejected,
    )
