"""One measurement API for every tuning surface in the repo.

The paper's refinement loop is *measure -> diagnose -> transform*; this module
owns the "measure" leg so that the closed-loop tuner (``autotune.tuner``), the
manual hillclimbing harness (``launch.hillclimb``), the dry-run sweep
(``launch.dryrun``) and the modelled refinement walk (``core.refine``) all
speak the same ``Measurement`` record and the same roofline-term arithmetic.

Two backends implement the measure protocol:

  * :class:`KernelModelBackend` — the analytic FPGA cost model
    (``core.costmodel``) for MachSuite kernels.  Instant, jax-free, exact
    reproduction of the paper's platform.
  * :class:`CostTwinBackend` — the lowered-HLO cost twin for LM configs
    (``launch.hillclimb`` / ``launch.dryrun``): lowers + compiles the real
    step function and derives the three roofline terms.  Compile-heavy;
    imported lazily.

A backend exposes::

    initial_state()            -> opaque state (OptLevel / frozenset[Step])
    applied(state)             -> set[Step] already applied
    candidate_steps(state)     -> steps that could be applied next
    apply(state, step)         -> new state with ``step`` applied
    measure(state)             -> Measurement
    describe(state)            -> short human label ("O3", "O{cache,pipe}")
"""

from __future__ import annotations

import dataclasses

from repro.core import costmodel
from repro.core.hw import FPGA_2012, TPU_V5E, TpuSpec
from repro.core.optlevel import LADDER, STEP_ORDER, OptLevel, Step


@dataclasses.dataclass
class Measurement:
    """One (target, configuration) performance measurement.

    ``total_s`` is the modeled wall time of the candidate — the objective the
    tuner minimizes.  The three roofline terms (plus the offload term for the
    comm-bound filter) are what the guideline diagnoses on.
    """

    target: str                  # "gemm" / "qwen3-8b/train_4k"
    label: str                   # "O2" / "{caching,pipelining}"
    compute_s: float
    memory_s: float
    collective_s: float = 0.0
    offload_s: float = 0.0       # host<->device payload time (PCIe analog)
    baseline_s: float = 0.0      # CPU baseline for the comm-bound filter
    total_s: float = 0.0
    breakdown: dict = dataclasses.field(default_factory=dict)
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        d["step_time_s"] = self.step_time_s
        return d


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
    *,
    chips: int = 1,
    model_flops: float = 0.0,
    fused_bytes_per_device: float = None,
    spec: TpuSpec = TPU_V5E,
) -> dict:
    """The repo-wide three-term roofline arithmetic, in one place.

    Per-device work over per-chip peak (see ``core.analyzer`` docstring on
    normalization).  Returns the ``*_s`` terms plus the derived diagnosis
    fields every harness reports (dominant term, step-time bound, roofline
    fraction); when ``fused_bytes_per_device`` is given, the fusion-adjusted
    twin view is included as ``*_fused`` fields.
    """
    rec = {
        "compute_s": flops_per_device / spec.peak_bf16_flops,
        "memory_s": bytes_per_device / spec.hbm_bw,
        "collective_s": collective_bytes_per_device / spec.ici_link_bw,
    }
    terms = {k[:-2]: rec[k] for k in ("compute_s", "memory_s", "collective_s")}
    rec["dominant"] = max(terms, key=terms.get)
    rec["step_time_s"] = max(terms.values())
    useful_s = model_flops / (chips * spec.peak_bf16_flops)
    rec["roofline_fraction"] = (
        useful_s / rec["step_time_s"] if rec["step_time_s"] else 0.0)
    total_flops = flops_per_device * chips
    rec["useful_flops_fraction"] = (
        model_flops / total_flops if total_flops else 0.0)
    if fused_bytes_per_device is not None:
        rec["memory_fused_s"] = fused_bytes_per_device / spec.hbm_bw
        fterms = dict(terms, memory=rec["memory_fused_s"])
        rec["dominant_fused"] = max(fterms, key=fterms.get)
        rec["step_time_fused_s"] = max(fterms.values())
        rec["roofline_fraction_fused"] = (
            useful_s / rec["step_time_fused_s"]
            if rec["step_time_fused_s"] else 0.0)
    return rec


# ---------------------------------------------------------------------------
# Cumulative-ladder state machine, shared by every backend whose steps are
# the paper's O0..O5 levels rather than independent knobs.
# ---------------------------------------------------------------------------


class CumulativeLadderState:
    """State is an :class:`OptLevel`.  The ladder is cumulative, so
    "applying" a step means moving to the lowest level that includes it
    (exactly what the paper's iterations do: Iter #3 lands at O5 having
    passed O4).

    ``top_level`` bounds the walk to the steps that exist on this
    surface: the paper's platforms stop at O5; the serving engine's
    ladder continues to O6 (paged scratchpad).  ``step_universe`` is the
    matching step set, handed to the guideline so it neither recommends a
    rung the surface lacks nor stops before one it has.
    """

    top_level: OptLevel = OptLevel.O5

    @property
    def step_universe(self) -> tuple:
        return LADDER[: int(self.top_level)]

    def initial_state(self) -> OptLevel:
        return OptLevel.O0

    def applied(self, state: OptLevel):
        return set(state.steps)

    def candidate_steps(self, state: OptLevel):
        # The ladder is cumulative, so the only *minimal* move is the next
        # level: offering later steps as candidates would bundle every
        # intervening step into one jump (O0 + scratchpad-reorg == O5) and
        # the frontier would trivially pick the whole ladder in one round.
        # Independent-knob backends (CostTwinBackend) offer the full set.
        if state >= self.top_level:
            return []
        return [LADDER[int(state)]]

    def apply(self, state: OptLevel, step: Step) -> OptLevel:
        return OptLevel(max(int(state), LADDER.index(step) + 1))

    def describe(self, state: OptLevel) -> str:
        return f"O{int(state)}"


# ---------------------------------------------------------------------------
# Backend 1: analytic cost model (MachSuite kernels, the paper's platform).
# ---------------------------------------------------------------------------


class KernelModelBackend(CumulativeLadderState):
    """Measure MachSuite kernels on the paper's analytic FPGA model.

    Instant, jax-free, exact reproduction of the paper's platform —
    including its resource feedback (Table 6): a level whose requested
    (cache, PE, word-width) configuration over-subscribes the BRAM fabric
    is not a dead end; ``costmodel.fit_resources`` shrinks the knobs,
    re-measures the feasible candidates, and the walk continues at the
    fastest one.  The fit is recorded in ``Measurement.meta['resource']``.
    """

    def __init__(self, profile: costmodel.KernelProfile, *, hw=None,
                 cache_bytes: float = 64 * 1024, pe: int = 128):
        self.profile = profile
        self.hw = hw or FPGA_2012
        self.cache_bytes = cache_bytes
        self.pe = pe

    @property
    def name(self) -> str:
        return self.profile.name

    def measure(self, state: OptLevel) -> Measurement:
        fit = costmodel.fit_resources(
            self.profile, state, self.hw,
            cache_bytes=self.cache_bytes, pe=self.pe)
        t = costmodel.kernel_time(
            self.profile, state, self.hw,
            cache_bytes=fit["cache_bytes"], pe=fit["pe"],
            word_bits=fit["word_bits"])
        return Measurement(
            target=self.profile.name,
            label=self.describe(state),
            compute_s=t["compute_s"],
            memory_s=t["dram_s"],
            offload_s=t["pcie_s"],
            baseline_s=self.profile.cpu_time_s,
            total_s=t["system_s"],
            breakdown=dict(t),
            meta={"backend": "kernel_model", "level": int(state),
                  "resource": fit},
        )


# ---------------------------------------------------------------------------
# Backend 2: lowered-HLO cost twin (LM configs, the TPU target).
# ---------------------------------------------------------------------------

# TPU analogs of the paper's five steps, expressed as ArchConfig overrides
# that change the *lowered program* (and therefore the measured twin terms):
#   caching      -> stage f32 params once in compute dtype before the FSDP
#                   gathers (halves gather + per-layer weight-read bytes)
#   pipelining   -> drop backward recompute (remat off): the backward pass
#                   reuses the forward pipeline instead of re-executing it
#   PE dup       -> per-DP-group MoE dispatch (more independent expert PEs;
#                   a no-op override for dense families, and measurement —
#                   not assumption — is what decides whether it helped)
#   double buf   -> overlap the gradient collective with compute; this is a
#                   *schedule* change, so it has no override: it changes the
#                   total-time rule from `max(comp,mem) + coll` to
#                   `max(comp, mem, coll)` (paper §5.1's sum->max move)
#   scratchpad   -> bf16 attention-score traffic (halve the widest on-chip
#                   intermediate, the wide-word packing analog)
LM_STEP_OVERRIDES = {
    Step.DATA_CACHING: {"cast_params_once": True},
    Step.PIPELINING: {"remat": False},
    Step.PE_DUPLICATION: {"moe_local_dispatch": True},
    Step.DOUBLE_BUFFERING: {},
    Step.SCRATCHPAD_REORG: {"scores_dtype": "bfloat16"},
}


class CostTwinBackend:
    """Measure an (arch, shape) cell by lowering + compiling its cost twin.

    State is a ``frozenset[Step]`` — unlike the FPGA ladder the LM analogs
    are independent knobs, so the frontier can apply them in any order.
    Each measurement is a full XLA lower+compile (minutes, not µs); the
    tuner's round count, not this class, is the budget lever.
    """

    def __init__(self, arch: str, shape: str, *, multi_pod: bool = False,
                 base_overrides: dict = None):
        self.arch = arch
        self.shape = shape
        self.multi_pod = multi_pod
        self.base_overrides = dict(base_overrides or {})

    @property
    def name(self) -> str:
        return f"{self.arch}/{self.shape}"

    def initial_state(self) -> frozenset:
        return frozenset()

    def applied(self, state: frozenset):
        return set(state)

    def candidate_steps(self, state: frozenset):
        return [s for s in STEP_ORDER if s not in state]

    def apply(self, state: frozenset, step: Step) -> frozenset:
        return state | {step}

    def describe(self, state: frozenset) -> str:
        if not state:
            return "O0"
        tags = [s.value.split("_")[-1] for s in STEP_ORDER if s in state]
        return "{" + ",".join(tags) + "}"

    def overrides_for(self, state: frozenset) -> dict:
        ov = dict(self.base_overrides)
        for step in STEP_ORDER:
            if step in state:
                ov.update(LM_STEP_OVERRIDES[step])
        return ov

    def measure(self, state: frozenset) -> Measurement:
        from repro.launch import hillclimb  # lazy: jax + XLA_FLAGS

        rec = hillclimb.measure(
            self.arch, self.shape, self.overrides_for(state),
            multi_pod=self.multi_pod, forensics=False)
        overlapped = Step.DOUBLE_BUFFERING in state
        onchip = max(rec["compute_s"], rec["memory_s"])
        total = (max(onchip, rec["collective_s"]) if overlapped
                 else onchip + rec["collective_s"])
        return Measurement(
            target=self.name,
            label=self.describe(state),
            compute_s=rec["compute_s"],
            memory_s=rec["memory_s"],
            collective_s=rec["collective_s"],
            total_s=total,
            breakdown={k: rec[k] for k in (
                "compute_s", "memory_s", "memory_fused_s", "collective_s",
                "step_time_s", "roofline_fraction", "useful_flops_fraction")},
            meta={
                "backend": "cost_twin",
                "overrides": self.overrides_for(state),
                "chips": rec["chips"],
                "overlapped": overlapped,
            },
        )


# ---------------------------------------------------------------------------
# Backend 3: the serving engine itself (measured tokens/sec, not a model).
# ---------------------------------------------------------------------------


def serving_smoke_config(arch: str, vocab: int = 0):
    """The smoke config, optionally with a production-sized vocabulary.

    ``vocab=0`` keeps the reduced smoke vocab — short ticks, so the
    host-side mechanics the upper ladder rungs change (overlap, packed
    resets) are a measurable fraction of a tick.  Passing e.g. 32768
    restores a serving-realistic lm head, which stresses the naive
    per-request path's full-logits round trips instead (layers stay
    smoke-sized either way).
    """
    import dataclasses

    from repro.configs import get_smoke

    cfg = get_smoke(arch)
    if vocab and vocab > cfg.vocab:
        cfg = dataclasses.replace(cfg, vocab=vocab)
    return cfg


def serving_workload(vocab: int, *, max_seq: int, n_requests: int,
                     max_new: int, seed: int = 0) -> list:
    """The fixed mixed-length workload every serving measurement decodes:
    ``[(prompt, max_new_tokens), ...]``, deterministic from ``seed``.
    Shared by :class:`ServingBackend` and ``benchmarks/serving_ladder.py``
    so the tuner and the benchmark can never drift apart."""
    import numpy as np

    if max_new < 1 or max_seq < 2:
        raise ValueError(
            f"serving workload needs max_new >= 1 and max_seq >= 2 "
            f"(got max_new={max_new}, max_seq={max_seq})")
    max_new = min(max_new, max_seq - 1)
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n_requests):
        plen = int(rng.integers(1, max(2, max_seq // 4)))
        new = int(rng.integers(min(2, max_new), max_new + 1))
        # keep every request admissible: prompt + budget within max_seq
        plen = max(1, min(plen, max_seq - new))
        reqs.append((rng.integers(1, vocab, plen).tolist(), new))
    return reqs


def run_serving_workload(engine, workload: list):
    """Submit ``workload`` to ``engine``, drain it, and return
    ``(wall_s, tokens, generated, ticks)`` for that run only (the engine
    may be reused across runs)."""
    import time

    from repro.serving import Request

    done_before = len(engine.finished)
    steps_before = engine.n_steps
    rids = [engine.submit(Request(prompt=list(p), max_new_tokens=n))
            for p, n in workload]
    t0 = time.perf_counter()
    engine.run()
    wall = time.perf_counter() - t0
    by_rid = {r.rid: r.generated for r in engine.finished[done_before:]}
    gen = [by_rid[rid] for rid in rids]
    return wall, sum(len(g) for g in gen), gen, engine.n_steps - steps_before


def serving_latency_probe(engine, vocab: int, *, prompt_len: int = 24,
                          max_new: int = 8, seed: int = 123):
    """One latency probe through the REAL prefill path: submit a single
    request to an idle, warm engine and step it to completion, timing

      * TTFT — wall seconds from submit until the host OBSERVES the
        first generated token (chunked prefill pays
        ceil(prompt_len/chunk) ticks here; the legacy path pays
        prompt_len), and
      * ITL — mean wall seconds between subsequent tokens.

    Returns ``(ttft_s, itl_s, tokens)``.  This is a single unloaded
    probe, NOT wall-clock under load: callers ride it through the same
    interleaved trimmed-min rounds as the throughput harness so process
    drift cancels (``benchmarks/serving_ladder.py``)."""
    import time

    from repro.serving import Request

    import numpy as np

    rng = np.random.default_rng(seed)
    prompt = rng.integers(1, vocab, prompt_len).tolist()
    req = Request(prompt=prompt, max_new_tokens=max_new)
    engine.submit(req)
    t0 = time.perf_counter()
    ticks = 0
    while not req.generated and ticks < 100_000:
        engine.step()
        ticks += 1
    ttft = time.perf_counter() - t0
    while not req.done and ticks < 200_000:
        engine.step()
        ticks += 1
    total = time.perf_counter() - t0
    itl = (total - ttft) / max(1, len(req.generated) - 1)
    return ttft, itl, len(req.generated)


class ServingBackend(CumulativeLadderState):
    """Measure ``repro.serving.DecodeEngine`` at each ladder level.

    Unlike the other two backends this one runs the *real* hot path: a
    fixed continuous-batching workload (mixed prompt/generation lengths,
    deterministic from ``seed``) is decoded to completion on the smoke
    config and the objective is measured wall-clock seconds (tokens/sec in
    ``meta``).  One engine is built per level, warmed up once so jit
    compilation never pollutes the timing, then the workload is re-run
    ``repeats`` times and the best run wins (best-of-K absorbs scheduler
    jitter; the workload itself is identical run to run).

    ``meta['generated']`` records every request's token ids so the ladder
    walk can assert bit-identical generations across levels under greedy
    sampling — the serving analog of MachSuite's O0..O5 output-equivalence
    matrix.  This surface's ladder extends past the paper's five to the
    paged-scratchpad and speculative rungs (``top_level = O7``);
    ``meta['kv_capacity']`` records each level's persistent decode-cache
    token capacity so the walk shows the paged rung's actual win
    (capacity at equal memory, not raw tok/s), and ``meta['layout']`` /
    ``meta['devices']`` record each
    rung's (cache layout, device count) cell — on a multi-device host the
    O3+ rungs shard (including the paged pool on its block axis at O6;
    layout and placement compose, see ``repro.serving.layout``).

    At the paged rung the attention implementation is itself a measured
    knob (``paged_attn="auto"``, the default): the walk builds BOTH the
    gather step (dense view re-materialized per tick) and the gather-free
    block-table kernel step, interleaves the timed repeats so process
    drift cancels, and keeps the winner — falling back to gather on a
    tie/loss (within 1%) or when the model family has no paged decode
    step.  ``meta['paged_attn']`` records the chosen implementation and
    ``meta['paged_attn_walls']`` both measured floors, AutoDSE-style:
    the rung is kept because it measured faster, not assumed so.

    The pool's stored dtype is a measured knob too (``kv_dtype="auto"``):
    the paged rung races its chosen bf16 engine against an int8 twin at
    EQUAL POOL MEMORY (the narrow blocks' saved bytes buy more blocks)
    and keeps narrow only when goodput/tok-s wins beyond the noise
    floor.  Narrow pools are held to the dtype's TOLERANCE contract
    (``serving.kvquant.tolerance_contract``) against the incumbent's
    tokens — never to bit-identity — plus strict determinism across
    repeats; ``meta['kv_dtype']`` records the shipped dtype and
    ``meta['kv_dtype_walls']`` both measured floors.

    The speculative rung (``top_level = O7``) follows the same rule with
    the window size as the knob: ``draft_k="auto"`` races K in {0,2,4,8}
    on interleaved repeats (K=0 is the incumbent O6-equivalent engine —
    speculation off) and keeps a K only when it WINS beyond the 1% noise
    floor.  Greedy rejection makes every K bit-identical, so the race is
    pure wall-clock; ``meta['draft_k_walls']`` records every measured
    floor keyed by the K that actually RAN, and ``meta['accept_rate']``
    / ``meta['eff_tok_per_step']`` the chosen engine's acceptance
    telemetry.

    TRAFFIC MODE (``traffic_rate > 0``): after the closed-loop races pick
    the level's engine, the walk replays a fixed open-loop arrival trace
    (``repro.launch.server``) at the target rate and the OBJECTIVE
    becomes inverse goodput-under-SLO — requests meeting both the TTFT
    and per-token SLOs, per second — instead of best-of-K wall clock.
    AutoDSE's lesson is that closed-loop tuning only transfers when the
    measured objective matches the deployment objective; for a server
    that objective is goodput at the offered rate, not drain time of a
    fixed batch.  The knob races above still run closed-loop (they pick
    the engine; bit-identity is asserted there), and
    ``meta['traffic']`` records the full latency/goodput row.
    """

    top_level = OptLevel.O7

    def __init__(self, arch: str = "qwen3-8b", *, batch_size: int = 4,
                 max_seq: int = 48, n_requests: int = 12, max_new: int = 8,
                 repeats: int = 3, policy: str = "fcfs", pe: int = 8,
                 vocab: int = 0, seed: int = 0, kv_block_size: int = 16,
                 kv_pool_blocks: int = 0, paged_attn: str = "auto",
                 prefill_chunk="auto", draft_model: str = "smollm-360m",
                 draft_k="auto", kv_dtype: str = "auto",
                 traffic_rate: float = 0.0,
                 traffic_pattern: str = "poisson",
                 ttft_slo_s: float = 0.5, tpot_slo_s: float = 0.1):
        from repro.serving.kvquant import KV_DTYPES
        if paged_attn not in ("auto", "gather", "kernel"):
            raise ValueError(f"paged_attn must be auto|gather|kernel "
                             f"(got {paged_attn!r})")
        if kv_dtype != "auto" and kv_dtype not in KV_DTYPES:
            raise ValueError(f"kv_dtype must be auto|{'|'.join(KV_DTYPES)} "
                             f"(got {kv_dtype!r})")
        if traffic_pattern not in ("poisson", "bursty"):
            raise ValueError(f"traffic_pattern must be poisson|bursty "
                             f"(got {traffic_pattern!r})")
        if prefill_chunk != "auto" and (not isinstance(prefill_chunk, int)
                                        or prefill_chunk < 0):
            raise ValueError(f"prefill_chunk must be 'auto' or an int >= 0 "
                             f"(got {prefill_chunk!r})")
        if draft_k != "auto" and (not isinstance(draft_k, int)
                                  or draft_k < 0):
            raise ValueError(f"draft_k must be 'auto' or an int >= 0 "
                             f"(got {draft_k!r})")
        self.prefill_chunk = prefill_chunk
        self.draft_model = draft_model
        self.draft_k = draft_k
        self.arch = arch
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.n_requests = n_requests
        self.max_new = max_new
        self.repeats = repeats
        self.policy = policy
        self.pe = pe
        self.vocab = vocab
        self.seed = seed
        self.kv_block_size = kv_block_size
        self.kv_pool_blocks = kv_pool_blocks
        self.paged_attn = paged_attn
        self.kv_dtype = kv_dtype
        self.traffic_rate = float(traffic_rate)
        self.traffic_pattern = traffic_pattern
        self.ttft_slo_s = float(ttft_slo_s)
        self.tpot_slo_s = float(tpot_slo_s)
        self._model = None
        self._params = None
        self._draft = None          # (ModelAPI, params) once built

    @property
    def name(self) -> str:
        return f"serve/{self.arch}"

    def _ensure_model(self):
        if self._model is None:
            import jax
            from repro.configs import get_smoke
            from repro.models import get_model

            cfg = serving_smoke_config(self.arch, self.vocab)
            self._model = get_model(cfg)
            self._params = self._model.init(jax.random.PRNGKey(self.seed))
            self._vocab = cfg.vocab
        return self._model, self._params

    def _workload(self):
        self._ensure_model()
        return serving_workload(self._vocab, max_seq=self.max_seq,
                                n_requests=self.n_requests,
                                max_new=self.max_new, seed=self.seed)

    def _ensure_drafter(self):
        """Build the drafter (api, params) ONCE and share it across every
        engine in the draft_k race — drafter weights are not a knob, and
        re-initializing per K would race different random drafters.  The
        drafter gets the same smoke config (and ``vocab`` override) as
        the target: this surface's token space is synthetic, so the two
        share it by construction — ``compatible_drafter`` still
        validates the pairing."""
        if self._draft is None:
            import jax
            from repro.models import get_model
            from repro.models.model_zoo import compatible_drafter

            model, _ = self._ensure_model()
            dcfg = serving_smoke_config(self.draft_model, self.vocab)
            dcfg = compatible_drafter(model.cfg, dcfg)
            api = get_model(dcfg)
            self._draft = (api, api.init(jax.random.PRNGKey(self.seed + 1)))
        return self._draft

    def _build_engine(self, state: OptLevel, paged_attn: str,
                      prefill_chunk: int = 0, draft_k: int = 0,
                      kv_dtype: str = "bf16", pool_blocks=None):
        from repro.core.optlevel import BestEffortConfig
        from repro.serving import DecodeEngine

        model, params = self._ensure_model()
        draft_api = draft_params = None
        if draft_k > 0:
            draft_api, draft_params = self._ensure_drafter()
        return DecodeEngine(
            model, params, batch_size=self.batch_size, max_seq=self.max_seq,
            config=BestEffortConfig(level=state, pe=self.pe,
                                    kv_block_size=self.kv_block_size,
                                    kv_pool_blocks=(
                                        self.kv_pool_blocks
                                        if pool_blocks is None
                                        else pool_blocks),
                                    paged_attn=paged_attn,
                                    prefill_chunk=prefill_chunk,
                                    draft_model=self.draft_model,
                                    draft_k=draft_k,
                                    kv_dtype=kv_dtype),
            policy=self.policy, draft_model=draft_api,
            draft_params=draft_params)

    def _traffic_measure(self, engine) -> dict:
        """Open-loop replay of a fixed deterministic trace at the target
        arrival rate on the (warm, drained) chosen engine; best goodput
        over ``repeats`` replays — wall-clock is noisy on a shared
        container, so the floor absorbs the jitter the same way the
        closed-loop best-of-K does."""
        from repro.launch.server import (latency_metrics, make_trace,
                                         serve_trace)

        trace = make_trace(
            n_requests=max(self.n_requests, 8), rate=self.traffic_rate,
            seed=self.seed, pattern=self.traffic_pattern,
            vocab=self._vocab,
            prompt_len=(2, max(3, min(10, self.max_seq // 4))),
            max_new=(2, max(3, self.max_new)))
        best = None
        for _ in range(max(1, self.repeats)):
            res = serve_trace(engine, trace)
            m = latency_metrics(res["finished"],
                                makespan_s=res["makespan_s"],
                                ttft_slo_s=self.ttft_slo_s,
                                tpot_slo_s=self.tpot_slo_s)
            m["rate_rps"] = self.traffic_rate
            m["pattern"] = self.traffic_pattern
            if best is None or m["goodput_rps"] > best["goodput_rps"]:
                best = m
        return best

    def measure(self, state: OptLevel) -> Measurement:
        model, _ = self._ensure_model()
        workload = self._workload()

        # The paged rung's attention implementation is a measured knob:
        # "auto" races gather vs the gather-free kernel (when the family
        # has one) and keeps the winner; gather wins ties.
        paged = state.has(Step.PAGED_SCRATCHPAD)
        if not paged:
            variants = ("gather",)            # ignored by the layout
        elif self.paged_attn == "auto" and model.paged_decode_step is not None:
            variants = ("gather", "kernel")
        else:
            variants = (self.paged_attn if self.paged_attn != "auto"
                        else "gather",)
        pinned = 0 if self.prefill_chunk == "auto" else int(self.prefill_chunk)
        engines = {v: self._build_engine(state, v, pinned) for v in variants}

        # warmup: jit compiles here (per engine — pool geometry and the
        # attention implementation are part of the program)
        generated = tokens = ticks = None
        for v in variants:
            _, tok, gen, tk = run_serving_workload(engines[v], workload)
            if generated is None:
                generated, tokens, ticks = gen, tok, tk
            else:
                assert gen == generated, (
                    f"paged_attn={v} changed greedy tokens")
        best = dict.fromkeys(variants)
        for _ in range(max(1, self.repeats)):
            for v in variants:                # interleaved: drift cancels
                wall, _, gen, _ = run_serving_workload(engines[v], workload)
                assert gen == generated, \
                    "serving workload must be deterministic"
                if best[v] is None or wall < best[v]:
                    best[v] = wall

        chosen = variants[0]
        if len(variants) > 1:
            # The kernel displaces gather only by WINNING beyond the 1%
            # noise floor; a tie or loss keeps the incumbent (the
            # best-effort keep-only-when-it-wins rule).
            if (engines["kernel"].layout.attn_impl == "kernel"
                    and best["kernel"] < 0.99 * best["gather"]):
                chosen = "kernel"
        engine = engines[chosen]
        best_wall = best[chosen]

        # Chunked prefill is itself a measured knob ("auto", paged rungs
        # only): race the chosen engine against a chunked twin of the
        # same (level, attn) cell, interleaving the timed repeats, and
        # keep the chunk only when it WINS beyond the 1% noise floor —
        # the same best-effort rule as the paged_attn race.
        chunk = pinned
        chunk_walls = None
        if (self.prefill_chunk == "auto" and state >= OptLevel.O6
                and model.prefill_step is not None):
            race_chunk = 16
            chunked = self._build_engine(state, chosen, race_chunk)
            if chunked.prefill_mode == "chunked":
                _, _, gen, _ = run_serving_workload(chunked, workload)
                assert gen == generated, \
                    "chunked prefill changed greedy tokens"
                best_c = None
                for _ in range(max(1, self.repeats)):
                    wall, _, gen, _ = run_serving_workload(chunked, workload)
                    assert gen == generated, \
                        "serving workload must be deterministic"
                    if best_c is None or wall < best_c:
                        best_c = wall
                    wall, _, _, _ = run_serving_workload(engine, workload)
                    if wall < best_wall:
                        best_wall = wall
                chunk_walls = {0: best_wall, race_chunk: best_c}
                # the extra interleaved repeats refine the incumbent's
                # floor — keep the recorded attn-race wall in sync
                best[chosen] = best_wall
                if best_c < 0.99 * best_wall:
                    engine, best_wall, chunk = chunked, best_c, race_chunk

        # The speculative rung's window size is a measured knob too
        # (``draft_k="auto"``, O7 only): race K in {0, 2, 4, 8} on
        # interleaved repeats.  K=0 is the incumbent engine chosen
        # above (speculation off — exactly the O6 hot path); a window
        # displaces it only by WINNING beyond the 1% noise floor.
        # Greedy rejection keeps every K bit-identical, so the race is
        # pure wall-clock — asserted, not assumed.
        draft_k_walls = None
        if (state.has(Step.SPECULATIVE) and self.draft_k != 0
                and model.verify_step is not None):
            ks = (2, 4, 8) if self.draft_k == "auto" else (self.draft_k,)
            spec_engines = {}
            for k in ks:
                e = self._build_engine(state, chosen, chunk, draft_k=k)
                if e.spec_mode != "draft":
                    # this (layout x placement x model) cell cannot
                    # speculate — degrade to the incumbent, no race
                    spec_engines = {}
                    break
                spec_engines[k] = e
            if spec_engines:
                for k, e in spec_engines.items():   # warmup: jit compiles
                    _, _, gen, _ = run_serving_workload(e, workload)
                    assert gen == generated, \
                        f"draft_k={k} changed greedy tokens"
                best_k = dict.fromkeys(spec_engines)
                for _ in range(max(1, self.repeats)):
                    for k, e in spec_engines.items():   # interleaved
                        wall, _, gen, _ = run_serving_workload(e, workload)
                        assert gen == generated, \
                            "serving workload must be deterministic"
                        if best_k[k] is None or wall < best_k[k]:
                            best_k[k] = wall
                    wall, _, _, _ = run_serving_workload(engine, workload)
                    if wall < best_wall:
                        best_wall = wall
                # keyed by the K each engine actually RAN at (0 = the
                # incumbent; spec engines were verified to be drafting)
                draft_k_walls = {0: best_wall}
                draft_k_walls.update(
                    {e.spec_stats["draft_k"]: best_k[k]
                     for k, e in spec_engines.items()})
                win = min(spec_engines, key=lambda k: best_k[k])
                if best_k[win] < 0.99 * best_wall:
                    engine, best_wall = spec_engines[win], best_k[win]

        # The pool's STORED dtype is the last measured knob (paged rungs
        # only): ``kv_dtype="auto"`` races the chosen bf16 engine against
        # a narrow (int8) twin holding the SAME pool memory — the bytes
        # the narrow blocks save are spent on MORE blocks, so the race
        # compares what deployment compares (capacity-for-precision at
        # equal HBM).  The narrow twin is NOT token-asserted against the
        # incumbent — quantized rungs carry a tolerance contract, not the
        # bit-identity contract — it must instead meet the contract's
        # agreement floor against the incumbent's tokens AND be
        # deterministic across repeats.  "auto" keeps narrow only when it
        # WINS beyond the 1% noise floor (goodput in traffic mode, drain
        # wall otherwise); a pinned narrow dtype ships narrow regardless
        # but still records both measured floors.
        kv_dtype_walls = None
        kv_agreement = None
        # Pure-state families (rwkv, mamba) have NO block leaves — state
        # rows are never quantized, so there is nothing for a narrow
        # pool to buy and the per-block byte arithmetic degenerates;
        # the race only runs when the cache actually pages KV blocks.
        has_blocks = paged and engine.cache_mgr.plan.token_bytes > 0
        if paged and has_blocks and self.kv_dtype != "bf16":
            from repro.serving import kvquant
            from repro.serving.paged import BlockPagingPlan

            narrow = "int8" if self.kv_dtype == "auto" else self.kv_dtype
            inc_mgr = engine.cache_mgr
            T = inc_mgr.block_size
            wide_plan = inc_mgr.plan
            nplan = BlockPagingPlan(model, self.batch_size, self.max_seq,
                                    T, inc_mgr.pool_blocks,
                                    kv_dtype=narrow)
            wide_bb = T * wide_plan.token_bytes \
                + wide_plan.scale_bytes_per_block
            narrow_bb = T * nplan.token_bytes + nplan.scale_bytes_per_block
            q_blocks = max(inc_mgr.pool_blocks,
                           inc_mgr.pool_blocks * wide_bb // narrow_bb)
            qk = 0
            if state.has(Step.SPECULATIVE):
                st = engine.spec_stats
                if st["spec_mode"] == "draft":
                    qk = st["draft_k"]
            qeng = self._build_engine(state, chosen, chunk, draft_k=qk,
                                      kv_dtype=narrow,
                                      pool_blocks=q_blocks)
            _, _, qgen, _ = run_serving_workload(qeng, workload)  # warmup
            tc = kvquant.tolerance_contract(narrow)
            kv_agreement = kvquant.token_agreement(generated, qgen)
            assert kv_agreement >= tc["min_agreement"], (
                f"kv_dtype={narrow} token agreement {kv_agreement:.3f} "
                f"below the {tc['min_agreement']} tolerance contract")
            best_q = None
            for _ in range(max(1, self.repeats)):
                wall, _, g, _ = run_serving_workload(qeng, workload)
                assert g == qgen, \
                    "narrow-pool serving workload must be deterministic"
                if best_q is None or wall < best_q:
                    best_q = wall
                wall, _, _, _ = run_serving_workload(engine, workload)
                if wall < best_wall:
                    best_wall = wall
            kv_dtype_walls = {"bf16": best_wall, narrow: best_q}
            if self.traffic_rate > 0:
                tm_b = self._traffic_measure(engine)
                tm_q = self._traffic_measure(qeng)
                win_q = (tm_q["goodput_rps"]
                         > 1.01 * tm_b["goodput_rps"])
            else:
                win_q = best_q < 0.99 * best_wall
            if self.kv_dtype != "auto" or win_q:
                engine, best_wall, generated = qeng, best_q, qgen

        # Unloaded single-request latency (TTFT / inter-token) through
        # the real prefill path, best-of-repeats on the warm engine.
        ttft = itl = None
        probe_len = max(1, min(24, self.max_seq - self.max_new))
        for _ in range(max(1, self.repeats)):
            t, il, _ = serving_latency_probe(
                engine, self._vocab, prompt_len=probe_len,
                max_new=self.max_new, seed=self.seed + 17)
            ttft = t if ttft is None else min(ttft, t)
            itl = il if itl is None else min(itl, il)

        tok_per_s = tokens / best_wall if best_wall > 0 else 0.0
        # Persistent decode-cache capacity in token positions: contiguous
        # rungs reserve B x max_seq; the paged rung holds pool_blocks x T.
        kv_capacity = engine.cache_mgr.capacity_tokens
        meta = {
            "backend": "serving",
            "level": int(state),
            "tok_per_s": tok_per_s,
            "tokens": tokens,
            "ticks": ticks,
            "batch_size": self.batch_size,
            "requests": self.n_requests,
            "policy": self.policy,
            "kv_capacity": kv_capacity,
            "layout": engine.layout.name,
            "devices": engine.placement.n_devices,
            "paged_attn": getattr(engine.layout, "attn_impl", None),
            "state_impl": getattr(engine.layout, "state_impl", "none"),
            "degrade_reason": getattr(engine, "degrade_reason", None),
            "kv_dtype": getattr(engine.layout, "kv_dtype", "bf16"),
            "prefill_chunk": chunk,
            "prefill_mode": engine.prefill_mode,
            "ttft_s": ttft,
            "itl_s": itl,
            "generated": [[int(t) for t in g] for g in generated],
        }
        if state.has(Step.SPECULATIVE):
            st = engine.spec_stats
            meta["spec_mode"] = st["spec_mode"]
            meta["draft_k"] = st["draft_k"]
            meta["draft_model"] = (self.draft_model
                                   if st["spec_mode"] == "draft" else None)
            meta["accept_rate"] = st["accept_rate"]
            meta["eff_tok_per_step"] = st["eff_tok_per_step"]
        if draft_k_walls is not None:
            meta["draft_k_walls"] = draft_k_walls
        if kv_dtype_walls is not None:
            # keyed by stored dtype; both floors recorded whether or not
            # the narrow pool was kept (AutoDSE-style: the decision is
            # auditable from the walls, not just the winner)
            meta["kv_dtype_walls"] = kv_dtype_walls
            meta["kv_agreement"] = kv_agreement
        if chunk_walls is not None:
            meta["prefill_chunk_walls"] = chunk_walls
        if paged:
            # keyed by the implementation that actually RAN (a pinned
            # "kernel" on a family without a paged decode step degrades
            # to gather — the walls must say so, not echo the request)
            meta["paged_attn_walls"] = {
                engines[v].layout.attn_impl: best[v] for v in variants}

        # Traffic mode: the tuner's objective flips from drain wall to
        # inverse goodput at the target arrival rate (lower total_s ==
        # more SLO-meeting requests per second); the closed-loop wall
        # stays in compute_s / breakdown for reference.
        objective = best_wall
        if self.traffic_rate > 0:
            traffic = self._traffic_measure(engine)
            meta["traffic"] = traffic
            objective = 1.0 / max(traffic["goodput_rps"], 1e-9)
        return Measurement(
            target=self.name,
            label=self.describe(state),
            compute_s=best_wall,
            memory_s=0.0,
            total_s=objective,
            breakdown={"wall_s": best_wall, "tok_per_s": tok_per_s},
            meta=meta,
        )
