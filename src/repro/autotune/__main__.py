"""CLI for the closed-loop autotuner.

MachSuite kernels (analytic model, instant):

  PYTHONPATH=src python -m repro.autotune --kernel gemm
  PYTHONPATH=src python -m repro.autotune --kernel all --frontier

LM configs (lowered-HLO cost twin on the production mesh; compile-heavy):

  PYTHONPATH=src python -m repro.autotune --arch qwen3-8b --shape train_4k

The serving engine itself (measured tokens/sec, smoke config, full O0->O7
ladder walk — O6 is the paged KV-block rung, O7 speculative decoding with
the draft window raced K in {0,2,4,8} and kept only when it wins):

  PYTHONPATH=src python -m repro.autotune --serve --arch qwen3-8b

Each run prints the per-round walk and writes a JSONL trajectory under
``experiments/autotune/`` (render with ``python -m benchmarks.autotune_table``
or, for --serve, ``python -m benchmarks.serving_ladder``).
"""

import argparse
import os
import sys


def _run_one(backend, args, *, ladder: bool = False):
    from repro.autotune.trajectory import render_rounds, write_trajectory
    from repro.autotune.tuner import autotune

    result = autotune(backend, frontier=args.frontier, ladder=ladder,
                      max_rounds=args.max_rounds)
    path = write_trajectory(result, out_dir=args.out)
    print(f"== {result.target} ({result.mode}) ==")
    print(render_rounds(result.to_records()))
    if result.rejected:
        print(f"VERDICT: REJECT — {result.target} is communication-bound "
              "(paper Table 5); no refinement attempted")
    else:
        print(f"VERDICT: {result.final_label} via "
              f"{' -> '.join(result.steps_taken) or 'no steps'} "
              f"({result.final_speedup:.1f}x vs start)")
    print(f"trajectory: {os.path.relpath(path)}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.autotune")
    target = ap.add_mutually_exclusive_group(required=True)
    target.add_argument("--kernel",
                        help="MachSuite kernel name, or 'all'")
    target.add_argument("--arch", help="LM architecture (repro.configs)")
    ap.add_argument("--shape", help="LM shape cell (e.g. train_4k)")
    ap.add_argument("--serve", action="store_true",
                    help="walk the serving engine itself O0->O7 on "
                         "measured tokens/sec (requires --arch; smoke "
                         "config; O6 = paged KV blocks, O7 = speculative "
                         "decoding)")
    ap.add_argument("--frontier", action="store_true",
                    help="AutoDSE-style mode: measure every remaining "
                         "candidate step per round, keep the best")
    ap.add_argument("--max-rounds", type=int, default=12)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None,
                    help="trajectory dir (default experiments/autotune)")
    ap.add_argument("--set", action="append", default=[],
                    metavar="key=value",
                    help="base ArchConfig overrides (LM mode)")
    # serving-walk knobs (--serve):
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=48)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--policy", default="fcfs",
                    choices=("fcfs", "spf", "deadline"))
    ap.add_argument("--traffic-rate", type=float, default=0.0,
                    help="autotune against OPEN-LOOP traffic at this "
                         "arrival rate (req/s) instead of a fixed batch: "
                         "each level replays a Poisson/bursty trace "
                         "through the async front end and the objective "
                         "becomes goodput under the latency SLOs "
                         "(0 = classic closed-loop drain wall)")
    ap.add_argument("--traffic-pattern", default="poisson",
                    choices=("poisson", "bursty"))
    ap.add_argument("--ttft-slo-ms", type=float, default=500.0,
                    help="traffic-mode TTFT SLO (milliseconds)")
    ap.add_argument("--tpot-slo-ms", type=float, default=100.0,
                    help="traffic-mode per-token latency SLO "
                         "(milliseconds)")
    ap.add_argument("--kv-block", type=int, default=16,
                    help="O6 paged-cache block size in tokens")
    ap.add_argument("--kv-pool-blocks", type=int, default=0,
                    help="O6 pool size in blocks (0 = auto: equal "
                         "worst-case capacity to the contiguous cache)")
    ap.add_argument("--paged-attn", default="auto",
                    choices=("auto", "gather", "kernel"),
                    help="O6 attention implementation: auto measures "
                         "gather vs the gather-free block-table kernel "
                         "and keeps the winner (gather on tie/loss)")
    ap.add_argument("--draft", default="smollm-360m", dest="draft_model",
                    help="O7 drafter arch (must share the target's vocab)")
    ap.add_argument("--draft-k", default="auto",
                    help="O7 speculation window: 'auto' races K in "
                         "{0,2,4,8} and keeps the winner; an int pins it "
                         "(0 disables speculation)")
    ap.add_argument("--kv-dtype", default="auto",
                    choices=("auto", "bf16", "int8", "fp8"),
                    help="O6 pool stored dtype: auto races bf16 vs an "
                         "int8 twin at equal pool memory and keeps "
                         "narrow only when it wins; bf16/int8/fp8 pin it")
    args = ap.parse_args(argv)
    if args.draft_k != "auto":
        try:
            args.draft_k = int(args.draft_k)
        except ValueError:
            ap.error(f"--draft-k must be 'auto' or an int "
                     f"(got {args.draft_k!r})")

    if args.serve:
        if not args.arch:
            ap.error("--serve needs --arch (e.g. --serve --arch qwen3-8b)")
        from repro.autotune.measurement import ServingBackend

        backend = ServingBackend(
            args.arch, batch_size=args.batch, max_seq=args.max_seq,
            n_requests=args.requests, max_new=args.max_new,
            repeats=args.repeats, policy=args.policy,
            kv_block_size=args.kv_block,
            kv_pool_blocks=args.kv_pool_blocks,
            paged_attn=args.paged_attn, draft_model=args.draft_model,
            draft_k=args.draft_k, kv_dtype=args.kv_dtype,
            traffic_rate=args.traffic_rate,
            traffic_pattern=args.traffic_pattern,
            ttft_slo_s=args.ttft_slo_ms / 1e3,
            tpot_slo_s=args.tpot_slo_ms / 1e3)
        result = _run_one(backend, args, ladder=True)
        levels = [r.measurement.meta for r in result.rounds]
        # Bit-identity is the contract for bf16 rungs only; a rung that
        # shipped a narrow pool is held to its tolerance contract
        # against the bf16 baseline instead.
        from repro.serving.kvquant import (token_agreement,
                                           tolerance_contract)
        base = levels[0]["generated"]
        same = True
        for m in levels:
            if m.get("kv_dtype", "bf16") == "bf16":
                same = same and m["generated"] == base
            else:
                tc = tolerance_contract(m["kv_dtype"])
                same = same and (token_agreement(base, m["generated"])
                                 >= tc["min_agreement"])
        print(f"generated tokens identical across levels "
              f"(narrow rungs: within tolerance contract): {same}")
        caps = {m["level"]: m.get("kv_capacity") for m in levels}
        print(f"decode-cache capacity (token positions) per level: {caps}")
        cells = {m["level"]: f"{m.get('layout')}x{m.get('devices')}dev"
                 for m in levels}
        print(f"layout x placement per level: {cells}")
        for m in levels:
            if m.get("traffic"):
                t = m["traffic"]
                print(f"O{m['level']} traffic @{t['rate_rps']:g}/s "
                      f"({t['pattern']}): goodput {t['goodput_rps']:.2f}/s "
                      f"({t['goodput_frac'] * 100:.0f}%), ttft p50/p99 "
                      f"{t['ttft_p50_s'] * 1e3:.0f}/"
                      f"{t['ttft_p99_s'] * 1e3:.0f}ms, tpot p50/p99 "
                      f"{t['tpot_p50_s'] * 1e3:.1f}/"
                      f"{t['tpot_p99_s'] * 1e3:.1f}ms")
        for m in levels:
            if m.get("paged_attn_walls"):
                walls = {k: f"{v:.4f}s"
                         for k, v in m["paged_attn_walls"].items()}
                print(f"O{m['level']} paged_attn measured {walls} -> "
                      f"kept {m['paged_attn']!r}")
            if m.get("draft_k_walls"):
                walls = {k: f"{v:.4f}s"
                         for k, v in m["draft_k_walls"].items()}
                print(f"O{m['level']} draft_k measured {walls} -> kept "
                      f"K={m['draft_k']} (accept {m['accept_rate']:.2f}, "
                      f"{m['eff_tok_per_step']:.2f} tok/step)")
            if m.get("kv_dtype_walls"):
                walls = {k: f"{v:.4f}s"
                         for k, v in m["kv_dtype_walls"].items()}
                print(f"O{m['level']} kv_dtype measured {walls} -> kept "
                      f"{m['kv_dtype']!r} (agreement "
                      f"{m['kv_agreement']:.2f})")
        return 0 if same else 1

    if args.kernel:
        from repro.autotune.measurement import KernelModelBackend
        from repro.core.costmodel import MACHSUITE_PROFILES

        names = (sorted(MACHSUITE_PROFILES) if args.kernel == "all"
                 else [args.kernel])
        for name in names:
            if name not in MACHSUITE_PROFILES:
                ap.error(f"unknown kernel {name!r}; "
                         f"choices: {', '.join(sorted(MACHSUITE_PROFILES))}")
            _run_one(KernelModelBackend(MACHSUITE_PROFILES[name]), args)
        return 0

    if not args.shape:
        ap.error("--arch needs --shape (e.g. --shape train_4k)")
    # The cost twin lowers on the 512-host-device production mesh; the flag
    # must be in place before jax touches the backend (hillclimb sets it too,
    # via setdefault, but only at its own import time).
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512")

    from repro.autotune.measurement import CostTwinBackend
    from repro.launch.hillclimb import parse_value

    overrides = {}
    for kv in getattr(args, "set"):
        k, v = kv.split("=", 1)
        overrides[k] = parse_value(v)
    _run_one(CostTwinBackend(args.arch, args.shape,
                             multi_pod=args.multi_pod,
                             base_overrides=overrides), args)
    return 0


if __name__ == "__main__":
    sys.exit(main())
