from repro.parallel.sharding import (
    Sharder,
    constrain,
    get_sharder,
    make_rules,
    set_sharder,
    use_sharder,
)
