"""GPipe-style pipeline parallelism over ``shard_map`` + collective_permute.

The layer stack is split into ``n_stages`` contiguous stages laid out on a
``stage`` mesh axis.  Microbatches stream through the stages with the
classic GPipe schedule: ``n_micro + n_stages - 1`` ticks, activations
hopping stage->stage+1 through ``jax.lax.ppermute`` each tick (on TPU this
lowers to neighbor collective-permute on the ICI ring — the
double-buffering step applied across chips: stage s computes microbatch m
while its previous output (m-1) is in flight to stage s+1).

This module is deliberately model-agnostic: it pipelines any
``stage_fn(stage_params, x) -> x`` whose stages have identical activation
shapes (true for homogeneous decoder stacks).  The LM integration test
builds a toy stack and checks pipeline == sequential exactly; the
production configs default to DP/FSDP/TP (DESIGN.md §5) with PP available
as a config knob for the 88L/96L dense giants.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(stage_params, x_micro, *, stage_fn, mesh: Mesh,
                   axis: str = "stage"):
    """Run the pipelined stack.

    stage_params: pytree whose leaves have a leading ``n_stages`` dim,
        sharded one-stage-per-device-row along ``axis``.
    x_micro: (n_micro, micro_batch, ...) activations (replicated entry).
    stage_fn(params_slice, x) -> y, applied by every stage to its resident
        microbatch each tick.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    n_ticks = n_micro + n_stages - 1
    fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def per_stage(params, xs):
        # params: (1, ...) slice for this stage; xs: full (n_micro, ...)
        # (microbatch stream is replicated into every stage; stage 0 is the
        # only consumer — the others overwrite their buffer via ppermute).
        sidx = jax.lax.axis_index(axis)
        p = jax.tree.map(lambda t: t[0], params)
        # mark the carries as stage-varying (each stage holds different
        # data); on older JAX (no jax.lax.pcast) shard_map values are
        # unconditionally varying, so the cast is a no-op
        pcast = getattr(jax.lax, "pcast", None)
        var = ((lambda t: pcast(t, (axis,), to="varying")) if pcast
               else (lambda t: t))
        buf = var(jnp.zeros_like(xs[0]))               # resident activation
        outs = var(jnp.zeros_like(xs))

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any remain)
            m_in = jnp.clip(t, 0, n_micro - 1)
            buf = jnp.where(sidx == 0, xs[m_in], buf)
            # every stage processes its resident microbatch
            y = stage_fn(p, buf)
            # last stage retires microbatch t - (n_stages - 1)
            m_out = t - (n_stages - 1)
            live = (sidx == n_stages - 1) & (m_out >= 0)

            def write(o):
                return jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(m_out, 0), 0)

            outs = jnp.where(live, write(outs), outs)
            # hop activations to the next stage (ring; wraparound value
            # lands in stage 0's buffer and is overwritten next tick)
            buf = jax.lax.ppermute(y, axis, fwd)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # all-reduce so every stage row returns the retired outputs
        return jax.lax.psum(outs, axis)

    spec_p = jax.tree.map(lambda _: P(axis), stage_params)
    return shard_map(
        per_stage, mesh=mesh,
        in_specs=(spec_p, P()),
        out_specs=P(),
    )(stage_params, x_micro)


def split_stages(stacked_params, n_stages: int):
    """(L, ...) scan-stacked params -> (n_stages, L/n_stages, ...)."""
    def re(t):
        L = t.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return t.reshape(n_stages, L // n_stages, *t.shape[1:])
    return jax.tree.map(re, stacked_params)


def make_stage_fn(layer_fn):
    """Lift a per-layer ``layer_fn(layer_params, x) -> x`` into a stage_fn
    that scans its (L/n_stages)-deep slice."""
    def stage_fn(stage_params, x):
        def body(h, lp):
            return layer_fn(lp, h), None
        y, _ = jax.lax.scan(body, x, stage_params)
        return y
    return stage_fn


@functools.partial(jax.jit, static_argnames=("stage_fn", "mesh", "axis"))
def _jit_pipeline(stage_params, x_micro, *, stage_fn, mesh, axis):
    return pipeline_apply(stage_params, x_micro, stage_fn=stage_fn,
                          mesh=mesh, axis=axis)
