"""Logical-axis sharding: DP / FSDP / TP / EP / SP on one mesh.

Mapping (defaults; per-arch overrides via ``ArchConfig``):

  batch   -> ("pod", "data")      data parallel (+ cross-pod DP)
  embed   -> ("data",)            FSDP / ZeRO-3 shard of weight d_model dims
             ("pod","data")       for the 123B/340B class (fsdp_over_pod)
  mlp     -> ("model",)           tensor parallel (ffn hidden)
  heads   -> ("model",)           tensor parallel (attention heads)
  kv      -> ("model",)           kv heads (usually < mesh => auto-dropped)
  vocab   -> ("model",)           embedding/lm-head vocab dim
  expert  -> ("model",)           expert parallel (MoE)
  kv_seq  -> ("model",)           sequence-parallel KV cache at decode
  layers  -> ()                   scan-stacked layer dim, never sharded

Divisibility degradation: if a tensor dim is not divisible by the mapped
mesh-axis product, the mapping *degrades* to the longest divisible prefix
(possibly replicated).  This is deliberate — the paper's theme is best-effort
programmability, and it makes every (arch x shape x mesh) cell lower without
hand-tuning 15-head / 8-kv-head edge cases.  The dry-run report records the
degradations so none of them are silent.
"""

from __future__ import annotations

import threading
from typing import Optional

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class Sharder:
    def __init__(self, mesh: Mesh, rules: dict):
        self.mesh = mesh
        self.rules = dict(rules)
        self.mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.degradations: list = []

    # -- spec construction ---------------------------------------------------
    def _axes_for(self, logical: Optional[str], dim: int, used: set):
        if logical is None:
            return ()
        mapped = self.rules.get(logical, ())
        picked = []
        size = 1
        for ax in mapped:
            if ax not in self.mesh_sizes or ax in used:
                continue
            nxt = size * self.mesh_sizes[ax]
            if dim % nxt != 0:
                break
            picked.append(ax)
            size = nxt
        if mapped and len(picked) < len([a for a in mapped
                                         if a in self.mesh_sizes]):
            self.degradations.append((logical, dim, tuple(mapped),
                                      tuple(picked)))
        return tuple(picked)

    def spec(self, logical_axes: tuple, shape: tuple) -> P:
        used: set = set()
        out = []
        for logical, dim in zip(logical_axes, shape):
            axes = self._axes_for(logical, dim, used)
            used.update(axes)
            if len(axes) == 0:
                out.append(None)
            elif len(axes) == 1:
                out.append(axes[0])
            else:
                out.append(tuple(axes))
        return P(*out)

    def named(self, logical_axes: tuple, shape: tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))

    def constrain(self, x, *logical_axes):
        if len(logical_axes) != x.ndim:
            raise ValueError(
                f"constrain: {len(logical_axes)} axes for rank-{x.ndim}"
            )
        return jax.lax.with_sharding_constraint(
            x, self.named(tuple(logical_axes), x.shape)
        )

    # -- whole-pytree helpers -------------------------------------------------
    def tree_shardings(self, axes_tree, shape_tree):
        """NamedSharding tree for params: axes_tree from ``param_axes``,
        shape_tree of arrays or ShapeDtypeStructs with matching structure."""
        return jax.tree.map(
            lambda ax, arr: self.named(tuple(ax), arr.shape),
            axes_tree, shape_tree,
            is_leaf=lambda a: isinstance(a, tuple),
        )


def make_rules(mesh: Mesh, *, fsdp_over_pod: bool = False) -> dict:
    has_pod = "pod" in mesh.axis_names
    batch = ("pod", "data") if has_pod else ("data",)
    fsdp = batch if (fsdp_over_pod and has_pod) else ("data",)
    return {
        "batch": batch,
        "embed": fsdp,
        "mlp": ("model",),
        "heads": ("model",),
        "kv": ("model",),
        "vocab": ("model",),
        "expert": ("model",),
        "kv_seq": ("model",),
        "q_seq": ("model",),
        "expert_cap": ("data",),
        "state": ("model",),
        "layers": (),
    }


# --------------------------------------------------------------------------
# PlacementPlan: the device-placement half of the serving engine's
# layout x placement product.  Cache LAYOUT (contiguous vs paged KV
# blocks, ``repro.serving.layout``) and device PLACEMENT (replicated vs
# PE-sharded) are orthogonal refinement axes — the paper applies PE
# duplication and scratchpad reorganization *together*, and AutoDSE-style
# search needs the knob space to stay a product — so the plan is its own
# object instead of a fork inside the engine.
# --------------------------------------------------------------------------


class PlacementPlan:
    """Where the serving engine's arrays live: one data-parallel mesh
    axis (or none).

    ``mesh is None`` is the replicated plan — every helper degrades to a
    no-op, so single-device engines pay nothing and callers never branch.
    With a mesh, the helpers hand out the three sharding families the
    decode step needs: ``replicated`` (params, block tables),
    :meth:`axis` (one array axis over ``"data"`` — the batch axis of a
    contiguous cache, the BLOCK axis of a paged pool), and the
    per-tick token/position shardings.
    """

    def __init__(self, mesh: Optional[Mesh] = None):
        self.mesh = mesh

    @property
    def sharded(self) -> bool:
        return self.mesh is not None

    @property
    def n_devices(self) -> int:
        return 1 if self.mesh is None else int(self.mesh.devices.size)

    # -- sharding constructors (None when unsharded) -------------------------
    @property
    def replicated(self) -> Optional[NamedSharding]:
        return None if self.mesh is None else NamedSharding(self.mesh, P())

    def axis(self, ax: int) -> Optional[NamedSharding]:
        """Shard one array axis over the data mesh axis."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(*([None] * ax + ["data"])))

    def token_shardings(self):
        """(tokens (B, 1), positions/seeds (B,)) shardings for the step."""
        if self.mesh is None:
            return None, None
        return (NamedSharding(self.mesh, P("data", None)),
                NamedSharding(self.mesh, P("data")))

    def cache_shardings(self, model, batch_size: int, max_seq: int):
        """Batch-axis shardings for a CONTIGUOUS per-slot cache tree
        (every leaf sharded on its logical ``batch`` axis)."""
        if self.mesh is None:
            return None
        sharder = Sharder(self.mesh, {"batch": ("data",)})
        return sharder.tree_shardings(model.cache_axes(),
                                      model.cache_spec(batch_size, max_seq))

    # -- placement application ----------------------------------------------
    def put_replicated(self, tree):
        """Replicate a pytree across the plan's devices (identity when
        unsharded)."""
        if self.mesh is None:
            return tree
        return jax.device_put(tree, self.replicated)

    def constrain_axis(self, leaf, ax: int):
        """In-graph re-shard of ``leaf`` on axis ``ax`` (identity when
        unsharded) — how the paged step turns its gathered dense view
        into a batch-sharded one so the model runs PE-duplicated."""
        if self.mesh is None:
            return leaf
        return jax.lax.with_sharding_constraint(leaf, self.axis(ax))

    def constrain_replicated(self, leaf):
        """In-graph re-shard of ``leaf`` to fully replicated (identity
        when unsharded) — how the gather-free paged KERNEL step keeps a
        BLOCK-axis-sharded pool working: the Pallas kernel is a
        single-device program, so the step replicates the pool for the
        kernel call and its ``out_shardings`` re-shard the written pool
        back onto the block axis.  Correctness everywhere, measured
        profitability decides (the best-effort contract)."""
        if self.mesh is None:
            return leaf
        return jax.lax.with_sharding_constraint(leaf, self.replicated)


def plan_pe_placement(config, batch_size: int,
                      devices=None) -> PlacementPlan:
    """Build the engine's :class:`PlacementPlan` from its config.

    PE duplication degrades, never fails (the repo-wide best-effort
    contract): ``pe`` is clipped to the visible devices, then reduced
    until the batch divides it; anything that lands at 1 returns the
    replicated plan.  The same plan serves both cache layouts — the
    layout object decides WHICH axis each array shards on.
    """
    pe = config.effective_pe
    if pe <= 1:
        return PlacementPlan()
    devs = list(devices) if devices is not None else jax.devices()
    n = min(pe, len(devs))
    while n > 1 and batch_size % n:
        n -= 1
    if n <= 1:
        return PlacementPlan()
    return PlacementPlan(Mesh(np.asarray(devs[:n]), ("data",)))


# --------------------------------------------------------------------------
# Ambient sharder: models call ``constrain(...)`` unconditionally; outside a
# mesh context it is the identity, so CPU smoke tests need no mesh plumbing.
# --------------------------------------------------------------------------

_local = threading.local()


def set_sharder(s: Optional[Sharder]):
    _local.sharder = s


def get_sharder() -> Optional[Sharder]:
    return getattr(_local, "sharder", None)


class use_sharder:
    def __init__(self, s: Sharder):
        self.s = s

    def __enter__(self):
        self.prev = get_sharder()
        set_sharder(self.s)
        return self.s

    def __exit__(self, *exc):
        set_sharder(self.prev)


def constrain(x, *logical_axes):
    s = get_sharder()
    if s is None:
        return x
    return s.constrain(x, *logical_axes)
