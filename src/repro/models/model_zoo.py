"""Unified model API: family dispatch + input specs for every shape cell.

``get_model(cfg)`` returns a ``ModelAPI`` whose functions close over the
arch config.  ``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins
for every model input of that (arch x shape) cell — weak-type-correct,
shardable, no device allocation — which is what the multi-pod dry-run
lowers against.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec, hybrid, mamba2, rwkv_lm, transformer


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ArchConfig
    init: Callable            # (rng) -> params
    axes: Callable            # () -> logical-axes tree
    defs: Callable            # () -> PDef tree
    loss: Callable            # (params, batch) -> scalar
    decode_step: Callable     # (params, cache, tokens, positions) -> (logits, cache)
    cache_spec: Callable      # (batch, max_seq) -> spec tree
    init_cache: Callable      # (batch, max_seq) -> cache tree
    cache_axes: Callable      # () -> logical-axes tree matching cache_spec
    # True for families whose decode cache is a CARRY (rwkv wkv state,
    # mamba conv/ssm state, the hybrid trunk) rather than a
    # position-addressed KV log.  The contiguous layout cannot park a
    # carried-state slot mid-prompt (a pad feed would fold garbage into
    # the carry forever), so it refuses chunked prefill for these
    # families; the paged layout parks them on the NULL state row
    # instead.  Enc-dec is False: its self-KV is rewrite-safe and its
    # cross-KV is read-only.
    carries_state: bool = False
    # Paged decode step (params, pool, *extras, tokens, positions) ->
    # (logits, pool): the serving O6 kernel path.  ``extras`` is what
    # the manager's ``step_extras()`` emits for the family — (tables,)
    # for pure transformers, (rows,) for pure recurrent state
    # (rwkv/mamba), (tables, rows) for mixed pools (hybrid, enc-dec).
    paged_decode_step: Callable = None
    # Chunked prefill (params, cache, tokens (B, C), start (B,), last
    # (B,)) -> (logits, cache): C prompt tokens per call, logits taken
    # at each row's ``last`` index.  Transformers batch the chunk into
    # one wide attention call; carried-state families scan the exact
    # single-token decode body with per-slot freeze past ``last``
    # (``models/scan_prefill.py``) — both bit-identical to C one-token
    # steps.  None only for MoE (expert capacity is
    # token-count-dependent) — the engine then degrades to the legacy
    # one-token-per-tick prestaged path.
    prefill_step: Callable = None
    # Same, straight off the paged pool via the multi-query kernel:
    # (params, pool, tables, tokens, start, last) -> (logits, pool).
    paged_prefill_step: Callable = None
    # Speculative verify (params, cache, tokens (B, C), start (B,)) ->
    # (logits (B, C, vocab_padded), cache): ONE batched forward over the
    # pending token + C-1 drafts per slot, logits at EVERY row so greedy
    # rejection can accept the argmax prefix.  None for families where a
    # window is not equivalent to C single-token steps (same gating as
    # prefill_step) — the engine then degrades speculation to plain
    # decode, recorded in ``engine.spec_mode``.
    verify_step: Callable = None
    # Same off the paged pool: (params, pool, tables, tokens, start) ->
    # (logits (B, C, vocab_padded), pool).
    paged_verify_step: Callable = None


def get_model(cfg: ArchConfig) -> ModelAPI:
    if cfg.family in ("dense", "moe", "vlm"):
        mod = transformer
    elif cfg.family == "ssm":
        mod = rwkv_lm
    elif cfg.family == "mamba":
        mod = mamba2
    elif cfg.family == "hybrid":
        mod = hybrid
    elif cfg.family == "audio":
        mod = encdec
    else:
        raise ValueError(f"unknown family {cfg.family}")

    paged_step = None
    if hasattr(mod, "paged_decode_step"):
        # The *extras* between pool and tokens are family-shaped —
        # tables and/or state rows, exactly what the paged manager's
        # ``step_extras()`` emits — so the wiring passes them through
        # positionally.
        paged_step = (lambda params, pool, *rest,
                      scales=None, kv_dtype="bf16":
                      mod.paged_decode_step(cfg, params, pool, *rest,
                                            scales=scales,
                                            kv_dtype=kv_dtype))

    prefill = paged_prefill = None
    if hasattr(mod, "prefill_step") and not cfg.n_experts:
        prefill = (lambda params, cache, tokens, start, last:
                   mod.prefill_step(cfg, params, cache, tokens, start,
                                    last))
        if hasattr(mod, "paged_prefill_step"):
            paged_prefill = (lambda params, pool, tables, tokens, start,
                             last, scales=None, kv_dtype="bf16":
                             mod.paged_prefill_step(cfg, params, pool,
                                                    tables, tokens, start,
                                                    last, scales=scales,
                                                    kv_dtype=kv_dtype))

    verify = paged_verify = None
    if hasattr(mod, "verify_step") and not cfg.n_experts:
        verify = (lambda params, cache, tokens, start:
                  mod.verify_step(cfg, params, cache, tokens, start))
        if hasattr(mod, "paged_verify_step"):
            paged_verify = (lambda params, pool, tables, tokens, start,
                            scales=None, kv_dtype="bf16":
                            mod.paged_verify_step(cfg, params, pool, tables,
                                                  tokens, start,
                                                  scales=scales,
                                                  kv_dtype=kv_dtype))

    return ModelAPI(
        cfg=cfg,
        carries_state=cfg.family in ("ssm", "mamba", "hybrid"),
        init=lambda rng: mod.init(cfg, rng),
        axes=lambda: mod.axes(cfg),
        defs=lambda: mod.model_defs(cfg),
        loss=lambda params, batch: mod.lm_loss(cfg, params, batch),
        decode_step=lambda params, cache, tokens, positions:
            mod.decode_step(cfg, params, cache, tokens, positions),
        cache_spec=lambda batch, max_seq:
            mod.cache_spec(cfg, batch, max_seq),
        init_cache=lambda batch, max_seq:
            mod.init_cache(cfg, batch, max_seq),
        cache_axes=lambda: mod.cache_axes(cfg),
        paged_decode_step=paged_step,
        prefill_step=prefill,
        paged_prefill_step=paged_prefill,
        verify_step=verify,
        paged_verify_step=paged_verify,
    )


# ---------------------------------------------------------------------------
# Drafter pairing (speculative decoding)
# ---------------------------------------------------------------------------

# Known (target -> drafter) pairings: the small zoo arch that proposes
# tokens for the big one.  A pairing here is a *candidate* — it still
# has to pass ``compatible_drafter``'s vocab check at the scale it runs
# (the smoke cells share a 256-token vocab; full smollm/qwen3 tokenizers
# differ, which the check rejects loudly rather than decoding garbage).
DRAFTER_PAIRS = {
    "qwen3-8b": "smollm-360m",
    "mistral-large-123b": "smollm-360m",
    "nemotron-4-340b": "smollm-360m",
}


def compatible_drafter(target, draft=None) -> ArchConfig:
    """Resolve and validate the (drafter, target) pair for speculation.

    ``target`` is an ArchConfig (or registry name); ``draft`` a registry
    name / ArchConfig, defaulting to the ``DRAFTER_PAIRS`` entry.  A
    string drafter resolves at the SAME scale as the target (smoke vs
    full).  Speculative verify compares the drafter's proposed token ids
    against the target's argmax, so the two models must share one token
    space: mismatched vocabs raise ValueError naming both sizes instead
    of silently decoding garbage."""
    from repro.configs import get_config, get_smoke

    if isinstance(target, str):
        target = get_config(target)
    if draft is None:
        try:
            draft = DRAFTER_PAIRS[target.name]
        except KeyError:
            raise ValueError(
                f"no known drafter pairing for target {target.name!r}; "
                f"pass draft_model explicitly (pairs: {sorted(DRAFTER_PAIRS)})"
            ) from None
    if isinstance(draft, str):
        try:
            full = get_config(target.name)
        except KeyError:
            full = target
        smoke = target != full
        draft = get_smoke(draft) if smoke else get_config(draft)
    if draft.vocab != target.vocab:
        raise ValueError(
            f"drafter {draft.name!r} (vocab {draft.vocab}) is not "
            f"token-compatible with target {target.name!r} (vocab "
            f"{target.vocab}): speculative verify compares token ids "
            f"across the two models, so they must share one tokenizer/"
            f"vocab"
        )
    return draft


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins and smoke-test shapes)
# ---------------------------------------------------------------------------

def _tok(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Train/prefill batch specs for one cell."""
    B, S = shape.global_batch, shape.seq_len
    emb_dt = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "audio":
        return {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), emb_dt),
            "tokens": _tok((B, S)),
            "labels": _tok((B, S)),
        }
    if cfg.family == "vlm":
        P = cfg.n_prefix
        St = S - P
        return {
            "patches": jax.ShapeDtypeStruct((B, P, cfg.d_model), emb_dt),
            "tokens": _tok((B, St)),
            "labels": _tok((B, St)),
        }
    return {"tokens": _tok((B, S)), "labels": _tok((B, S))}


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig):
    """(cache_specs, tokens, positions) for a serve_step cell."""
    B, S = shape.global_batch, shape.seq_len
    model = get_model(cfg)
    cache = model.cache_spec(B, S)
    return cache, _tok((B, 1)), jax.ShapeDtypeStruct((B,), jnp.int32)


def make_batch(cfg: ArchConfig, shape: ShapeConfig, rng) -> dict:
    """Materialize a synthetic batch matching ``input_specs`` (smoke/tests)."""
    specs = input_specs(cfg, shape)
    out = {}
    for k, s in specs.items():
        rng, sub = jax.random.split(rng)
        if s.dtype == jnp.int32:
            out[k] = jax.random.randint(sub, s.shape, 0, cfg.vocab,
                                        dtype=jnp.int32)
        else:
            out[k] = jax.random.normal(sub, s.shape, s.dtype) * 0.02
    return out
