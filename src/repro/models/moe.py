"""Mixture-of-Experts: top-k token-choice routing with capacity + EP.

Dispatch is sort-based (the TPU-friendly adaptation of the paper's "PE
duplication" step for experts): assignments are ranked within their expert
via an argsort, scattered into a dense (E, C, d) buffer (overflow drops to a
trash slot), run through the expert FFNs as one batched einsum with the
expert dim sharded over ``model`` (expert parallelism), and combined back by
gather + weighted sum.  No (T, E, C) one-hot tensor is ever materialized.

Returns an auxiliary load-balancing loss (Switch-style) so training drivers
can regularize routing.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import PDef, mlp_apply, swiglu_defs
from repro.parallel.sharding import constrain


def moe_defs(d: int, n_experts: int, expert_d_ff: int,
             shared_d_ff: int = 0) -> dict:
    defs = {
        "router": PDef((d, n_experts), ("embed", "expert"), "small"),
        "wi": PDef((n_experts, d, expert_d_ff), ("expert", "embed", "mlp")),
        "wg": PDef((n_experts, d, expert_d_ff), ("expert", "embed", "mlp")),
        "wo": PDef((n_experts, expert_d_ff, d), ("expert", "mlp", "embed")),
    }
    if shared_d_ff:
        defs["shared"] = swiglu_defs(d, shared_d_ff)
    return defs


def moe_apply(params, x, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25):
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    dt = x.dtype
    T = B * S
    xf = x.reshape(T, d)

    gates = jnp.einsum(
        "td,de->te", xf, params["router"].astype(dt)
    ).astype(jnp.float32)
    probs = jax.nn.softmax(gates, axis=-1)
    gate_w, sel = jax.lax.top_k(probs, top_k)            # (T, k)
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    # Load-balance aux (Switch): E * sum_e f_e * p_e.
    me = jnp.mean(probs, axis=0)                          # (E,)
    ce = jnp.zeros((n_experts,), jnp.float32).at[sel.reshape(-1)].add(
        1.0 / (T * top_k)
    )
    aux = n_experts * jnp.sum(me * ce)

    # ---- sort-based dispatch -------------------------------------------------
    N = T * top_k
    C = max(1, int(math.ceil(T * top_k / n_experts * capacity_factor)))
    e_flat = sel.reshape(N)
    order = jnp.argsort(e_flat)
    sorted_e = e_flat[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(n_experts))
    ranks_sorted = jnp.arange(N) - starts[sorted_e]
    ranks = jnp.zeros((N,), jnp.int32).at[order].set(
        ranks_sorted.astype(jnp.int32)
    )
    slot = jnp.where(ranks < C, e_flat * C + ranks, n_experts * C)

    tok_idx = jnp.broadcast_to(
        jnp.arange(T)[:, None], (T, top_k)
    ).reshape(N)
    xin = xf[tok_idx]                                      # (N, d)
    buf = jnp.zeros((n_experts * C + 1, d), dt).at[slot].set(xin)
    ebuf = buf[: n_experts * C].reshape(n_experts, C, d)
    ebuf = constrain(ebuf, "expert", "expert_cap", None)

    # ---- expert FFN (EP over `model`) ---------------------------------------
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", ebuf, params["wg"].astype(dt))
    ) * jnp.einsum("ecd,edf->ecf", ebuf, params["wi"].astype(dt))
    eout = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(dt))
    eout = constrain(eout, "expert", "expert_cap", None)

    # ---- combine -------------------------------------------------------------
    flat = jnp.concatenate(
        [eout.reshape(n_experts * C, d), jnp.zeros((1, d), dt)], axis=0
    )
    y = flat[slot] * gate_w.reshape(N, 1).astype(dt)       # (N, d)
    y = y.reshape(T, top_k, d).sum(axis=1)

    if "shared" in params:
        y = y + mlp_apply(params["shared"], xf, "swiglu")
    return y.reshape(B, S, d), aux


def _dp_group_count():
    """Data-parallel shard count from the ambient sharder (1 on CPU)."""
    from repro.parallel.sharding import get_sharder
    s = get_sharder()
    if s is None:
        return 1
    g = 1
    for ax in s.rules.get("batch", ()):
        g *= s.mesh_sizes.get(ax, 1)
    return max(1, g)


def moe_apply_grouped(params, x, *, n_experts: int, top_k: int,
                      capacity_factor: float = 1.25, groups: int = 0):
    """Locality-aware dispatch (§Perf): routing/rank/scatter run PER
    data-parallel group, so dispatch and combine are shard-local and the
    only cross-device movement is the (G <-> E) reshard — which SPMD lowers
    to an all-to-all over the EP axis instead of the (T, d) f32 all-reduce
    the global-scatter formulation costs in backward.

    Capacity becomes per-group (standard local-capacity semantics; equal to
    global capacity when routing is balanced — and exactly equal outputs
    when nothing overflows, which the equivalence test checks).
    """
    B, S, d = x.shape
    dt = x.dtype
    T = B * S
    G = groups or _dp_group_count()
    if T % G or (T // G) < 1:
        G = 1
    Tg = T // G
    xg = x.reshape(G, Tg, d)
    xg = constrain(xg, "batch", None, None)

    gates = jnp.einsum("gtd,de->gte", xg,
                       params["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(gates, axis=-1)
    gate_w, sel = jax.lax.top_k(probs, top_k)              # (G, Tg, k)
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.zeros((n_experts,), jnp.float32).at[sel.reshape(-1)].add(
        1.0 / (T * top_k))
    aux = n_experts * jnp.sum(me * ce)

    # ---- per-group sort-based dispatch ------------------------------------
    N = Tg * top_k
    C = max(1, int(math.ceil(N / n_experts * capacity_factor)))
    e_flat = sel.reshape(G, N)
    order = jnp.argsort(e_flat, axis=1)
    sorted_e = jnp.take_along_axis(e_flat, order, axis=1)
    starts = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(n_experts)))(sorted_e)
    ranks_sorted = (jnp.arange(N)[None, :]
                    - jnp.take_along_axis(starts, sorted_e, axis=1))
    g_idx = jnp.arange(G)[:, None]
    ranks = jnp.zeros((G, N), jnp.int32).at[g_idx, order].set(
        ranks_sorted.astype(jnp.int32))
    slot = jnp.where(ranks < C, e_flat * C + ranks, n_experts * C)

    tok_idx = jnp.broadcast_to(
        jnp.arange(Tg)[None, :, None], (G, Tg, top_k)).reshape(G, N)
    xin = jnp.take_along_axis(xg, tok_idx[..., None], axis=1)   # (G, N, d)
    buf = jnp.zeros((G, n_experts * C + 1, d), dt).at[
        g_idx[..., None], slot[..., None], jnp.arange(d)[None, None, :]
    ].set(xin)
    ebuf = buf[:, : n_experts * C].reshape(G, n_experts, C, d)
    # (G, E, C, d) -> (E, G*C, d): the G<->E axis swap is the all-to-all.
    ebuf = jnp.swapaxes(ebuf, 0, 1).reshape(n_experts, G * C, d)
    ebuf = constrain(ebuf, "expert", "expert_cap", None)

    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", ebuf, params["wg"].astype(dt))
    ) * jnp.einsum("ecd,edf->ecf", ebuf, params["wi"].astype(dt))
    eout = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(dt))
    eout = constrain(eout, "expert", "expert_cap", None)

    # back to (G, E*C, d) + per-group trash row, combine locally
    back = jnp.swapaxes(eout.reshape(n_experts, G, C, d), 0, 1)
    back = constrain(back, "batch", None, None, None)
    flat = jnp.concatenate(
        [back.reshape(G, n_experts * C, d),
         jnp.zeros((G, 1, d), dt)], axis=1)
    y = jnp.take_along_axis(flat, slot[..., None], axis=1) \
        * gate_w.reshape(G, N, 1).astype(dt)
    y = y.reshape(G, Tg, top_k, d).sum(axis=2)

    if "shared" in params:
        y = y + mlp_apply(params["shared"], xg, "swiglu")
    return y.reshape(B, S, d), aux
