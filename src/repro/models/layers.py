"""Model-building primitives: declarative param defs, norms, MLPs, rope.

Every layer declares its parameters as a nested dict of ``PDef`` records
(shape + logical sharding axes + initializer).  A single generic
``init_params`` / ``param_axes`` pair then guarantees the param pytree and
its sharding-spec pytree never drift apart — the property tests rely on this.

Logical axes used across the repo (mapped to mesh axes by
``parallel/sharding.py``):

  embed   — the d_model dimension of weights (FSDP axis)
  mlp     — the hidden/ffn dimension (tensor-parallel axis)
  heads   — attention-head dimension of fused head weights (TP axis)
  kv      — kv-head dimension
  vocab   — vocabulary dimension (TP axis)
  expert  — MoE expert dimension (expert-parallel axis)
  layers  — the scan-stacked layer dimension (never sharded)
  None    — replicated
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PDef:
    shape: tuple
    axes: tuple                  # logical axis names (len == len(shape))
    init: str = "normal"         # normal | zeros | ones | small
    scale: Optional[float] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _fan_in(shape: tuple) -> int:
    return shape[-2] if len(shape) >= 2 else max(1, shape[-1])


def init_params(rng: jax.Array, defs, dtype=jnp.float32):
    """Initialize a (nested-dict) tree of PDefs into arrays."""
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, PDef)
    )
    rngs = jax.random.split(rng, len(leaves))
    arrs = []
    for r, d in zip(rngs, leaves):
        if d.init == "zeros":
            a = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            a = jnp.ones(d.shape, dtype)
        else:
            std = d.scale if d.scale is not None else 1.0 / math.sqrt(
                _fan_in(d.shape)
            )
            if d.init == "small":
                std = d.scale if d.scale is not None else 0.02
            a = (jax.random.normal(r, d.shape) * std).astype(dtype)
        arrs.append(a)
    return jax.tree.unflatten(treedef, arrs)


def param_axes(defs):
    """Same-structure tree of logical-axes tuples."""
    return jax.tree.map(
        lambda d: d.axes, defs, is_leaf=lambda x: isinstance(x, PDef)
    )


def param_shapes(defs, dtype=jnp.float32):
    """Same-structure tree of ShapeDtypeStructs (for dry-run/abstract init)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
        defs,
        is_leaf=lambda x: isinstance(x, PDef),
    )


def stack_defs(defs, n: int):
    """Prepend a scan-stacked ``layers`` dimension to every PDef."""
    return jax.tree.map(
        lambda d: PDef((n,) + d.shape, ("layers",) + d.axes, d.init, d.scale),
        defs,
        is_leaf=lambda x: isinstance(x, PDef),
    )


def count_params(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * weight.astype(dt)


def rms_norm_defs(d: int) -> PDef:
    return PDef((d,), (None,), "ones")


def rope(x, positions, theta: float = 10_000.0):
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def swiglu_defs(d: int, d_ff: int) -> dict:
    return {
        "wi": PDef((d, d_ff), ("embed", "mlp")),
        "wg": PDef((d, d_ff), ("embed", "mlp")),
        "wo": PDef((d_ff, d), ("mlp", "embed")),
    }


def relu2_defs(d: int, d_ff: int) -> dict:
    """Nemotron-4 squared-ReLU MLP (no gating)."""
    return {
        "wi": PDef((d, d_ff), ("embed", "mlp")),
        "wo": PDef((d_ff, d), ("mlp", "embed")),
    }


def mlp_apply(params: dict, x, kind: str = "swiglu"):
    dt = x.dtype
    if kind == "relu2":
        h = jnp.maximum(x @ params["wi"].astype(dt), 0.0)
        h = h * h
        return h @ params["wo"].astype(dt)
    h = jax.nn.silu(x @ params["wg"].astype(dt)) * (x @ params["wi"].astype(dt))
    return h @ params["wo"].astype(dt)


def mlp_defs(d: int, d_ff: int, kind: str = "swiglu") -> dict:
    return relu2_defs(d, d_ff) if kind == "relu2" else swiglu_defs(d, d_ff)


def embed_defs(vocab: int, d: int) -> dict:
    return {
        "embedding": PDef((vocab, d), ("vocab", "embed"), "small"),
        "lm_head": PDef((d, vocab), ("embed", "vocab")),
        "final_norm": rms_norm_defs(d),
    }


def chunked_cross_entropy(h, params, labels, *, chunk: int = 2048,
                          compute_dtype=jnp.bfloat16, unroll: bool = False):
    """Mean token cross-entropy with the vocab projection applied per
    sequence-chunk (bounds peak logits memory — the 'explicit data caching'
    step applied to the loss).  h: (B, S, d); labels: (B, S) int32."""
    d = h.shape[-1]
    B, S = labels.shape
    lm_head = params["lm_head"].astype(compute_dtype)
    n_chunks = max(1, S // chunk)
    while S % n_chunks:          # S need not be chunk-aligned (e.g. the
        n_chunks -= 1            # vlm 32768-256 prefill): largest divisor
    hs = h.reshape(B, n_chunks, -1, d).swapaxes(0, 1)       # (C, B, s, d)
    ls = labels.reshape(B, n_chunks, -1).swapaxes(0, 1)     # (C, B, s)

    def body(carry, xs):
        hc, lc = xs
        logits = (hc @ lm_head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, lc[..., None], axis=-1
        ).squeeze(-1)
        return carry + jnp.sum(logz - gold), None

    from repro.models.loops import scan_or_unroll
    total, _ = scan_or_unroll(body, jnp.zeros((), jnp.float32), (hs, ls),
                              unroll=unroll)
    return total / (B * S)
