"""Mamba-2 (SSD) block: chunked state-space scan + single-step decode.

The chunked algorithm is the TPU adaptation of the paper's ladder applied to
a recurrence: intra-chunk work is a dense (Q x Q) block computed on the MXU
(explicit data caching: the chunk is the tile), chunks are walked by a
``lax.scan`` carrying the (H, P, N) state (customized pipelining), heads
shard over ``model`` (PE duplication).  A Pallas kernel with the identical
chunk math lives in ``repro/kernels/mamba2_ssd.py``.

Shapes: x (B, S, d_model); internally d_inner = expand*d_model split into
H = d_inner/P heads of dim P; state N = ssm_state; groups fixed at 1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (
    PDef, chunked_cross_entropy, init_params, param_axes, rms_norm,
    rms_norm_defs, stack_defs,
)
from repro.parallel.sharding import constrain


def mamba2_defs(d: int, *, expand: int = 2, head_dim: int = 64,
                state: int = 64, conv_width: int = 4) -> dict:
    d_in = expand * d
    nheads = d_in // head_dim
    conv_ch = d_in + 2 * state
    return {
        "norm": PDef((d,), (None,), "ones"),
        # in_proj -> [z (d_in), x (d_in), B (N), C (N), dt (H)]
        "in_proj": PDef((d, 2 * d_in + 2 * state + nheads),
                        ("embed", "mlp")),
        "conv_w": PDef((conv_width, conv_ch), (None, "mlp"), "small"),
        "conv_b": PDef((conv_ch,), ("mlp",), "zeros"),
        "A_log": PDef((nheads,), (None,), "zeros"),
        "D": PDef((nheads,), (None,), "ones"),
        "dt_bias": PDef((nheads,), (None,), "zeros"),
        "gate_norm": PDef((d_in,), ("mlp",), "ones"),
        "out_proj": PDef((d_in, d), ("mlp", "embed")),
    }


def _split_proj(zxbcdt, d_in, state, nheads):
    z = zxbcdt[..., :d_in]
    xs = zxbcdt[..., d_in: 2 * d_in]
    Bs = zxbcdt[..., 2 * d_in: 2 * d_in + state]
    Cs = zxbcdt[..., 2 * d_in + state: 2 * d_in + 2 * state]
    dt = zxbcdt[..., 2 * d_in + 2 * state:]
    return z, xs, Bs, Cs, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, S, ch); w: (K, ch)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + pad[:, i: i + x.shape[1]] * w[i]
    return out + b


def ssd_chunked(xh, dt, A, Bs, Cs, *, chunk: int, init_state=None,
                unroll: bool = False):
    """Chunked SSD scan.

    xh: (B, S, H, P); dt: (B, S, H) (post-softplus); A: (H,) (negative);
    Bs, Cs: (B, S, N).  Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = xh.shape
    N = Bs.shape[-1]
    nc = S // chunk
    assert S % chunk == 0, (S, chunk)

    # Chunk-major layout for the scan: (nc, B, Q, ...).
    xc = jnp.moveaxis(xh.reshape(Bsz, nc, chunk, H, P), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(Bsz, nc, chunk, H), 1, 0)
    Bc = jnp.moveaxis(Bs.reshape(Bsz, nc, chunk, N), 1, 0)
    Cc = jnp.moveaxis(Cs.reshape(Bsz, nc, chunk, N), 1, 0)

    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, :, :, None]  # (1,Q,Q,1)
    s0 = (init_state if init_state is not None
          else jnp.zeros((Bsz, H, P, N), xh.dtype)).astype(jnp.float32)

    def chunk_body(state, inp):
        """One chunk: intra-chunk dense block + state read/update.

        The (B, Q, Q, H) decay tensor exists only for the current chunk —
        the scan is the load-compute-store rotation over chunks."""
        x_c, dt_c, B_c, C_c = inp                # (B,Q,H,P),(B,Q,H),(B,Q,N)
        la = dt_c * A                            # (B,Q,H), <= 0
        cum = jnp.cumsum(la, axis=1)             # (B,Q,H)

        seg = cum[:, :, None, :] - cum[:, None, :, :]     # (B,Q,Q,H)
        L = jnp.where(causal, jnp.exp(seg), 0.0).astype(xh.dtype)
        CB = jnp.einsum("bin,bjn->bij", C_c, B_c)         # (B,Q,Q)
        xdt = x_c * dt_c[..., None]                       # (B,Q,H,P)
        y_diag = jnp.einsum("bij,bijh,bjhp->bihp", CB, L, xdt)

        # Contribution of the incoming state.
        out_decay = jnp.exp(cum).astype(xh.dtype)         # (B,Q,H)
        y_off = jnp.einsum("bin,bhpn,bih->bihp", C_c,
                           state.astype(xh.dtype), out_decay)

        # Update state to end of chunk.
        decay_states = jnp.exp(cum[:, -1:, :] - cum)      # (B,Q,H)
        st_c = jnp.einsum("bjn,bjh,bjhp->bhpn", B_c, decay_states, xdt)
        chunk_decay = jnp.exp(cum[:, -1, :]).astype(jnp.float32)
        new_state = (state * chunk_decay[:, :, None, None]
                     + st_c.astype(jnp.float32))
        return new_state, (y_diag + y_off)

    from repro.models.loops import scan_or_unroll
    final, ys = scan_or_unroll(chunk_body, s0, (xc, dtc, Bc, Cc),
                               unroll=unroll)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, H, P)
    return y, final.astype(xh.dtype)


def mamba2_apply(params, x, *, expand=2, head_dim=64, state=64,
                 conv_width=4, chunk=256, unroll=False):
    """Full-sequence block apply. x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    dt_ = x.dtype
    d_in = expand * d
    H = d_in // head_dim

    h = rms_norm(x, params["norm"])
    zxbcdt = h @ params["in_proj"].astype(dt_)
    z, xs, Bs, Cs, dtr = _split_proj(zxbcdt, d_in, state, H)

    xbc = jnp.concatenate([xs, Bs, Cs], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"].astype(dt_),
                                   params["conv_b"].astype(dt_)))
    xs, Bs, Cs = (xbc[..., :d_in], xbc[..., d_in:d_in + state],
                  xbc[..., d_in + state:])

    dt = jax.nn.softplus(dtr.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs.reshape(B, S, H, head_dim)
    xh = constrain(xh, "batch", None, "heads", None)

    chunk = min(chunk, S)
    y, _ = ssd_chunked(xh, dt.astype(dt_), A.astype(dt_), Bs, Cs,
                       chunk=chunk, unroll=unroll)
    y = y + xh * params["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(B, S, d_in)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"])
    return y @ params["out_proj"].astype(dt_)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def mamba2_state_spec(batch, d, *, expand=2, head_dim=64, state=64,
                      conv_width=4, dtype=jnp.bfloat16):
    d_in = expand * d
    H = d_in // head_dim
    conv_ch = d_in + 2 * state
    return {
        "conv": jax.ShapeDtypeStruct((batch, conv_width - 1, conv_ch), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, H, head_dim, state), dtype),
    }


def mamba2_init_state(batch, d, *, expand=2, head_dim=64, state=64,
                      conv_width=4, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        mamba2_state_spec(batch, d, expand=expand, head_dim=head_dim,
                          state=state, conv_width=conv_width, dtype=dtype),
    )


def mamba2_decode(params, x, cache, *, expand=2, head_dim=64, state=64,
                  conv_width=4):
    """Single-token step. x: (B, 1, d); cache: {conv, ssm}."""
    B, T, d = x.shape
    dt_ = x.dtype
    d_in = expand * d
    H = d_in // head_dim

    h = rms_norm(x, params["norm"])
    zxbcdt = h @ params["in_proj"].astype(dt_)
    z, xs, Bs, Cs, dtr = _split_proj(zxbcdt, d_in, state, H)

    xbc_t = jnp.concatenate([xs, Bs, Cs], axis=-1)[:, 0]   # (B, ch)
    window = jnp.concatenate(
        [cache["conv"].astype(dt_), xbc_t[:, None]], axis=1
    )                                                       # (B, K, ch)
    conv_out = jnp.einsum("bkc,kc->bc", window,
                          params["conv_w"].astype(dt_)) \
        + params["conv_b"].astype(dt_)
    xbc = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]

    xs_t = xbc[:, :d_in]
    B_t = xbc[:, d_in:d_in + state]
    C_t = xbc[:, d_in + state:]

    dt = jax.nn.softplus(dtr[:, 0].astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)                                 # (B,H)

    xh = xs_t.reshape(B, H, head_dim)
    xh = constrain(xh, "batch", "heads", None)
    ssm = cache["ssm"].astype(jnp.float32)
    ssm = constrain(ssm, "batch", "heads", None, None)
    upd = jnp.einsum("bhp,bn,bh->bhpn", xh.astype(jnp.float32),
                     B_t.astype(jnp.float32), dt)
    upd = constrain(upd, "batch", "heads", None, None)
    ssm = ssm * decay[:, :, None, None] + upd
    ssm = constrain(ssm, "batch", "heads", None, None)
    y = jnp.einsum("bhpn,bn->bhp", ssm, C_t.astype(jnp.float32))
    y = constrain(y, "batch", "heads", None)
    y = y.astype(dt_) + xh * params["D"].astype(dt_)[None, :, None]
    y = y.reshape(B, 1, d_in)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"])
    out = y @ params["out_proj"].astype(dt_)
    return out, {"conv": new_conv.astype(cache["conv"].dtype),
                 "ssm": ssm.astype(cache["ssm"].dtype)}


# ---------------------------------------------------------------------------
# Language model: embed -> L x residual mamba2 block -> norm -> head.
# The pure-SSM zoo member ("mamba" family): same block library the hybrid
# trunk uses, but no attention anywhere — decode state is O(1) per slot.
# ---------------------------------------------------------------------------

def _block_kw(cfg) -> dict:
    return dict(expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                state=cfg.ssm_state, conv_width=cfg.conv_width)


def model_defs(cfg) -> dict:
    from repro.models.transformer import padded_vocab
    d = cfg.d_model
    vp = padded_vocab(cfg.vocab)
    return {
        "embedding": PDef((vp, d), ("vocab", "embed"), "small"),
        "lm_head": PDef((d, vp), ("embed", "vocab")),
        "final_norm": rms_norm_defs(d),
        "layers": stack_defs(mamba2_defs(d, **_block_kw(cfg)),
                             cfg.n_layers),
    }


def forward(cfg, params, tokens):
    dt = jnp.dtype(cfg.compute_dtype)
    h = params["embedding"].astype(dt)[tokens]
    h = constrain(h, "batch", None, None)

    def body(h, layer_params):
        out = mamba2_apply(layer_params, h, unroll=cfg.unroll_layers,
                           **_block_kw(cfg))
        return h + out, None

    from repro.models.remat import resolve_policy, wrap_layer_body
    body_fn = wrap_layer_body(body, resolve_policy(cfg))
    from repro.models.loops import scan_or_unroll
    h, _ = scan_or_unroll(body_fn, h, params["layers"],
                          unroll=cfg.unroll_layers)
    return rms_norm(h, params["final_norm"])


def lm_loss(cfg, params, batch):
    h = forward(cfg, params, batch["tokens"])
    return chunked_cross_entropy(
        h, params, batch["labels"],
        chunk=min(cfg.loss_chunk, batch["labels"].shape[1]),
        compute_dtype=jnp.dtype(cfg.compute_dtype),
        unroll=cfg.unroll_layers,
    )


def cache_spec(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16) -> dict:
    per = mamba2_state_spec(batch, cfg.d_model, dtype=dtype,
                            **_block_kw(cfg))
    stack = lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape,
                                           s.dtype)
    return jax.tree.map(stack, per)


def init_cache(cfg, batch, max_seq, dtype=jnp.bfloat16):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, max_seq, dtype))


def decode_step(cfg, params, cache, tokens, positions):
    """positions unused (state carries history) but kept for API parity."""
    dt = jnp.dtype(cfg.compute_dtype)
    h = params["embedding"].astype(dt)[tokens]           # (B,1,d)

    def body(h, xs):
        layer_params, st = xs
        out, new_st = mamba2_decode(layer_params, h, st, **_block_kw(cfg))
        return h + out, new_st

    from repro.models.loops import scan_or_unroll
    h, new_cache = scan_or_unroll(
        body, h, (params["layers"], {"conv": cache["conv"],
                                     "ssm": cache["ssm"]}),
        unroll=cfg.unroll_layers)
    h = rms_norm(h, params["final_norm"])
    logits = (h[:, 0] @ params["lm_head"].astype(dt)).astype(jnp.float32)
    return logits, new_cache


def cache_axes(cfg) -> dict:
    return {
        "conv": ("layers", "batch", None, "mlp"),
        "ssm": ("layers", "batch", "heads", None, None),
    }


def paged_decode_step(cfg, params, pool, rows, tokens, positions,
                      scales=None, kv_dtype: str = "bf16"):
    """State-pool decode step (serving O6): slot->row indirection over
    the conv/ssm row pools; gather active rows, run the contiguous
    decode body, scatter back (NULL-row slots sink into the garbage
    row).  Recurrent state is never quantized — ``scales``/``kv_dtype``
    exist only for signature parity."""
    del scales, kv_dtype
    cache = jax.tree.map(lambda l: jnp.take(l, rows, axis=1), pool)
    logits, new = decode_step(cfg, params, cache, tokens, positions)
    new_pool = jax.tree.map(
        lambda p, n: p.at[:, rows].set(n.astype(p.dtype)), pool, new)
    return logits, new_pool


def prefill_step(cfg, params, cache, tokens, start, last):
    """Chunked prefill by scanning the decode body, with per-slot freeze
    past ``last`` (see :mod:`repro.models.scan_prefill`)."""
    from repro.models.scan_prefill import batch_axes_of, scan_prefill
    from repro.models.transformer import padded_vocab

    def step(c, tok, pos):
        return decode_step(cfg, params, c, tok, pos)

    return scan_prefill(step, cache, tokens, start, last,
                        logits_width=padded_vocab(cfg.vocab),
                        batch_axes=batch_axes_of(cache_axes(cfg)))


def init(cfg, rng):
    return init_params(rng, model_defs(cfg), jnp.dtype(cfg.param_dtype))


def axes(cfg):
    return param_axes(model_defs(cfg))
