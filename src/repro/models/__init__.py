from repro.models.model_zoo import (
    ModelAPI,
    decode_input_specs,
    get_model,
    input_specs,
    make_batch,
)
