"""Attention: GQA with rope / qk-norm, chunked prefill, cached decode.

Memory discipline follows the paper's ladder: the *naive* (O0) formulation
materializes the full (S, S) score tensor; the production path is the
*chunked* formulation (O1 explicit caching + O2 pipelining via ``lax.scan``
over query blocks) which keeps a (q_chunk, S) working set — the jnp analog
of the Pallas flash kernel in ``repro/kernels/flash_attention.py`` (used on
real TPU hardware; the scan form is what the dry-run lowers).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import PDef, rms_norm, rope
from repro.parallel.sharding import constrain


def attn_defs(d: int, n_heads: int, n_kv: int, head_dim: int,
              qk_norm: bool = False) -> dict:
    defs = {
        "wq": PDef((d, n_heads, head_dim), ("embed", "heads", None)),
        "wk": PDef((d, n_kv, head_dim), ("embed", "kv", None)),
        "wv": PDef((d, n_kv, head_dim), ("embed", "kv", None)),
        "wo": PDef((n_heads, head_dim, d), ("heads", None, "embed")),
    }
    if qk_norm:
        defs["q_norm"] = PDef((head_dim,), (None,), "ones")
        defs["k_norm"] = PDef((head_dim,), (None,), "ones")
    return defs


def _project_qkv(params, x, positions, *, qk_norm: bool, rope_theta: float,
                 use_rope: bool = True):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if use_rope:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    return q, k, v


def _heads_shardable(n_heads: int) -> bool:
    """True when the merged head count divides the mesh axes mapped to
    "heads" (ambient sharder; True on CPU/no-mesh)."""
    from repro.parallel.sharding import get_sharder
    s = get_sharder()
    if s is None:
        return True
    tp = 1
    for ax in s.rules.get("heads", ()):
        tp *= s.mesh_sizes.get(ax, 1)
    return tp <= 1 or n_heads % tp == 0


def _gqa_scores(q, k, scale):
    """q: (B, qc, KV, G, dh); k: (B, S, KV, dh) -> (B, KV, G, qc, S)."""
    return jnp.einsum("bqhgk,bshk->bhgqs", q, k) * scale


def attention(params, x, positions, *, n_heads, n_kv, head_dim,
              causal=True, qk_norm=False, rope_theta=1e4, q_chunk=1024,
              kv_x=None, kv_positions=None, use_rope=True, unroll=False,
              scores_dtype=jnp.float32):
    """Chunked multi-head attention.

    ``kv_x`` switches to cross-attention (keys/values from encoder states,
    no causal mask, no rope on kv side unless positions given).
    x: (B, S, d) -> (B, S, d).
    """
    B, S, d = x.shape
    dt = x.dtype
    scale = head_dim ** -0.5
    group = n_heads // n_kv

    if kv_x is None:
        q, k, v = _project_qkv(params, x, positions, qk_norm=qk_norm,
                               rope_theta=rope_theta, use_rope=use_rope)
        kv_pos = positions
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
        k = jnp.einsum("bsd,dhk->bshk", kv_x, params["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", kv_x, params["wv"].astype(dt))
        if qk_norm:
            q = rms_norm(q, params["q_norm"])
            k = rms_norm(k, params["k_norm"])
        if use_rope:
            q = rope(q, positions, rope_theta)
            if kv_positions is not None:
                k = rope(k, kv_positions, rope_theta)
        kv_pos = kv_positions

    # Layout selection: when the merged head count divides the TP axis,
    # use the MERGED-heads discipline (Megatron): expand KV heads to the
    # full H once, so q / k / v / scores / probs / o are ALL sharded on the
    # same "heads" axis and the attention path needs zero resharding.  (A
    # split (KV, G) layout forces the SPMD partitioner into involuntary
    # full rematerialization between the heads-sharded projections and any
    # score sharding — EXPERIMENTS §Perf measures the difference.)
    #
    # When heads DON'T divide (llama4's 40, smollm's 15 on a 16-way axis),
    # expansion would replicate k/v AND the compute; instead keep the
    # grouped GQA math with the query-SEQUENCE dim sharded end-to-end
    # (sequence parallelism): scores, probs and o all shard over qc, so
    # the quadratic work still spreads across the TP axis.
    merged = _heads_shardable(n_heads)
    S_kv = k.shape[1]

    if merged:
        if group > 1:
            k = jnp.repeat(k, group, axis=2)              # (B, Skv, H, dh)
            v = jnp.repeat(v, group, axis=2)
        k = constrain(k, "batch", None, "heads", None)
        v = constrain(v, "batch", None, "heads", None)
        q = constrain(q, "batch", None, "heads", None)
    else:
        k = constrain(k, "batch", None, "kv", None)
        v = constrain(v, "batch", None, "kv", None)
        q = constrain(q, "batch", "q_seq", None, None)

    n_chunks = max(1, S // q_chunk)
    qc = S // n_chunks if S % n_chunks == 0 else S
    if S % qc != 0:
        n_chunks, qc = 1, S

    if merged:
        q = q.reshape(B, n_chunks, qc, n_heads, head_dim).swapaxes(0, 1)
    else:
        q = q.reshape(B, n_chunks, qc, n_kv, group, head_dim).swapaxes(0, 1)
    qpos = positions.reshape(B, n_chunks, qc).swapaxes(0, 1) \
        if positions is not None else None
    kvp = (kv_pos if kv_pos is not None
           else jnp.broadcast_to(jnp.arange(S_kv)[None], (B, S_kv)))

    def _softmax(s):
        if s.dtype == jnp.float32:
            return jax.nn.softmax(s, axis=-1).astype(dt)
        # bf16 logits: subtract the (f32) rowmax, exponentiate in bf16,
        # normalize with an f32 sum — the flash-kernel numerics
        m = jnp.max(s.astype(jnp.float32), axis=-1, keepdims=True)
        e = jnp.exp(s - m.astype(s.dtype))
        z = jnp.sum(e.astype(jnp.float32), axis=-1, keepdims=True)
        return (e / z.astype(s.dtype)).astype(dt)

    def block_merged(q_blk, qp_blk):
        s = jnp.einsum("bqhk,bshk->bhqs", q_blk, k) * scale
        s = s.astype(scores_dtype)   # f32 faithful; bf16 = §Perf knob
        s = constrain(s, "batch", "heads", "q_seq", None)
        if causal:
            mask = qp_blk[:, None, :, None] >= kvp[:, None, None, :]
            s = jnp.where(mask, s, -1e30)
        p = _softmax(s)
        o = jnp.einsum("bhqs,bshk->bqhk", p, v)           # (B,qc,H,dh)
        return constrain(o, "batch", "q_seq", "heads", None)

    def block_grouped(q_blk, qp_blk):
        q_blk = constrain(q_blk, "batch", "q_seq", None, None, None)
        s = jnp.einsum("bqhgk,bshk->bhgqs", q_blk, k) * scale
        s = s.astype(scores_dtype)
        s = constrain(s, "batch", "kv", None, "q_seq", None)
        if causal:
            mask = (qp_blk[:, None, None, :, None]
                    >= kvp[:, None, None, None, :])
            s = jnp.where(mask, s, -1e30)
        p = _softmax(s)
        o = jnp.einsum("bhgqs,bshk->bqhgk", p, v)         # (B,qc,KV,G,dh)
        o = constrain(o, "batch", "q_seq", "kv", None, None)
        return o.reshape(o.shape[0], o.shape[1], n_heads, head_dim)

    block = block_merged if merged else block_grouped

    if n_chunks == 1:
        out = block(q[0], None if qpos is None else qpos[0])
        out = out[None]
    else:
        # Remat per q-chunk: the backward pass recomputes one chunk's
        # scores at a time instead of keeping all of them resident.
        from repro.models.loops import map_or_unroll
        blk = jax.checkpoint(lambda args: block(*args))
        out = map_or_unroll(blk, (q, qpos), unroll=unroll)

    out = out.swapaxes(0, 1).reshape(B, S, n_heads, head_dim)
    out = constrain(out, "batch", None, "heads", None)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))


def init_kv_cache(batch, max_seq, n_kv, head_dim, dtype=jnp.bfloat16):
    shape = (batch, max_seq, n_kv, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_spec(batch, max_seq, n_kv, head_dim, dtype=jnp.bfloat16):
    shape = (batch, max_seq, n_kv, head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def decode_attention(params, x, cache, positions, *, n_heads, n_kv, head_dim,
                     qk_norm=False, rope_theta=1e4, cross=False,
                     update_cache=True):
    """Single-token attention against a KV cache.

    This is the DENSE decode-attention implementation — one of the two
    pluggable decode hooks a model's ``decode_step`` can run: dense
    attention over a per-slot ``(B, S_max, ...)`` cache view (this
    function; the paged serving rung feeds it a gathered view), or
    :func:`paged_decode_attention`, which consumes a paged block pool +
    block tables directly and never builds the dense view at all.

    x: (B, 1, d); positions: (B,) current index per sequence.
    cache: {"k","v"} of (B, S_max, KV, dh), sequence-sharded for long ctx.
    Returns (out (B, 1, d), new_cache).
    """
    B, T, d = x.shape
    dt = x.dtype
    scale = head_dim ** -0.5
    group = n_heads // n_kv

    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(dt))
    if qk_norm:
        q = rms_norm(q, params["q_norm"])
    q = rope(q, positions[:, None], rope_theta)

    if cross or not update_cache:
        ck, cv = cache["k"], cache["v"]
        new_cache = cache
    else:
        k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(dt))
        v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(dt))
        if qk_norm:
            k = rms_norm(k, params["k_norm"])
        k = rope(k, positions[:, None], rope_theta)
        b_idx = jnp.arange(B)
        ck = cache["k"].at[b_idx, positions].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[b_idx, positions].set(v[:, 0].astype(cache["v"].dtype))
        new_cache = {"k": ck, "v": cv}

    ck = constrain(ck, "batch", "kv_seq", "kv", None)
    cv = constrain(cv, "batch", "kv_seq", "kv", None)
    S = ck.shape[1]

    qg = q.reshape(B, T, n_kv, group, head_dim)
    s = jnp.einsum("bthgk,bshk->bhgts", qg, ck.astype(dt)) * scale
    s = s.astype(jnp.float32)
    kv_pos = jnp.arange(S)[None]
    valid = kv_pos <= positions[:, None] if not cross \
        else jnp.ones((B, S), bool)
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(dt)
    o = jnp.einsum("bhgts,bshk->bthgk", p, cv.astype(dt))
    o = o.reshape(B, T, n_heads, head_dim)
    out = jnp.einsum("bthk,hkd->btd", o, params["wo"].astype(dt))
    return out, new_cache


def chunk_prefill_attention(params, x, cache, positions, *, n_heads, n_kv,
                            head_dim, qk_norm=False, rope_theta=1e4):
    """Multi-token (prompt-chunk) attention against a dense KV cache —
    the qlen > 1 sibling of :func:`decode_attention`.

    x: (B, C, d) — C consecutive prompt tokens per slot.
    positions: (B, C) — each token's absolute cache index.  Rows past
    the prompt (the padded tail of the final chunk) carry clipped
    positions; their K/V writes land at future positions that are
    rewritten in-graph before first read (the engine's standing garbage
    invariant) and their outputs are discarded by the caller.
    Returns (out (B, C, d), new_cache).  Row arithmetic is identical to
    the single-token path (per-row projections, rope, masked f32
    softmax over the same cache rows), which is what keeps chunked
    prefill bit-identical to feeding the prompt one token at a time.
    """
    B, C, d = x.shape
    dt = x.dtype
    scale = head_dim ** -0.5
    group = n_heads // n_kv

    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(dt))
    if qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    q = rope(q, positions, rope_theta)
    k = rope(k, positions, rope_theta)

    b_idx = jnp.arange(B)[:, None]
    ck = cache["k"].at[b_idx, positions].set(k.astype(cache["k"].dtype))
    cv = cache["v"].at[b_idx, positions].set(v.astype(cache["v"].dtype))

    ck = constrain(ck, "batch", "kv_seq", "kv", None)
    cv = constrain(cv, "batch", "kv_seq", "kv", None)
    S = ck.shape[1]

    qg = q.reshape(B, C, n_kv, group, head_dim)
    s = jnp.einsum("bthgk,bshk->bhgts", qg, ck.astype(dt)) * scale
    s = s.astype(jnp.float32)
    kv_pos = jnp.arange(S)[None, None]
    valid = kv_pos <= positions[:, :, None]              # (B, C, S)
    s = jnp.where(valid[:, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(dt)
    o = jnp.einsum("bhgts,bshk->bthgk", p, cv.astype(dt))
    o = o.reshape(B, C, n_heads, head_dim)
    out = jnp.einsum("bthk,hkd->btd", o, params["wo"].astype(dt))
    return out, {"k": ck, "v": cv}


def _unpack_paged(kvs):
    """(ck, cv) or (ck, cv, sk, sv) from the paged kv-leaf tuple."""
    if len(kvs) == 2:
        return kvs[0], kvs[1], None, None
    ck, cv, sk, sv = kvs
    return ck, cv, sk, sv


def _quant_block_write(blk, sblk, write_fn, valid, kv_dtype, dt):
    """Shared requant-on-append discipline for ONE window of pool
    blocks: dequantize the stored window (same rounding site as the
    kernel/gather), apply ``write_fn`` to install the new bf16 K/V,
    zero positions outside ``valid`` so stale garbage never inflates the
    absmax, then re-derive the scale and re-quantize.  Returns
    (quantized window, new scales)."""
    from repro.serving import kvquant

    wide = kvquant.dequantize(blk, sblk, dt)
    wide = jnp.where(valid, write_fn(wide), 0)
    # token + head-dim axes of the (..., T, KV, dh) window
    sx = (wide.ndim - 3, wide.ndim - 1)
    s = kvquant.block_scale(wide, sx, kv_dtype)
    return kvquant.quantize(wide, s, kv_dtype), s


def paged_chunk_prefill_attention(params, x, kvs, tables, positions,
                                  lengths, *, n_heads, n_kv, head_dim,
                                  qk_norm=False, rope_theta=1e4,
                                  kv_dtype="bf16", start=None):
    """Prompt-chunk attention straight off the paged block pool — the
    qlen > 1 sibling of :func:`paged_decode_attention`.

    x: (B, C, d); kvs: (k, v) pool leaves (R, T, KV, dh) — or
    (k, v, k_scale, v_scale) with (R, 1, KV, 1) scales for narrow
    pools; tables: (B, nb); positions: (B, C) absolute index per chunk
    token (clipped for the padded tail — those writes go to
    in-reservation blocks or the NULL block, both write-garbage-safe);
    lengths: (B,) UNCLIPPED ``start + C`` so the kernel's per-row causal
    limits stay exact for the real rows even when the padded tail clips;
    ``start`` (B,) anchors the narrow pools' requant window.
    Returns (out (B, C, d), new kv-leaf tuple).
    """
    from repro.kernels.paged_attention.ops import paged_prefill_attention

    B, C, d = x.shape
    dt = x.dtype
    ck, cv, sk, sv = _unpack_paged(kvs)
    T = ck.shape[1]
    nb = tables.shape[1]

    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(dt))
    if qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    q = rope(q, positions, rope_theta)
    k = rope(k, positions, rope_theta)

    if sk is None:
        rows = jnp.take_along_axis(tables, positions // T, axis=1)  # (B, C)
        offs = positions % T
        ck = ck.at[rows, offs].set(k.astype(ck.dtype))
        cv = cv.at[rows, offs].set(v.astype(cv.dtype))
        o = paged_prefill_attention(q, ck, cv, tables, lengths)
        out = jnp.einsum("bthk,hkd->btd", o.astype(dt),
                         params["wo"].astype(dt))
        return out, (ck, cv)

    # Narrow pool: a chunk spans at most ceil(C/T)+1 logical blocks, so
    # read-modify-write exactly that window per slot.  Window entries
    # past the table horizon redirect to the NULL block (never clip to a
    # real row — a duplicate write there would corrupt live state; NULL
    # absorbs duplicates by design).
    from repro.serving.paged import NULL_BLOCK

    nt = min((C - 1) // T + 2, nb)
    jb_first = (start // T).astype(jnp.int32)             # (B,)
    jbs = jb_first[:, None] + jnp.arange(nt)[None]        # (B, nt)
    rows = jnp.where(
        jbs < nb,
        jnp.take_along_axis(tables, jnp.clip(jbs, 0, nb - 1), axis=1),
        NULL_BLOCK)
    bi = jnp.arange(B)[:, None]
    wi = jnp.clip(positions // T - jb_first[:, None], 0, nt - 1)
    woff = positions % T
    abs_idx = jbs[:, :, None] * T + jnp.arange(T)[None, None]  # (B, nt, T)
    valid = (abs_idx < lengths[:, None, None])[..., None, None]

    ck, nsk = _quant_block_write(
        ck[rows], sk[rows],
        lambda w: w.at[bi, wi, woff].set(k.astype(dt)), valid, kv_dtype, dt)
    cv, nsv = _quant_block_write(
        cv[rows], sv[rows],
        lambda w: w.at[bi, wi, woff].set(v.astype(dt)), valid, kv_dtype, dt)
    ck = kvs[0].at[rows].set(ck)
    cv = kvs[1].at[rows].set(cv)
    sk = sk.at[rows].set(nsk)
    sv = sv.at[rows].set(nsv)

    o = paged_prefill_attention(q, ck, cv, tables, lengths,
                                k_scale=sk[:, 0, :, 0],
                                v_scale=sv[:, 0, :, 0])
    out = jnp.einsum("bthk,hkd->btd", o.astype(dt), params["wo"].astype(dt))
    return out, (ck, cv, sk, sv)


def paged_decode_attention(params, x, kvs, tables, positions, *, n_heads,
                           n_kv, head_dim, qk_norm=False, rope_theta=1e4,
                           kv_dtype="bf16"):
    """Gather-free decode attention against a paged KV block pool.

    The paged-decode counterpart of :func:`decode_attention` (the other
    pluggable hook): instead of a per-slot dense cache view it takes the
    raw pool leaves plus each slot's block table, appends the current
    token's K/V into the slot's active block IN PLACE — one (KV, dh)
    vector per slot, O(B) traffic, not the O(B * max_seq) dense gather —
    and runs the block-table-aware Pallas kernel, which walks the table
    and streams only the blocks each slot's table references.

    x: (B, 1, d); kvs: (k, v) pool leaves (R, T, KV, dh), row 0 the
    NULL block — or (k, v, k_scale, v_scale) with (R, 1, KV, 1) scales
    for narrow pools, which re-quantize the slot's ACTIVE block around
    the append (dequantize, write, mask the unwritten tail, rescale);
    tables: (B, nb); positions: (B,) current index per slot.  Inactive
    slots point every table entry at the NULL block, whose contents are
    write-garbage by design — their outputs are discarded by the
    engine.  Returns (out (B, 1, d), new kv-leaf tuple).
    """
    from repro.kernels.paged_attention.ops import paged_attention

    B, _, d = x.shape
    dt = x.dtype
    ck, cv, sk, sv = _unpack_paged(kvs)
    T = ck.shape[1]

    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(dt))
    if qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    q = rope(q, positions[:, None], rope_theta)
    k = rope(k, positions[:, None], rope_theta)

    # In-place append: position p lives in logical block p // T at
    # offset p % T; the table maps it to a physical pool row.
    row = jnp.take_along_axis(tables, (positions // T)[:, None],
                              axis=1)[:, 0]
    off = positions % T

    if sk is None:
        ck = ck.at[row, off].set(k[:, 0].astype(ck.dtype))
        cv = cv.at[row, off].set(v[:, 0].astype(cv.dtype))
        o = paged_attention(q[:, 0], ck, cv, tables, positions + 1)
        out = jnp.einsum("bhk,hkd->bd", o.astype(dt),
                         params["wo"].astype(dt))
        return out[:, None], (ck, cv)

    bi = jnp.arange(B)
    valid = (jnp.arange(T)[None, :] <= off[:, None])[..., None, None]
    nckb, nsk = _quant_block_write(
        ck[row], sk[row],
        lambda w: w.at[bi, off].set(k[:, 0].astype(dt)), valid, kv_dtype, dt)
    ncvb, nsv = _quant_block_write(
        cv[row], sv[row],
        lambda w: w.at[bi, off].set(v[:, 0].astype(dt)), valid, kv_dtype, dt)
    ck = ck.at[row].set(nckb)
    cv = cv.at[row].set(ncvb)
    sk = sk.at[row].set(nsk)
    sv = sv.at[row].set(nsv)

    o = paged_attention(q[:, 0], ck, cv, tables, positions + 1,
                        k_scale=sk[:, 0, :, 0], v_scale=sv[:, 0, :, 0])
    out = jnp.einsum("bhk,hkd->bd", o.astype(dt), params["wo"].astype(dt))
    return out[:, None], (ck, cv, sk, sv)
