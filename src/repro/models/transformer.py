"""Decoder-only transformer (dense + MoE): train forward, loss, decode.

Structure notes (scale posture):
  * Layers are scan-stacked (``jax.lax.scan`` over a (L, ...) param tree) —
    compile time and HLO size are O(1) in depth (88/96-layer configs).
  * Per-layer remat (``jax.checkpoint``) bounds activation memory to one
    layer's inputs; policy from ``ArchConfig.remat``.
  * Vocab is padded to a multiple of 256 so the TP axis always divides it.
  * Loss uses chunked cross-entropy (no full (B, S, V) f32 logits tensor).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.layers import (
    PDef, chunked_cross_entropy, init_params, mlp_apply, mlp_defs,
    param_axes, rms_norm, rms_norm_defs, stack_defs,
)
from repro.parallel.sharding import constrain

VOCAB_PAD = 256


def padded_vocab(v: int) -> int:
    return (v + VOCAB_PAD - 1) // VOCAB_PAD * VOCAB_PAD


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------

def block_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    defs = {
        "attn_norm": rms_norm_defs(d),
        "attn": attn.attn_defs(d, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, cfg.qk_norm),
        "mlp_norm": rms_norm_defs(d),
    }
    if cfg.n_experts:
        defs["moe"] = moe_mod.moe_defs(
            d, cfg.n_experts, cfg.expert_d_ff,
            shared_d_ff=cfg.d_ff if cfg.shared_expert else 0,
        )
    else:
        defs["mlp"] = mlp_defs(d, cfg.d_ff, cfg.mlp_kind)
    return defs


def model_defs(cfg: ArchConfig) -> dict:
    vp = padded_vocab(cfg.vocab)
    return {
        "embedding": PDef((vp, cfg.d_model), ("vocab", "embed"), "small"),
        "lm_head": PDef((cfg.d_model, vp), ("embed", "vocab")),
        "final_norm": rms_norm_defs(cfg.d_model),
        "layers": stack_defs(block_defs(cfg), cfg.n_layers),
    }


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def block_apply(cfg: ArchConfig, params, h, positions):
    """One decoder block. h: (B, S, d)."""
    a = attn.attention(
        params["attn"], rms_norm(h, params["attn_norm"]), positions,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
        causal=True, qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
        q_chunk=cfg.q_chunk, unroll=cfg.unroll_layers,
        scores_dtype=jnp.dtype(cfg.scores_dtype),
    )
    h = h + a
    hn = rms_norm(h, params["mlp_norm"])
    if cfg.n_experts:
        moe_fn = (moe_mod.moe_apply_grouped if cfg.moe_local_dispatch
                  else moe_mod.moe_apply)
        m, aux = moe_fn(
            params["moe"], hn, n_experts=cfg.n_experts, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor,
        )
    else:
        m, aux = mlp_apply(params["mlp"], hn, cfg.mlp_kind), 0.0
    return h + m, aux


def forward(cfg: ArchConfig, params, tokens, *, extra_embeds=None):
    """tokens (B, S) -> (hidden (B, S, d), aux).  ``extra_embeds``
    (B, P, d) is prepended (VLM patches / audio frames)."""
    dt = jnp.dtype(cfg.compute_dtype)
    emb = params["embedding"].astype(dt)
    h = emb[tokens]
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(dt), h], axis=1)
    B, S, _ = h.shape
    h = constrain(h, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(carry, layer_params):
        h, aux = carry
        h, a = block_apply(cfg, layer_params, h, positions)
        return (h, aux + a), None

    from repro.models.remat import resolve_policy, wrap_layer_body
    body_fn = wrap_layer_body(body, resolve_policy(cfg))
    from repro.models.loops import scan_or_unroll
    (h, aux), _ = scan_or_unroll(body_fn, (h, jnp.zeros((), jnp.float32)),
                                 params["layers"], unroll=cfg.unroll_layers)
    h = rms_norm(h, params["final_norm"])
    return h, aux


def lm_loss(cfg: ArchConfig, params, batch):
    """batch: {"tokens": (B,S), "labels": (B,S)} (+ optional "frames" /
    "patches" (B,P,d) prepended; loss is over the text positions only)."""
    extra = batch.get("frames", batch.get("patches"))
    h, aux = forward(cfg, params, batch["tokens"], extra_embeds=extra)
    if extra is not None:
        h = h[:, extra.shape[1]:]
    loss = chunked_cross_entropy(
        h, params, batch["labels"],
        chunk=min(cfg.loss_chunk, batch["labels"].shape[1]),
        compute_dtype=jnp.dtype(cfg.compute_dtype),
        unroll=cfg.unroll_layers,
    )
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def cache_spec(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    per = attn.kv_cache_spec(batch, max_seq, cfg.n_kv_heads, cfg.head_dim,
                             dtype)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype),
        per,
    )


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, max_seq, dtype))


def _decode_layers(cfg: ArchConfig, params, kv_leaves, tokens, attn_body,
                   last=None, all_rows=False):
    """Shared decode skeleton: embed -> scan layers -> final norm ->
    logits.  ``attn_body`` is the pluggable decode-attention hook applied
    per layer — dense attention on a per-slot cache view
    (:func:`decode_step`), or the paged Pallas kernel on the raw block
    pool (:func:`paged_decode_step`); ``kv_leaves`` is the TUPLE of
    matching stacked-over-layers cache leaves it consumes and rewrites —
    (k, v) for bf16 pools, (k, v, k_scale, v_scale) for narrow pools —
    and the same-arity tuple of new leaves comes back out.

    ``tokens`` may carry C >= 1 positions per row (chunked prefill).
    ``last`` (B,) selects the logits row per slot — the chunk's final
    REAL prompt token, so a padded final chunk still emits the right
    first token; ``None`` keeps the decode path's row 0 untouched.
    ``all_rows`` returns logits at EVERY row (B, C, vocab_padded) for
    speculative verify — projected one row at a time so each (B, d) @
    (d, vocab) matmul is the exact shape the decode path runs (same
    reduction, bit-identical logits per row)."""
    dt = jnp.dtype(cfg.compute_dtype)
    h = params["embedding"].astype(dt)[tokens]           # (B, C, d)

    def body(h, xs):
        layer_params = xs[0]
        a, new_kvs = attn_body(layer_params,
                               rms_norm(h, layer_params["attn_norm"]),
                               *xs[1:])
        h = h + a
        hn = rms_norm(h, layer_params["mlp_norm"])
        if cfg.n_experts:
            m, _ = moe_mod.moe_apply(
                layer_params["moe"], hn, n_experts=cfg.n_experts,
                top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            )
        else:
            m = mlp_apply(layer_params["mlp"], hn, cfg.mlp_kind)
        return h + m, tuple(new_kvs)

    from repro.models.loops import scan_or_unroll
    h, new_leaves = scan_or_unroll(body, h,
                                   (params["layers"],) + tuple(kv_leaves),
                                   unroll=cfg.unroll_layers)
    h = rms_norm(h, params["final_norm"])
    if all_rows:
        w = params["lm_head"].astype(dt)
        logits = jnp.stack(
            [(h[:, j] @ w).astype(jnp.float32) for j in range(h.shape[1])],
            axis=1)
        return logits, new_leaves
    hl = h[:, 0] if last is None else jnp.take_along_axis(
        h, last[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    logits = (hl @ params["lm_head"].astype(dt)).astype(jnp.float32)
    return logits, new_leaves


def decode_step(cfg: ArchConfig, params, cache, tokens, positions):
    """One decode step. tokens (B, 1) int32; positions (B,) int32.
    Returns (logits (B, vocab_padded), new_cache)."""

    def attn_body(layer_params, hn, ck, cv):
        a, nc = attn.decode_attention(
            layer_params["attn"], hn, {"k": ck, "v": cv}, positions,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
        )
        return a, (nc["k"], nc["v"])

    logits, (nk, nv) = _decode_layers(
        cfg, params, (cache["k"], cache["v"]), tokens, attn_body)
    return logits, {"k": nk, "v": nv}


def _paged_leaves(pool, scales):
    """The kv-leaf tuple a paged step scans over: (k, v) plus, for
    narrow pools, the per-layer (L, R, 1, KV, 1) scale leaves."""
    if scales is None:
        return (pool["k"], pool["v"])
    return (pool["k"], pool["v"], scales["k"], scales["v"])


def _paged_result(logits, new_leaves, scales):
    if scales is None:
        nk, nv = new_leaves
        return logits, {"k": nk, "v": nv}
    nk, nv, nsk, nsv = new_leaves
    return logits, {"k": nk, "v": nv}, {"k": nsk, "v": nsv}


def paged_decode_step(cfg: ArchConfig, params, pool, tables, tokens,
                      positions, scales=None, kv_dtype: str = "bf16"):
    """Gather-free paged decode step (the serving O6 kernel path).

    Identical layer structure to :func:`decode_step`, but each layer's
    attention consumes the raw block-pool leaves (R, T, KV, dh) plus the
    per-slot block tables via ``attn.paged_decode_attention`` — the
    dense (B, max_seq, ...) view is never materialized; the current
    token's K/V is appended into the active block in place and the
    Pallas kernel streams only the blocks each slot's table references.

    Narrow pools (``scales`` given) re-quantize the slot's active block
    around the append and return the scales as a third result:
    (logits, pool, scales).
    """

    def attn_body(layer_params, hn, *kvs):
        return attn.paged_decode_attention(
            layer_params["attn"], hn, kvs, tables, positions,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
            kv_dtype=kv_dtype,
        )

    logits, new_leaves = _decode_layers(
        cfg, params, _paged_leaves(pool, scales), tokens, attn_body)
    return _paged_result(logits, new_leaves, scales)


def prefill_step(cfg: ArchConfig, params, cache, tokens, start, last):
    """One prompt-chunk step against the dense cache: tokens (B, C)
    int32 — C consecutive prompt tokens per slot starting at cache
    position ``start`` (B,); ``last`` (B,) is the row index of the
    chunk's final real token.  Returns (logits (B, vocab_padded) for the
    ``last`` rows, new_cache).  The padded tail of a final chunk rides
    along with clipped positions — its K/V writes land at future
    positions that are rewritten before first read, its logits rows are
    never selected.  Not valid for MoE configs (expert capacity is
    token-count-dependent); the ModelAPI wiring gates that."""
    C = tokens.shape[1]
    max_seq = cache["k"].shape[2]
    positions = jnp.clip(start[:, None] + jnp.arange(C)[None], 0,
                         max_seq - 1).astype(jnp.int32)

    def attn_body(layer_params, hn, ck, cv):
        a, nc = attn.chunk_prefill_attention(
            layer_params["attn"], hn, {"k": ck, "v": cv}, positions,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
        )
        return a, (nc["k"], nc["v"])

    logits, (nk, nv) = _decode_layers(
        cfg, params, (cache["k"], cache["v"]), tokens, attn_body, last=last)
    return logits, {"k": nk, "v": nv}


def paged_prefill_step(cfg: ArchConfig, params, pool, tables, tokens,
                       start, last, scales=None, kv_dtype: str = "bf16"):
    """Prompt-chunk step straight off the paged block pool: the chunk's
    K/V is scattered into pool blocks through the slot's table and the
    multi-query Pallas kernel attends the whole prefix — the dense view
    is never materialized.  Same signature discipline as
    :func:`prefill_step` plus the tables (and, for narrow pools, the
    scales: returns (logits, pool, scales))."""
    C = tokens.shape[1]
    T = pool["k"].shape[2]
    nb = tables.shape[1]
    positions = jnp.clip(start[:, None] + jnp.arange(C)[None], 0,
                         nb * T - 1).astype(jnp.int32)
    lengths = (start + C).astype(jnp.int32)      # unclipped: exact row masks

    def attn_body(layer_params, hn, *kvs):
        return attn.paged_chunk_prefill_attention(
            layer_params["attn"], hn, kvs, tables,
            positions, lengths,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
            kv_dtype=kv_dtype, start=start,
        )

    logits, new_leaves = _decode_layers(
        cfg, params, _paged_leaves(pool, scales), tokens, attn_body,
        last=last)
    return _paged_result(logits, new_leaves, scales)


def verify_step(cfg: ArchConfig, params, cache, tokens, start):
    """Speculative-verify step against the dense cache: tokens (B, C) —
    the pending token plus C-1 drafted tokens per slot, written at cache
    positions ``start`` .. ``start + C - 1``.  Returns (logits
    (B, C, vocab_padded) at EVERY row, new_cache): row j is the target's
    distribution after token j, so greedy rejection accepts the longest
    prefix where draft j+1 == argmax(row j).  Attention math is the
    chunked-prefill path (row arithmetic bit-identical to single-token
    decode); rejected rows' K/V writes land beyond the slot's frontier
    and are rewritten before first unmasked read — rollback is free.
    Not valid for MoE configs; the ModelAPI wiring gates that."""
    C = tokens.shape[1]
    max_seq = cache["k"].shape[2]
    positions = jnp.clip(start[:, None] + jnp.arange(C)[None], 0,
                         max_seq - 1).astype(jnp.int32)

    def attn_body(layer_params, hn, ck, cv):
        a, nc = attn.chunk_prefill_attention(
            layer_params["attn"], hn, {"k": ck, "v": cv}, positions,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
        )
        return a, (nc["k"], nc["v"])

    logits, (nk, nv) = _decode_layers(
        cfg, params, (cache["k"], cache["v"]), tokens, attn_body,
        all_rows=True)
    return logits, {"k": nk, "v": nv}


def paged_verify_step(cfg: ArchConfig, params, pool, tables, tokens, start,
                      scales=None, kv_dtype: str = "bf16"):
    """Speculative-verify step straight off the paged block pool: the
    window's K/V is scattered into pool blocks through the slot's table
    (writes past the reservation are absorbed by the NULL block) and the
    multi-query Pallas kernel attends the whole prefix.  Same all-rows
    logits contract as :func:`verify_step`; rejected drafts roll back by
    slot-length truncation — the table rows never change, so blocks
    never leak.  Narrow pools return (logits, pool, scales)."""
    C = tokens.shape[1]
    T = pool["k"].shape[2]
    nb = tables.shape[1]
    positions = jnp.clip(start[:, None] + jnp.arange(C)[None], 0,
                         nb * T - 1).astype(jnp.int32)
    lengths = (start + C).astype(jnp.int32)      # unclipped: exact row masks

    def attn_body(layer_params, hn, *kvs):
        return attn.paged_chunk_prefill_attention(
            layer_params["attn"], hn, kvs, tables,
            positions, lengths,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
            kv_dtype=kv_dtype, start=start,
        )

    logits, new_leaves = _decode_layers(
        cfg, params, _paged_leaves(pool, scales), tokens, attn_body,
        all_rows=True)
    return _paged_result(logits, new_leaves, scales)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def cache_axes(cfg: ArchConfig) -> dict:
    ax = ("layers", "batch", "kv_seq", "kv", None)
    return {"k": ax, "v": ax}


def init(cfg: ArchConfig, rng) -> dict:
    return init_params(rng, model_defs(cfg), jnp.dtype(cfg.param_dtype))


def axes(cfg: ArchConfig):
    return param_axes(model_defs(cfg))
