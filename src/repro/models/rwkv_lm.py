"""RWKV-6 language model: attention-free stack of time-mix + channel-mix."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import rwkv6
from repro.models.layers import (
    PDef, chunked_cross_entropy, init_params, param_axes, rms_norm,
    rms_norm_defs, stack_defs,
)
from repro.models.transformer import padded_vocab
from repro.parallel.sharding import constrain


def model_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    vp = padded_vocab(cfg.vocab)
    block = {
        "tm": rwkv6.rwkv6_time_mix_defs(d, cfg.rwkv_head_dim),
        "cm": rwkv6.rwkv6_channel_mix_defs(d, cfg.d_ff),
    }
    return {
        "embedding": PDef((vp, d), ("vocab", "embed"), "small"),
        "lm_head": PDef((d, vp), ("embed", "vocab")),
        "final_norm": rms_norm_defs(d),
        "layers": stack_defs(block, cfg.n_layers),
    }


def forward(cfg: ArchConfig, params, tokens):
    dt = jnp.dtype(cfg.compute_dtype)
    h = params["embedding"].astype(dt)[tokens]
    h = constrain(h, "batch", None, None)

    def body(h, layer_params):
        out, _ = rwkv6.time_mix_apply(layer_params["tm"], h,
                                      head_dim=cfg.rwkv_head_dim,
                                      unroll=cfg.unroll_layers)
        h = h + out
        out, _ = rwkv6.channel_mix_apply(layer_params["cm"], h)
        return h + out, None

    from repro.models.remat import resolve_policy, wrap_layer_body
    body_fn = wrap_layer_body(body, resolve_policy(cfg))
    from repro.models.loops import scan_or_unroll
    h, _ = scan_or_unroll(body_fn, h, params["layers"],
                          unroll=cfg.unroll_layers)
    return rms_norm(h, params["final_norm"])


def lm_loss(cfg: ArchConfig, params, batch):
    h = forward(cfg, params, batch["tokens"])
    return chunked_cross_entropy(
        h, params, batch["labels"],
        chunk=min(cfg.loss_chunk, batch["labels"].shape[1]),
        compute_dtype=jnp.dtype(cfg.compute_dtype),
        unroll=cfg.unroll_layers,
    )


# ---------------------------------------------------------------------------
# Decode (state: wkv matrix + the two token-shift slots per layer)
# ---------------------------------------------------------------------------

def cache_spec(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    d, N = cfg.d_model, cfg.rwkv_head_dim
    H = d // N
    L = cfg.n_layers
    return {
        "wkv": jax.ShapeDtypeStruct((L, batch, H, N, N), jnp.float32),
        "tm_prev": jax.ShapeDtypeStruct((L, batch, d), dtype),
        "cm_prev": jax.ShapeDtypeStruct((L, batch, d), dtype),
    }


def init_cache(cfg, batch, max_seq, dtype=jnp.bfloat16):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, max_seq, dtype))


def decode_step(cfg: ArchConfig, params, cache, tokens, positions):
    """positions unused (state carries history) but kept for API parity."""
    dt = jnp.dtype(cfg.compute_dtype)
    h = params["embedding"].astype(dt)[tokens]           # (B,1,d)

    def body(h, xs):
        layer_params, wkv, tm_prev, cm_prev = xs
        out, (new_wkv, tm_last) = rwkv6.time_mix_apply(
            layer_params["tm"], h, head_dim=cfg.rwkv_head_dim,
            state=wkv, x_prev=tm_prev, decode=True,
        )
        h = h + out
        out, cm_last = rwkv6.channel_mix_apply(
            layer_params["cm"], h, x_prev=cm_prev,
        )
        return h + out, (new_wkv, tm_last.astype(tm_prev.dtype),
                         cm_last.astype(cm_prev.dtype))

    from repro.models.loops import scan_or_unroll
    h, (wkv, tm_p, cm_p) = scan_or_unroll(
        body, h,
        (params["layers"], cache["wkv"], cache["tm_prev"], cache["cm_prev"]),
        unroll=cfg.unroll_layers)
    h = rms_norm(h, params["final_norm"])
    logits = (h[:, 0] @ params["lm_head"].astype(dt)).astype(jnp.float32)
    return logits, {"wkv": wkv, "tm_prev": tm_p, "cm_prev": cm_p}


def cache_axes(cfg: ArchConfig) -> dict:
    return {
        "wkv": ("layers", "batch", "heads", None, None),
        "tm_prev": ("layers", "batch", None),
        "cm_prev": ("layers", "batch", None),
    }


def paged_decode_step(cfg: ArchConfig, params, pool, rows, tokens,
                      positions, scales=None, kv_dtype: str = "bf16"):
    """State-pool decode step (serving O6): every cache leaf lives in a
    row pool with a spare NULL garbage row; ``rows`` (B,) int32 maps
    each slot to its state row.  Gather the active rows to the dense
    batch view, run the exact contiguous decode body, scatter back
    through the same rows — slots parked on the NULL row read garbage
    (their logits are discarded) and their writes collapse into the
    sink.  Recurrent state is never quantized, so ``scales``/
    ``kv_dtype`` are accepted only for signature parity with the
    transformer's paged step."""
    del scales, kv_dtype
    cache = jax.tree.map(lambda l: jnp.take(l, rows, axis=1), pool)
    logits, new = decode_step(cfg, params, cache, tokens, positions)
    new_pool = jax.tree.map(
        lambda p, n: p.at[:, rows].set(n.astype(p.dtype)), pool, new)
    return logits, new_pool


def prefill_step(cfg: ArchConfig, params, cache, tokens, start, last):
    """Chunked prefill by scanning the decode body: bit-identical to C
    one-token ticks, with per-slot freeze past ``last`` so pad feeds
    never corrupt the carried wkv/token-shift state."""
    from repro.models.scan_prefill import batch_axes_of, scan_prefill

    def step(c, tok, pos):
        return decode_step(cfg, params, c, tok, pos)

    return scan_prefill(step, cache, tokens, start, last,
                        logits_width=padded_vocab(cfg.vocab),
                        batch_axes=batch_axes_of(cache_axes(cfg)))


def init(cfg: ArchConfig, rng):
    return init_params(rng, model_defs(cfg), jnp.dtype(cfg.param_dtype))


def axes(cfg: ArchConfig):
    return param_axes(model_defs(cfg))
