"""Scan-or-unroll: every loop in the model zoo goes through here.

Production lowering uses ``lax.scan`` (O(1) HLO size in depth).  The
*cost-twin* lowering (see ``launch/dryrun.py``) unrolls every loop because
XLA's ``cost_analysis()`` counts a while-loop body once regardless of trip
count — measured in this container: a 10-iteration scan of a 256x256 matmul
reports 33.5 MFLOP instead of 335 MFLOP.  The dry-run therefore lowers a
small unrolled twin and extrapolates linearly in layer count; model code
switches on ``ArchConfig.unroll_layers``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def scan_or_unroll(body, carry, xs, *, unroll: bool = False,
                   length: int = None):
    """Drop-in for ``jax.lax.scan(body, carry, xs, length=)``."""
    if not unroll:
        return jax.lax.scan(body, carry, xs, length=length)
    n = length if xs is None else jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def map_or_unroll(fn, xs, *, unroll: bool = False):
    """Drop-in for ``jax.lax.map(fn, xs)``."""
    if not unroll:
        return jax.lax.map(fn, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = [fn(jax.tree.map(lambda a: a[i], xs)) for i in range(n)]
    return jax.tree.map(lambda *a: jnp.stack(a), *ys)
