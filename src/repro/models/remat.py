"""Remat policy knob — §Perf's activation-checkpoint lever.

The paper's guideline trades scratchpad capacity against recompute; on TPU
the same trade is the activation-checkpoint policy:

  "full"  — per-layer ``jax.checkpoint``: minimal activation memory,
            recomputes the whole layer forward in the backward pass
            (the paper-faithful default for the big configs)
  "dots"  — ``checkpoint_dots_with_no_batch_dims``: saves matmul outputs
            (cheap to store, expensive to recompute), recomputes only
            elementwise chains — most of full-remat's memory saving at a
            fraction of its recompute flops/bytes
  "none"  — no outer checkpoint (the attention module still remats its
            score blocks per q-chunk, so peak stays bounded in S)
"""

from __future__ import annotations

import jax


def wrap_layer_body(body, policy):
    """Apply the configured checkpoint policy to a scan body."""
    if policy in (False, None, "none"):
        return body
    if policy in (True, "full"):
        return jax.checkpoint(body)
    if policy == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )
    raise ValueError(f"unknown remat policy {policy!r}")


def resolve_policy(cfg):
    """ArchConfig -> policy value (remat_policy overrides legacy remat)."""
    pol = getattr(cfg, "remat_policy", "")
    if pol:
        return pol
    return "full" if cfg.remat else "none"
