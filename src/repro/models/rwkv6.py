"""RWKV-6 ("Finch"): data-dependent-decay linear attention, attention-free.

Time-mix uses the RWKV-6 ddlerp (token-shift mixed by a low-rank,
data-dependent amount) and a per-channel data-dependent decay
``w = exp(-exp(ww))``; the WKV recurrence

    y_t = r_t . (S_{t-1} + u (x) k_t v_t),   S_t = diag(w_t) S_{t-1} + k_t v_t

is evaluated in *chunked* form for training (the load-compute-store ladder
applied to a recurrence; mirrored by ``repro/kernels/rwkv6_wkv.py``) and as
a single-step update for decode.

Numerics: within-chunk decay products are computed as exp(cum_i - cum_j)
with log-decay clamped to [-LW_CLAMP, 0] so chunk-local exponents stay in
f32 range (documented in DESIGN.md; the sequential oracle uses the same
clamp, so chunked == sequential holds exactly).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import PDef, rms_norm
from repro.parallel.sharding import constrain

LORA_MIX = 32       # ddlerp low-rank width
LORA_DECAY = 64     # decay low-rank width
LW_CLAMP = 0.35     # max |log w| per step (see module docstring)


def rwkv6_time_mix_defs(d: int, head_dim: int = 64) -> dict:
    H = d // head_dim
    return {
        "ln": PDef((d,), (None,), "ones"),
        "mu_base": PDef((d,), (None,), "small"),
        "mix_w1": PDef((d, 5 * LORA_MIX), ("embed", None), "small"),
        "mix_w2": PDef((5, LORA_MIX, d), (None, None, "embed"), "small"),
        "mu5": PDef((5, d), (None, None), "small"),
        "decay_w0": PDef((d,), (None,), "small"),
        "decay_w1": PDef((d, LORA_DECAY), ("embed", None), "small"),
        "decay_w2": PDef((LORA_DECAY, d), (None, "embed"), "small"),
        "wr": PDef((d, d), ("embed", "heads")),
        "wk": PDef((d, d), ("embed", "heads")),
        "wv": PDef((d, d), ("embed", "heads")),
        "wg": PDef((d, d), ("embed", "heads")),
        "bonus_u": PDef((H, head_dim), ("heads", None), "small"),
        "wo": PDef((d, d), ("heads", "embed")),
        "out_gn": PDef((d,), (None,), "ones"),
    }


def rwkv6_channel_mix_defs(d: int, d_ff: int) -> dict:
    return {
        "ln": PDef((d,), (None,), "ones"),
        "mu_k": PDef((d,), (None,), "small"),
        "mu_r": PDef((d,), (None,), "small"),
        "wk": PDef((d, d_ff), ("embed", "mlp")),
        "wv": PDef((d_ff, d), ("mlp", "embed")),
        "wr": PDef((d, d), ("embed", "embed2")),
    }


def _token_shift(x, x_prev_token=None):
    """Shift right by one along seq; first slot filled by x_prev_token."""
    first = (jnp.zeros_like(x[:, :1]) if x_prev_token is None
             else x_prev_token[:, None])
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _ddlerp(params, x, xx):
    """RWKV6 data-dependent lerp -> the 5 mixed inputs (w,k,v,r,g)."""
    base = x + xx * params["mu_base"].astype(x.dtype)
    lora = jnp.tanh(base @ params["mix_w1"].astype(x.dtype))
    B, S, _ = lora.shape
    lora = lora.reshape(B, S, 5, LORA_MIX)
    dyn = jnp.einsum("bsfl,fld->bsfd", lora,
                     params["mix_w2"].astype(x.dtype))
    mixed = (x[:, :, None]
             + xx[:, :, None] * (params["mu5"].astype(x.dtype) + dyn))
    return [mixed[:, :, i] for i in range(5)]


def wkv_chunked(r, k, v, lw, u, *, chunk: int, init_state=None,
                unroll: bool = False):
    """Chunked WKV. r,k,v: (B,S,H,N); lw: (B,S,H,N) log-decay in [-c,0];
    u: (H,N).  Returns (y (B,S,H,N), final_state (B,H,N,N))."""
    B, S, H, N = r.shape
    nc = S // chunk
    assert S % chunk == 0

    cm = lambda t: jnp.moveaxis(t.reshape(B, nc, chunk, H, N), 1, 0)
    rc, kc, vc, lwc = cm(r), cm(k), cm(v), cm(lw)
    ii = jnp.arange(chunk)
    strict = (ii[:, None] > ii[None, :])[None, :, :, None]   # j < i
    s0 = (init_state if init_state is not None
          else jnp.zeros((B, H, N, N), jnp.float32))

    def body(state, inp):
        r_c, k_c, v_c, lw_c = inp                 # (B,Q,H,N)
        cum = jnp.cumsum(lw_c, axis=1)            # (B,Q,H,N)
        # A[i,j] = sum_c r_i[c] k_j[c] exp(cum_{i-1,c} - cum_{j,c})  (j<i)
        ri = r_c * jnp.exp(cum - lw_c)            # r_i * exp(cum_{i-1})
        kj = k_c * jnp.exp(-cum)
        A = jnp.einsum("bihc,bjhc->bhij", ri, kj)
        A = jnp.where(jnp.moveaxis(strict, -1, 1), A, 0.0)
        diag = jnp.einsum("bihc,hc,bihc->bih", r_c, u, k_c)
        y = jnp.einsum("bhij,bjhn->bihn", A, v_c) \
            + diag[..., None] * v_c
        # State read: y_i += r_i exp(cum_{i-1}) . S_0
        y = y + jnp.einsum("bihc,bhcn->bihn", ri, state.astype(ri.dtype))
        # State update to end of chunk.
        decay_k = jnp.exp(cum[:, -1:] - cum)      # (B,Q,H,N)
        st_c = jnp.einsum("bjhc,bjhn->bhcn", k_c * decay_k, v_c)
        total_decay = jnp.exp(cum[:, -1])         # (B,H,N)
        new_state = (state * total_decay[..., None].astype(jnp.float32)
                     + st_c.astype(jnp.float32))
        return new_state, y

    from repro.models.loops import scan_or_unroll
    final, ys = scan_or_unroll(body, s0, (rc, kc, vc, lwc), unroll=unroll)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, N)
    return y, final


def wkv_sequential(r, k, v, lw, u, *, init_state=None):
    """Step-by-step oracle for the chunked form (tests/property checks)."""
    B, S, H, N = r.shape
    s0 = (init_state if init_state is not None
          else jnp.zeros((B, H, N, N), jnp.float32))

    def step(state, inp):
        r_t, k_t, v_t, lw_t = inp                 # (B,H,N)
        kv = jnp.einsum("bhc,bhn->bhcn", k_t, v_t).astype(jnp.float32)
        kv = constrain(kv, "batch", "heads", None, None)
        y = jnp.einsum("bhc,bhcn->bhn", r_t.astype(jnp.float32),
                       state + u[..., None] * kv)
        state = state * jnp.exp(lw_t.astype(jnp.float32))[..., None] + kv
        state = constrain(state, "batch", "heads", None, None)
        return state, y

    tm = lambda t: jnp.moveaxis(t, 1, 0)
    final, ys = jax.lax.scan(step, s0, (tm(r), tm(k), tm(v), tm(lw)))
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), final


def time_mix_apply(params, x, *, head_dim=64, chunk=128, state=None,
                   x_prev=None, decode=False, unroll=False):
    """x: (B, S, d).  Returns (out, (final_wkv_state, last_token))."""
    B, S, d = x.shape
    dt_ = x.dtype
    H = d // head_dim

    h = rms_norm(x, params["ln"])
    xx = _token_shift(h, x_prev) - h
    xw, xk, xv, xr, xg = _ddlerp(params, h, xx)

    ww = params["decay_w0"].astype(dt_) + jnp.tanh(
        xw @ params["decay_w1"].astype(dt_)
    ) @ params["decay_w2"].astype(dt_)
    lw = -jnp.clip(jnp.exp(ww.astype(jnp.float32)), 0.0, LW_CLAMP)  # (B,S,d)

    r = (xr @ params["wr"].astype(dt_)).reshape(B, S, H, head_dim)
    k = (xk @ params["wk"].astype(dt_)).reshape(B, S, H, head_dim)
    v = (xv @ params["wv"].astype(dt_)).reshape(B, S, H, head_dim)
    g = jax.nn.silu(xg @ params["wg"].astype(dt_))
    lwh = lw.reshape(B, S, H, head_dim).astype(dt_)
    r = constrain(r, "batch", None, "heads", None)
    u = params["bonus_u"].astype(dt_)

    if decode:
        y, new_state = wkv_sequential(r, k, v, lwh, u, init_state=state)
    else:
        ck = min(chunk, S)
        if S % ck != 0:
            y, new_state = wkv_sequential(r, k, v, lwh, u, init_state=state)
        else:
            y, new_state = wkv_chunked(r, k, v, lwh, u, chunk=ck,
                                       init_state=state, unroll=unroll)

    y = y.reshape(B, S, d)
    y = rms_norm(y, params["out_gn"]) * g
    out = y @ params["wo"].astype(dt_)
    return out, (new_state, h[:, -1])


def channel_mix_apply(params, x, *, x_prev=None):
    """x: (B, S, d) -> (out, last_token)."""
    dt_ = x.dtype
    h = rms_norm(x, params["ln"])
    xx = _token_shift(h, x_prev) - h
    xk = h + xx * params["mu_k"].astype(dt_)
    xr = h + xx * params["mu_r"].astype(dt_)
    k = jnp.maximum(xk @ params["wk"].astype(dt_), 0.0)
    kv = (k * k) @ params["wv"].astype(dt_)
    rgate = jax.nn.sigmoid(xr @ params["wr"].astype(dt_))
    return rgate * kv, h[:, -1]
