"""Chunked prefill for carried-state decoders, by scanning the decode body.

Transformers chunk prefill by batching C prompt tokens into one wide
attention call (``transformer.prefill_step``) — legal because a KV cache
is position-addressed: a padded tail's writes land at future positions
that are rewritten before first read.  Recurrent families (rwkv, mamba,
hybrid) cannot do that: their state is carried, so feeding a parked
slot's pad token would fold garbage into the carry forever.

This module makes chunking legal for those families a different way:
``lax.scan`` the exact single-token decode body over the chunk's C
positions, and FREEZE each slot's cache leaves once the scan passes that
slot's last real token (``j > last``) — a per-leaf ``where`` on the
batch axis, so a short slot's carry stops advancing instead of eating
pads.  The result is bit-identical to C one-token-per-tick steps by
construction (same body, same order, same dtypes), which is what lets
``prefill_mode == "chunked"`` stay inside the ladder's bit-exactness
contract for every family, not just transformers.

One jitted scan per chunk width replaces C dispatches — the win is
dispatch overhead and scheduler ticks, not FLOPs (the body still runs C
times).  That is exactly the paper's communication-batching posture:
same work, fewer round trips.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def batch_axes_of(axes_tree) -> list:
    """Batch-axis index per cache leaf, in tree-flatten order."""
    leaves = jax.tree.leaves(axes_tree,
                             is_leaf=lambda x: isinstance(x, tuple))
    return [ax.index("batch") for ax in leaves]


def scan_prefill(decode_fn, cache, tokens, start, last, *,
                 logits_width: int, batch_axes: list, max_seq=None):
    """Run ``decode_fn`` over a prompt chunk, one token at a time.

    ``decode_fn(cache, tok (B, 1), pos (B,)) -> (logits (B, V), cache)``
    is the family's single-token decode body.  ``tokens`` (B, C) holds C
    consecutive prompt tokens per slot starting at position ``start``
    (B,); ``last`` (B,) is the row index of each slot's final real token
    in this chunk (rows past it are pad).  Returns (logits (B, V) f32
    taken at each slot's ``last`` row, new_cache).

    Slots whose prompt ends mid-chunk are frozen: every cache leaf keeps
    its pre-step value on that slot's batch row for ``j > last``, so pad
    feeds never touch carried state.  ``max_seq`` clips positions for
    families that also hold a position-addressed KV leaf (hybrid
    shared_kv, enc-dec self_kv) — the clipped tail writes are frozen out
    anyway, the clip just keeps indices in range.
    """
    B, C = tokens.shape
    leaves0, treedef = jax.tree.flatten(cache)
    sel0 = jnp.zeros((B, logits_width), jnp.float32)

    def body(carry, j):
        leaves, sel = carry
        tok = jax.lax.dynamic_index_in_dim(tokens, j, axis=1,
                                           keepdims=True)        # (B, 1)
        pos = (start + j).astype(jnp.int32)
        if max_seq is not None:
            pos = jnp.clip(pos, 0, max_seq - 1)
        logits, new_cache = decode_fn(jax.tree.unflatten(treedef, leaves),
                                      tok, pos)
        live = j <= last                                          # (B,)
        out = []
        for old, new, bax in zip(leaves, jax.tree.leaves(new_cache),
                                 batch_axes):
            mask = live.reshape((1,) * bax + (B,) +
                                (1,) * (old.ndim - bax - 1))
            out.append(jnp.where(mask, new.astype(old.dtype), old))
        sel = jnp.where((last == j)[:, None], logits, sel)
        return (out, sel), None

    (leaves, sel), _ = jax.lax.scan(body, (leaves0, sel0),
                                    jnp.arange(C, dtype=jnp.int32))
    return sel, jax.tree.unflatten(treedef, leaves)
