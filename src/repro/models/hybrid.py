"""Zamba2-style hybrid: Mamba-2 trunk + one *shared* attention block.

54 mamba layers in 9 groups of 6; after each group the shared block
(attention + MLP, weights reused across all 9 applications) runs on
``concat(hidden, embedding_output)`` projected down by a per-application
(unshared) linear — the Zamba2 weight-sharing scheme.  The shared block uses
full attention, so this arch is the hybrid long-context cell: its KV caches
exist only at the 9 application points (O(S) memory, sub-quadratic overall
compute share).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mamba2
from repro.models.layers import (
    PDef, chunked_cross_entropy, init_params, mlp_apply, mlp_defs,
    param_axes, rms_norm, rms_norm_defs, stack_defs,
)
from repro.models.transformer import padded_vocab
from repro.parallel.sharding import constrain


def _n_apps(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def _mamba_kw(cfg: ArchConfig) -> dict:
    return dict(expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                state=cfg.ssm_state, conv_width=cfg.conv_width)


def model_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    vp = padded_vocab(cfg.vocab)
    na = _n_apps(cfg)
    shared = {
        "attn_norm": rms_norm_defs(d),
        "attn": attn.attn_defs(d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        "mlp_norm": rms_norm_defs(d),
        "mlp": mlp_defs(d, cfg.d_ff, cfg.mlp_kind),
    }
    return {
        "embedding": PDef((vp, d), ("vocab", "embed"), "small"),
        "lm_head": PDef((d, vp), ("embed", "vocab")),
        "final_norm": rms_norm_defs(d),
        "mamba": stack_defs(mamba2.mamba2_defs(d, **_mamba_kw(cfg)),
                            cfg.n_layers),
        "shared": shared,
        "app_proj": PDef((na, 2 * d, d), ("layers", "embed", None), "small"),
    }


def _shared_block(cfg, shared, proj, h, emb0, positions):
    dt = h.dtype
    x = jnp.concatenate([h, emb0], axis=-1) @ proj.astype(dt)
    a = attn.attention(
        shared["attn"], rms_norm(x, shared["attn_norm"]), positions,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
        causal=True, rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk,
        scores_dtype=jnp.dtype(cfg.scores_dtype),
        unroll=cfg.unroll_layers,
    )
    x = x + a
    m = mlp_apply(shared["mlp"], rms_norm(x, shared["mlp_norm"]),
                  cfg.mlp_kind)
    return h + (x + m)


def _regroup(tree, na, per):
    return jax.tree.map(lambda x: x.reshape((na, per) + x.shape[1:]), tree)


def forward(cfg: ArchConfig, params, tokens):
    dt = jnp.dtype(cfg.compute_dtype)
    h = params["embedding"].astype(dt)[tokens]
    emb0 = h
    B, S, _ = h.shape
    h = constrain(h, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    na, per = _n_apps(cfg), cfg.attn_every
    mkw = _mamba_kw(cfg)

    grouped = _regroup(params["mamba"], na, per)

    from repro.models.loops import scan_or_unroll

    def inner(h, layer_params):
        out = mamba2.mamba2_apply(layer_params, h,
                                  unroll=cfg.unroll_layers, **mkw)
        return h + out, None

    from repro.models.remat import resolve_policy, wrap_layer_body
    inner_fn = wrap_layer_body(inner, resolve_policy(cfg))

    def group(h, xs):
        layer_group, proj = xs
        h, _ = scan_or_unroll(inner_fn, h, layer_group,
                              unroll=cfg.unroll_layers)
        h = _shared_block(cfg, params["shared"], proj, h, emb0, positions)
        return h, None

    h, _ = scan_or_unroll(group, h, (grouped, params["app_proj"]),
                          unroll=cfg.unroll_layers)
    return rms_norm(h, params["final_norm"])


def lm_loss(cfg: ArchConfig, params, batch):
    h = forward(cfg, params, batch["tokens"])
    return chunked_cross_entropy(
        h, params, batch["labels"],
        chunk=min(cfg.loss_chunk, batch["labels"].shape[1]),
        compute_dtype=jnp.dtype(cfg.compute_dtype),
        unroll=cfg.unroll_layers,
    )


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def cache_spec(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    na = _n_apps(cfg)
    m = mamba2.mamba2_state_spec(batch, cfg.d_model, dtype=dtype,
                                 **_mamba_kw(cfg))
    stack = lambda s, n: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype)
    kv = attn.kv_cache_spec(batch, max_seq, cfg.n_kv_heads, cfg.head_dim,
                            dtype)
    return {
        "mamba": jax.tree.map(lambda s: stack(s, cfg.n_layers), m),
        "shared_kv": jax.tree.map(lambda s: stack(s, na), kv),
    }


def init_cache(cfg, batch, max_seq, dtype=jnp.bfloat16):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, max_seq, dtype))


def decode_step(cfg: ArchConfig, params, cache, tokens, positions):
    dt = jnp.dtype(cfg.compute_dtype)
    h = params["embedding"].astype(dt)[tokens]            # (B,1,d)
    emb0 = h[:, 0]
    na, per = _n_apps(cfg), cfg.attn_every
    mkw = _mamba_kw(cfg)

    grouped = _regroup(params["mamba"], na, per)
    mcache = _regroup(cache["mamba"], na, per)

    def inner(h, xs):
        layer_params, st = xs
        out, new_st = mamba2.mamba2_decode(layer_params, h, st, **mkw)
        return h + out, new_st

    def group(carry, xs):
        h = carry
        layer_group, st_group, proj, kv = xs
        h, new_states = scan_or_unroll(inner, h, (layer_group, st_group),
                                       unroll=cfg.unroll_layers)
        x = jnp.concatenate([h, emb0[:, None]], axis=-1) @ proj.astype(dt)
        a, new_kv = attn.decode_attention(
            params["shared"]["attn"],
            rms_norm(x, params["shared"]["attn_norm"]), kv, positions,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta,
        )
        x = x + a
        m = mlp_apply(params["shared"]["mlp"],
                      rms_norm(x, params["shared"]["mlp_norm"]),
                      cfg.mlp_kind)
        h = h + (x + m)
        return h, (new_states, new_kv)

    from repro.models.loops import scan_or_unroll
    kvc = {"k": cache["shared_kv"]["k"], "v": cache["shared_kv"]["v"]}
    h, (new_m, new_kv) = scan_or_unroll(
        group, h, (grouped, mcache, params["app_proj"], kvc),
        unroll=cfg.unroll_layers)
    h = rms_norm(h, params["final_norm"])
    logits = (h[:, 0] @ params["lm_head"].astype(dt)).astype(jnp.float32)
    flat_m = jax.tree.map(
        lambda x: x.reshape((na * per,) + x.shape[2:]), new_m)
    return logits, {"mamba": flat_m, "shared_kv": new_kv}


def cache_axes(cfg: ArchConfig) -> dict:
    kv = ("layers", "batch", "kv_seq", "kv", None)
    return {
        "mamba": {"conv": ("layers", "batch", None, "mlp"),
                  "ssm": ("layers", "batch", "heads", None, None)},
        "shared_kv": {"k": kv, "v": kv},
    }


def paged_decode_step(cfg: ArchConfig, params, pool, tables, rows, tokens,
                      positions, scales=None, kv_dtype: str = "bf16"):
    """MIXED-pool decode step (serving O6): the shared attention block
    reads/appends through per-slot block ``tables`` via the paged Pallas
    kernel (gather-free, like the transformer path), while the mamba
    trunk's carried state moves through ``rows`` — slot->state-row
    indirection into the conv/ssm row pools, gathered to the dense batch
    view around the exact contiguous layer bodies and scattered back.
    Narrow pools quantize only the shared_kv block leaves; the mamba
    scale placeholders pass through untouched (state is never
    quantized).  Returns (logits, pool[, scales])."""
    dt = jnp.dtype(cfg.compute_dtype)
    h = params["embedding"].astype(dt)[tokens]            # (B,1,d)
    emb0 = h[:, 0]
    na, per = _n_apps(cfg), cfg.attn_every
    mkw = _mamba_kw(cfg)

    grouped = _regroup(params["mamba"], na, per)
    mstate = jax.tree.map(lambda l: jnp.take(l, rows, axis=1),
                          pool["mamba"])
    mcache = _regroup(mstate, na, per)

    kv_leaves = (pool["shared_kv"]["k"], pool["shared_kv"]["v"])
    if scales is not None:
        kv_leaves += (scales["shared_kv"]["k"], scales["shared_kv"]["v"])

    from repro.models.loops import scan_or_unroll

    def inner(h, xs):
        layer_params, st = xs
        out, new_st = mamba2.mamba2_decode(layer_params, h, st, **mkw)
        return h + out, new_st

    def group(carry, xs):
        h = carry
        layer_group, st_group, proj = xs[:3]
        kvs = xs[3:]
        h, new_states = scan_or_unroll(inner, h, (layer_group, st_group),
                                       unroll=cfg.unroll_layers)
        x = jnp.concatenate([h, emb0[:, None]], axis=-1) @ proj.astype(dt)
        a, new_kvs = attn.paged_decode_attention(
            params["shared"]["attn"],
            rms_norm(x, params["shared"]["attn_norm"]), kvs, tables,
            positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            kv_dtype=kv_dtype,
        )
        x = x + a
        m = mlp_apply(params["shared"]["mlp"],
                      rms_norm(x, params["shared"]["mlp_norm"]),
                      cfg.mlp_kind)
        h = h + (x + m)
        return h, (new_states, tuple(new_kvs))

    h, (new_m, new_kvs) = scan_or_unroll(
        group, h, (grouped, mcache, params["app_proj"]) + kv_leaves,
        unroll=cfg.unroll_layers)
    h = rms_norm(h, params["final_norm"])
    logits = (h[:, 0] @ params["lm_head"].astype(dt)).astype(jnp.float32)
    flat_m = jax.tree.map(
        lambda x: x.reshape((na * per,) + x.shape[2:]), new_m)
    new_mpool = jax.tree.map(
        lambda p, n: p.at[:, rows].set(n.astype(p.dtype)),
        pool["mamba"], flat_m)
    if scales is None:
        nk, nv = new_kvs
        return logits, {"mamba": new_mpool,
                        "shared_kv": {"k": nk, "v": nv}}
    nk, nv, nsk, nsv = new_kvs
    return (logits,
            {"mamba": new_mpool, "shared_kv": {"k": nk, "v": nv}},
            {"mamba": scales["mamba"],
             "shared_kv": {"k": nsk, "v": nsv}})


def prefill_step(cfg: ArchConfig, params, cache, tokens, start, last):
    """Chunked prefill by scanning the decode body (see
    :mod:`repro.models.scan_prefill`): the mamba trunk's carry freezes
    per-slot past ``last``; shared_kv writes at clipped positions are
    frozen out the same way."""
    from repro.models.scan_prefill import batch_axes_of, scan_prefill

    def step(c, tok, pos):
        return decode_step(cfg, params, c, tok, pos)

    return scan_prefill(step, cache, tokens, start, last,
                        logits_width=padded_vocab(cfg.vocab),
                        batch_axes=batch_axes_of(cache_axes(cfg)),
                        max_seq=cache["shared_kv"]["k"].shape[2])


def init(cfg: ArchConfig, rng):
    return init_params(rng, model_defs(cfg), jnp.dtype(cfg.param_dtype))


def axes(cfg: ArchConfig):
    return param_axes(model_defs(cfg))
