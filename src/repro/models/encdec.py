"""Whisper-style encoder-decoder backbone (conv frontend STUBBED).

Per the assignment, the modality frontend is a stub: ``input_specs()``
supplies precomputed frame embeddings (B, S, d_model).  The backbone is a
bidirectional encoder + causal decoder with cross-attention.  Decode caches
both the decoder self-attn KV and the (precomputed-once) cross-attn KV.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.layers import (
    PDef, chunked_cross_entropy, init_params, mlp_apply, mlp_defs,
    param_axes, rms_norm, rms_norm_defs, stack_defs,
)
from repro.models.transformer import padded_vocab
from repro.parallel.sharding import constrain


def _enc_block_defs(cfg):
    d = cfg.d_model
    return {
        "attn_norm": rms_norm_defs(d),
        "attn": attn.attn_defs(d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim),
        "mlp_norm": rms_norm_defs(d),
        "mlp": mlp_defs(d, cfg.d_ff, cfg.mlp_kind),
    }


def _dec_block_defs(cfg):
    d = cfg.d_model
    defs = _enc_block_defs(cfg)
    defs["cross_norm"] = rms_norm_defs(d)
    defs["cross"] = attn.attn_defs(d, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim)
    return defs


def model_defs(cfg: ArchConfig) -> dict:
    vp = padded_vocab(cfg.vocab)
    return {
        "embedding": PDef((vp, cfg.d_model), ("vocab", "embed"), "small"),
        "lm_head": PDef((cfg.d_model, vp), ("embed", "vocab")),
        "enc_norm": rms_norm_defs(cfg.d_model),
        "final_norm": rms_norm_defs(cfg.d_model),
        "encoder": stack_defs(_enc_block_defs(cfg), cfg.n_enc_layers),
        "decoder": stack_defs(_dec_block_defs(cfg), cfg.n_layers),
    }


def encode(cfg: ArchConfig, params, frames):
    """frames: (B, S_enc, d) precomputed embeddings -> encoder states."""
    dt = jnp.dtype(cfg.compute_dtype)
    h = frames.astype(dt)
    h = constrain(h, "batch", None, None)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(h, layer_params):
        a = attn.attention(
            layer_params["attn"], rms_norm(h, layer_params["attn_norm"]),
            positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim, causal=False,
            rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk,
            unroll=cfg.unroll_layers,
        )
        h = h + a
        m = mlp_apply(layer_params["mlp"],
                      rms_norm(h, layer_params["mlp_norm"]), cfg.mlp_kind)
        return h + m, None

    from repro.models.remat import resolve_policy, wrap_layer_body
    body_fn = wrap_layer_body(body, resolve_policy(cfg))
    from repro.models.loops import scan_or_unroll
    h, _ = scan_or_unroll(body_fn, h, params["encoder"],
                          unroll=cfg.unroll_layers)
    return rms_norm(h, params["enc_norm"])


def decode_full(cfg: ArchConfig, params, tokens, enc_h):
    """Teacher-forced decoder pass. tokens: (B, S_dec)."""
    dt = jnp.dtype(cfg.compute_dtype)
    h = params["embedding"].astype(dt)[tokens]
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    Se = enc_h.shape[1]
    enc_pos = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))

    def body(h, layer_params):
        a = attn.attention(
            layer_params["attn"], rms_norm(h, layer_params["attn_norm"]),
            positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim, causal=True,
            rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk,
            unroll=cfg.unroll_layers,
        )
        h = h + a
        c = attn.attention(
            layer_params["cross"], rms_norm(h, layer_params["cross_norm"]),
            positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim, causal=False,
            rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk,
            kv_x=enc_h, kv_positions=enc_pos,
            unroll=cfg.unroll_layers,
        )
        h = h + c
        m = mlp_apply(layer_params["mlp"],
                      rms_norm(h, layer_params["mlp_norm"]), cfg.mlp_kind)
        return h + m, None

    from repro.models.remat import resolve_policy, wrap_layer_body
    body_fn = wrap_layer_body(body, resolve_policy(cfg))
    from repro.models.loops import scan_or_unroll
    h, _ = scan_or_unroll(body_fn, h, params["decoder"],
                          unroll=cfg.unroll_layers)
    return rms_norm(h, params["final_norm"])


def lm_loss(cfg: ArchConfig, params, batch):
    """batch: {"frames": (B,S,d), "tokens": (B,S), "labels": (B,S)}."""
    enc_h = encode(cfg, params, batch["frames"])
    h = decode_full(cfg, params, batch["tokens"], enc_h)
    return chunked_cross_entropy(
        h, params, batch["labels"],
        chunk=min(cfg.loss_chunk, batch["labels"].shape[1]),
        compute_dtype=jnp.dtype(cfg.compute_dtype),
        unroll=cfg.unroll_layers,
    )


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def cache_spec(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16, enc_len: int = None) -> dict:
    enc_len = enc_len or max_seq
    L = cfg.n_layers
    kv = attn.kv_cache_spec(batch, max_seq, cfg.n_kv_heads, cfg.head_dim,
                            dtype)
    cross = attn.kv_cache_spec(batch, enc_len, cfg.n_kv_heads, cfg.head_dim,
                               dtype)
    stack = lambda t: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((L,) + s.shape, s.dtype), t)
    return {"self_kv": stack(kv), "cross_kv": stack(cross)}


def init_cache(cfg, batch, max_seq, dtype=jnp.bfloat16, enc_len=None):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, max_seq, dtype, enc_len))


def build_cross_cache(cfg: ArchConfig, params, enc_h):
    """Precompute per-layer cross-attention K/V from encoder states."""
    dt = enc_h.dtype

    def per_layer(layer_params):
        k = jnp.einsum("bsd,dhk->bshk", enc_h,
                       layer_params["cross"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", enc_h,
                       layer_params["cross"]["wv"].astype(dt))
        return {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}

    return jax.lax.map(per_layer, params["decoder"])


def decode_step(cfg: ArchConfig, params, cache, tokens, positions):
    dt = jnp.dtype(cfg.compute_dtype)
    h = params["embedding"].astype(dt)[tokens]

    def body(h, xs):
        layer_params, sk, sv, ck, cv = xs
        a, new_self = attn.decode_attention(
            layer_params["attn"], rms_norm(h, layer_params["attn_norm"]),
            {"k": sk, "v": sv}, positions,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta,
        )
        h = h + a
        c, _ = attn.decode_attention(
            layer_params["cross"], rms_norm(h, layer_params["cross_norm"]),
            {"k": ck, "v": cv}, positions,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, cross=True,
        )
        h = h + c
        m = mlp_apply(layer_params["mlp"],
                      rms_norm(h, layer_params["mlp_norm"]), cfg.mlp_kind)
        return h + m, (new_self["k"], new_self["v"])

    from repro.models.loops import scan_or_unroll
    h, (nk, nv) = scan_or_unroll(
        body, h,
        (params["decoder"], cache["self_kv"]["k"], cache["self_kv"]["v"],
         cache["cross_kv"]["k"], cache["cross_kv"]["v"]),
        unroll=cfg.unroll_layers)
    h = rms_norm(h, params["final_norm"])
    logits = (h[:, 0] @ params["lm_head"].astype(dt)).astype(jnp.float32)
    return logits, {"self_kv": {"k": nk, "v": nv},
                    "cross_kv": cache["cross_kv"]}


def cache_axes(cfg: ArchConfig) -> dict:
    kv = ("layers", "batch", "kv_seq", "kv", None)
    return {"self_kv": {"k": kv, "v": kv},
            "cross_kv": {"k": kv, "v": kv}}


def paged_decode_step(cfg: ArchConfig, params, pool, tables, rows, tokens,
                      positions, scales=None, kv_dtype: str = "bf16"):
    """MIXED-pool decode step (serving O6): decoder self-attention runs
    gather-free through per-slot block ``tables`` via the paged Pallas
    kernel, while the per-slot cross-attention KV (a fixed-size blob,
    not a growing sequence) lives in a state-row pool addressed by
    ``rows`` — gathered to the dense batch view for the plain cross
    attention and returned UNCHANGED (cross KV is written once at
    insert, read-only thereafter).  Narrow pools quantize only the
    self_kv block leaves; cross state stays bf16.  Returns
    (logits, pool[, scales])."""
    dt = jnp.dtype(cfg.compute_dtype)
    h = params["embedding"].astype(dt)[tokens]
    cross = jax.tree.map(lambda l: jnp.take(l, rows, axis=1),
                         pool["cross_kv"])
    kv_leaves = (pool["self_kv"]["k"], pool["self_kv"]["v"])
    if scales is not None:
        kv_leaves += (scales["self_kv"]["k"], scales["self_kv"]["v"])

    def body(h, xs):
        layer_params, ck, cv = xs[:3]
        kvs = xs[3:]
        a, new_kvs = attn.paged_decode_attention(
            layer_params["attn"], rms_norm(h, layer_params["attn_norm"]),
            kvs, tables, positions,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, kv_dtype=kv_dtype,
        )
        h = h + a
        c, _ = attn.decode_attention(
            layer_params["cross"], rms_norm(h, layer_params["cross_norm"]),
            {"k": ck, "v": cv}, positions,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, cross=True,
        )
        h = h + c
        m = mlp_apply(layer_params["mlp"],
                      rms_norm(h, layer_params["mlp_norm"]), cfg.mlp_kind)
        return h + m, tuple(new_kvs)

    from repro.models.loops import scan_or_unroll
    h, new_kvs = scan_or_unroll(
        body, h, (params["decoder"], cross["k"], cross["v"]) + kv_leaves,
        unroll=cfg.unroll_layers)
    h = rms_norm(h, params["final_norm"])
    logits = (h[:, 0] @ params["lm_head"].astype(dt)).astype(jnp.float32)
    if scales is None:
        nk, nv = new_kvs
        return logits, {"self_kv": {"k": nk, "v": nv},
                        "cross_kv": pool["cross_kv"]}
    nk, nv, nsk, nsv = new_kvs
    return (logits,
            {"self_kv": {"k": nk, "v": nv}, "cross_kv": pool["cross_kv"]},
            {"self_kv": {"k": nsk, "v": nsv},
             "cross_kv": scales["cross_kv"]})


def prefill_step(cfg: ArchConfig, params, cache, tokens, start, last):
    """Chunked prefill by scanning the decode body (see
    :mod:`repro.models.scan_prefill`): self-KV writes freeze per-slot
    past ``last``; cross KV passes through unchanged."""
    from repro.models.scan_prefill import batch_axes_of, scan_prefill

    def step(c, tok, pos):
        return decode_step(cfg, params, c, tok, pos)

    return scan_prefill(step, cache, tokens, start, last,
                        logits_width=padded_vocab(cfg.vocab),
                        batch_axes=batch_axes_of(cache_axes(cfg)),
                        max_seq=cache["self_kv"]["k"].shape[2])


def init(cfg: ArchConfig, rng):
    return init_params(rng, model_defs(cfg), jnp.dtype(cfg.param_dtype))


def axes(cfg: ArchConfig):
    return param_axes(model_defs(cfg))
