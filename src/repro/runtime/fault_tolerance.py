"""Fault tolerance: heartbeat, resilient step loop, fault injection.

At 1000+-node scale the question is not *if* a step fails but *when*.  The
runner below wraps any step callable with:

  * **checkpoint/restart** — on failure, restore the latest checkpoint and
    resume; with the deterministic data pipeline (``data/pipeline.py``)
    the recovered run is bitwise-identical to an unfailed one (tested).
  * **bounded retries** — per-step transient retry (preemption, DMA error)
    with exponential backoff before escalating to restore.
  * **heartbeat** — a watchdog thread that flags a hung step (collective
    deadlock, straggler host) after ``timeout_s``; the step is then treated
    as failed.  On real fleets the supervisor would kill+restart the
    process; here the deadline fires an exception in-loop.
  * **straggler mitigation** — per-step deadline accounting: steps whose
    wall time exceeds ``straggler_factor`` x the running median are logged
    and counted (the scheduler's signal for hot-swapping a slow host).

``FaultInjector`` deterministically raises at chosen steps to let the tests
exercise all paths without real hardware faults.
"""

from __future__ import annotations

import threading
import time


class StepFailure(RuntimeError):
    pass


class HeartbeatTimeout(StepFailure):
    pass


class FaultInjector:
    """Deterministically fail chosen (step, attempt) pairs.

    Faults are ONE-SHOT: each key fires once, modelling a real transient
    (a preempted host does not re-fail on the replayed step after
    restore).  Keys are ``(step, attempt)`` pairs or bare ``step`` ints
    (= attempt 0)."""

    def __init__(self, fail_at=(), hang_at=()):
        self.fail_at = set(fail_at)      # {(step, attempt), ...} or {step}
        self.hang_at = set(hang_at)
        self.log: list = []

    def maybe_fail(self, step: int, attempt: int):
        for key in ((step, attempt), step if attempt == 0 else None):
            if key is not None and key in self.fail_at:
                self.fail_at.discard(key)
                self.log.append(("fault", step, attempt))
                raise StepFailure(f"injected fault at step {step} "
                                  f"(attempt {attempt})")
        if step in self.hang_at and attempt == 0:
            self.hang_at.discard(step)
            self.log.append(("hang", step, attempt))
            time.sleep(3600)


class Heartbeat:
    """Watchdog: ``beat()`` regularly or ``expired`` flips true."""

    def __init__(self, timeout_s: float):
        self.timeout_s = timeout_s
        self._last = time.monotonic()
        self._lock = threading.Lock()

    def beat(self):
        with self._lock:
            self._last = time.monotonic()

    @property
    def expired(self) -> bool:
        with self._lock:
            return (time.monotonic() - self._last) > self.timeout_s

    def check(self):
        if self.expired:
            raise HeartbeatTimeout(
                f"no heartbeat for > {self.timeout_s}s")


class ResilientRunner:
    """Run ``n_steps`` of ``step_fn`` with retry + restore-on-failure.

    step_fn(state, step) -> state          (pure training step + host work)
    save_fn(state, step)                   (checkpoint hook, every ``every``)
    restore_fn() -> (state, step) | None   (latest checkpoint or None)
    """

    def __init__(self, step_fn, *, save_fn=None, restore_fn=None,
                 every: int = 10, max_retries: int = 2,
                 max_restores: int = 3, backoff_s: float = 0.0,
                 straggler_factor: float = 3.0, injector=None):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.every = every
        self.max_retries = max_retries
        self.max_restores = max_restores
        self.backoff_s = backoff_s
        self.straggler_factor = straggler_factor
        self.injector = injector
        self.events: list = []
        self.step_times: list = []
        self.stragglers: list = []

    def _median_time(self) -> float:
        if not self.step_times:
            return float("inf")
        s = sorted(self.step_times)
        return s[len(s) // 2]

    def run(self, state, *, start_step: int = 0, n_steps: int = 100):
        step = start_step
        restores = 0
        end = start_step + n_steps
        while step < end:
            attempt = 0
            while True:
                try:
                    t0 = time.monotonic()
                    if self.injector is not None:
                        self.injector.maybe_fail(step, attempt)
                    state = self.step_fn(state, step)
                    dt = time.monotonic() - t0
                    med = self._median_time()
                    if (len(self.step_times) >= 5
                            and dt > self.straggler_factor * med):
                        self.stragglers.append((step, dt, med))
                        self.events.append(("straggler", step, dt))
                    self.step_times.append(dt)
                    break
                except StepFailure as e:
                    attempt += 1
                    self.events.append(("failure", step, attempt, str(e)))
                    if attempt <= self.max_retries:
                        if self.backoff_s:
                            time.sleep(self.backoff_s * (2 ** (attempt - 1)))
                        continue
                    # escalate: restore from checkpoint
                    restores += 1
                    if (self.restore_fn is None
                            or restores > self.max_restores):
                        raise
                    restored = self.restore_fn()
                    if restored is None:
                        raise
                    state, step = restored
                    self.events.append(("restore", step))
                    attempt = 0
            step += 1
            if self.save_fn is not None and step % self.every == 0:
                self.save_fn(state, step)
        return state, step
