from repro.runtime.compression import (int8_compress,  # noqa: F401
                                       int8_decompress, CompressedReducer)
from repro.runtime.fault_tolerance import (Heartbeat,  # noqa: F401
                                           ResilientRunner, FaultInjector)
from repro.runtime.overlap import DelayedGradSync  # noqa: F401
