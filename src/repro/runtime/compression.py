"""int8 gradient compression with error feedback — the scratchpad-
reorganization step (bit packing, paper §5.2) applied to the cross-pod
all-reduce.

Cross-pod (DCN) bandwidth is the scarcest link at multi-pod scale
(~6 GB/s/chip vs 819 GB/s HBM): packing f32 gradients into int8 + one f32
scale per tensor cuts the pod-axis reduction bytes 4x.  Error feedback
(Seide et al.; Karimireddy et al.) accumulates the quantization residual
locally and re-injects it next step, making the long-run bias vanish —
property-tested in tests/test_runtime.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress(g: jax.Array):
    """Symmetric per-tensor int8 quantization.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


class CompressedReducer:
    """Error-feedback compressed reduction over a named mesh axis.

    Use inside shard_map/pjit-traced code::

        reducer = CompressedReducer(axis="pod")
        mean_g, new_err = reducer.reduce(g, err)

    The returned ``new_err`` must be threaded through the training carry
    (it is part of the optimizer state in ``launch/train.py``).
    """

    def __init__(self, axis: str = "pod"):
        self.axis = axis

    def reduce(self, g: jax.Array, err: jax.Array):
        """Compress (g + err), all-reduce-mean the int8 payload, return
        (reduced_f32, new_local_err)."""
        target = g + err
        q, scale = int8_compress(target)
        local_deq = int8_decompress(q, scale)
        new_err = target - local_deq
        # Mean of dequantized payloads over the axis.  (int8 summation
        # happens on the wire; the f32 scale rides along per tensor.)
        reduced = jax.lax.pmean(local_deq, self.axis)
        return reduced, new_err

    def init_error(self, g_spec):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, jnp.float32), g_spec)


def tree_compress_bytes(tree) -> tuple:
    """(f32_bytes, int8_bytes) for a gradient pytree — the 4x the paper's
    bit-packing step buys on the pod axis (used by the roofline notes)."""
    f32 = sum(x.size * 4 for x in jax.tree.leaves(tree))
    i8 = sum(x.size * 1 + 4 for x in jax.tree.leaves(tree))
    return f32, i8
