"""One-step-delayed cross-pod gradient sync — the double-buffering step
(paper §5.1) applied to the distributed optimizer.

The paper's 3-slot rotation overlaps load/compute/store of adjacent
iterations.  At multi-pod scale the analogous exposed latency is the
cross-pod (DCN) gradient all-reduce: instead of blocking step N on its own
pod-reduction, we apply the *previous* step's pod-reduced gradient while
step N's local gradient is being reduced — the classic one-step-stale
overlap (compute of step N hides the collective of step N-1).

Semantics: params_{t+1} = opt(params_t, pod_mean(grads_{t-1})).  The first
step applies a zero gradient (warmup).  Staleness-1 SGD/Adam convergence
is well-studied; the framework exposes it as a config knob
(``BestEffortConfig.overlap_grad_sync``), default off, and the equivalence
test checks the pipeline produces exactly the immediate-sync update
sequence shifted by one step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


class DelayedGradSync:
    """Functional helper: thread ``pending`` (previous step's local grads)
    through the training carry.

    make_step wraps a ``apply_update(params, opt, grads) -> (params, opt)``
    and a ``local_grads(params, batch) -> grads`` into a one-step-delayed
    pipeline.  ``reduce`` is the (possibly compressed) pod reduction.
    """

    def __init__(self, reduce_fn=None):
        self.reduce_fn = reduce_fn or (lambda g: g)

    def init_pending(self, grad_spec):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            grad_spec)

    def step(self, params, opt, pending, batch, *, local_grads,
             apply_update):
        """One overlapped step.  Returns (params, opt, new_pending, aux).

        The data dependence is arranged so XLA can schedule the reduction
        of ``pending`` (previous grads) concurrently with ``local_grads``
        of the current batch: neither consumes the other's output.
        """
        reduced_prev = self.reduce_fn(pending)          # collective (N-1)
        new_local, aux = local_grads(params, batch)     # compute (N)
        new_params, new_opt = apply_update(params, opt, reduced_prev)
        return new_params, new_opt, new_local, aux
