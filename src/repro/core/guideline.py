"""The best-effort guideline: bottleneck -> recommended next step.

This encodes the paper's decision procedure (§3-§6):

  * Before anything: the communication-bound filter (paper Table 5) — if
    host<->device (TPU: interconnect) time rivals the useful compute time,
    the kernel is "non-acceleratable"; stop (BFS/SPMV analog).
  * DRAM/memory-dominated  -> explicit data caching; if caching is already
    applied -> double buffering, then scratchpad reorganization (the paper's
    Iter #3 order).
  * Compute-dominated      -> customized pipelining, then PE duplication
    (the paper's Iter #2 order).
  * Resource feedback (paper Table 6): strategies that need <10% of a
    resource are always applied; conflicts resolve by shrinking cache size
    first (paper: 64 KB suffices), then PE count.
"""

from __future__ import annotations

import dataclasses

from repro.core.optlevel import STEP_ORDER, OptLevel, Step


@dataclasses.dataclass
class Recommendation:
    step: Step | None
    reason: str
    stop: bool = False

    def __str__(self) -> str:
        head = "STOP" if self.stop else (self.step.value if self.step else "done")
        return f"{head}: {self.reason}"


# Communication-bound threshold: paper Table 5 rejects BFS (0.8) and
# SPMV (1.3) whose PCIe time is within ~1x of CPU runtime, and accepts
# KMP at 5.9e-2.  We use 0.5 as the cut, as the paper's accepted kernels
# are all <0.06 and rejected ones >0.8.
COMM_BOUND_THRESHOLD = 0.5


def comm_bound_filter(offload_s: float, baseline_s: float) -> Recommendation | None:
    """Paper Table 5: reject kernels whose offload cost rivals the baseline."""
    if baseline_s <= 0:
        return None
    ratio = offload_s / baseline_s
    if ratio > COMM_BOUND_THRESHOLD:
        return Recommendation(
            None,
            f"offload/baseline = {ratio:.2f} > {COMM_BOUND_THRESHOLD}: "
            "communication-bound, not acceleratable on this platform "
            "(the paper's BFS/SPMV case)",
            stop=True,
        )
    return None


def recommend(
    *,
    level: OptLevel = None,
    applied=None,
    compute_s: float,
    memory_s: float,
    collective_s: float = 0.0,
    offload_s: float = 0.0,
    baseline_s: float = 0.0,
    steps=None,
) -> Recommendation:
    """Given the current breakdown, pick the paper's next step.

    The applied-step set comes from ``level`` (the cumulative FPGA ladder)
    or, for surfaces whose steps are independent knobs (the LM cost-twin
    backend in ``repro.autotune``), from ``applied`` directly.

    ``collective_s`` generalizes the paper's PCIe term to the TPU mesh: a
    dominant collective term is attacked with the O4/O5 analogs (overlap,
    compressed/wider-word collectives) rather than more PEs.

    ``steps`` is the step universe available on the surface being tuned —
    default the paper's five (``STEP_ORDER``).  The serving runtime passes
    its extended ladder so the paged-scratchpad rung (memory-system step,
    tried after wide-word reorg, exactly the paper's Iter #3 escalation)
    is recommended there and nowhere else.
    """
    comm = comm_bound_filter(offload_s, baseline_s)
    if comm is not None:
        return comm

    universe = tuple(steps) if steps is not None else STEP_ORDER
    if applied is None:
        if level is None:
            raise TypeError("recommend() needs `level` or `applied`")
        applied = set(level.steps)
    else:
        applied = set(applied)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)

    if dominant == "memory":
        order = (Step.DATA_CACHING, Step.DOUBLE_BUFFERING,
                 Step.SCRATCHPAD_REORG, Step.PAGED_SCRATCHPAD)
        why = "memory term dominates (paper Iter #1/#3: DRAM access bound)"
    elif dominant == "compute":
        order = (Step.PIPELINING, Step.PE_DUPLICATION)
        why = "compute term dominates (paper Iter #2: frequency-deficit bound)"
    else:
        order = (Step.DOUBLE_BUFFERING, Step.SCRATCHPAD_REORG,
                 Step.PAGED_SCRATCHPAD, Step.PE_DUPLICATION)
        why = ("collective term dominates (TPU generalization of the PCIe "
               "column: overlap it, then shrink it by packing)")

    for step in order:
        if step in universe and step not in applied:
            return Recommendation(step, why)
    # Everything that attacks the dominant term is already applied.
    for step in universe:
        if step not in applied:
            return Recommendation(
                step, f"dominant-term steps exhausted; next ladder step ({why})"
            )
    if universe == STEP_ORDER:
        reason = ("all five steps applied — the paper stops here "
                  "(best-effort, not necessarily optimal)")
    else:
        reason = (f"all {len(universe)} ladder steps applied — top of this "
                  "surface's ladder (best-effort, not necessarily optimal)")
    return Recommendation(None, reason, stop=True)
