"""Analytic performance model of the paper's CPU-FPGA platform.

The paper reports wall-clock speedups measured on a Xilinx Virtex-7 @200 MHz
next to a Xeon E5-2420 @1.9 GHz (Table 2).  This container has neither, so
the *faithful* reproduction validates against an analytic model built from
the paper's own published constants and mechanisms:

  * DRAM burst:    100-cycle initiation + ~1 cycle/beat            (paper 3.2)
  * naive port:    every element access pays the 100-cycle init    (paper 3.1)
  * pipelining:    loop time N*L -> N*II + L                       (paper 4.1)
  * PE duplication: compute time / min(PE, available parallelism)  (paper 4.2)
  * double buffer: total = max(load, compute, store) per iteration (paper 5.1)
  * scratchpad:    DRAM<->BRAM beats scale with word width         (paper 5.2)
  * PCIe offload:  payload / 8 GB/s, counted in system speedup     (paper 6)

Each MachSuite kernel is described by a ``KernelProfile`` capturing its
operational characteristics (element count, ops/element, iteration latency,
achievable II, parallelism structure, word width).  The model then evaluates
time at every OptLevel — reproducing Figures 1/6/9/12 and Tables 4/5.

The model is *mechanistic*, not a curve fit: the same five formulas the paper
narrates, with per-kernel parameters taken from MachSuite's documented input
sizes (Table 3) and per-kernel loop structure.  EXPERIMENTS.md compares its
outputs against every number range the paper prints.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.hw import FPGA_2012, FpgaSpec
from repro.core.optlevel import OptLevel, Step


@dataclasses.dataclass(frozen=True)
class KernelProfile:
    """Operational profile of one MachSuite kernel on the paper's platform.

    Attributes:
      name: kernel id.
      bytes_in / bytes_out: total DRAM traffic (one pass over the input set).
      n_iters: trip count of the dominant (innermost pipelined) loop.
      iter_latency: latency L (cycles) of one iteration un-pipelined.
      ii: initiation interval achievable by `#pragma HLS pipeline` alone.
      parallel_jobs: number of independent jobs for PE duplication
        (0 => PE duplication inapplicable, e.g. BFS).
      tree_reduce: SORT-style halving parallelism across levels.
      word_bytes: natural element width of the kernel's data type.
      cpu_time_s: single-thread Xeon baseline (derived from ops at a
        per-kernel effective IPC on the 1.9 GHz core).
      max_pe: resource-bound PE cap on the Virtex-7 for this kernel.
      dram_bound_after_o1: fraction of time that is DRAM even after caching.
    """

    name: str
    bytes_in: float
    bytes_out: float
    n_iters: float
    iter_latency: float
    ii: float
    parallel_jobs: float
    cpu_time_s: float
    word_bytes: int = 4
    max_pe: int = 128
    tree_reduce: bool = False
    compute_scale: float = 1.0   # extra per-iteration compute weight
    naive_accesses_per_iter: float = 2.0  # DRAM touches per loop body at O0
    pcie_bytes: float = 0.0      # host<->device payload; 0 => bytes_in+out
                                 # (differs when tiling re-reads DRAM, GEMM)
    overlappable: bool = True    # False: next iter depends on prev (BFS)
    pack_compute: bool = False   # byte kernels: O5 packs 4 bytes/op (paper:
                                 # 'bit packing' is the software counterpart)
    max_word_bits: int = 512     # BRAM-resource cap on scratchpad width


def _dram_naive(p: KernelProfile, hw: FpgaSpec) -> float:
    """O0: every operand reference in the loop body is its own 100-cycle-init
    DRAM transaction (paper §3.1: 'Every data access has to physically go
    off chip') — the loop body's loads/stores, sbox lookups, bookkeeping
    arrays etc. all live in DRAM in the naive port."""
    accesses = p.n_iters * p.naive_accesses_per_iter
    return accesses * (hw.dram_init_cycles + 1) * hw.cycle_s


def _dram_batched(
    p: KernelProfile, hw: FpgaSpec, cache_bytes: float, width_bits: int
) -> float:
    """O1+: burst transfers of ``cache_bytes`` payloads at ``width_bits``."""
    total = p.bytes_in + p.bytes_out
    if total <= 0:
        return 0.0
    n_bursts = max(1.0, math.ceil(total / cache_bytes))
    per_burst_payload = total / n_bursts
    return n_bursts * hw.burst_time(per_burst_payload, width_bits)


def _compute_time(
    p: KernelProfile, hw: FpgaSpec, level: OptLevel, pe: int
) -> float:
    """Sequential / pipelined / duplicated compute time."""
    n, latency = p.n_iters, p.iter_latency * p.compute_scale
    if level.has(Step.PIPELINING):
        cycles = n * p.ii + latency          # paper: N*L -> N*II + L
    else:
        cycles = n * latency
    if level.has(Step.PE_DUPLICATION) and p.parallel_jobs > 0:
        eff = min(pe, p.max_pe, p.parallel_jobs)
        if p.tree_reduce:
            # SORT: log2(n) merge levels, level k exposes jobs/2^k
            # independent merges (paper §4.2: parallelism halves per layer).
            levels = max(1.0, math.log2(max(2.0, p.parallel_jobs)))
            par = sum(
                1.0 / min(eff, max(1.0, p.parallel_jobs / 2**k))
                for k in range(int(levels))
            )
            cycles = cycles * (par / levels)
        else:
            cycles = cycles / eff
    return cycles * hw.cycle_s


def kernel_time(
    p: KernelProfile,
    level: OptLevel,
    hw: FpgaSpec = FPGA_2012,
    *,
    cache_bytes: float = 64 * 1024,
    pe: int = 128,
    word_bits: int = None,
) -> dict:
    """Evaluate the model at one optimization level.

    Returns dict with dram_s, compute_s, total_s, pcie_s (system offload).
    """
    natural_bits = p.word_bytes * 8
    if word_bits is None:
        word_bits = p.max_word_bits if level.has(Step.SCRATCHPAD_REORG) else natural_bits
    if not level.has(Step.SCRATCHPAD_REORG):
        word_bits = natural_bits

    if level.has(Step.DATA_CACHING):
        dram = _dram_batched(p, hw, cache_bytes, word_bits)
    else:
        dram = _dram_naive(p, hw)

    comp = _compute_time(p, hw, level, pe)
    if level.has(Step.SCRATCHPAD_REORG) and p.pack_compute:
        comp /= 4.0  # 4 bytes per 32-bit word-op once buffers are widened

    if level.has(Step.DOUBLE_BUFFERING) and p.overlappable:
        # 3-stage coarse pipeline: steady-state is the max stage; one
        # fill + one drain of the shorter stage remain exposed.
        total = max(dram, comp) + min(dram, comp) / max(
            1.0, (p.bytes_in + p.bytes_out) / cache_bytes
        )
    else:
        total = dram + comp

    pcie = (p.pcie_bytes or (p.bytes_in + p.bytes_out)) / hw.pcie_bw
    return {
        "dram_s": dram,
        "compute_s": comp,
        "kernel_s": total,
        "pcie_s": pcie,
        "system_s": total + pcie,
        "speedup_vs_cpu": p.cpu_time_s / (total + pcie),
    }


# ---------------------------------------------------------------------------
# Resource model + feedback (paper Table 6 / §5.2).
# ---------------------------------------------------------------------------

MIN_CACHE_BYTES = 4 * 1024      # below this, burst init dominates (paper §3.2)


def bram_blocks(capacity_bytes: float, width_bits: int,
                hw: FpgaSpec = FPGA_2012) -> int:
    """18 Kb BRAM blocks to build a ``width_bits``-wide buffer of the given
    capacity: a block supplies <=36 bits of width, so wider words gang
    ceil(w/36) blocks; the total must also cover the capacity."""
    by_width = math.ceil(width_bits / hw.bram_block_max_width)
    by_cap = math.ceil(capacity_bytes * 8 / hw.bram_block_bits)
    return max(by_width, by_cap)


def bram_demand(p: KernelProfile, level: OptLevel, hw: FpgaSpec = FPGA_2012,
                *, cache_bytes: float, pe: int, word_bits: int) -> int:
    """Modeled BRAM block demand of one configuration (paper §5.2's
    feasibility check: buffers x PEs x blocks-per-buffer)."""
    if not level.has(Step.DATA_CACHING):
        return 0                     # no on-chip buffers in the naive port
    n_pe = (min(pe, p.max_pe)
            if level.has(Step.PE_DUPLICATION) and p.parallel_jobs > 0 else 1)
    width = word_bits if level.has(Step.SCRATCHPAD_REORG) else p.word_bytes * 8
    bufs = 3 if (level.has(Step.DOUBLE_BUFFERING) and p.overlappable) else 1
    per_pe = max(1.0, cache_bytes / n_pe)
    return bufs * n_pe * bram_blocks(per_pe, width, hw)


def _halvings(top, floor):
    out = []
    v = top
    while v >= floor:
        out.append(v)
        if v == floor:
            break
        v = max(floor, v // 2)
    return out


def fit_resources(p: KernelProfile, level: OptLevel,
                  hw: FpgaSpec = FPGA_2012, *,
                  cache_bytes: int = 64 * 1024, pe: int = 128,
                  word_bits: int = None) -> dict:
    """Paper Table 6 resource feedback: on a modeled BRAM conflict, do NOT
    stop the walk — shrink the knobs and re-measure.

    The shrink space follows the guideline's order (cache size first, then
    PE count, trading scratchpad width last) as halving grids; every
    feasible candidate is *re-measured* on the model and the fastest one
    wins, so a width-bound conflict (where shrinking the cache frees no
    blocks) correctly resolves by narrowing the scratchpad word or folding
    PEs rather than thrashing the cache.
    """
    natural = p.word_bytes * 8
    want_w = (word_bits if word_bits is not None
              else (p.max_word_bits if level.has(Step.SCRATCHPAD_REORG)
                    else natural))
    demand = bram_demand(p, level, hw, cache_bytes=cache_bytes, pe=pe,
                         word_bits=want_w)
    fit = {
        "cache_bytes": cache_bytes, "pe": pe, "word_bits": want_w,
        "demand_blocks": demand, "budget_blocks": hw.bram_blocks,
        "shrunk": False,
    }
    if demand <= hw.bram_blocks:
        return fit

    requested = dict(cache_bytes=cache_bytes, pe=pe, word_bits=want_w,
                     demand_blocks=demand)
    best = None
    for c in _halvings(cache_bytes, MIN_CACHE_BYTES):
        for q in _halvings(pe, 1):
            for w in _halvings(want_w, natural):
                d = bram_demand(p, level, hw, cache_bytes=c, pe=q,
                                word_bits=w)
                if d > hw.bram_blocks:
                    continue
                t = kernel_time(p, level, hw, cache_bytes=c, pe=q,
                                word_bits=w)["system_s"]
                key = (t, -c, -q, -w)
                if best is None or key < best[0]:
                    best = (key, dict(cache_bytes=c, pe=q, word_bits=w,
                                      demand_blocks=d))
    if best is None:
        # Even the floor config over-subscribes (pathological profile);
        # take the floor and report the overrun rather than stopping.
        c, q, w = MIN_CACHE_BYTES, 1, natural
        best = (None, dict(
            cache_bytes=c, pe=q, word_bits=w,
            demand_blocks=bram_demand(p, level, hw, cache_bytes=c, pe=q,
                                      word_bits=w)))
    fit.update(best[1])
    fit["shrunk"] = True
    fit["requested"] = requested
    return fit


def refinement_curve(
    p: KernelProfile, hw: FpgaSpec = FPGA_2012, **kw
) -> dict:
    """Times at every level O0..O5 — one paper Fig. 12 bar group.  The
    curve is paper-scoped: it stops at O5 (the serving-only O6 paged rung
    has no FPGA analog and would render as a duplicate O5 bar)."""
    return {int(lvl): kernel_time(p, lvl, hw, **kw)
            for lvl in OptLevel if lvl <= OptLevel.O5}


# ---------------------------------------------------------------------------
# MachSuite kernel profiles (inputs from paper Table 3).
#
# cpu_time_s derivations assume the Xeon executes the kernel's scalar op
# stream at an effective throughput consistent with the paper's Table 5
# PCIe-to-CPU-runtime ratios, which pin absolute CPU runtimes:
#   AES:  134 MB / 8 GB/s / 2.2e-3  = 7.6 s    (64 MB in+out through PCIe)
#   GEMM: 25.2 MB / 8GB/s / 6.0e-4  = 5.2 s
#   KMP:  128 MB / 8 GB/s / 5.9e-2  = 0.27 s
#   NW:   33.6 MB / 8GB/s / 1.5e-3  = 2.8 s
#   SORT: 134 MB / 8 GB/s / 4.9e-3  = 3.4 s
#   SPMV: 16.8MB / 8 GB/s / 1.3     = 1.6e-3 s
#   BFS:  0.84MB / 8 GB/s / 0.8     = 1.3e-4 s
#   VITERBI: 1.03GB / 8GB/s / 1.4e-2 = 9.2 s
# These anchor the model to the paper's own measurements.
# ---------------------------------------------------------------------------

MACHSUITE_PROFILES = {
    # AES ECB over 64 MB: 4M blocks x 14 rounds x 16 byte-ops.  Pipelining
    # gains 1.4x (Table 4) => L/ii ~= 7/5.  Naive port touches state/sbox/key
    # in DRAM (~1.25 effective transactions per byte-op after trivial
    # coalescing by the HLS scheduler).
    "aes": KernelProfile(
        name="aes",
        bytes_in=64e6, bytes_out=64e6,
        n_iters=4e6 * 14 * 16,
        iter_latency=7, ii=5,
        parallel_jobs=4e6, cpu_time_s=7.6,
        word_bytes=1, max_pe=128,
        naive_accesses_per_iter=1.25, pack_compute=True,
    ),
    # Queue-based BFS: 4K nodes, 64K edges; chain-dependent -> no PE dup,
    # no double buffering (next frontier depends on this one).
    # Pipelining 1.4x (Table 4) => 10/7.
    "bfs": KernelProfile(
        name="bfs",
        bytes_in=0.84e6, bytes_out=0.016e6,
        n_iters=64e3 + 4e3,
        iter_latency=5, ii=3.5,      # irregular accesses limit II
        parallel_jobs=0, cpu_time_s=1.3e-4,
        word_bytes=4, max_pe=1,
        naive_accesses_per_iter=2.5, overlappable=False,
    ),
    # 1024^3 double GEMM; pipelining 10.5x (Table 4) => L=11, II=1.
    # Tiled traffic: 2*N^3/T * 8B at T=64 => ~0.27 GB.
    "gemm": KernelProfile(
        name="gemm",
        bytes_in=2 * 1024**3 / 64 * 8, bytes_out=1024 * 1024 * 8,
        pcie_bytes=3 * 1024 * 1024 * 8,   # the two inputs + the output
        n_iters=1024**3,
        iter_latency=11, ii=1,
        parallel_jobs=1024 * 1024, cpu_time_s=5.2,
        word_bytes=8, max_pe=64,     # DSP-bound for double-precision
        naive_accesses_per_iter=3.0,
    ),
    # KMP over 128 MB text; pipelining 7.0x (Table 4) => L=7, II=1.
    "kmp": KernelProfile(
        name="kmp",
        bytes_in=128e6, bytes_out=4,
        n_iters=128e6,
        iter_latency=7, ii=1,
        parallel_jobs=64,            # segment the text into chunks
        cpu_time_s=0.27, word_bytes=1, max_pe=64,
        naive_accesses_per_iter=2.0, pack_compute=True, max_word_bits=256,
    ),
    # NW: 64K pairs of 128-nt sequences; pipelining 8.8x => L=9, II=1.
    "nw": KernelProfile(
        name="nw",
        bytes_in=64e3 * 256, bytes_out=64e3 * 256,
        n_iters=64e3 * 128 * 128,    # DP cells
        iter_latency=9, ii=1,
        parallel_jobs=64e3, cpu_time_s=2.8,
        word_bytes=1, max_pe=128,
        naive_accesses_per_iter=2.0,
    ),
    # Merge sort of 64 MB ints, 1 MB (256K-element) chunks; pipelining
    # 1.8x (Table 4) => 9/5; tree-reduce parallelism within each chunk.
    "sort": KernelProfile(
        name="sort",
        bytes_in=64e6, bytes_out=64e6,
        n_iters=64 * (256e3 * 18),   # 64 chunks x n log n
        iter_latency=9, ii=5,
        parallel_jobs=256e3,         # merges at the leaf level of a chunk
        cpu_time_s=3.4, word_bytes=4, max_pe=64, tree_reduce=True,
        naive_accesses_per_iter=2.5,
    ),
    # SPMV ELLPACK 4096x512; pipelining 10.9x => L=11, II=1.  val/col
    # streams coalesce even naively => ~1 transaction per element.
    "spmv": KernelProfile(
        name="spmv",
        bytes_in=4096 * 512 * (8 + 4), bytes_out=4096 * 8,
        n_iters=4096 * 512,
        iter_latency=11, ii=1,
        parallel_jobs=4096, cpu_time_s=1.6e-3,
        word_bytes=8, max_pe=64,
        naive_accesses_per_iter=1.0,
    ),
    # Viterbi: 1M chains x 128 steps (64 states unrolled in-stage);
    # float add/mul/cmp chain -> pipelining 3.2x (Table 4) => 40/12.
    "viterbi": KernelProfile(
        name="viterbi",
        bytes_in=1e6 * 128 * 8, bytes_out=1e6 * 4,
        n_iters=1e6 * 128,
        iter_latency=40, ii=12,
        parallel_jobs=1e6, cpu_time_s=9.2,
        word_bytes=8, max_pe=32,
        naive_accesses_per_iter=12,   # state vector mostly register-held
    ),
}


def paper_validation_table(hw: FpgaSpec = FPGA_2012) -> dict:
    """Model outputs in the shape of the paper's headline numbers.

    Returns per-kernel naive slowdown, final speedup, naive->final
    improvement, plus the aggregate gmean stats the abstract quotes.
    """
    rows = {}
    for name, prof in MACHSUITE_PROFILES.items():
        t0 = kernel_time(prof, OptLevel.O0, hw)
        t5 = kernel_time(prof, OptLevel.O5, hw)
        rows[name] = {
            "naive_speedup": t0["speedup_vs_cpu"],
            "final_speedup": t5["speedup_vs_cpu"],
            "improvement": t0["system_s"] / t5["system_s"],
            "pcie_over_cpu": t0["pcie_s"] / prof.cpu_time_s,
        }
    sl = [1.0 / r["naive_speedup"] for r in rows.values()]
    sp = [r["final_speedup"] for r in rows.values()]
    imp = [r["improvement"] for r in rows.values()]
    gmean = lambda xs: math.exp(sum(math.log(x) for x in xs) / len(xs))
    rows["_aggregate"] = {
        "gmean_naive_slowdown": gmean(sl),
        "gmean_final_speedup": gmean(sp),
        "mean_improvement": sum(imp) / len(imp),
        "min_improvement": min(imp),
        "max_improvement": max(imp),
    }
    return rows
