"""The paper's five best-effort refinement steps as a first-class config.

Cong et al. 2018 (Table 1) prescribe five programmer-accessible HLS
optimizations applied through data-driven iterative refinement.  This module
reifies them so that *every* layer of this framework — MachSuite kernels,
Pallas kernels, and the distributed LM runtime — can be built "at" an
optimization level, and so the refinement driver (``core.refine``) can move a
design up the ladder one step at a time, exactly as the paper does.

Level semantics (cumulative, matching the paper's iterations):

  O0  naive           — direct port; compute touches DRAM/HBM per element
  O1  +data caching   — explicit scratchpad staging (batch / tile)   [Iter #1]
  O2  +pipelining     — loop/grid pipelines, II->1 where legal       [Iter #2.1]
  O3  +PE duplication — spatial parallelism (unroll / shard)         [Iter #2.2]
  O4  +double buffer  — load/compute/store overlap                   [Iter #3.1]
  O5  +scratchpad reorg — wide-word / packed layouts                 [Iter #3.2]

Beyond the paper's table, the serving runtime grows the ladder further
(same methodology — reshape the hot loop to the access pattern, then
*measure*):

  O6  +paged scratchpad — fixed-size KV blocks + per-request block
      tables (vLLM-style), i.e. scratchpad reorganization level 2: the
      decode cache stops reserving batch x max_seq contiguous memory per
      slot and instead allocates from a shared block pool sized to the
      live working set.
  O7  +speculative decoding — a small drafter proposes K tokens per
      slot per tick; the target verifies them in one batched multi-token
      forward and greedy rejection accepts exactly the target's argmax
      prefix, so output stays bit-identical while effective tokens/tick
      rises toward 1 + acceptance * K (the hardware analog: branch
      prediction — speculate, verify, roll back for free).

``STEP_ORDER`` stays the paper's five steps (everything that reproduces
the paper's tables iterates it); ``LADDER`` is the full cumulative order
including the serving extension.
"""

from __future__ import annotations

import dataclasses
import enum


class Step(enum.Enum):
    """One refinement step from Table 1 of the paper."""

    DATA_CACHING = "explicit_data_caching"
    PIPELINING = "customized_pipelining"
    PE_DUPLICATION = "pe_duplication"
    DOUBLE_BUFFERING = "double_buffering"
    SCRATCHPAD_REORG = "scratchpad_reorganization"
    # Serving extension (not in the paper's Table 1): scratchpad
    # reorganization level 2 — paged KV blocks + per-request block tables.
    PAGED_SCRATCHPAD = "paged_scratchpad"
    # Serving extension: speculative decoding — a small drafter proposes
    # K tokens per slot per tick and the target verifies them in ONE
    # batched multi-token forward, collapsing up to K+1 decode ticks
    # into one (greedy rejection keeps output bit-identical).
    SPECULATIVE = "speculative_decoding"

    @property
    def software_counterpart(self) -> str:
        return _COUNTERPART[self]

    @property
    def paper_speedup_range(self) -> tuple:
        """(lo, hi) speedup the paper reports for this step (Table 1)."""
        return _PAPER_RANGE[self]


_COUNTERPART = {
    Step.DATA_CACHING: "data tiling",
    Step.PIPELINING: "directive-based programming",
    Step.PE_DUPLICATION: "multithreading",
    Step.DOUBLE_BUFFERING: "computation/communication overlapping",
    Step.SCRATCHPAD_REORG: "bit packing",
    Step.PAGED_SCRATCHPAD: "paged virtual memory (vLLM block tables)",
    Step.SPECULATIVE: "branch prediction (speculate, verify, roll back)",
}

# Table 1. Double buffering's range is folded into Iter#3's 1.2~19.2x in the
# paper; we carry the per-step figure the paper gives in Fig. 12 (<=2.1x).
_PAPER_RANGE = {
    Step.DATA_CACHING: (5.6, 32.1),
    Step.PIPELINING: (1.3, 10.3),
    Step.PE_DUPLICATION: (1.0, 53.6),
    Step.DOUBLE_BUFFERING: (1.0, 2.1),
    Step.SCRATCHPAD_REORG: (1.1, 19.1),
    # Not a paper figure: the paged rung's win is capacity (admitted
    # concurrency at equal memory), not raw speedup; throughput stays
    # within noise of O5 by design.
    Step.PAGED_SCRATCHPAD: (1.0, 1.0),
    # Not a paper figure either: the speculative rung's win is effective
    # tokens per tick (1 + acceptance * K), bounded by the measured
    # draft-vs-verify wall ratio; the autotuner races K and keeps K=0
    # (plain decode) on a tie/loss.
    Step.SPECULATIVE: (1.0, 1.0),
}

# The paper's Table 1: every surface that reproduces the paper's numbers
# (MachSuite kernels, the LM cost twin, the modelled refinement walk)
# iterates exactly these five.
STEP_ORDER = (
    Step.DATA_CACHING,
    Step.PIPELINING,
    Step.PE_DUPLICATION,
    Step.DOUBLE_BUFFERING,
    Step.SCRATCHPAD_REORG,
)

# Full cumulative ladder: OptLevel n enables LADDER[:n].  The serving
# runtime walks all of it; paper-scoped surfaces stop at STEP_ORDER.
LADDER = STEP_ORDER + (Step.PAGED_SCRATCHPAD, Step.SPECULATIVE)


class OptLevel(enum.IntEnum):
    O0 = 0   # naive
    O1 = 1   # + explicit data caching
    O2 = 2   # + customized pipelining
    O3 = 3   # + PE duplication
    O4 = 4   # + double buffering
    O5 = 5   # + scratchpad reorganization
    O6 = 6   # + paged scratchpad (serving extension: KV block tables)
    O7 = 7   # + speculative decoding (serving extension: draft/verify)

    @property
    def steps(self) -> tuple:
        return LADDER[: int(self)]

    def has(self, step: Step) -> bool:
        return step in self.steps

    @property
    def next_step(self):
        """The step that upgrading one level would add (None at the top)."""
        if int(self) >= len(LADDER):
            return None
        return LADDER[int(self)]


@dataclasses.dataclass(frozen=True)
class BestEffortConfig:
    """Knobs for the five steps, used by kernels and by the LM runtime.

    The defaults follow the paper's guidance:
      * cache_bytes — paper §3.2: >=64 KB amortizes burst init to <10% and
        saturates DRAM bw; we default to 64 KB-class VMEM blocks.
      * pe — the spatial parallelism degree ("unroll factor" on-chip,
        shard count off-chip).
      * n_buffers — 3-slot rotation as in paper Fig. 4(c)/5(c).
      * word_bits — scratchpad word width; 512 is the AXI/lane-packed max.
    """

    level: OptLevel = OptLevel.O5
    cache_bytes: int = 64 * 1024
    pe: int = 8
    n_buffers: int = 3
    word_bits: int = 512
    # LM-runtime extensions of the same five steps:
    remat: bool = False                # recompute vs cache activations
    overlap_grad_sync: bool = False    # O4 analog across pods
    compress_grads: bool = False       # O5 analog: int8 pod all-reduce
    # O6 (serving): paged decode-cache geometry.  kv_pool_blocks == 0
    # auto-sizes the pool to hold batch_size full sequences (equal
    # worst-case capacity to the contiguous cache; shrink it to trade
    # memory for queueing).
    kv_block_size: int = 16
    kv_pool_blocks: int = 0
    # O6 attention implementation: "gather" re-materializes each slot's
    # dense KV view from the pool every tick (jnp.take) and runs dense
    # decode attention on it; "kernel" runs the block-table-aware Pallas
    # kernel straight on the pool — gather-free, O(blocks touched) KV
    # traffic per tick instead of O(B * max_seq).  Best-effort contract:
    # families without a paged decode step (rwkv/mamba/hybrid/enc-dec)
    # fall back to gather, and the autotuner measures both and keeps
    # the winner (gather on tie/loss).
    paged_attn: str = "gather"
    # Chunked prefill: 0 keeps the legacy prestaged path (each prompt
    # token rides one decode tick); > 0 processes prompts in chunks of
    # this many tokens, one chunk per tick, interleaved with in-flight
    # decode — TTFT drops from O(prompt_len) ticks to
    # O(ceil(prompt_len / chunk)).  Best-effort contract: families
    # without a prefill step (MoE, recurrent-state) degrade to the
    # legacy path, and greedy tokens are bit-identical either way.
    prefill_chunk: int = 0
    # O7 (serving): speculative decoding.  ``draft_model`` names a small
    # zoo arch that proposes ``draft_k`` tokens per slot per tick; the
    # target model verifies all of them in one batched multi-token
    # forward and greedy rejection accepts exactly the target's argmax
    # prefix — output stays bit-identical to plain decode while
    # effective tokens/tick rises toward 1 + acceptance * draft_k.
    # Best-effort contract: no drafter configured, draft_k == 0, a
    # stochastic sampler, or a model family without verify hooks all
    # degrade to the plain O6 decode path (recorded in
    # ``engine.spec_mode``), never fail.
    draft_model: str = ""
    draft_k: int = 4
    # O6 refinement (serving): stored dtype of the paged KV pool blocks.
    # "bf16" keeps today's bit-identical ladder; "int8" / "fp8" store
    # blocks in the narrow dtype with per-(block x kv-head) absmax scales
    # kept alongside the block tables — double the admitted concurrency
    # at equal pool memory and half the kernel's streamed bytes/tick, in
    # exchange for a TOLERANCE contract vs the bf16 reference instead of
    # bit-identity (``repro.serving.kvquant.tolerance_contract``).  The
    # knob is inert on contiguous layouts, and the autotuner races it
    # like ``paged_attn`` (keep narrow only when it measures faster).
    kv_dtype: str = "bf16"

    def __post_init__(self):
        from repro.serving.kvquant import KV_DTYPES
        if self.kv_dtype not in KV_DTYPES:
            raise ValueError(f"kv_dtype {self.kv_dtype!r}; "
                             f"choices: {KV_DTYPES}")

    def with_level(self, level: OptLevel) -> "BestEffortConfig":
        return dataclasses.replace(self, level=level)

    @property
    def effective_pe(self) -> int:
        return self.pe if self.level.has(Step.PE_DUPLICATION) else 1

    # Cache LAYOUT (contiguous vs paged) and device PLACEMENT (replicated
    # vs PE-sharded, ``effective_pe``) are ORTHOGONAL serving axes, not
    # alternatives: the ladder is cumulative, so O6 includes PE
    # duplication, and a paged engine with effective_pe > 1 on >= 2
    # devices shards the block pool instead of falling back (the paper's
    # steps compose — see ``repro.serving.layout``).  No (layout,
    # placement) combination is invalid.
    @property
    def kv_layout(self) -> str:
        return ("paged" if self.level.has(Step.PAGED_SCRATCHPAD)
                else "contiguous")

    @property
    def effective_buffers(self) -> int:
        return self.n_buffers if self.level.has(Step.DOUBLE_BUFFERING) else 1

    @property
    def effective_word_bits(self) -> int:
        return self.word_bits if self.level.has(Step.SCRATCHPAD_REORG) else 8


ALL_LEVELS = tuple(OptLevel)
