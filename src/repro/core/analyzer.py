"""Roofline-term extraction from compiled XLA artifacts.

This is the TPU analog of the paper's execution-time breakdown (Fig. 3/7/11):
instead of DRAM-vs-compute wall-time bars measured on the board, we derive

    compute term    = HLO_FLOPs        / (chips x peak_FLOP/s)
    memory term     = HLO_bytes        / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

from ``compiled.cost_analysis()`` and the post-optimization HLO text
(collective bytes are not in cost_analysis; see ``core.hlo_stats``).

NOTE on normalization: ``cost_analysis()`` runs on the SPMD-partitioned
module, so its flops/bytes are *per device*.  We therefore multiply by the
device count to obtain module-total HLO_FLOPs/HLO_bytes before applying the
formulas above (equivalently: per-device work over per-chip peak).  The same
holds for collective operand bytes parsed from the partitioned HLO.
"""

from __future__ import annotations

import dataclasses
import json

from repro.core import hlo_stats
from repro.core.hw import TPU_V5E, TpuSpec


@dataclasses.dataclass
class Roofline:
    """Three-term roofline for one (program, mesh) pair."""

    arch: str
    shape: str
    mesh: str
    chips: int
    # Raw, per-device:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    # Terms, in seconds:
    compute_s: float
    memory_s: float
    collective_s: float
    # Accounting:
    model_flops: float = 0.0            # 6*N*D (dense) or 6*N_active*D (MoE)
    peak_memory_bytes: float = 0.0      # per-device, from memory_analysis
    argument_bytes: float = 0.0
    output_bytes: float = 0.0
    collective_breakdown: dict = dataclasses.field(default_factory=dict)
    notes: str = ""

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower-bound step time if nothing overlaps badly: max of terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound implied by the terms: useful compute time
        over the bounding term (1.0 == useful work runs at chip peak)."""
        if self.step_time_s == 0:
            return 0.0
        useful_s = self.model_flops / (self.chips * TPU_V5E.peak_bf16_flops)
        return useful_s / self.step_time_s

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        d["step_time_s"] = self.step_time_s
        d["useful_flops_fraction"] = self.useful_flops_fraction
        d["roofline_fraction"] = self.roofline_fraction
        return d


def _cost_value(cost: dict, *keys: str) -> float:
    for k in keys:
        if k in cost and cost[k] is not None:
            try:
                v = float(cost[k])
            except (TypeError, ValueError):
                continue
            if v >= 0:
                return v
    return 0.0


def extract_cost(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across JAX versions."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def roofline_from_compiled(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    model_flops: float = 0.0,
    spec: TpuSpec = TPU_V5E,
    hlo_text: str = None,
    notes: str = "",
) -> Roofline:
    cost = extract_cost(compiled)
    flops = _cost_value(cost, "flops")
    bytes_accessed = _cost_value(cost, "bytes accessed", "bytes_accessed")
    text = hlo_text if hlo_text is not None else compiled.as_text()
    stats = hlo_stats.parse_hlo(text)

    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "peak": getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0),
            "args": getattr(ma, "argument_size_in_bytes", 0),
            "out": getattr(ma, "output_size_in_bytes", 0),
        }
    except Exception:  # pragma: no cover - backend-dependent
        mem = {"peak": 0, "args": 0, "out": 0}

    # Per-device -> module totals (see module docstring).
    total_flops = flops * chips
    total_bytes = bytes_accessed * chips
    total_coll = stats.collective_bytes * chips

    return Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        collective_bytes_per_device=stats.collective_bytes,
        compute_s=total_flops / (chips * spec.peak_bf16_flops),
        memory_s=total_bytes / (chips * spec.hbm_bw),
        collective_s=total_coll / (chips * spec.ici_link_bw),
        model_flops=model_flops,
        peak_memory_bytes=mem["peak"],
        argument_bytes=mem["args"],
        output_bytes=mem["out"],
        collective_breakdown={
            k: v.operand_bytes for k, v in stats.collectives.items()
        },
        notes=notes,
    )


def save_roofline(r: Roofline, path: str) -> None:
    with open(path, "w") as f:
        json.dump(r.to_dict(), f, indent=2)


def load_roofline(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
