"""Core: the paper's best-effort guideline, reified.

Public surface:
  OptLevel / Step / BestEffortConfig  — the five steps as config
  recommend / comm_bound_filter       — bottleneck -> next step
  refine_modelled / refine_compiled   — the iterative refinement drivers
  Roofline / roofline_from_compiled   — 3-term analysis of compiled programs
  KernelProfile / kernel_time / MACHSUITE_PROFILES — faithful FPGA model
  TPU_V5E / FPGA_2012                 — platform constants
"""

from repro.core.analyzer import (
    Roofline,
    extract_cost,
    roofline_from_compiled,
)
from repro.core.costmodel import (
    MACHSUITE_PROFILES,
    KernelProfile,
    kernel_time,
    paper_validation_table,
    refinement_curve,
)
from repro.core.guideline import (
    COMM_BOUND_THRESHOLD,
    Recommendation,
    comm_bound_filter,
    recommend,
)
from repro.core.hlo_stats import HloStats, parse_hlo, shape_bytes
from repro.core.hw import FPGA_2012, TPU_V5E
from repro.core.optlevel import (
    ALL_LEVELS,
    LADDER,
    STEP_ORDER,
    BestEffortConfig,
    OptLevel,
    Step,
)
from repro.core.refine import RefineRecord, refine_compiled, refine_modelled

__all__ = [
    "ALL_LEVELS", "BestEffortConfig", "COMM_BOUND_THRESHOLD", "FPGA_2012",
    "HloStats", "KernelProfile", "LADDER", "MACHSUITE_PROFILES", "OptLevel",
    "Recommendation", "RefineRecord", "Roofline", "STEP_ORDER", "Step",
    "TPU_V5E", "comm_bound_filter", "extract_cost", "kernel_time",
    "paper_validation_table", "parse_hlo", "recommend", "refine_compiled",
    "refine_modelled", "refinement_curve", "roofline_from_compiled",
    "shape_bytes",
]
