"""Data-driven iterative refinement driver (the paper's outer loop).

Two backends:

  * ``refine_modelled`` — drives a ``costmodel.KernelProfile`` up the
    O0..O5 ladder exactly as the paper does its three iterations,
    re-measuring the (modelled) breakdown each time and letting the
    guideline pick the next step.  This reproduces the *process*, not just
    the endpoint, and is what ``examples/machsuite_refine.py`` prints.

  * ``refine_compiled`` — the TPU-native version: takes a callable that
    (re)builds a jitted program for a given ``BestEffortConfig``, lowers +
    compiles it, extracts roofline terms, and asks the guideline for the
    next step.  This is the hillclimbing harness used in EXPERIMENTS §Perf.
"""

from __future__ import annotations

import dataclasses

from repro.core import costmodel
from repro.core.analyzer import Roofline
from repro.core.guideline import Recommendation, recommend
from repro.core.optlevel import STEP_ORDER, BestEffortConfig, OptLevel


@dataclasses.dataclass
class RefineRecord:
    level: OptLevel
    breakdown: dict
    recommendation: str
    speedup_vs_baseline: float


def refine_modelled(
    profile: costmodel.KernelProfile,
    *,
    hw=None,
    cache_bytes: float = 64 * 1024,
    pe: int = 128,
) -> list:
    """Walk the ladder, logging breakdown + recommendation per level."""
    hw = hw or costmodel.FPGA_2012
    records = []
    t0 = None
    level = OptLevel.O0
    while True:
        t = costmodel.kernel_time(
            profile, level, hw, cache_bytes=cache_bytes, pe=pe
        )
        if t0 is None:
            t0 = t["system_s"]
        rec = recommend(
            level=level,
            compute_s=t["compute_s"],
            memory_s=t["dram_s"],
            offload_s=t["pcie_s"],
            baseline_s=profile.cpu_time_s,
        )
        records.append(
            RefineRecord(
                level=level,
                breakdown={k: t[k] for k in ("dram_s", "compute_s", "pcie_s",
                                             "kernel_s", "system_s")},
                recommendation=str(rec),
                speedup_vs_baseline=t0 / t["system_s"],
            )
        )
        if rec.stop or rec.step is None or level == OptLevel.O5:
            break
        # Apply the recommended step = move to the level that includes it.
        level = OptLevel(STEP_ORDER.index(rec.step) + 1)
    return records


def refine_compiled(
    build_and_compile,
    *,
    max_iters: int = 6,
    start: BestEffortConfig = None,
) -> list:
    """TPU-native refinement: ``build_and_compile(cfg) -> Roofline``.

    The callable re-lowers the program under ``cfg`` and returns a
    ``Roofline``; the guideline chooses the next step from its terms.
    Returns [(cfg, roofline, recommendation), ...].
    """
    cfg = start or BestEffortConfig(level=OptLevel.O0)
    out = []
    for _ in range(max_iters):
        rf: Roofline = build_and_compile(cfg)
        rec: Recommendation = recommend(
            level=cfg.level,
            compute_s=rf.compute_s,
            memory_s=rf.memory_s,
            collective_s=rf.collective_s,
        )
        out.append((cfg, rf, str(rec)))
        if rec.stop or rec.step is None:
            break
        cfg = cfg.with_level(OptLevel(STEP_ORDER.index(rec.step) + 1))
    return out
