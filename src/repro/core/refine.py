"""Data-driven iterative refinement driver (the paper's outer loop).

Two backends:

  * ``refine_modelled`` — drives a ``costmodel.KernelProfile`` up the
    O0..O5 ladder exactly as the paper does its three iterations,
    re-measuring the (modelled) breakdown each time and letting the
    guideline pick the next step.  This reproduces the *process*, not just
    the endpoint, and is what ``examples/machsuite_refine.py`` prints.

  * ``refine_compiled`` — the TPU-native version: takes a callable that
    (re)builds a jitted program for a given ``BestEffortConfig``, lowers +
    compiles it, extracts roofline terms, and asks the guideline for the
    next step.  This is the hillclimbing harness used in EXPERIMENTS §Perf.
"""

from __future__ import annotations

import dataclasses

from repro.core import costmodel
from repro.core.analyzer import Roofline
from repro.core.guideline import Recommendation, recommend
from repro.core.optlevel import STEP_ORDER, BestEffortConfig, OptLevel


@dataclasses.dataclass
class RefineRecord:
    level: OptLevel
    breakdown: dict
    recommendation: str
    speedup_vs_baseline: float


def refine_modelled(
    profile: costmodel.KernelProfile,
    *,
    hw=None,
    cache_bytes: float = 64 * 1024,
    pe: int = 128,
) -> list:
    """Walk the ladder, logging breakdown + recommendation per level.

    Thin compatibility wrapper over the closed-loop tuner
    (``repro.autotune``): one greedy guideline-driven walk of the analytic
    model, reshaped into the original ``RefineRecord`` stream.
    """
    from repro.autotune import KernelModelBackend, autotune

    backend = KernelModelBackend(
        profile, hw=hw, cache_bytes=cache_bytes, pe=pe)
    result = autotune(backend)
    return [
        RefineRecord(
            level=OptLevel(r.measurement.meta["level"]),
            breakdown={k: r.measurement.breakdown[k]
                       for k in ("dram_s", "compute_s", "pcie_s",
                                 "kernel_s", "system_s")},
            recommendation=r.recommendation,
            speedup_vs_baseline=r.speedup_vs_start,
        )
        for r in result.rounds
    ]


def refine_compiled(
    build_and_compile,
    *,
    max_iters: int = 6,
    start: BestEffortConfig = None,
) -> list:
    """TPU-native refinement: ``build_and_compile(cfg) -> Roofline``.

    The callable re-lowers the program under ``cfg`` and returns a
    ``Roofline``; the guideline chooses the next step from its terms.
    Returns [(cfg, roofline, recommendation), ...].
    """
    cfg = start or BestEffortConfig(level=OptLevel.O0)
    out = []
    for _ in range(max_iters):
        rf: Roofline = build_and_compile(cfg)
        rec: Recommendation = recommend(
            level=cfg.level,
            compute_s=rf.compute_s,
            memory_s=rf.memory_s,
            collective_s=rf.collective_s,
        )
        out.append((cfg, rf, str(rec)))
        if rec.stop or rec.step is None:
            break
        cfg = cfg.with_level(OptLevel(STEP_ORDER.index(rec.step) + 1))
    return out
