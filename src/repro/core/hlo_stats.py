"""Parse HLO text for the statistics ``cost_analysis()`` does not expose.

The roofline's collective term requires summing operand bytes over every
``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` in the *post-optimization* HLO
(``compiled.as_text()``), since that is where SPMD partitioning has already
materialized the real collective schedule.

Also provides an op census (for remat/duplication forensics) and a
reshape/transpose count (layout-mismatch smell, per the brief's hints).
"""

from __future__ import annotations

import dataclasses
import re
from collections import Counter

# Bytes per element for HLO primitive types.
_DTYPE_BYTES = {
    "pred": 1,
    "s2": 1, "u2": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1,
    "s16": 2, "u16": 2,
    "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e4m3": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

# One array shape like ``bf16[128,1024]{1,0:T(8,128)}`` or ``f32[]``.
_SHAPE_RE = re.compile(
    r"\b([a-z][a-z0-9]*)\[([0-9,]*)\](?:\{[^}]*\})?"
)

# ``%name = `` prefix of an instruction definition line.
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\s*\(")


def _consume_shape(s: str):
    """Split ``s`` into (shape_text, rest). Handles tuple shapes and layout
    annotations containing parens, e.g. ``f32[8,128]{1,0:T(8,128)}``."""
    s = s.lstrip()
    if s.startswith("("):
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return s[: i + 1], s[i + 1:]
        return s, ""
    m = re.match(r"[a-z][a-z0-9]*\[[0-9,]*\]", s)
    if not m:
        return "", s
    end = m.end()
    if end < len(s) and s[end] == "{":
        depth = 0
        for i in range(end, len(s)):
            if s[i] == "{":
                depth += 1
            elif s[i] == "}":
                depth -= 1
                if depth == 0:
                    end = i + 1
                    break
    return s[:end], s[end:]


def _parse_instr(ln: str):
    """Parse one instruction line -> (name, shape_text, opcode, args_text)."""
    m = _DEF_RE.match(ln)
    if not m:
        return None
    name = m.group(1)
    shape_text, rest = _consume_shape(ln[m.end():])
    if not shape_text:
        return None
    m2 = _OPCODE_RE.match(rest)
    if not m2:
        return None
    opcode = m2.group(1)
    paren = rest[m2.end():]
    depth, end = 1, len(paren)
    for i, ch in enumerate(paren):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return name, shape_text, opcode, paren[:end]

_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVE_OPCODES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
    "ragged-all-to-all",
)


def shape_bytes(shape_text: str) -> int:
    """Total bytes of every array shape appearing in ``shape_text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveInfo:
    opcode: str
    operand_bytes: int
    result_bytes: int
    count: int = 1


@dataclasses.dataclass
class HloStats:
    """Aggregate statistics over one HLO module's text."""

    collective_bytes: int
    collectives: dict            # opcode -> CollectiveInfo (aggregated)
    op_census: Counter           # opcode -> count
    reshape_transpose_count: int
    fusion_count: int
    instruction_count: int

    def bytes_by_opcode(self) -> dict:
        return {k: v.operand_bytes for k, v in self.collectives.items()}


def _base_opcode(opcode: str) -> str:
    """Map async start/done variants onto their base collective opcode."""
    for base in COLLECTIVE_OPCODES:
        if opcode == base or opcode == base + "-start":
            return base
    return ""


def parse_hlo(text: str) -> HloStats:
    """One pass over HLO text, resolving operand shapes via a symbol table.

    Async collectives appear as ``<op>-start`` / ``<op>-done`` pairs; only the
    ``-start`` (or the sync form) is counted, so nothing is double-counted.
    """
    # Pass 1: symbol table  name -> result bytes.
    sym: dict = {}
    parsed = []
    for ln in text.splitlines():
        rec = _parse_instr(ln)
        if rec is None:
            continue
        name, shape_text, opcode, args = rec
        rb = shape_bytes(shape_text)
        sym[name] = rb
        parsed.append((name, shape_text, opcode, args, rb, ln))

    census: Counter = Counter()
    collectives: dict = {}
    total_coll_bytes = 0
    reshapes = 0
    fusions = 0

    for name, shape_text, opcode, args, result_bytes, ln_full in parsed:
        census[opcode] += 1
        if opcode in ("reshape", "transpose", "copy"):
            reshapes += 1
        if opcode == "fusion":
            fusions += 1
        base = _base_opcode(opcode)
        if not base:
            continue
        # Operand bytes: prefer inline operand shapes inside the call parens;
        # fall back to symbol-table lookup of operand names.
        op_bytes = shape_bytes(args)
        if op_bytes == 0:
            for oname in _OPERAND_NAME_RE.findall(args):
                op_bytes += sym.get(oname, 0)
        if op_bytes == 0:
            # Last resort: for -start ops the result is a tuple (in, out);
            # use result bytes as a proxy.
            op_bytes = result_bytes
        # XLA's bf16->f32 all-reduce *promotion* (CPU backend) widens the
        # wire payload artificially; the TPU target reduces bf16 on the
        # wire (f32 accumulate inside the reduction unit).  Count promoted
        # collectives at their pre-promotion width.
        if "_promoted" in ln_full:
            op_bytes //= 2
        info = collectives.setdefault(
            base, CollectiveInfo(base, 0, 0, 0)
        )
        info.operand_bytes += op_bytes
        info.result_bytes += result_bytes
        info.count += 1
        total_coll_bytes += op_bytes

    return HloStats(
        collective_bytes=total_coll_bytes,
        collectives=collectives,
        op_census=census,
        reshape_transpose_count=reshapes,
        fusion_count=fusions,
        instruction_count=len(parsed),
    )


def top_collectives(text: str, n: int = 15) -> list:
    """The n largest individual collective instructions: (opcode,
    operand_bytes, result_shape) — the §Perf forensic that tells you WHICH
    tensor a fat all-reduce is moving."""
    sym: dict = {}
    rows = []
    for ln in text.splitlines():
        rec = _parse_instr(ln)
        if rec is None:
            continue
        name, shape_text, opcode, args = rec
        sym[name] = shape_bytes(shape_text)
        base = _base_opcode(opcode)
        if not base:
            continue
        op_bytes = shape_bytes(args)
        if op_bytes == 0:
            for oname in _OPERAND_NAME_RE.findall(args):
                op_bytes += sym.get(oname, 0)
        if op_bytes == 0:
            op_bytes = sym[name]
        rows.append((base, op_bytes, shape_text[:64]))
    rows.sort(key=lambda r: -r[1])
    return rows[:n]


# Opcodes whose results genuinely materialize in HBM on the TPU target.
# Elementwise chains (convert/add/multiply/select/broadcast/...) fuse into
# their consumers under the TPU XLA pipeline; the CPU backend we lower on
# leaves them unfused, which inflates raw "bytes accessed" several-fold
# (see EXPERIMENTS §Perf forensics).  ``fused_bytes`` re-censuses the
# module counting only fusion-boundary traffic — the TPU-target estimate.
MATERIALIZING_OPS = frozenset({
    "dot", "convolution", "fusion", "custom-call", "copy",
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "concatenate", "pad", "reduce", "reduce-window", "sort", "rng",
    "cholesky", "triangular-solve",
} | set(COLLECTIVE_OPCODES))


def fused_bytes(text: str) -> int:
    """TPU-fusion-adjusted byte census: operand+result bytes summed over
    materializing ops only (fusion operands resolve through the symbol
    table, so a fusion's internal ops are never double-counted).

    In-place update ops (scatter / dynamic-update-slice) alias their
    destination buffer on TPU: only the written region moves, so they are
    counted at 2x the non-destination operand bytes (read-modify-write of
    the touched rows) instead of the full buffer the XLA cost model
    charges — this is what makes decode-cell KV-cache updates sane."""
    sym: dict = {}
    total = 0
    for ln in text.splitlines():
        rec = _parse_instr(ln)
        if rec is None:
            continue
        name, shape_text, opcode, args = rec
        rb = shape_bytes(shape_text)
        sym[name] = rb
        base = _base_opcode(opcode) or opcode
        if base not in MATERIALIZING_OPS:
            continue
        ops = [sym.get(o, 0) for o in _OPERAND_NAME_RE.findall(args)]
        if base in ("scatter", "dynamic-update-slice") and ops:
            total += 2 * (sum(ops) - max(ops))   # updates + indices, r+w
            continue
        total += rb + sum(ops)
    return total


def bytes_by_opcode(text: str, n: int = 12) -> list:
    """Aggregate (operand+result) bytes per opcode over the module — the
    fusion-boundary traffic census that approximates what cost_analysis
    counts as "bytes accessed".  Returns the top-n (opcode, bytes, count)."""
    sym: dict = {}
    agg: Counter = Counter()
    cnt: Counter = Counter()
    for ln in text.splitlines():
        rec = _parse_instr(ln)
        if rec is None:
            continue
        name, shape_text, opcode, args = rec
        rb = shape_bytes(shape_text)
        sym[name] = rb
        ob = 0
        for oname in _OPERAND_NAME_RE.findall(args):
            ob += sym.get(oname, 0)
        if opcode in ("parameter", "constant", "iota"):
            continue
        agg[opcode] += rb + ob
        cnt[opcode] += 1
    return [(op, b, cnt[op]) for op, b in agg.most_common(n)]


def remat_duplication(census: Counter) -> dict:
    """Heuristic remat detector: ops whose counts look duplicated.

    Returns {opcode: count} for the compute-heavy opcodes; the refinement
    driver compares counts across policies to spot recompute blowups.
    """
    heavy = ("dot", "convolution", "fusion", "custom-call")
    return {k: census[k] for k in heavy if census.get(k)}
