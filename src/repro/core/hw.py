"""Hardware constants for the two platforms this repo reasons about.

``TPU_V5E`` is the TARGET platform (the container is CPU-only; all perf
numbers are derived analytically from compiled artifacts against these
constants, per the brief).

``FPGA_2012`` reproduces the paper's experimental platform (Table 2 of
Cong et al. 2018) and is used by ``core.costmodel`` to validate the
faithful reproduction against the paper's own reported numbers.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TpuSpec:
    """One TPU chip + its pod interconnect."""

    name: str
    peak_bf16_flops: float      # FLOP/s per chip
    peak_int8_ops: float        # OP/s per chip
    hbm_bytes: int              # per chip
    hbm_bw: float               # bytes/s per chip
    vmem_bytes: int             # per core
    ici_link_bw: float          # bytes/s per link (one direction)
    ici_links: int              # links per chip in the 2D torus
    dcn_bw: float               # bytes/s per chip for cross-pod (data-center net)
    mxu_shape: tuple = (128, 128)
    vpu_lanes: tuple = (8, 128)
    clock_hz: float = 0.94e9

    # Derived helpers -----------------------------------------------------
    def compute_time(self, flops: float, chips: int = 1) -> float:
        return flops / (chips * self.peak_bf16_flops)

    def memory_time(self, bytes_: float, chips: int = 1) -> float:
        return bytes_ / (chips * self.hbm_bw)

    def collective_time(self, bytes_: float, chips: int = 1) -> float:
        # Per the brief: collective term = collective_bytes / (chips x link_bw).
        return bytes_ / (chips * self.ici_link_bw)


# Constants fixed by the brief: 197 TFLOP/s bf16; 819 GB/s HBM; ~50 GB/s/link ICI.
TPU_V5E = TpuSpec(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    peak_int8_ops=394e12,
    hbm_bytes=16 * 1024**3,
    hbm_bw=819e9,
    vmem_bytes=128 * 1024**2,
    ici_link_bw=50e9,
    ici_links=4,
    dcn_bw=6.25e9,   # ~50 Gb/s per chip across pods, conservative
)


@dataclasses.dataclass(frozen=True)
class FpgaSpec:
    """The paper's 2012 CPU-FPGA platform (Table 2 + §3 constants)."""

    name: str = "virtex7_sdaccel_2015_4"
    clock_hz: float = 200e6                  # FPGA fabric clock
    cpu_clock_hz: float = 1.9e9              # Xeon E5-2420
    dram_bw: float = 12.8e9                  # device DDR3-1600, bytes/s
    pcie_bw: float = 8e9                     # PCIe gen3 x8, bytes/s
    dram_init_cycles: int = 100              # per-burst initiation (~500 ns)
    bram_total_bytes: int = 4 * 1024**2      # usable for accelerators (~4 MB)
    bram_blocks: int = 3000                  # 18 Kb blocks on the fabric
    bram_block_bits: int = 18 * 1024
    bram_block_max_width: int = 36           # bits, single block
    axi_bus_bits: int = 512                  # max burst datapath width
    max_pe: int = 128                        # paper sweeps 1..128 PEs

    @property
    def cycle_s(self) -> float:
        return 1.0 / self.clock_hz

    def burst_time(self, payload_bytes: float, width_bits: int = 512) -> float:
        """Time for one DRAM burst: init overhead + streaming at bus width.

        The paper's model (§3.2): 100 cycles init + ~1 cycle per beat.
        A beat moves ``width_bits`` bits.
        """
        beats = payload_bytes * 8.0 / width_bits
        return (self.dram_init_cycles + beats) * self.cycle_s


FPGA_2012 = FpgaSpec()

# Mesh/pod geometry used throughout (fixed by the brief).
SINGLE_POD_SHAPE = (16, 16)            # axes ("data", "model") = 256 chips
MULTI_POD_SHAPE = (2, 16, 16)          # axes ("pod", "data", "model") = 512 chips
SINGLE_POD_CHIPS = 256
MULTI_POD_CHIPS = 512
