from repro.checkpoint.sharded import (CheckpointManager,  # noqa: F401
                                      load_checkpoint, save_checkpoint)
