"""Sharded checkpointing: per-shard files, async writer, elastic restore.

Layout of one checkpoint directory::

    step_000123/
      MANIFEST.json            tree structure, per-leaf shape/dtype, step,
                               mesh shape it was saved under
      <leaf>__shard<k>.npy     one file per (leaf, distinct shard)

Properties (all tested):

  * **Shard-parallel**: each leaf is written as its distinct device shards
    (addressable only), so at scale every host writes only its slice and no
    host needs the full array in memory.
  * **Atomic**: written into ``<dir>.tmp`` then renamed — a crash mid-save
    never corrupts the latest checkpoint.
  * **Async**: ``save_async`` hands the arrays (host-fetched shards) to a
    writer thread; training continues while IO drains (the double-buffering
    step applied to checkpointing).
  * **Elastic restore**: ``load_checkpoint(dir, target_shardings)``
    reassembles leaves from shard files and re-places them under a *new*
    mesh/sharding — restoring a 512-chip checkpoint onto 256 chips (or a
    host mesh in the tests) re-shards transparently.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor

import numpy as np
import jax


SEP = "."


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = SEP.join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class _HostShardsArr:
    """Host-side snapshot of a sharded array (what the writer thread sees)."""

    def __init__(self, arr: "jax.Array"):
        self.shape = arr.shape
        self.shards = _jax_array_shards(arr)


def _jax_array_shards(arr):
    seen = {}
    for sh in arr.addressable_shards:
        key = tuple((s.start, s.stop) for s in _norm_index(sh.index,
                                                           arr.shape))
        if key not in seen:
            seen[key] = (sh.index, np.asarray(sh.data))
    return list(seen.values())


def _leaf_shards(arr):
    """[(index_tuple, np.ndarray)] for the addressable distinct shards."""
    if isinstance(arr, _HostShardsArr):
        return arr.shards
    if not isinstance(arr, jax.Array):
        return [((slice(None),) * np.ndim(arr), np.asarray(arr))]
    return _jax_array_shards(arr)


def _norm_index(index, shape):
    out = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else sl.start
        stop = dim if sl.stop is None else sl.stop
        out.append(slice(start, stop))
    return tuple(out)


def save_checkpoint(path: str, tree, *, step: int, extra: dict = None):
    """Synchronous sharded save (atomic via tmp+rename)."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for key, leaf in leaves:
        shards = _leaf_shards(leaf)
        rec = {"shape": list(np.shape(leaf)),
               "dtype": str(np.asarray(shards[0][1]).dtype),
               "shards": []}
        for si, (index, data) in enumerate(shards):
            fname = f"{key}__shard{si}.npy"
            np.save(os.path.join(tmp, fname), data)
            rec["shards"].append({
                "file": fname,
                "index": [[s.start, s.stop] for s in
                          _norm_index(index, np.shape(leaf))],
            })
        manifest["leaves"][key] = rec

    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)
    return path


def load_manifest(path: str) -> dict:
    with open(os.path.join(path, "MANIFEST.json")) as f:
        return json.load(f)


def load_checkpoint(path: str, target_tree, *, shardings=None):
    """Restore into the structure of ``target_tree`` (arrays or
    ShapeDtypeStructs).  ``shardings``: same-structure tree of NamedSharding
    for elastic re-shard; None -> plain host arrays."""
    manifest = load_manifest(path)
    t_leaves, treedef = _flatten_with_paths(target_tree)
    s_leaves = (jax.tree.leaves(shardings) if shardings is not None
                else [None] * len(t_leaves))
    assert len(t_leaves) == len(s_leaves), "sharding tree mismatch"

    out = []
    for (key, spec), shd in zip(t_leaves, s_leaves):
        rec = manifest["leaves"].get(key)
        if rec is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        shape = tuple(rec["shape"])
        if tuple(np.shape(spec)) != shape:
            raise ValueError(
                f"{key}: checkpoint shape {shape} != target "
                f"{np.shape(spec)}")
        dtype = np.dtype(rec["dtype"])   # ml_dtypes names resolve too
        full = np.empty(shape, dtype)
        for sh in rec["shards"]:
            data = np.load(os.path.join(path, sh["file"]))
            if data.dtype != dtype:
                # np.load round-trips ml_dtypes (bf16/f8) as raw void:
                # reinterpret, same itemsize
                data = data.view(dtype)
            idx = tuple(slice(a, b) for a, b in sh["index"])
            full[idx] = data
        if shd is not None:
            arr = jax.make_array_from_callback(
                shape, shd, lambda idx, _full=full: _full[idx])
        else:
            arr = full
        out.append(arr)
    tree = jax.tree.unflatten(treedef, out)
    return tree, manifest["step"], manifest.get("extra", {})


class CheckpointManager:
    """Rotating async checkpoint writer.

    ``save_async`` snapshots device shards to host synchronously (cheap)
    and writes files on a worker thread; ``wait()`` drains.  Keeps the
    ``keep`` newest checkpoints; ``latest()``/``restore`` find them.
    """

    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="ckpt")
        self._lock = threading.Lock()
        self._pending: list = []

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def save_async(self, tree, *, step: int, extra: dict = None) -> Future:
        # Snapshot to host NOW so training can donate/overwrite buffers.
        host_tree = jax.tree.map(_snapshot_leaf, tree)
        fut = self._pool.submit(self._save_and_gc, host_tree, step, extra)
        with self._lock:
            self._pending.append(fut)
        return fut

    def _save_and_gc(self, host_tree, step, extra):
        path = save_checkpoint(self._dir(step), host_tree, step=step,
                               extra=extra)
        self._gc()
        return path

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)

    def all_steps(self):
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except (IndexError, ValueError):
                    continue
        return sorted(out)

    def latest(self):
        steps = self.all_steps()
        return self._dir(steps[-1]) if steps else None

    def restore_latest(self, target_tree, *, shardings=None):
        path = self.latest()
        if path is None:
            return None
        return load_checkpoint(path, target_tree, shardings=shardings)

    def wait(self):
        with self._lock:
            pending, self._pending = self._pending, []
        for f in pending:
            f.result()

    def close(self):
        self.wait()
        self._pool.shutdown(wait=True)


def _snapshot_leaf(leaf):
    if isinstance(leaf, jax.Array):
        return _HostShardsArr(leaf)
    return np.asarray(leaf)
