"""Token samplers for the decode engine — and where they run.

``make_sampler(cfg)`` returns a pure function
``sample(logits[B, V], seeds[B]) -> tokens[B]`` built from jnp ops, so the
same math can run

  * UNFUSED (O0/O1): the jitted model step returns full-vocab logits, and
    the sampler runs as a second, separate dispatch — the naive two-kernel
    path, with the (B, 1, V) logits materialized between them; or
  * IN-GRAPH (O2+, the customized-pipelining step): the sampler is fused
    into the jitted decode step, so only the (B,) sampled token ids ever
    leave the graph.

Greedy sampling is deterministic, so fused and unfused paths emit
bit-identical tokens — the property the ladder tests pin.  Stochastic
kinds (temperature / top-k) derive one fold-in seed per (request,
emission-index) on the host, making them reproducible per request
regardless of batch composition or slot placement.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

KINDS = ("greedy", "temperature", "top_k")


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    kind: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0               # 0 => full vocab
    seed: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown sampler {self.kind!r}; "
                             f"choices: {KINDS}")

    @property
    def stochastic(self) -> bool:
        return self.kind != "greedy"

    def request_seed(self, rid: int, n_emitted: int) -> int:
        """Stable per-(request, emission) seed, independent of slot/batch."""
        h = (self.seed * 1_000_003 + rid * 7_919 + n_emitted) & 0x7FFFFFFF
        return h


def make_sampler(cfg: SamplerConfig):
    """Returns ``sample(logits[B, V], seeds[B]) -> tokens[B]`` (int32)."""

    if cfg.kind == "greedy":
        # Not jnp.argmax: XLA CPU lowers argmax to a slow variadic reduce
        # (~2.5x the two-pass form at 32k vocab).  max + min-index-of-max
        # is vectorizable and has identical first-max semantics (and
        # matches np.argmax on the host path bit for bit).
        def sample(logits, seeds):
            del seeds
            m = jnp.max(logits, axis=-1, keepdims=True)
            idx = jnp.where(logits == m,
                            jnp.arange(logits.shape[-1], dtype=jnp.int32),
                            logits.shape[-1])
            return jnp.min(idx, axis=-1).astype(jnp.int32)
        return sample

    temp = max(cfg.temperature, 1e-6)
    top_k = cfg.top_k

    def sample_row(logits, seed):
        key = jax.random.PRNGKey(seed)
        scaled = logits.astype(jnp.float32) / temp
        if top_k and top_k > 0:
            kth = jax.lax.top_k(scaled, top_k)[0][..., -1]
            scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
        return jax.random.categorical(key, scaled).astype(jnp.int32)

    return jax.vmap(sample_row)
