"""KVLayout: the cache-LAYOUT half of the serving engine's
layout x placement product.

The paper's whole point is that refinement steps COMPOSE — PE
duplication (step 3) and scratchpad reorganization (step 5) are applied
together, not as alternatives — and AutoDSE-style search needs the knob
space to stay a product of independent axes.  So the engine selects two
orthogonal strategy objects instead of forking on ``if paged``:

  * :class:`KVLayout` (this module) — HOW the decode cache is stored:
    :class:`ContiguousLayout` (one ``batch x max_seq`` slice per slot,
    O0..O5) or :class:`PagedLayout` (a pooled KV-block scratchpad with
    per-request block tables, O6).  The layout owns cache-manager
    construction, scheduler wiring (admission gates for the block pool)
    and the step-wrapping that used to be inlined in the engine as
    ``_make_fused`` / ``_make_paged_fused``.
  * :class:`repro.parallel.sharding.PlacementPlan` — WHERE the arrays
    live: replicated, or PE-sharded over a 1-D data mesh.

Every (layout, placement) cell compiles a decode step:

  contiguous x replicated  — the process-wide shared jitted step
  contiguous x sharded     — per-engine step; cache/tokens sharded on
                             the batch axis (classic O3)
  paged      x replicated  — per-engine step (pool geometry is part of
                             the program); gather -> decode -> scatter,
                             or — ``paged_attn="kernel"`` — the
                             gather-free block-table Pallas kernel on
                             the raw pool (no dense view at all)
  paged      x sharded     — per-engine step; the pool is sharded on the
                             BLOCK axis (rows padded to a device
                             multiple), block tables replicated, and the
                             gathered dense view is re-sharded onto the
                             batch axis so the model itself runs
                             PE-duplicated (O3 x O6 composed); the
                             kernel variant replicates the pool
                             in-graph for the (single-device) kernel
                             call and re-shards the written pool

Greedy tokens are bit-identical across all four cells: sharding touches
only non-contraction axes (batch, pool rows), so no reduction is ever
split — the same oracle the O0..O6 ladder tests pin.

The shared step cache here is weakref-keyed: entries hold the model only
through a weak proxy and are evicted the moment the model dies, so a
process that keeps constructing engines never pins dead models (the old
``id(model)``-keyed cache did, until LRU churn).
"""

from __future__ import annotations

import collections
import logging
import weakref

import jax

from repro.core.optlevel import BestEffortConfig
from repro.serving.cache import CacheManager
from repro.serving.paged import PagedCacheManager
from repro.serving.sampler import make_sampler

log = logging.getLogger("repro.serving")


def _last_logits(logits):
    """(B, V) or (B, 1, V) -> (B, V): the newest position's logits."""
    if logits.ndim == 3:
        return logits[:, -1, :]
    return logits


def make_fused(model, sample):
    """The batched fused decode+sample step (contiguous O2+); one
    definition shared by the replicated and the PE-sharded instantiation
    so they can never drift apart."""
    def _fused(params, cache, tokens, positions, seeds):
        logits, new_cache = model.decode_step(
            params, cache, tokens, positions)
        return sample(_last_logits(logits), seeds), new_cache

    return _fused


def _split_cache(cache, quantized):
    """(pool, scales) from the step's cache argument: narrow pools
    travel as a ``{"pool", "scale"}`` bundle, wide pools bare."""
    if quantized:
        return cache["pool"], cache["scale"]
    return cache, None


def _join_cache(pool, scales, quantized):
    if quantized:
        return {"pool": pool, "scale": scales}
    return pool


def _split_extras(manager, extras):
    """(tables, rows) from a step's variadic extras, per the manager's
    leaf population: tables iff it has block leaves, rows iff it has
    state leaves — the same order ``step_extras()`` emits."""
    tables = rows = None
    it = iter(extras)
    if manager.has_blocks:
        tables = next(it)
    if manager.state is not None:
        rows = next(it)
    return tables, rows


def make_paged_fused(model, sample, manager, constrain=None):
    """The paged GATHER step: block-table gather (block leaves) +
    state-row gather (state leaves) -> the SAME ``decode_step`` the
    dense rungs run -> state-row scatter + single-block scatter.  The
    dense view the model sees is bit-identical at every unmasked
    position (see ``paged`` docstring) and state rows gather the exact
    carried state, so greedy tokens cannot drift from the contiguous
    path.  Narrow pools (``kv_dtype`` int8/fp8) dequantize inside the
    gather and re-quantize each slot's active block inside the scatter —
    tokens then track the dense oracle only up to the dtype's tolerance
    contract, never bit-exactly (state rows are never quantized).

    ``constrain`` (from the sharded placement) re-shards the gathered
    dense view onto the batch axis in-graph, so under a mesh the model
    body runs PE-duplicated while the pool stays block/row-sharded.
    """
    plan, splan = manager.plan, manager.state_plan
    quantized = plan.quantized

    def _fused(params, cache, *rest):
        extras, (tokens, positions, seeds) = rest[:-3], rest[-3:]
        tables, rows = _split_extras(manager, extras)
        pool, scales = _split_cache(cache, quantized)
        dense = pool
        if tables is not None:
            dense = plan.gather(dense, tables, scales)
        if rows is not None:
            dense = splan.gather(dense, rows)
        if constrain is not None:
            dense = plan.map_batch_axes(dense, constrain)
        logits, new_dense = model.decode_step(
            params, dense, tokens, positions)
        toks = sample(_last_logits(logits), seeds)
        new_pool = pool
        if rows is not None:
            new_pool = splan.scatter(new_pool, rows, new_dense)
        if tables is None:
            return toks, _join_cache(new_pool, scales, quantized)
        if quantized:
            new_pool, scales = plan.scatter(new_pool, tables, new_dense,
                                            positions, scales=scales)
            return toks, _join_cache(new_pool, scales, True)
        return toks, plan.scatter(new_pool, tables, new_dense, positions)

    return _fused


def make_paged_kernel_fused(model, sample, manager, replicate=None):
    """The paged KERNEL step (``paged_attn="kernel"``): the model's
    ``paged_decode_step`` consumes the block pool + tables (+ state
    rows) + positions DIRECTLY — the per-tick O(B * max_seq) dense
    gather/scatter of :func:`make_paged_fused` is gone; each attention
    layer appends the current token's K/V into the active block in place
    and the block-table-aware Pallas kernel streams only the blocks each
    slot references (O(blocks touched) KV traffic per tick), while state
    leaves move through O(B) row indirection.  Narrow pools thread the
    per-block scale subtree alongside and the kernel dequantizes each
    streamed block in place.

    ``replicate`` (from a sharded placement): the Pallas kernel is a
    single-device program, so under a BLOCK-axis-sharded pool the step
    re-constrains the pool leaves to replicated in-graph for the kernel
    call and ``out_shardings`` re-shards the written pool back onto the
    block axis.  Correct everywhere; whether it *wins* there is the
    autotuner's call, like every best-effort rung.
    """
    quantized = manager.plan.quantized
    kv_dtype = manager.plan.kv_dtype

    def _fused(params, cache, *rest):
        extras, (tokens, positions, seeds) = rest[:-3], rest[-3:]
        pool, scales = _split_cache(cache, quantized)
        if replicate is not None:
            pool = jax.tree.map(replicate, pool)
            if scales is not None:
                scales = jax.tree.map(replicate, scales)
        if quantized:
            logits, new_pool, new_scales = model.paged_decode_step(
                params, pool, *extras, tokens, positions,
                scales=scales, kv_dtype=kv_dtype)
        else:
            logits, new_pool = model.paged_decode_step(
                params, pool, *extras, tokens, positions)
            new_scales = None
        toks = sample(_last_logits(logits), seeds)
        return toks, _join_cache(new_pool, new_scales, quantized)

    return _fused


# ---------------------------------------------------------------------------
# Shared jitted steps (contiguous, replicated) — weakref-keyed.
# ---------------------------------------------------------------------------

# Jitted step functions are shared across engines of the same
# (model, sampler, fusion mode): every replicated contiguous level from
# O2 up runs the *same* compiled decode program, so measured differences
# between ladder rungs come from the host-side mechanics each rung
# actually changes, not from per-engine jit-instance luck.  (Sharded and
# paged engines build their own step: shardings and pool geometry are
# part of the program.)  Entries reference the model only through a weak
# proxy and a ``weakref.finalize`` evicts them when the model dies, so
# the cache never outlives its models; the LRU bound stays as a backstop
# against many live models.
_STEP_CACHE = collections.OrderedDict()
_STEP_CACHE_MAX = 8


class _WeakModel:
    """Attribute proxy holding the model weakly.  The jitted closures
    resolve it at trace time only (some engine is mid-construction or
    mid-retrace, so the model is alive); once compiled, the executable
    needs no model at all."""

    __slots__ = ("_ref",)

    def __init__(self, model):
        self._ref = weakref.ref(model)

    def __getattr__(self, name):
        model = self._ref()
        if model is None:
            raise ReferenceError(
                "shared decode step retraced after its model was "
                "garbage-collected (the owning engine must outlive "
                "retraces)")
        return getattr(model, name)


def shared_steps(model, sampler_cfg):
    key = (id(model), sampler_cfg)
    if key in _STEP_CACHE:
        _STEP_CACHE.move_to_end(key)
        return _STEP_CACHE[key]

    sample = make_sampler(sampler_cfg)
    axes_tree = model.cache_axes()
    leaves_axes = jax.tree.leaves(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))
    batch_axes = [ax.index("batch") for ax in leaves_axes]
    weak = _WeakModel(model)

    def _single(params, cache, token, position, islot):
        """One request's decode step: slice slot ``islot``'s cache rows,
        run a batch-1 model step, write the rows back.  The un-pipelined
        serving loop — each request pays its own model call (and its own
        pass over the weights)."""
        leaves, treedef = jax.tree.flatten(cache)
        row = jax.tree.unflatten(treedef, [
            jax.lax.dynamic_slice_in_dim(leaf, islot, 1, axis=bax)
            for leaf, bax in zip(leaves, batch_axes)])
        logits, new_row = weak.decode_step(
            params, row, token[None, None], position[None])
        row_leaves = jax.tree.leaves(new_row)
        new_cache = jax.tree.unflatten(treedef, [
            jax.lax.dynamic_update_slice_in_dim(leaf, new, islot, axis=bax)
            for leaf, new, bax in zip(leaves, row_leaves, batch_axes)])
        return _last_logits(logits)[0], new_cache

    def _prefill(params, cache, islot, tokens, start, last, seeds):
        """One slot's prefill CHUNK: slice slot ``islot``'s cache rows,
        run a batch-1 multi-token prefill step over ``tokens`` (1, C)
        starting at absolute position ``start``, write the rows back and
        sample the logits at row ``last`` (the chunk's final real
        token — only the final chunk's sample is ever used).  Chunks are
        PADDED to a fixed C, so one trace serves the whole prompt: pad
        rows write at future (or clipped) positions that are either
        overwritten in-graph before first read or masked, and their
        logits are never selected."""
        leaves, treedef = jax.tree.flatten(cache)
        row = jax.tree.unflatten(treedef, [
            jax.lax.dynamic_slice_in_dim(leaf, islot, 1, axis=bax)
            for leaf, bax in zip(leaves, batch_axes)])
        logits, new_row = weak.prefill_step(params, row, tokens, start,
                                            last)
        row_leaves = jax.tree.leaves(new_row)
        new_cache = jax.tree.unflatten(treedef, [
            jax.lax.dynamic_update_slice_in_dim(leaf, new, islot, axis=bax)
            for leaf, new, bax in zip(leaves, row_leaves, batch_axes)])
        return sample(logits, seeds)[0], new_cache

    def _verify(params, cache, tokens, start):
        """Speculative verify: ONE batched forward over tokens (B, C) —
        each slot's pending token + C-1 drafts written at positions
        ``start`` .. ``start + C - 1`` — returning the greedy token at
        EVERY row (B, C).  Only traced for greedy samplers (the engine
        gates speculation on determinism), where ``sample`` reduces over
        the last axis row-independently."""
        logits, new_cache = weak.verify_step(params, cache, tokens, start)
        return sample(logits, None), new_cache

    _STEP_CACHE[key] = {
        "fused": jax.jit(make_fused(weak, sample), donate_argnums=(1,)),
        "single": jax.jit(_single, donate_argnums=(1,)),
        "prefill": jax.jit(_prefill, donate_argnums=(1,)),
        "verify": jax.jit(_verify, donate_argnums=(1,)),
        "sample": jax.jit(sample),
    }
    # Evict on model death (runs at deallocation, before the id can be
    # recycled, so a stale entry can never alias a new model).
    weakref.finalize(model, _STEP_CACHE.pop, key, None)
    if len(_STEP_CACHE) > _STEP_CACHE_MAX:
        _STEP_CACHE.popitem(last=False)
    return _STEP_CACHE[key]


# ---------------------------------------------------------------------------
# The layout protocol + its two implementations.
# ---------------------------------------------------------------------------


class KVLayout:
    """Strategy protocol for the decode-cache layout.

    ``name``              — "contiguous" / "paged" (mirrors
                            ``BestEffortConfig.kv_layout``).
    ``supports_step_fn``  — whether a caller-supplied fused step can
                            drive this layout (the paged step needs the
                            block-table argument, so it cannot).
    ``build_manager``     — construct the cache manager, already placed
                            per the :class:`PlacementPlan`.
    ``wire_scheduler``    — attach admission gate / lifecycle hooks.
    ``make_step``         — the jitted fused decode+sample step for
                            (this layout) x (this placement).
    ``make_prefill_step`` — the jitted single-slot prefill-CHUNK step
                            (or None when this layout x placement x
                            model cell cannot chunk — the engine then
                            degrades to the legacy one-token-per-tick
                            prestaged prefill).

    The engine holds one of each and never branches on layout again; the
    extra per-tick step inputs (block tables, state rows) come from the
    manager's ``step_extras()`` so the dispatch path is layout-blind
    too — the prefill step takes the same extras between cache and slot
    index.

    Three RECORDED strings replace silent degrades (the best-effort
    contract: degrade, don't fail, and say so):

    ``attn_impl``   — the attention implementation the built step
                      actually uses ("gather"/"kernel"; None on the
                      contiguous layout).
    ``state_impl``  — how recurrent/cross state moves ("rows" when the
                      paged manager row-pools state leaves, "none" when
                      the family has none or the layout is contiguous).
    ``degrade_reason`` — why a requested capability fell back (kernel ->
                      gather, chunked -> token), None when nothing did.
    """

    name: str = "?"
    supports_step_fn: bool = False
    attn_impl = None
    state_impl: str = "none"
    degrade_reason = None

    def build_manager(self, model, batch_size, max_seq, config, placement):
        raise NotImplementedError

    def wire_scheduler(self, scheduler, manager) -> None:
        pass

    def make_step(self, model, sampler_cfg, manager, placement):
        raise NotImplementedError

    def make_prefill_step(self, model, sampler_cfg, manager, placement):
        """(params, cache, *extras, islot, tokens (1, C), start (1,),
        last (1,), seeds (1,)) -> (token, cache), or None when this cell
        cannot run a chunked prefill (no model prefill step, or a
        sharded placement — a batch-1 chunk under a batch/block-sharded
        program would retrace the whole step; the legacy path already
        serves that cell)."""
        return None

    def make_verify_step(self, model, sampler_cfg, manager, placement):
        """The jitted speculative-verify step for (this layout) x (this
        placement): (params, cache, *extras, tokens (B, C), start (B,))
        -> (greedy tokens (B, C), cache) — one batched multi-token
        forward over every slot's pending token + drafts, greedy argmax
        at every row in-graph.  None when this layout x placement x
        model cell cannot verify (no model verify hook) — the engine
        then degrades speculation to plain decode."""
        return None


class ContiguousLayout(KVLayout):
    """One ``batch x max_seq`` cache slice per slot (rungs O0..O5).
    Placement shards every leaf on its batch axis."""

    name = "contiguous"
    supports_step_fn = True

    def build_manager(self, model, batch_size, max_seq,
                      config: BestEffortConfig, placement):
        return CacheManager(
            model, batch_size, max_seq, config.level,
            shardings=placement.cache_shardings(model, batch_size, max_seq))

    def make_step(self, model, sampler_cfg, manager, placement):
        if not placement.sharded:
            return shared_steps(model, sampler_cfg)["fused"]
        # Sharded PE duplication: shardings are part of the program, so
        # this engine compiles its own instance of the fused step.
        tok_sh, pos_sh = placement.token_shardings()
        return jax.jit(
            make_fused(model, make_sampler(sampler_cfg)),
            donate_argnums=(1,),
            in_shardings=(placement.replicated, manager.shardings,
                          tok_sh, pos_sh, pos_sh),
            out_shardings=(pos_sh, manager.shardings))

    def make_prefill_step(self, model, sampler_cfg, manager, placement):
        if placement.sharded or model.prefill_step is None:
            return None
        if model.carries_state:
            # Chunked prefill parks mid-prompt slots inside the BATCHED
            # decode tick by feeding them their next prompt token; for
            # KV families that write is rewritten by the next chunk, but
            # carried state would advance twice.  The contiguous layout
            # has no indirection to park through — the paged layout
            # aliases parked slots to the NULL state row instead.
            self.degrade_reason = (
                f"prefill_chunk requested but family "
                f"'{model.cfg.family}' carries recurrent state, which the "
                f"contiguous layout cannot park mid-prompt; degraded to "
                f"token-by-token prefill (the paged layout (level>=6) "
                f"chunks this family via NULL-row parking)")
            log.warning("%s", self.degrade_reason)
            return None
        return shared_steps(model, sampler_cfg)["prefill"]

    def make_verify_step(self, model, sampler_cfg, manager, placement):
        if model.verify_step is None:
            return None
        if not placement.sharded:
            return shared_steps(model, sampler_cfg)["verify"]
        # Sharded PE duplication: the verify window shards on the batch
        # axis exactly like the decode step's tokens — no reduction is
        # split, so greedy rows stay bit-identical to the replicated cell.
        sample = make_sampler(sampler_cfg)

        def _verify(params, cache, tokens, start):
            logits, new_cache = model.verify_step(params, cache, tokens,
                                                  start)
            return sample(logits, None), new_cache

        tok_sh, pos_sh = placement.token_shardings()
        return jax.jit(
            _verify, donate_argnums=(1,),
            in_shardings=(placement.replicated, manager.shardings,
                          tok_sh, pos_sh),
            out_shardings=(tok_sh, manager.shardings))


class PagedLayout(KVLayout):
    """Pooled KV-block scratchpad with per-request block tables (O6).

    Placement shards the POOL on its block axis (the pool's leading
    rows, padded up to a device multiple at construction) while block
    tables stay replicated; inside the step the gathered per-slot dense
    view is re-sharded onto the batch axis so the model body runs
    PE-duplicated exactly like the contiguous O3 path — layout and
    placement compose instead of excluding each other.

    ``paged_attn`` selects the step's attention implementation
    (``BestEffortConfig.paged_attn``): "gather" re-materializes the
    dense per-slot view every tick; "kernel" runs the block-table-aware
    Pallas decode kernel straight on the pool.  ``attn_impl`` records
    what :meth:`make_step` actually built — a model without a paged
    decode step degrades to gather, never fails, and ``degrade_reason``
    + a warning log say why (every zoo family ships one now, so this
    fires only for stripped/exotic ModelAPIs).  ``state_impl`` records
    "rows" when the family's recurrent/cross state leaves live in the
    row pool.

    ``kv_dtype`` selects the pool's STORED dtype
    (``BestEffortConfig.kv_dtype``): "bf16" stores compute-width blocks
    (bit-identical ladder contract); "int8"/"fp8" store narrow blocks
    with per-block absmax scales — the manager's cache becomes a
    ``{"pool", "scale"}`` bundle the steps split and re-join, and the
    rung's contract relaxes to the dtype's tolerance contract
    (``serving.kvquant.tolerance_contract``).
    """

    name = "paged"
    supports_step_fn = False

    def __init__(self, paged_attn: str = "gather",
                 kv_dtype: str = "bf16"):
        from repro.serving import kvquant
        if paged_attn not in ("gather", "kernel"):
            raise ValueError(
                f"paged_attn must be 'gather' or 'kernel' "
                f"(got {paged_attn!r})")
        kvquant.validate_kv_dtype(kv_dtype)
        self.paged_attn = paged_attn
        self.attn_impl = paged_attn      # updated by make_step
        self.state_impl = "none"         # "rows" when state leaves pool
        self.degrade_reason = None       # recorded fallback, or None
        self.kv_dtype = kv_dtype
        self.quantized = kvquant.is_quantized(kv_dtype)

    def build_manager(self, model, batch_size, max_seq,
                      config: BestEffortConfig, placement):
        return PagedCacheManager(
            model, batch_size, max_seq,
            block_size=config.kv_block_size,
            pool_blocks=config.kv_pool_blocks,
            placement=placement,
            kv_dtype=self.kv_dtype)

    def wire_scheduler(self, scheduler, manager) -> None:
        # The scheduler drives the block lifecycle: admission is gated
        # on free blocks (a request that fits max_seq but not the pool
        # queues), admit allocates the reservation, retire returns it
        # before the next admission wave.  The submit gate rejects the
        # one class of request no wave can ever admit — a reservation
        # larger than the TOTAL pool — at the submission boundary.
        scheduler.admission_gate = manager.can_admit
        scheduler.submit_gate = manager.infeasible_reason
        scheduler.on_admit = manager.admit_slot
        scheduler.on_retire = manager.release_slot

    def make_step(self, model, sampler_cfg, manager, placement):
        # Pool geometry (and any shardings) are part of the program, so
        # each paged engine compiles its own step.
        use_kernel = (self.paged_attn == "kernel"
                      and model.paged_decode_step is not None)
        self.attn_impl = "kernel" if use_kernel else "gather"
        self.state_impl = "rows" if manager.state is not None else "none"
        if self.paged_attn == "kernel" and not use_kernel:
            self.degrade_reason = (
                f"paged_attn='kernel' requested but family "
                f"'{model.cfg.family}' has no paged_decode_step; "
                f"degraded to the dense gather step")
            log.warning("%s", self.degrade_reason)
        sample = make_sampler(sampler_cfg)
        if use_kernel:
            fused = make_paged_kernel_fused(
                model, sample, manager,
                replicate=placement.constrain_replicated
                if placement.sharded else None)
        else:
            fused = make_paged_fused(
                model, sample, manager,
                constrain=placement.constrain_axis if placement.sharded
                else None)
        if not placement.sharded:
            return jax.jit(fused, donate_argnums=(1,))
        pool_sh = manager.pool_shardings(placement)
        tok_sh, pos_sh = placement.token_shardings()
        repl = placement.replicated
        n_extras = int(manager.has_blocks) + int(manager.state is not None)
        return jax.jit(
            fused, donate_argnums=(1,),
            in_shardings=(repl, pool_sh) + (repl,) * n_extras
            + (tok_sh, pos_sh, pos_sh),
            out_shardings=(pos_sh, pool_sh))

    def make_prefill_step(self, model, sampler_cfg, manager, placement):
        """The paged prefill chunk, matching ``attn_impl``:

        * gather — slice slot ``islot``'s block-table row and/or state
          row, gather its single-slot dense view, run the SAME dense
          ``prefill_step`` the contiguous rungs run, scatter the state
          row and every block of the view back (``scatter_view`` — a
          chunk spans several blocks).  This is how carried-state
          families chunk: the chunk advances the slot's REAL state row
          here, while the batched decode tick parks the slot on the
          NULL row (``step_extras(parked=...)``).
        * kernel — the model's ``paged_prefill_step`` writes chunk K/V
          straight into pool blocks and runs the multi-query
          block-table Pallas kernel; no dense view is built at all.

        A kernel-mode engine whose model lacks a paged prefill step
        degrades to gather (same best-effort rule as ``make_step``).
        """
        if placement.sharded or model.prefill_step is None:
            return None
        sample = make_sampler(sampler_cfg)
        plan, splan = manager.plan, manager.state_plan
        quantized = plan.quantized
        kv_dtype = plan.kv_dtype
        use_kernel = (self.attn_impl == "kernel"
                      and model.paged_prefill_step is not None)
        if use_kernel:
            def _prefill(params, cache, *rest):
                extras = rest[:-5]
                islot, tokens, start, last, seeds = rest[-5:]
                tables, _rows = _split_extras(manager, extras)
                pool, scales = _split_cache(cache, quantized)
                row = jax.lax.dynamic_slice_in_dim(tables, islot, 1,
                                                   axis=0)
                if quantized:
                    logits, new_pool, new_scales = model.paged_prefill_step(
                        params, pool, row, tokens, start, last,
                        scales=scales, kv_dtype=kv_dtype)
                else:
                    logits, new_pool = model.paged_prefill_step(
                        params, pool, row, tokens, start, last)
                    new_scales = None
                return (sample(logits, seeds)[0],
                        _join_cache(new_pool, new_scales, quantized))
        else:
            def _prefill(params, cache, *rest):
                extras = rest[:-5]
                islot, tokens, start, last, seeds = rest[-5:]
                tables, rows = _split_extras(manager, extras)
                pool, scales = _split_cache(cache, quantized)
                row_t = row_r = None
                dense = pool
                if tables is not None:
                    row_t = jax.lax.dynamic_slice_in_dim(tables, islot, 1,
                                                         axis=0)
                    dense = plan.gather(dense, row_t, scales)
                if rows is not None:
                    row_r = jax.lax.dynamic_slice_in_dim(rows, islot, 1,
                                                         axis=0)
                    dense = splan.gather(dense, row_r)
                logits, new_dense = model.prefill_step(
                    params, dense, tokens, start, last)
                new_pool = pool
                if rows is not None:
                    new_pool = splan.scatter(new_pool, row_r, new_dense)
                if tables is None:
                    return (sample(logits, seeds)[0],
                            _join_cache(new_pool, scales, quantized))
                if quantized:
                    new_pool, new_scales = plan.scatter_view(
                        new_pool, row_t, new_dense, scales=scales,
                        lengths=start + tokens.shape[1])
                    return (sample(logits, seeds)[0],
                            _join_cache(new_pool, new_scales, True))
                new_pool = plan.scatter_view(new_pool, row_t, new_dense)
                return sample(logits, seeds)[0], new_pool
        return jax.jit(_prefill, donate_argnums=(1,))

    def make_verify_step(self, model, sampler_cfg, manager, placement):
        """The paged speculative verify, matching ``attn_impl``:

        * gather — materialize every slot's dense view, run the SAME
          dense ``verify_step`` the contiguous rung runs, scatter the
          WHOLE view back (``scatter_view`` — a speculative window spans
          several blocks; writes past a slot's reservation land in NULL
          table entries and vanish into the write-garbage NULL row, so
          rejection rolls back by slot-length truncation alone and
          blocks never leak).
        * kernel — the model's ``paged_verify_step`` scatters the
          window's K/V straight into pool blocks and the multi-query
          block-table Pallas kernel attends the prefix; no dense view.

        A kernel-mode engine whose model lacks a paged verify step
        degrades to gather (same best-effort rule as ``make_step``)."""
        if model.verify_step is None:
            return None
        sample = make_sampler(sampler_cfg)
        plan = manager.plan
        quantized = plan.quantized
        kv_dtype = plan.kv_dtype
        use_kernel = (self.attn_impl == "kernel"
                      and model.paged_verify_step is not None)
        splan = manager.state_plan
        if use_kernel:
            def _verify(params, cache, *rest):
                extras, (tokens, start) = rest[:-2], rest[-2:]
                tables, _rows = _split_extras(manager, extras)
                pool, scales = _split_cache(cache, quantized)
                if placement.sharded:
                    pool = jax.tree.map(placement.constrain_replicated,
                                        pool)
                    if scales is not None:
                        scales = jax.tree.map(
                            placement.constrain_replicated, scales)
                if quantized:
                    logits, new_pool, new_scales = model.paged_verify_step(
                        params, pool, tables, tokens, start,
                        scales=scales, kv_dtype=kv_dtype)
                else:
                    logits, new_pool = model.paged_verify_step(
                        params, pool, tables, tokens, start)
                    new_scales = None
                return (sample(logits, None),
                        _join_cache(new_pool, new_scales, quantized))
        else:
            def _verify(params, cache, *rest):
                extras, (tokens, start) = rest[:-2], rest[-2:]
                tables, rows = _split_extras(manager, extras)
                pool, scales = _split_cache(cache, quantized)
                dense = pool
                if tables is not None:
                    dense = plan.gather(dense, tables, scales)
                if rows is not None:
                    dense = splan.gather(dense, rows)
                if placement.sharded:
                    dense = plan.map_batch_axes(dense,
                                                placement.constrain_axis)
                logits, new_dense = model.verify_step(params, dense,
                                                      tokens, start)
                new_pool = pool
                if rows is not None:
                    new_pool = splan.scatter(new_pool, rows, new_dense)
                if tables is None:
                    return (sample(logits, None),
                            _join_cache(new_pool, scales, quantized))
                if quantized:
                    new_pool, new_scales = plan.scatter_view(
                        new_pool, tables, new_dense, scales=scales,
                        lengths=start + tokens.shape[1])
                    return (sample(logits, None),
                            _join_cache(new_pool, new_scales, True))
                new_pool = plan.scatter_view(new_pool, tables, new_dense)
                return sample(logits, None), new_pool
        if not placement.sharded:
            return jax.jit(_verify, donate_argnums=(1,))
        pool_sh = manager.pool_shardings(placement)
        tok_sh, pos_sh = placement.token_shardings()
        repl = placement.replicated
        n_extras = int(manager.has_blocks) + int(manager.state is not None)
        return jax.jit(
            _verify, donate_argnums=(1,),
            in_shardings=(repl, pool_sh) + (repl,) * n_extras
            + (tok_sh, pos_sh),
            out_shardings=(tok_sh, pool_sh))


def select_layout(config: BestEffortConfig) -> KVLayout:
    """The layout axis of the config, as a strategy object."""
    if config.kv_layout == "paged":
        return PagedLayout(config.paged_attn, kv_dtype=config.kv_dtype)
    return ContiguousLayout()
