"""Admission + slot bookkeeping, split out of the decode engine.

The scheduler owns the request queue, the fixed pool of B slots, and the
per-slot position arithmetic.  Three admission policies:

  * ``fcfs`` — first come, first served (the classic continuous-batching
    default; fair, latency-predictable).
  * ``spf``  — shortest-prompt-first WITH AGING: admit the queued request
    with the fewest *effective* prompt tokens, where every admission wave
    a request sits queued shaves one token off its effective length
    (``effective_prompt_len``).  Short requests still jump long prefills
    (SJF applied to the prefill phase), but a long prompt's priority
    decays to the front in at most ``n_prompt`` waves — pure SPF starves
    it FOREVER under sustained open-loop arrivals of short requests.
  * ``deadline`` — earliest-deadline-first on ``Request.deadline_s``
    (absolute ``time.monotonic`` seconds); requests without a deadline
    sort last, ties broken by arrival order.  The SLO-aware policy for
    the open-loop traffic front end (``launch/server.py``).

Request validation happens at ``submit`` time, not mid-flight: an
oversized request raises ``ValueError`` immediately instead of asserting
deep inside the engine tick, and a degenerate ``max_new_tokens <= 0``
request is retired on the spot (empty completion) rather than ever
occupying a slot — the naive path admitted it and, depending on prompt
length vs ``max_seq``, could pin the slot forever.

Submit-time validation is deliberately *static* (the single-request
``max_seq`` capacity only): under the O6 paged cache a request that fits
``max_seq`` but not the currently-free KV blocks must QUEUE until
retirements free blocks, never raise — block availability is a property
of the moment, not of the request.  That dynamic check is the
``admission_gate`` hook, consulted per candidate at admit time; a gated
candidate stays queued and ends this tick's admission wave (no
head-of-line bypass, so fcfs arrival order survives).  The cache layer
tracks slot tenancy through ``on_admit(i, req)`` / ``on_retire(i, req)``,
fired exactly once per occupancy at every retirement site (serial
advance, planned tick_advance retirement, surprise eos in finalize).
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Optional

POLICIES = ("fcfs", "spf", "deadline")


@dataclasses.dataclass
class Request:
    prompt: list
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    rid: int = -1
    # SLO inputs (open-loop traffic): absolute completion deadline on the
    # ``time.monotonic`` clock, consumed by the "deadline" policy.
    deadline_s: Optional[float] = None
    # filled by the engine:
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    # True when the engine's tick budget expired with this request still
    # queued or mid-flight (``DecodeEngine.run``): the completion is
    # partial, NOT a normal finish.
    truncated: bool = False
    # Lifecycle timestamps (``time.monotonic`` seconds), threaded through
    # for TTFT / per-token latency measurement under open-loop traffic:
    arrival_s: Optional[float] = None       # stamped at submit()/place()
    first_token_s: Optional[float] = None   # first generated token lands
    finish_s: Optional[float] = None        # retirement
    # Admission wave at which the request joined the queue — the aging
    # clock for the spf policy (waves, not wall seconds: deterministic).
    queued_wave: int = 0

    @property
    def n_prompt(self):
        return len(self.prompt)

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token, when both stamps exist."""
        if self.arrival_s is None or self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def tpot_s(self) -> Optional[float]:
        """Mean per-token latency AFTER the first token (time-per-output-
        token) — None until finished or with fewer than two tokens."""
        if self.first_token_s is None or self.finish_s is None:
            return None
        if len(self.generated) < 2:
            return None
        return ((self.finish_s - self.first_token_s)
                / (len(self.generated) - 1))


@dataclasses.dataclass
class Slot:
    req: Optional[Request] = None
    pos: int = 0              # tokens consumed (prompt + generated)

    @property
    def active(self):
        return self.req is not None and not self.req.done

    def next_token(self) -> int:
        r = self.req
        if self.pos < r.n_prompt:
            return r.prompt[self.pos]
        return r.generated[-1]

    @property
    def prefilling(self) -> bool:
        # the step that consumes prompt token n_prompt-1 emits the first
        # generated token, so "prefilling" = pos < n_prompt - 1
        return self.pos < self.req.n_prompt - 1


class Scheduler:
    """Queue + slot pool.  The engine asks it who to admit, feeds it the
    sampled token per slot per tick, and it decides retirement."""

    def __init__(self, n_slots: int, max_seq: int, *, policy: str = "fcfs"):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; choices: {POLICIES}")
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.policy = policy
        self.slots = [Slot() for _ in range(n_slots)]
        self.queue: collections.deque = collections.deque()
        self.finished: list = []
        self._rid = itertools.count()
        # Admission-wave counter: bumped once per admit() call.  The spf
        # aging clock — a queued request's effective prompt length decays
        # by (wave - queued_wave), so nothing starves.
        self._wave = 0
        # Cache-layer hooks (wired by the engine for the paged path):
        self.admission_gate = None     # (req) -> bool: may admit now?
        self.on_admit = None           # (slot_index, req): slot occupied
        self.on_retire = None          # (slot_index, req): slot freed
        # Feasibility hook, consulted at SUBMIT time: (req) -> error
        # string, or None when some future pool state can admit the
        # request.  The paged layout wires it to the allocator's
        # whole-pool check — a reservation larger than the TOTAL pool
        # would pass the static max_seq validation yet be gated out every
        # wave, so run() would spin all max_ticks doing nothing.
        self.submit_gate = None

    # -- submission -----------------------------------------------------------
    def submit(self, req: Request) -> int:
        req.rid = next(self._rid)
        if req.arrival_s is None:
            req.arrival_s = time.monotonic()
        if req.n_prompt < 1:
            raise ValueError(f"req {req.rid}: empty prompt")
        if req.n_prompt + max(req.max_new_tokens, 0) > self.max_seq:
            raise ValueError(
                f"req {req.rid}: prompt ({req.n_prompt}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds engine max_seq "
                f"({self.max_seq})")
        if self.submit_gate is not None:
            reason = self.submit_gate(req)
            if reason:
                # Infeasible under ANY pool state (not just the current
                # one): admitting it is impossible, so queuing it would
                # gate out every future admission wave — reject loudly
                # at the submission boundary instead.
                raise ValueError(f"req {req.rid}: {reason}")
        if req.max_new_tokens <= 0:
            # Degenerate request: nothing to generate.  Retire immediately
            # with an empty completion instead of occupying a slot (the old
            # engine admitted it and could pin the slot forever when the
            # prompt ended at the max_seq boundary).
            req.done = True
            req.finish_s = time.monotonic()
            self.finished.append(req)
            return req.rid
        req.queued_wave = self._wave
        self.queue.append(req)
        return req.rid

    def effective_prompt_len(self, req: Request) -> int:
        """The spf admission key: prompt length minus the aging credit
        (one token per admission wave spent queued, floored at 0).  A
        long prompt's effective length reaches 0 after at most
        ``n_prompt`` waves, so sustained short-request arrivals can only
        delay it a bounded number of admissions — the starvation fix."""
        return max(0, req.n_prompt - (self._wave - req.queued_wave))

    def _next_index(self) -> int:
        """Queue index of the request the policy would admit next."""
        if self.policy == "spf":
            return min(range(len(self.queue)),
                       key=lambda i: (self.effective_prompt_len(
                           self.queue[i]), self.queue[i].rid))
        if self.policy == "deadline":
            inf = float("inf")
            return min(range(len(self.queue)),
                       key=lambda i: (
                           self.queue[i].deadline_s
                           if self.queue[i].deadline_s is not None else inf,
                           self.queue[i].rid))
        return 0

    def _pop(self, at: int) -> Request:
        self.queue.rotate(-at)
        req = self.queue.popleft()
        self.queue.rotate(at)
        return req

    # -- per-tick phases ------------------------------------------------------
    def admit(self) -> list:
        """Fill free slots from the queue; returns newly occupied indices.

        Each candidate is checked against the ``admission_gate`` before
        leaving the queue; a gated-out candidate (e.g. not enough free KV
        blocks for its reservation) stays queued and stops this wave —
        admitting someone behind it would reorder arrivals.
        """
        self._wave += 1
        admitted = []
        for i, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            at = self._next_index()
            if (self.admission_gate is not None
                    and not self.admission_gate(self.queue[at])):
                break
            req = self._pop(at)
            self.slots[i] = Slot(req=req, pos=0)
            if self.on_admit is not None:
                self.on_admit(i, req)
            admitted.append(i)
        return admitted

    @property
    def active_indices(self) -> list:
        return [i for i, s in enumerate(self.slots) if s.active]

    def has_work(self) -> bool:
        return bool(self.queue) or any(s.active for s in self.slots)

    def advance_chunk(self, i: int, n: int):
        """Consume ``n`` prompt tokens of slot ``i`` in one chunked-prefill
        dispatch — position bookkeeping only, no emission.  The chunk must
        stay strictly inside the prompt: the chunk that consumes prompt
        token ``n_prompt - 1`` emits the first generated token, so the
        engine sizes the final chunk one short and hands the closing token
        to ``advance`` (reusing all retirement logic).
        """
        s = self.slots[i]
        assert n >= 0 and s.pos + n < s.req.n_prompt, \
            f"chunk overruns prompt: pos={s.pos} n={n} " \
            f"n_prompt={s.req.n_prompt}"
        s.pos += n

    def place(self, req: Request, i: int):
        """Occupy free slot ``i`` with a request whose prompt was already
        prefilled OUTSIDE the engine (the prefill->insert->generate API):
        the slot starts at ``pos = n_prompt - 1`` — the position the
        legacy path reaches when it consumes the last prompt token — and
        the engine records the externally sampled first token via
        ``advance``.  Fires ``on_admit`` like a queue admission so cache
        tenancy hooks see exactly one occupy per occupancy."""
        if self.slots[i].active:
            raise ValueError(f"slot {i} is occupied")
        if req.rid < 0:
            req.rid = next(self._rid)
        if req.arrival_s is None:
            req.arrival_s = time.monotonic()
        self.slots[i] = Slot(req=req, pos=req.n_prompt - 1)
        if self.on_admit is not None:
            self.on_admit(i, req)

    def prefill_queue(self) -> list:
        """Active slots still consuming their prompt, in the order the
        admission policy would serve them: fcfs by arrival (rid), spf by
        fewest prompt tokens REMAINING (the chunked analog of
        shortest-prompt-first) with rid as the tiebreak."""
        pending = [i for i, s in enumerate(self.slots)
                   if s.active and s.pos < s.req.n_prompt]
        if self.policy == "spf":
            return sorted(pending, key=lambda i: (
                self.slots[i].req.n_prompt - self.slots[i].pos,
                self.slots[i].req.rid))
        if self.policy == "deadline":
            inf = float("inf")
            return sorted(pending, key=lambda i: (
                self.slots[i].req.deadline_s
                if self.slots[i].req.deadline_s is not None else inf,
                self.slots[i].req.rid))
        return sorted(pending, key=lambda i: self.slots[i].req.rid)

    def advance(self, i: int, token: int):
        """Post-step bookkeeping for slot ``i`` given its sampled ``token``.

        Returns the retired ``Request`` if the slot finished, else None.
        """
        s = self.slots[i]
        emitted = not s.prefilling
        s.pos += 1
        if not emitted:
            return None
        r = s.req
        r.generated.append(int(token))
        if r.first_token_s is None:
            r.first_token_s = time.monotonic()
        hit_eos = r.eos_id is not None and int(token) == r.eos_id
        if (len(r.generated) >= r.max_new_tokens or hit_eos
                or s.pos + 1 >= self.max_seq):
            r.done = True
            r.finish_s = time.monotonic()
            self.finished.append(r)
            self.slots[i] = Slot()
            if self.on_retire is not None:
                self.on_retire(i, r)
            return r
        return None

    def advance_multi(self, i: int, tokens) -> tuple:
        """Record a speculative window's accepted tokens for slot ``i``,
        one at a time through :meth:`advance` so every retirement rule
        (eos, max_new, the max_seq boundary) applies at the exact token
        it lands on — which may be MID-window.  Recording stops at the
        first retirement; later tokens in the window are discarded (the
        engine already rolled their cache writes back by frontier
        truncation, so nothing of them survives).  Returns
        ``(n_recorded, retired_request_or_None)``."""
        n = 0
        for t in tokens:
            retired = self.advance(i, t)
            n += 1
            if retired is not None:
                return n, retired
        return n, None

    # -- overlapped (double-buffered) tick protocol ---------------------------
    # The engine's O4+ path splits ``advance`` in two so the host can do
    # slot bookkeeping while the device computes: retirements decided by
    # token COUNT or the max_seq boundary are known the moment the step is
    # dispatched — only an eos hit needs the actual token.  ``tick_advance``
    # runs at dispatch time, frees the count-retired slots (so the
    # overlapped admission can refill them under the running step), and
    # ``finalize`` completes the bookkeeping when the tokens arrive.

    def tick_advance(self, active: list) -> list:
        """Advance positions for this tick; plan count/boundary retirements.

        Returns emissions ``[(slot_index, request, planned_retire)]`` — the
        slots whose sampled token must be recorded at ``finalize``.
        """
        out = []
        for i in active:
            s = self.slots[i]
            emitted = not s.prefilling
            s.pos += 1
            if not emitted:
                continue
            r = s.req
            # Emission count from position arithmetic, NOT len(generated):
            # with the pipelined engine, finalize (which appends to
            # generated) trails the dispatch frontier, so the list is
            # stale here.  After the increment, this tick's emission is
            # number ``pos - n_prompt + 1``.
            n_emitted = s.pos - r.n_prompt + 1
            planned = (n_emitted >= r.max_new_tokens
                       or s.pos + 1 >= self.max_seq)
            if planned:
                self.slots[i] = Slot()      # free under the running step
                if self.on_retire is not None:
                    # Blocks freed here may be reallocated by the very
                    # next admit(): the in-flight step still scatters the
                    # retiree's final token into them, but a new tenant
                    # only ever reads positions it has itself written
                    # (everything else is masked), so the stale write is
                    # unobservable.
                    self.on_retire(i, r)
            out.append((i, r, planned))
        return out

    def finalize(self, emissions: list, toks):
        """Record the device's tokens for ``tick_advance``'s emissions;
        complete planned retirements and surprise eos stops."""
        for i, r, planned in emissions:
            if r.done:
                # stale emission: the request hit eos in an earlier tick
                # but the pipelined engine had already dispatched this
                # one — its token is discarded, not recorded.
                continue
            tok = int(toks[i])
            r.generated.append(tok)
            if r.first_token_s is None:
                r.first_token_s = time.monotonic()
            hit_eos = r.eos_id is not None and tok == r.eos_id
            if planned or hit_eos:
                r.done = True
                r.finish_s = time.monotonic()
                self.finished.append(r)
                if not planned and self.slots[i].req is r:
                    self.slots[i] = Slot()
                    if self.on_retire is not None:
                        self.on_retire(i, r)
