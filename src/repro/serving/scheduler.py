"""Admission + slot bookkeeping, split out of the decode engine.

The scheduler owns the request queue, the fixed pool of B slots, and the
per-slot position arithmetic.  Two admission policies:

  * ``fcfs`` — first come, first served (the classic continuous-batching
    default; fair, latency-predictable).
  * ``spf``  — shortest-prompt-first: admit the queued request with the
    fewest prompt tokens, so short requests are not convoyed behind long
    prefills (SJF applied to the prefill phase; throughput-friendly under
    mixed lengths).

Request validation happens at ``submit`` time, not mid-flight: an
oversized request raises ``ValueError`` immediately instead of asserting
deep inside the engine tick, and a degenerate ``max_new_tokens <= 0``
request is retired on the spot (empty completion) rather than ever
occupying a slot — the naive path admitted it and, depending on prompt
length vs ``max_seq``, could pin the slot forever.

Submit-time validation is deliberately *static* (the single-request
``max_seq`` capacity only): under the O6 paged cache a request that fits
``max_seq`` but not the currently-free KV blocks must QUEUE until
retirements free blocks, never raise — block availability is a property
of the moment, not of the request.  That dynamic check is the
``admission_gate`` hook, consulted per candidate at admit time; a gated
candidate stays queued and ends this tick's admission wave (no
head-of-line bypass, so fcfs arrival order survives).  The cache layer
tracks slot tenancy through ``on_admit(i, req)`` / ``on_retire(i, req)``,
fired exactly once per occupancy at every retirement site (serial
advance, planned tick_advance retirement, surprise eos in finalize).
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Optional

POLICIES = ("fcfs", "spf")


@dataclasses.dataclass
class Request:
    prompt: list
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    rid: int = -1
    # filled by the engine:
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def n_prompt(self):
        return len(self.prompt)


@dataclasses.dataclass
class Slot:
    req: Optional[Request] = None
    pos: int = 0              # tokens consumed (prompt + generated)

    @property
    def active(self):
        return self.req is not None and not self.req.done

    def next_token(self) -> int:
        r = self.req
        if self.pos < r.n_prompt:
            return r.prompt[self.pos]
        return r.generated[-1]

    @property
    def prefilling(self) -> bool:
        # the step that consumes prompt token n_prompt-1 emits the first
        # generated token, so "prefilling" = pos < n_prompt - 1
        return self.pos < self.req.n_prompt - 1


class Scheduler:
    """Queue + slot pool.  The engine asks it who to admit, feeds it the
    sampled token per slot per tick, and it decides retirement."""

    def __init__(self, n_slots: int, max_seq: int, *, policy: str = "fcfs"):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; choices: {POLICIES}")
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.policy = policy
        self.slots = [Slot() for _ in range(n_slots)]
        self.queue: collections.deque = collections.deque()
        self.finished: list = []
        self._rid = itertools.count()
        # Cache-layer hooks (wired by the engine for the paged path):
        self.admission_gate = None     # (req) -> bool: may admit now?
        self.on_admit = None           # (slot_index, req): slot occupied
        self.on_retire = None          # (slot_index, req): slot freed

    # -- submission -----------------------------------------------------------
    def submit(self, req: Request) -> int:
        req.rid = next(self._rid)
        if req.n_prompt < 1:
            raise ValueError(f"req {req.rid}: empty prompt")
        if req.n_prompt + max(req.max_new_tokens, 0) > self.max_seq:
            raise ValueError(
                f"req {req.rid}: prompt ({req.n_prompt}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds engine max_seq "
                f"({self.max_seq})")
        if req.max_new_tokens <= 0:
            # Degenerate request: nothing to generate.  Retire immediately
            # with an empty completion instead of occupying a slot (the old
            # engine admitted it and could pin the slot forever when the
            # prompt ended at the max_seq boundary).
            req.done = True
            self.finished.append(req)
            return req.rid
        self.queue.append(req)
        return req.rid

    def _next_index(self) -> int:
        """Queue index of the request the policy would admit next."""
        if self.policy == "spf":
            return min(range(len(self.queue)),
                       key=lambda i: self.queue[i].n_prompt)
        return 0

    def _pop(self, at: int) -> Request:
        self.queue.rotate(-at)
        req = self.queue.popleft()
        self.queue.rotate(at)
        return req

    # -- per-tick phases ------------------------------------------------------
    def admit(self) -> list:
        """Fill free slots from the queue; returns newly occupied indices.

        Each candidate is checked against the ``admission_gate`` before
        leaving the queue; a gated-out candidate (e.g. not enough free KV
        blocks for its reservation) stays queued and stops this wave —
        admitting someone behind it would reorder arrivals.
        """
        admitted = []
        for i, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            at = self._next_index()
            if (self.admission_gate is not None
                    and not self.admission_gate(self.queue[at])):
                break
            req = self._pop(at)
            self.slots[i] = Slot(req=req, pos=0)
            if self.on_admit is not None:
                self.on_admit(i, req)
            admitted.append(i)
        return admitted

    @property
    def active_indices(self) -> list:
        return [i for i, s in enumerate(self.slots) if s.active]

    def has_work(self) -> bool:
        return bool(self.queue) or any(s.active for s in self.slots)

    def advance_chunk(self, i: int, n: int):
        """Consume ``n`` prompt tokens of slot ``i`` in one chunked-prefill
        dispatch — position bookkeeping only, no emission.  The chunk must
        stay strictly inside the prompt: the chunk that consumes prompt
        token ``n_prompt - 1`` emits the first generated token, so the
        engine sizes the final chunk one short and hands the closing token
        to ``advance`` (reusing all retirement logic).
        """
        s = self.slots[i]
        assert n >= 0 and s.pos + n < s.req.n_prompt, \
            f"chunk overruns prompt: pos={s.pos} n={n} " \
            f"n_prompt={s.req.n_prompt}"
        s.pos += n

    def place(self, req: Request, i: int):
        """Occupy free slot ``i`` with a request whose prompt was already
        prefilled OUTSIDE the engine (the prefill->insert->generate API):
        the slot starts at ``pos = n_prompt - 1`` — the position the
        legacy path reaches when it consumes the last prompt token — and
        the engine records the externally sampled first token via
        ``advance``.  Fires ``on_admit`` like a queue admission so cache
        tenancy hooks see exactly one occupy per occupancy."""
        if self.slots[i].active:
            raise ValueError(f"slot {i} is occupied")
        if req.rid < 0:
            req.rid = next(self._rid)
        self.slots[i] = Slot(req=req, pos=req.n_prompt - 1)
        if self.on_admit is not None:
            self.on_admit(i, req)

    def prefill_queue(self) -> list:
        """Active slots still consuming their prompt, in the order the
        admission policy would serve them: fcfs by arrival (rid), spf by
        fewest prompt tokens REMAINING (the chunked analog of
        shortest-prompt-first) with rid as the tiebreak."""
        pending = [i for i, s in enumerate(self.slots)
                   if s.active and s.pos < s.req.n_prompt]
        if self.policy == "spf":
            return sorted(pending, key=lambda i: (
                self.slots[i].req.n_prompt - self.slots[i].pos,
                self.slots[i].req.rid))
        return sorted(pending, key=lambda i: self.slots[i].req.rid)

    def advance(self, i: int, token: int):
        """Post-step bookkeeping for slot ``i`` given its sampled ``token``.

        Returns the retired ``Request`` if the slot finished, else None.
        """
        s = self.slots[i]
        emitted = not s.prefilling
        s.pos += 1
        if not emitted:
            return None
        r = s.req
        r.generated.append(int(token))
        hit_eos = r.eos_id is not None and int(token) == r.eos_id
        if (len(r.generated) >= r.max_new_tokens or hit_eos
                or s.pos + 1 >= self.max_seq):
            r.done = True
            self.finished.append(r)
            self.slots[i] = Slot()
            if self.on_retire is not None:
                self.on_retire(i, r)
            return r
        return None

    def advance_multi(self, i: int, tokens) -> tuple:
        """Record a speculative window's accepted tokens for slot ``i``,
        one at a time through :meth:`advance` so every retirement rule
        (eos, max_new, the max_seq boundary) applies at the exact token
        it lands on — which may be MID-window.  Recording stops at the
        first retirement; later tokens in the window are discarded (the
        engine already rolled their cache writes back by frontier
        truncation, so nothing of them survives).  Returns
        ``(n_recorded, retired_request_or_None)``."""
        n = 0
        for t in tokens:
            retired = self.advance(i, t)
            n += 1
            if retired is not None:
                return n, retired
        return n, None

    # -- overlapped (double-buffered) tick protocol ---------------------------
    # The engine's O4+ path splits ``advance`` in two so the host can do
    # slot bookkeeping while the device computes: retirements decided by
    # token COUNT or the max_seq boundary are known the moment the step is
    # dispatched — only an eos hit needs the actual token.  ``tick_advance``
    # runs at dispatch time, frees the count-retired slots (so the
    # overlapped admission can refill them under the running step), and
    # ``finalize`` completes the bookkeeping when the tokens arrive.

    def tick_advance(self, active: list) -> list:
        """Advance positions for this tick; plan count/boundary retirements.

        Returns emissions ``[(slot_index, request, planned_retire)]`` — the
        slots whose sampled token must be recorded at ``finalize``.
        """
        out = []
        for i in active:
            s = self.slots[i]
            emitted = not s.prefilling
            s.pos += 1
            if not emitted:
                continue
            r = s.req
            # Emission count from position arithmetic, NOT len(generated):
            # with the pipelined engine, finalize (which appends to
            # generated) trails the dispatch frontier, so the list is
            # stale here.  After the increment, this tick's emission is
            # number ``pos - n_prompt + 1``.
            n_emitted = s.pos - r.n_prompt + 1
            planned = (n_emitted >= r.max_new_tokens
                       or s.pos + 1 >= self.max_seq)
            if planned:
                self.slots[i] = Slot()      # free under the running step
                if self.on_retire is not None:
                    # Blocks freed here may be reallocated by the very
                    # next admit(): the in-flight step still scatters the
                    # retiree's final token into them, but a new tenant
                    # only ever reads positions it has itself written
                    # (everything else is masked), so the stale write is
                    # unobservable.
                    self.on_retire(i, r)
            out.append((i, r, planned))
        return out

    def finalize(self, emissions: list, toks):
        """Record the device's tokens for ``tick_advance``'s emissions;
        complete planned retirements and surprise eos stops."""
        for i, r, planned in emissions:
            if r.done:
                # stale emission: the request hit eos in an earlier tick
                # but the pipelined engine had already dispatched this
                # one — its token is discarded, not recorded.
                continue
            tok = int(toks[i])
            r.generated.append(tok)
            hit_eos = r.eos_id is not None and tok == r.eos_id
            if planned or hit_eos:
                r.done = True
                self.finished.append(r)
                if not planned and self.slots[i].req is r:
                    self.slots[i] = Slot()
                    if self.on_retire is not None:
                        self.on_retire(i, r)
