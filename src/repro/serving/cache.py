"""Per-slot decode-cache management at every rung of the ladder.

The engine's cache tree (KV caches for transformers, recurrent states for
RWKV/SSM, both for hybrids) has one batch axis per leaf, located via the
model's ``cache_axes()`` logical names — no layout guessing.  Admitting a
request into slot ``i`` must reset that slot's slice; how that reset is
done is exactly the paper's memory-system ladder:

  O0 (no data caching)   — per-request cache REBUILD: allocate a fresh
      cache tree and copy every surviving slot's slice across, one
      host-driven dispatch per (leaf x live slot).  This is the "every
      access goes back to DRAM" analog: nothing persistent is reused in
      place.
  O1+ (data caching)     — the cache is a persistent device-resident
      scratchpad; admission zeroes just the new slot's slice in place.
  O5 (scratchpad reorg)  — packed slot resets: all slots admitted in one
      tick are zeroed by a single jitted, donated call (one wide write per
      leaf instead of one narrow write per slot per leaf — the wide-word
      packing analog).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.optlevel import OptLevel, Step


def make_packed_zero(batch_axes: list, skip: list = None):
    """The O5 packed reset as a reusable jitted closure: one donated call
    zeroes slot slices ``idx`` of every leaf (``skip[i]`` leaves pass
    through untouched — the paged manager uses it to zero only the
    recurrent-state leaves while block-table leaves stay mask-protected).
    """
    skip = skip or [False] * len(batch_axes)

    def zero(cache, idx):
        leaves, treedef = jax.tree.flatten(cache)
        out = []
        for leaf, bax, skp in zip(leaves, batch_axes, skip):
            if skp:
                out.append(leaf)
                continue
            sel = (slice(None),) * bax + (idx,)
            out.append(leaf.at[sel].set(0))
        return jax.tree.unflatten(treedef, out)

    return jax.jit(zero, donate_argnums=(0,))


class CacheManager:
    def __init__(self, model, batch_size: int, max_seq: int,
                 level: OptLevel = OptLevel.O5, shardings=None):
        self.model = model
        self.B = batch_size
        self.max_seq = max_seq
        self.level = level
        self.cache = model.init_cache(batch_size, max_seq)
        self.batch_axes = self._find_batch_axes()
        self.shardings = shardings
        if shardings is not None:
            self.cache = jax.device_put(self.cache, shardings)
        self._packed_zero = None

    @property
    def capacity_tokens(self) -> int:
        """Persistent decode-cache capacity in token positions: the
        contiguous cache reserves the full horizon for every slot (the
        reservation the paged manager's block pool replaces)."""
        return self.B * self.max_seq

    def step_extras(self, parked=None) -> tuple:
        """Per-tick step inputs beyond (params, cache, tokens, positions,
        seeds).  The contiguous step needs none; the paged manager
        returns its block tables (and state rows, for families with
        recurrent/cross state) here — the hook that keeps the engine's
        dispatch path layout-blind.  ``parked`` (slot indices mid-prefill
        this tick) is a paged-manager concern — contiguous KV writes are
        rewrite-safe, so it is ignored here."""
        del parked
        return ()

    def insert_slot(self, i: int, state):
        """Install an externally prefilled batch-1 cache tree into slot
        ``i`` (the INSERT phase of prefill->insert->generate): each leaf
        of ``state`` matches the engine cache leaf with its batch axis
        collapsed to 1, and is copied over that slot's slice.  The
        contiguous copy is exact — the paged manager overrides this to
        scatter the sequence axis through slot ``i``'s block table."""
        leaves, treedef = jax.tree.flatten(self.cache)
        st_leaves = jax.tree.leaves(state)
        assert len(leaves) == len(st_leaves), "prefill state tree drift"
        out = []
        for leaf, st, bax in zip(leaves, st_leaves, self.batch_axes):
            sel = (slice(None),) * bax + (i,)
            out.append(leaf.at[sel].set(
                jnp.take(st, 0, axis=bax).astype(leaf.dtype)))
        self.cache = jax.tree.unflatten(treedef, out)

    def _find_batch_axes(self) -> list:
        axes_tree = self.model.cache_axes()
        leaves_axes = jax.tree.leaves(
            axes_tree, is_leaf=lambda x: isinstance(x, tuple))
        leaves_cache = jax.tree.leaves(self.cache)
        assert len(leaves_axes) == len(leaves_cache), "cache axes drift"
        return [ax.index("batch") for ax in leaves_axes]

    # -- reset strategies ----------------------------------------------------
    def reset_slots(self, indices: list, live: list):
        """Reset the cache slices of ``indices`` (newly admitted slots).

        ``live`` are the slot indices whose state must survive — only the
        O0 rebuild path needs them.
        """
        if not indices:
            return
        if not self.level.has(Step.DATA_CACHING):
            self._rebuild(set(indices), live)
        elif self.level.has(Step.SCRATCHPAD_REORG):
            self._zero_packed(indices)
        else:
            for i in indices:
                self._zero_slot(i)

    def _rebuild(self, dropped: set, live: list):
        """O0: no in-place scratchpad — build a fresh cache and copy every
        surviving slot's slice over, slot by slot, leaf by leaf."""
        fresh = self.model.init_cache(self.B, self.max_seq)
        if self.shardings is not None:
            fresh = jax.device_put(fresh, self.shardings)
        old_leaves, treedef = jax.tree.flatten(self.cache)
        new_leaves = jax.tree.leaves(fresh)
        out = []
        keep = [i for i in live if i not in dropped]
        for old, new, bax in zip(old_leaves, new_leaves, self.batch_axes):
            for i in keep:
                idx = [slice(None)] * new.ndim
                idx[bax] = i
                new = new.at[tuple(idx)].set(old[tuple(idx)])
            out.append(new)
        self.cache = jax.tree.unflatten(treedef, out)

    def _zero_slot(self, i: int):
        """O1..O4: zero one slot's slice in the persistent cache."""
        leaves, treedef = jax.tree.flatten(self.cache)
        out = []
        for leaf, bax in zip(leaves, self.batch_axes):
            idx = [slice(None)] * leaf.ndim
            idx[bax] = i
            out.append(leaf.at[tuple(idx)].set(0))
        self.cache = jax.tree.unflatten(treedef, out)

    def _zero_packed(self, indices: list):
        """O5: one fused, donated call zeroes every admitted slot at once."""
        if self._packed_zero is None:
            self._packed_zero = make_packed_zero(self.batch_axes)
        self.cache = self._packed_zero(
            self.cache, jnp.asarray(indices, jnp.int32))
