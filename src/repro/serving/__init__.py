"""Serving subsystem: the decode engine refactored onto the ladder.

One class per paper step — ``scheduler`` (admission + slots),
``cache`` (data caching / scratchpad reorg), ``sampler`` (pipelined
sample-in-graph), ``overlap`` (host/device double buffering) — assembled
by ``engine.DecodeEngine`` at any ``OptLevel`` and tuned end-to-end by
``python -m repro.autotune --serve``.

Cache layout and device placement are orthogonal strategy layers:
``layout.KVLayout`` (``ContiguousLayout`` / ``PagedLayout``) owns how
the decode cache is stored, ``parallel.sharding.PlacementPlan`` owns
where it lives, and every (layout, placement) combination compiles a
decode step — including the block-axis-sharded paged pool (O3 x O6).
"""

from repro.serving.cache import CacheManager            # noqa: F401
from repro.serving.engine import (                       # noqa: F401
    DecodeEngine, PrefillResult, TickBudgetExceeded)
from repro.serving.layout import (                       # noqa: F401
    ContiguousLayout, KVLayout, PagedLayout, select_layout)
from repro.serving.overlap import HostOverlap, TickBuffers  # noqa: F401
from repro.serving.paged import (                        # noqa: F401
    BlockAllocator, BlockPagingPlan, PagedAllocator, PagedCacheManager)
from repro.serving.sampler import SamplerConfig, make_sampler  # noqa: F401
from repro.serving.scheduler import Request, Scheduler, Slot  # noqa: F401
