"""Serving subsystem: the decode engine refactored onto the ladder.

One class per paper step — ``scheduler`` (admission + slots),
``cache`` (data caching / scratchpad reorg), ``sampler`` (pipelined
sample-in-graph), ``overlap`` (host/device double buffering) — assembled
by ``engine.DecodeEngine`` at any ``OptLevel`` and tuned end-to-end by
``python -m repro.autotune --serve``.
"""

from repro.serving.cache import CacheManager            # noqa: F401
from repro.serving.engine import DecodeEngine            # noqa: F401
from repro.serving.overlap import HostOverlap, TickBuffers  # noqa: F401
from repro.serving.paged import (                        # noqa: F401
    BlockAllocator, PagedAllocator, PagedCacheManager)
from repro.serving.sampler import SamplerConfig, make_sampler  # noqa: F401
from repro.serving.scheduler import Request, Scheduler, Slot  # noqa: F401
