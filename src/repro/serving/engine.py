"""Slot-based continuous-batching decode engine, built at an OptLevel.

The serving counterpart of the paper's five-step ladder, with every step a
real, independently toggleable stage keyed by ``BestEffortConfig.level``:

  O1 data caching      — persistent device-resident cache with in-place
                         per-slot resets (``cache.CacheManager``); O0 falls
                         back to a per-request cache rebuild.
  O2 pipelining        — continuous batching: every active slot decodes in
                         ONE fused jitted step with sampling in-graph
                         (``sampler``), amortizing the pass over the
                         weights; O0/O1 run the un-pipelined loop — one
                         batch-1 model call per request per tick, host-side
                         sampling over that request's full-vocab logits.
  O3 PE duplication    — sharding across devices when
                         ``config.effective_pe > 1``
                         (``parallel.sharding.PlacementPlan`` on a 1-D
                         data mesh): the contiguous cache on its batch
                         axis, the paged pool on its BLOCK axis.
  O4 double buffering  — host prestages next tick's token/position buffers
                         while the device runs this tick (``overlap``).
  O5 scratchpad reorg  — packed slot admission: all slots admitted in a
                         tick are zeroed by one fused donated call.
  O6 paged scratchpad  — the decode cache becomes a pool of fixed-size
                         KV blocks with per-request block tables
                         (``paged.PagedCacheManager``); the jitted step
                         gathers each slot's dense view from the pool and
                         scatters back the one block it wrote.  Admission
                         is gated on free blocks (queue, never reject).

Cache LAYOUT (contiguous vs paged, ``serving.layout.KVLayout``) and
device PLACEMENT (replicated vs PE-sharded,
``parallel.sharding.PlacementPlan``) are two orthogonal strategy objects
selected here once — the engine itself never branches on them again, so
O3 x O6 compose (a paged engine with ``effective_pe > 1`` on >= 2
devices runs a block-axis-sharded step) instead of excluding each other.

Prefill is a first-class phase with two implementations:

  * LEGACY prestaged (``config.prefill_chunk == 0``): every step feeds
    one token per active slot — a slot still consuming its prompt feeds
    the next prompt token (its logits are discarded), a generating slot
    feeds its last sampled token.  One jitted step serves all families
    (KV-cache transformers, RWKV/SSM state models, enc-dec) and all
    request phases; TTFT is O(prompt_len) ticks.
  * CHUNKED (``config.prefill_chunk > 0``): prompts are consumed in
    fixed-size multi-token chunks — one batch-1 chunk dispatch per tick
    for the head of the scheduler's prefill queue, interleaved with the
    batched decode step over the generating slots (prefilling slots are
    parked in that step: fed their real next prompt token so the row
    stays harmless, but advanced only by chunks).  TTFT drops to
    O(ceil(prompt_len / chunk)) ticks.  Families without a model prefill
    step (MoE, recurrent-state), sharded placements, caller step_fns and
    the un-pipelined O0/O1 loop degrade to the legacy path
    (``prefill_mode == "token"``); greedy tokens are bit-identical
    either way — the same oracle the O0..O6 ladder pins.

  O7 speculative decode — a small drafter model proposes ``draft_k``
                         tokens per generating slot per tick; the target
                         verifies the whole window in ONE batched
                         multi-token forward (the layout's verify step —
                         PR 6's qlen>1 machinery) and greedy rejection
                         accepts exactly the target's argmax prefix, so
                         output stays bit-identical to O5/O6 while up to
                         ``1 + acceptance * K`` tokens land per tick.
                         Rollback is free on both layouts: rejected
                         writes sit beyond the slot's frontier
                         (contiguous — rewritten before unmasked read;
                         paged — confined to the slot's own reservation
                         or the NULL block, so truncating the logical
                         length rolls back without touching the block
                         tables and blocks never leak).  No drafter
                         configured, ``draft_k == 0``, a stochastic
                         sampler, or a family without verify hooks all
                         degrade to the plain decode path — recorded in
                         ``engine.spec_mode`` ("draft" / "off"), never a
                         failure.  The speculative tick replaces the O4
                         double-buffered schedule (acceptance must be
                         known before the next window can be drafted);
                         the drafter's own dispatches pipeline against
                         the verify step instead.

The phases are also exposed directly (the JetStream-style serving API):
``prefill(prompt)`` consumes a prompt on a standalone batch-1 cache and
samples the first token, ``insert(result)`` installs that KV state into
a free slot (scattering it through a freshly reserved block table under
the paged layout), and ``generate()`` drains the decode loop.

Admission, slot bookkeeping and retirement live in ``scheduler``; the
engine is only the tick loop that wires scheduler, cache manager, sampler
and overlap together under one config.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.optlevel import BestEffortConfig, OptLevel, Step
from repro.parallel.sharding import plan_pe_placement
from repro.serving.layout import select_layout, shared_steps
from repro.serving.overlap import HostOverlap
from repro.serving.sampler import SamplerConfig
from repro.serving.scheduler import Request, Scheduler


class TickBudgetExceeded(RuntimeError):
    """``DecodeEngine.run`` exhausted ``max_ticks`` with work still
    queued or in flight.  Every surviving request has been marked
    ``truncated`` (its partial ``generated`` list is intact); the
    engine's slots and queue are untouched, so a caller that expected a
    long drain can catch this and keep ticking.  The silent alternative
    — returning only ``finished`` — let a permanently-gated queue spin
    the whole budget and then LOOK like a clean drain."""

    def __init__(self, msg: str, survivors: list):
        super().__init__(msg)
        self.survivors = survivors


@dataclasses.dataclass
class PrefillResult:
    """Output of the standalone PREFILL phase — everything INSERT needs:
    the request (rid already assigned, so stochastic sampling seeds are
    stable), the first sampled token, and the batch-1 dense cache tree
    holding the prompt's K/V (or recurrent state)."""
    request: Request
    first_token: int
    kv_state: object
    length: int          # prompt tokens consumed


class DecodeEngine:
    def __init__(self, model, params, *, batch_size: int, max_seq: int,
                 pad_id: int = 0, config: Optional[BestEffortConfig] = None,
                 sampler: Optional[SamplerConfig] = None,
                 policy: str = "fcfs", step_fn=None,
                 draft_model=None, draft_params=None):
        self.model = model
        self.B = batch_size
        self.max_seq = max_seq
        self.pad_id = pad_id
        self.config = config or BestEffortConfig(level=OptLevel.O5)
        self.level = self.config.level
        self.sampler_cfg = sampler or SamplerConfig()
        self.scheduler = Scheduler(batch_size, max_seq, policy=policy)
        self.n_steps = 0

        # The two orthogonal serving axes, as strategy objects: cache
        # layout (contiguous O0..O5 / paged O6) and device placement
        # (replicated / PE-sharded).  Every combination compiles a step.
        self.layout = select_layout(self.config)
        if step_fn is not None and not self.layout.supports_step_fn:
            # A caller-supplied fused step has no block-table argument;
            # silently falling back to the contiguous cache would let an
            # operator believe they are measuring the paged rung.
            raise ValueError(
                "step_fn is incompatible with the paged O6 cache (the "
                "jitted step must thread block tables); build the engine "
                "at O5 or drop step_fn")
        self.placement = plan_pe_placement(self.config, batch_size)
        self.params = self.placement.put_replicated(params)
        self.cache_mgr = self.layout.build_manager(
            model, batch_size, max_seq, self.config, self.placement)
        self.layout.wire_scheduler(self.scheduler, self.cache_mgr)

        self._fused = self.level.has(Step.PIPELINING) or step_fn is not None
        if step_fn is not None:
            # Back-compat hook: a caller-supplied fused step
            # (params, cache, tokens, positions) -> (tokens, cache).
            self._step_fn = lambda p, c, t, pos, seeds: step_fn(p, c, t, pos)
        elif self._fused:
            self._step_fn = self.layout.make_step(
                model, self.sampler_cfg, self.cache_mgr, self.placement)
        else:
            # O0/O1: the un-pipelined serving loop — each active request
            # runs its OWN batch-1 model call per tick (every request pays
            # a full pass over the weights; no continuous batching), and
            # sampling happens OUTSIDE the graph: greedy argmax runs on
            # the host over the request's transferred logits; stochastic
            # kinds run as a separate device dispatch (host RNG would
            # diverge from the fused path's bits).
            shared = shared_steps(model, self.sampler_cfg)
            self._single_fn = shared["single"]
            self._sample_fn = shared["sample"]
            self._host_greedy = not self.sampler_cfg.stochastic

        # O4: host/device overlap via rotating prestaged buffers plus the
        # split-tick protocol (dispatch -> bookkeeping under the running
        # step -> finalize next tick).
        self._overlap = (HostOverlap(batch_size, pad_id,
                                     self.config.effective_buffers)
                         if self.level.has(Step.DOUBLE_BUFFERING) else None)
        self._pending = None        # (toks_future, emissions) of last tick

        # Chunked prefill: a single-slot multi-token chunk step, or None
        # when this (model, layout, placement) cell cannot chunk — the
        # tick loop then runs the legacy prestaged prompt path.
        self._prefill_chunk = int(self.config.prefill_chunk)
        self._prefill_fn = None
        if (self._prefill_chunk > 0 and self._fused and step_fn is None
                and not self.placement.sharded):
            self._prefill_fn = self.layout.make_prefill_step(
                model, self.sampler_cfg, self.cache_mgr, self.placement)
        self.prefill_mode = ("chunked" if self._prefill_fn is not None
                             else "token")
        # Best-effort degrades are RECORDED, never silent: the layout
        # stamps a reason when a requested capability fell back (kernel
        # attention without a paged step, chunked prefill on a family
        # the cell cannot chunk) — surfaced in serve/autotune meta.
        self.degrade_reason = getattr(self.layout, "degrade_reason", None)

        # O7: speculative decoding.  Active only when every piece is in
        # place — the rung enabled, a drafter configured (by name in the
        # config or passed in directly), draft_k > 0, a deterministic
        # (greedy) sampler, the fused engine path, and a layout verify
        # step for this (layout x placement x model) cell.  Anything
        # missing degrades to the plain decode path above, recorded in
        # ``spec_mode`` — never a failure.  A vocab-incompatible
        # (drafter, target) pair, however, raises loudly
        # (``model_zoo.compatible_drafter``): that is an operator error,
        # not a best-effort gap.
        self._spec = False
        self.spec_mode = "off"
        self._draft_k = max(int(self.config.draft_k), 0)
        self._verify_fn = None
        self.spec_drafted = self.spec_accepted = 0
        self.spec_emitted = self.spec_ticks = self.spec_windows = 0
        # Window baseline for spec_stats_window: counter values at the
        # last snapshot reset (long-running servers need per-interval
        # acceptance, not lifetime averages that drift stale).
        self._spec_window_base = (0, 0, 0, 0, 0)
        self._dstate = [(-1, 0)] * batch_size   # per-slot (rid, drafter pos)
        spec_wanted = (self.level.has(Step.SPECULATIVE)
                       and (draft_model is not None
                            or bool(self.config.draft_model))
                       and self._draft_k > 0)
        if (spec_wanted and self._fused and step_fn is None
                and not self.sampler_cfg.stochastic):
            self._verify_fn = self.layout.make_verify_step(
                model, self.sampler_cfg, self.cache_mgr, self.placement)
            if self._verify_fn is not None:
                self._wire_drafter(draft_model, draft_params)
                self._spec = True
                self.spec_mode = "draft"

    def _wire_drafter(self, api, params):
        """Build (or adopt) the drafter: a small zoo model with its own
        batch-B contiguous cache, running the shared greedy fused step.
        The pairing is validated by ``model_zoo.compatible_drafter`` —
        the drafter proposes token IDS the target scores, so the two
        must share one vocab."""
        from repro.models import model_zoo
        if api is None:
            dcfg = model_zoo.compatible_drafter(self.model.cfg,
                                                self.config.draft_model)
            api = model_zoo.get_model(dcfg)
        else:
            model_zoo.compatible_drafter(self.model.cfg, api.cfg)
        if params is None:
            params = api.init(jax.random.PRNGKey(0))
        self._draft_api = api
        self._draft_params = self.placement.put_replicated(params)
        self._draft_cache = api.init_cache(self.B, self.max_seq)
        dsteps = shared_steps(api, SamplerConfig())     # greedy drafts
        self._draft_fused = dsteps["fused"]
        self._draft_prefill_fn = (dsteps["prefill"]
                                  if api.prefill_step is not None else None)
        self._draft_seeds = jnp.zeros((self.B,), jnp.int32)

    # -- public API -----------------------------------------------------------
    @property
    def cache(self):
        return self.cache_mgr.cache

    @property
    def spec_stats(self) -> dict:
        """Speculation counters: drafts proposed/accepted over the
        engine's lifetime, tokens emitted through verify windows, and
        the two ladder columns — ``accept_rate`` (accepted / proposed)
        and ``eff_tok_per_step`` (tokens emitted per slot per verify
        window, in [1, K+1] and equal to ``1 + accept_rate * draft_k``
        absent mid-window retirements)."""
        drafted = self.spec_drafted
        windows = self.spec_windows
        return {
            "spec_mode": self.spec_mode,
            "draft_k": self._draft_k if self._spec else 0,
            "drafted": drafted,
            "accepted": self.spec_accepted,
            "accept_rate": (self.spec_accepted / drafted) if drafted else 0.0,
            "emitted": self.spec_emitted,
            "eff_tok_per_step": (self.spec_emitted / windows) if windows
            else 0.0,
        }

    def spec_stats_window(self, *, reset: bool = True) -> dict:
        """Speculation counters over the window SINCE the last reset —
        the per-measurement-interval view a long-running server needs
        (the lifetime ``spec_stats`` averages drift stale as traffic
        shifts).  Same shape as ``spec_stats``; ``reset=True`` (the
        default) starts the next window at the current counters, so
        back-to-back calls bracket disjoint intervals.  The lifetime
        counters themselves are never rewound."""
        base = self._spec_window_base
        cur = (self.spec_drafted, self.spec_accepted, self.spec_emitted,
               self.spec_ticks, self.spec_windows)
        drafted, accepted, emitted, _ticks, windows = (
            c - b for c, b in zip(cur, base))
        if reset:
            self._spec_window_base = cur
        return {
            "spec_mode": self.spec_mode,
            "draft_k": self._draft_k if self._spec else 0,
            "drafted": drafted,
            "accepted": accepted,
            "accept_rate": (accepted / drafted) if drafted else 0.0,
            "emitted": emitted,
            "eff_tok_per_step": (emitted / windows) if windows else 0.0,
        }

    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def finished(self):
        return self.scheduler.finished

    @property
    def slots(self):
        return self.scheduler.slots

    def submit(self, req: Request) -> int:
        return self.scheduler.submit(req)

    # -- prefill -> insert -> generate ---------------------------------------
    def prefill(self, prompt, *, max_new_tokens: int = 16,
                eos_id: Optional[int] = None,
                chunk: Optional[int] = None) -> PrefillResult:
        """PREFILL phase: consume ``prompt`` on a standalone batch-1
        contiguous cache — in multi-token chunks when the model has a
        prefill step, else one token per step — and sample the first
        generated token.  No engine slot is touched: :meth:`insert`
        installs the returned KV state into a free slot (scattering it
        through a block table under the paged layout) and
        :meth:`generate` decodes from there.  Greedy tokens are
        bit-identical to submitting the same request through the
        engine's internal admission path."""
        req = Request(prompt=list(prompt), max_new_tokens=max_new_tokens,
                      eos_id=eos_id)
        req.rid = next(self.scheduler._rid)
        if req.n_prompt < 1:
            raise ValueError(f"req {req.rid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"req {req.rid}: prefill needs max_new_tokens >= 1")
        if req.n_prompt + req.max_new_tokens > self.max_seq:
            raise ValueError(
                f"req {req.rid}: prompt ({req.n_prompt}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds engine max_seq "
                f"({self.max_seq})")
        cfg = self.sampler_cfg
        shared = shared_steps(self.model, cfg)
        cache = self.model.init_cache(1, self.max_seq)
        P = req.n_prompt
        seed = cfg.request_seed(req.rid, 0) if cfg.stochastic else 0
        if self.model.prefill_step is not None:
            C = int(chunk or self._prefill_chunk or min(P, 64))
            fn = shared["prefill"]
            pos = 0
            tok_dev = None
            while pos < P:
                n = min(C, P - pos)
                toks = np.full((1, C), self.pad_id, np.int32)
                toks[0, :n] = req.prompt[pos:pos + n]
                tok_dev, cache = fn(
                    self.params, cache, jnp.int32(0), jnp.asarray(toks),
                    jnp.asarray([pos], jnp.int32),
                    jnp.asarray([n - 1], jnp.int32),
                    jnp.asarray([seed], jnp.int32))
                pos += n
            first = int(np.asarray(tok_dev))
        else:
            # Per-token fallback: works for every family (recurrent
            # state included) — the same batch-1 step the O0 loop runs.
            single, sample = shared["single"], shared["sample"]
            logits = None
            for p in range(P):
                logits, cache = single(
                    self.params, cache, jnp.int32(req.prompt[p]),
                    jnp.int32(p), jnp.int32(0))
            if cfg.stochastic:
                first = int(sample(jnp.asarray(logits)[None],
                                   jnp.asarray([seed], jnp.int32))[0])
            else:
                first = int(np.asarray(logits).argmax())
        return PrefillResult(request=req, first_token=first,
                             kv_state=cache, length=P)

    def insert(self, result: PrefillResult,
               slot: Optional[int] = None) -> int:
        """INSERT phase: occupy a free slot with a prefilled request.
        Copies the batch-1 KV state over the slot's cache slice
        (contiguous) or scatters it through the slot's freshly reserved
        block table (paged), places the scheduler slot at the
        post-prompt position and records the first token — after which
        the request decodes like any other.  Raises when no slot is
        free or (paged) the pool cannot hold the request's reservation
        right now; callers queue and retry after retirements."""
        sched = self.scheduler
        req = result.request
        if slot is None:
            free = [i for i, s in enumerate(sched.slots) if not s.active]
            if not free:
                raise ValueError("no free slot to insert into")
            slot = free[0]
        if sched.submit_gate is not None:
            reason = sched.submit_gate(req)
            if reason:
                # Never-fits: distinct from the transient gate below —
                # no retirement will ever make room for this one.
                raise ValueError(f"req {req.rid}: {reason}")
        if (sched.admission_gate is not None
                and not sched.admission_gate(req)):
            raise ValueError(
                "insufficient free KV blocks to insert (retire requests "
                "or enlarge the pool)")
        sched.place(req, slot)          # fires on_admit (block reserve)
        self.cache_mgr.insert_slot(slot, result.kv_state)
        sched.advance(slot, result.first_token)
        return slot

    def generate(self, *, max_ticks: int = 10_000) -> list:
        """GENERATE phase: drain inserted and queued requests — an alias
        of :meth:`run`, named for the prefill->insert->generate
        protocol."""
        return self.run(max_ticks=max_ticks)

    def step(self) -> bool:
        """One engine tick: admit, run the batched decode step, retire."""
        if self._spec:
            return self._step_spec()
        if self._overlap is not None:
            return self._step_overlapped()
        return self._step_serial()

    def _dispatch(self, tokens_np, positions_np, seeds_np, parked=None):
        """Run the batched fused device step; returns the (possibly still
        in-flight) sampled tokens and installs the new cache.  The
        manager's ``step_extras()`` supplies any layout-specific step
        inputs — the paged manager's cached device block tables and
        state rows (invalidated at admission/retirement; the shapes
        never change, so there is no retrace) — keeping this path
        layout-blind.  ``parked`` names slots mid-chunked-prefill this
        tick: managers with carried state alias them to the NULL state
        row so the batched pad-feed cannot advance their real state
        (their prompt advances only through ``_prefill_tick``)."""
        toks_dev, new_cache = self._step_fn(
            self.params, self.cache_mgr.cache,
            *self.cache_mgr.step_extras(parked=parked),
            jnp.asarray(tokens_np), jnp.asarray(positions_np),
            jnp.asarray(seeds_np))
        self.cache_mgr.cache = new_cache
        self.n_steps += 1
        return toks_dev

    def _prefill_tick(self, i: int):
        """Dispatch one prefill CHUNK for slot ``i`` and do its
        bookkeeping: up to ``prefill_chunk`` prompt tokens in one
        batch-1 multi-token step (padded to the fixed chunk width so a
        single trace serves every chunk).  The chunk that consumes the
        LAST prompt token also emits the request's first generated token
        — sampled in-graph from the chunk's closing logits and handed to
        ``advance`` so all retirement logic is reused; earlier chunks
        only move the position (``advance_chunk``)."""
        sched = self.scheduler
        s = sched.slots[i]
        r = s.req
        C = self._prefill_chunk
        start = s.pos
        n = min(C, r.n_prompt - start)
        toks = np.full((1, C), self.pad_id, np.int32)
        toks[0, :n] = r.prompt[start:start + n]
        final = start + n == r.n_prompt
        cfg = self.sampler_cfg
        seed = cfg.request_seed(r.rid, 0) if cfg.stochastic and final else 0
        tok_dev, new_cache = self._prefill_fn(
            self.params, self.cache_mgr.cache,
            *self.cache_mgr.step_extras(),
            jnp.int32(i), jnp.asarray(toks),
            jnp.asarray([start], jnp.int32),
            jnp.asarray([n - 1], jnp.int32),
            jnp.asarray([seed], jnp.int32))
        self.cache_mgr.cache = new_cache
        if final:
            sched.advance_chunk(i, n - 1)
            sched.advance(i, int(np.asarray(tok_dev)))
        else:
            sched.advance_chunk(i, n)

    # -- speculative decoding (O7) -------------------------------------------
    def _token_at(self, i: int, q: int) -> int:
        """Token ``q`` of slot ``i``'s sequence (prompt, then generated) —
        what the drafter replays while catching up to the target."""
        r = self.scheduler.slots[i].req
        return r.prompt[q] if q < r.n_prompt else r.generated[q - r.n_prompt]

    def _draft_dispatch(self, tokens_np, positions_np):
        """One batched drafter decode tick on the drafter's own cache."""
        toks, self._draft_cache = self._draft_fused(
            self._draft_params, self._draft_cache,
            jnp.asarray(tokens_np), jnp.asarray(positions_np),
            self._draft_seeds)
        return np.asarray(toks).reshape(self.B, -1)[:, -1]

    def _draft_catchup_chunks(self, i: int, tgt: int):
        """Replay a LONG stretch of slot ``i``'s known tokens into the
        drafter cache via the drafter's chunked prefill step (a fresh
        tenant's whole prompt) — fixed-width chunks so one trace serves
        every catch-up."""
        C = 16
        rid, dpos = self._dstate[i]
        while dpos < tgt:
            n = min(C, tgt - dpos)
            toks = np.full((1, C), self.pad_id, np.int32)
            toks[0, :n] = [self._token_at(i, q) for q in range(dpos,
                                                               dpos + n)]
            _, self._draft_cache = self._draft_prefill_fn(
                self._draft_params, self._draft_cache, jnp.int32(i),
                jnp.asarray(toks), jnp.asarray([dpos], jnp.int32),
                jnp.asarray([n - 1], jnp.int32),
                jnp.asarray([0], jnp.int32))
            dpos += n
        self._dstate[i] = (rid, dpos)

    def _draft_tokens(self, emit: list) -> dict:
        """Catch the drafter up to each emitting slot's frontier, then
        run K batched greedy drafter ticks from the pending token —
        returns ``{slot: [d_1 .. d_K]}``.

        Catch-up replays KNOWN tokens only (prompt + accepted output),
        so the drafter cache never depends on rejected drafts: after a
        partial acceptance the drafter position is truncated to the
        accepted frontier and the stale draft K/V beyond it is rewritten
        here before the drafter ever attends it unmasked — the same
        standing-garbage discipline the target caches use.  Slots not
        being drafted this dispatch are parked: pad token written at
        ``max_seq - 1``, a position every real consumer rewrites in the
        same dispatch that first reads it."""
        slots = self.scheduler.slots
        K = self._draft_k
        for i in emit:
            rid = slots[i].req.rid
            if self._dstate[i][0] != rid:
                self._dstate[i] = (rid, 0)      # fresh tenant: replay all
            if (self._draft_prefill_fn is not None
                    and slots[i].pos - self._dstate[i][1] > 2 * (K + 1)):
                self._draft_catchup_chunks(i, slots[i].pos)
        while True:
            behind = [i for i in emit if self._dstate[i][1] < slots[i].pos]
            if not behind:
                break
            tokens = np.full((self.B, 1), self.pad_id, np.int32)
            positions = np.full((self.B,), self.max_seq - 1, np.int32)
            for i in behind:
                dpos = self._dstate[i][1]
                tokens[i, 0] = self._token_at(i, dpos)
                positions[i] = dpos
            self._draft_dispatch(tokens, positions)
            for i in behind:
                rid, dpos = self._dstate[i]
                self._dstate[i] = (rid, dpos + 1)
        drafts = {i: [] for i in emit}
        cur = {i: slots[i].next_token() for i in emit}
        for j in range(K):
            tokens = np.full((self.B, 1), self.pad_id, np.int32)
            positions = np.full((self.B,), self.max_seq - 1, np.int32)
            for i in emit:
                tokens[i, 0] = cur[i]
                positions[i] = slots[i].pos + j
            out = self._draft_dispatch(tokens, positions)
            for i in emit:
                cur[i] = int(out[i])
                drafts[i].append(cur[i])
        for i in emit:
            # Drafter K/V now covers positions .. pos+K-1; acceptance
            # bookkeeping truncates this back if drafts are rejected.
            self._dstate[i] = (self._dstate[i][0], slots[i].pos + K)
        return drafts

    def _step_spec(self) -> bool:
        """One speculative tick: draft K per generating slot, verify the
        whole batch's windows in ONE multi-token target forward, accept
        each slot's longest draft==argmax prefix plus the target's
        bonus/correction token, and roll rejected tails back by frontier
        truncation.  Prompt-consuming slots ride the SAME verify forward
        as fixed-width prefill chunks; slots within K of the ``max_seq``
        boundary (where window positions would clip onto each other)
        take a plain decode dispatch instead — at most their last few
        ticks."""
        sched = self.scheduler
        slots = sched.slots
        admitted = sched.admit()
        active = sched.active_indices
        self.cache_mgr.reset_slots(admitted, active)
        if not active:
            return False
        K = self._draft_k
        W = K + 1
        emit, boundary, prefill = [], [], []
        for i in active:
            s = slots[i]
            if s.pos < s.req.n_prompt - 1:
                prefill.append(i)
            elif s.pos + K < self.max_seq:
                emit.append(i)
            else:
                boundary.append(i)

        drafts = self._draft_tokens(emit) if emit else {}

        greedy = None
        if emit or prefill:
            tokens = np.full((self.B, W), self.pad_id, np.int32)
            start = np.full((self.B,), self.max_seq - 1, np.int32)
            pf_real = {}
            for i in emit:
                s = slots[i]
                start[i] = s.pos
                tokens[i, 0] = s.next_token()
                tokens[i, 1:] = drafts[i]
            for i in prefill:
                s = slots[i]
                r = s.req
                start[i] = s.pos
                n = min(W, r.n_prompt - s.pos)
                tokens[i, :n] = r.prompt[s.pos:s.pos + n]
                pf_real[i] = n
            toks_dev, new_cache = self._verify_fn(
                self.params, self.cache_mgr.cache,
                *self.cache_mgr.step_extras(),
                jnp.asarray(tokens), jnp.asarray(start))
            self.cache_mgr.cache = new_cache
            self.n_steps += 1
            greedy = np.asarray(toks_dev).reshape(self.B, W)

        btoks = None
        if boundary:
            tokens_np = np.full((self.B, 1), self.pad_id, np.int32)
            positions_np = np.full((self.B,), self.max_seq - 1, np.int32)
            for i in boundary:
                s = slots[i]
                tokens_np[i, 0] = s.next_token()
                positions_np[i] = s.pos
            toks_b = self._dispatch(tokens_np, positions_np,
                                    np.zeros((self.B,), np.int32))
            btoks = np.asarray(toks_b).reshape(self.B, -1)[:, -1]

        # -- bookkeeping (host) ----------------------------------------------
        if emit:
            self.spec_ticks += 1
        for i in emit:
            g = greedy[i]
            d = drafts[i]
            a = 0
            while a < K and d[a] == g[a]:
                a += 1          # draft j+1 must equal the target's row j
            p = slots[i].pos
            rid = slots[i].req.rid
            window = [int(x) for x in g[:a + 1]]
            n_rec, _ = sched.advance_multi(i, window)
            self.spec_drafted += K
            self.spec_accepted += a
            self.spec_emitted += n_rec
            self.spec_windows += 1
            # Truncate the drafter to what actually survived: positions
            # beyond pos + n_rec hold rejected-draft K/V, replayed from
            # the accepted tokens before the next draft attends them.
            self._dstate[i] = (rid, min(p + K, p + n_rec))
        for i in prefill:
            s = slots[i]
            n = pf_real[i]
            if s.pos + n == s.req.n_prompt:     # window closes the prompt
                sched.advance_chunk(i, n - 1)
                sched.advance(i, int(greedy[i][n - 1]))
            else:
                sched.advance_chunk(i, n)
        for i in boundary:
            sched.advance(i, int(btoks[i]))
        return True

    def _step_serial(self) -> bool:
        """O0..O3: admit -> fill -> dispatch -> wait -> retire, in order.

        Below O2 (no pipelining) each active request additionally runs its
        own batch-1 model call, one after another — the naive per-request
        loop a batched tick replaces.
        """
        sched = self.scheduler
        admitted = sched.admit()
        active = sched.active_indices
        self.cache_mgr.reset_slots(admitted, active)
        if not active:
            return False

        cfg = self.sampler_cfg
        slots = sched.slots
        if not self._fused:
            # O0/O1: one model call per request, host-side sampling.
            toks = np.zeros((self.B,), np.int32)
            for i in active:
                s = slots[i]
                logits, self.cache_mgr.cache = self._single_fn(
                    self.params, self.cache_mgr.cache,
                    jnp.int32(s.next_token()), jnp.int32(s.pos),
                    jnp.int32(i))
                if self._host_greedy:
                    toks[i] = int(np.asarray(logits).argmax())
                else:
                    seed = cfg.request_seed(s.req.rid, len(s.req.generated))
                    toks[i] = int(self._sample_fn(
                        jnp.asarray(logits)[None],
                        jnp.asarray([seed], jnp.int32))[0])
            self.n_steps += 1
            for i in active:
                sched.advance(i, toks[i])
            return True

        # Chunked prefill: one prompt chunk (head of the prefill queue)
        # dispatches before the batched step; slots still consuming
        # their prompt are PARKED in that step — fed their real next
        # prompt token (so the row's write is the value a later chunk
        # rewrites; carried-state families additionally alias parked
        # slots to the NULL state row) but advanced only by chunks.
        parked = None
        if self._prefill_fn is not None:
            pf = sched.prefill_queue()
            if pf:
                self._prefill_tick(pf[0])
                active = sched.active_indices   # chunk may have retired
            if not active:
                return True
            gen = [i for i in active
                   if slots[i].pos >= slots[i].req.n_prompt]
            if not gen:
                return True                     # prefill-only tick
            parked = [i for i in active if i not in set(gen)]
        else:
            gen = active

        # O2/O3: one batched fused step for every active slot.
        tokens_np = np.asarray(
            [[s.next_token() if s.active else self.pad_id]
             for s in slots], np.int32)
        positions_np = np.asarray(
            [s.pos if s.active else 0 for s in slots], np.int32)
        seeds_np = (np.asarray(
            [cfg.request_seed(s.req.rid, len(s.req.generated))
             if s.active else 0 for s in slots], np.int32)
            if cfg.stochastic else np.zeros((self.B,), np.int32))

        toks_dev = self._dispatch(tokens_np, positions_np, seeds_np,
                                  parked=parked)
        toks = np.asarray(toks_dev).reshape(self.B, -1)[:, -1]
        for i in gen:
            sched.advance(i, toks[i])
        return True

    def _step_overlapped(self) -> bool:
        """O4+: double-buffered schedule.  Each call finalizes the
        previous tick (its tokens have been computing since last call),
        dispatches this tick from mostly-prestaged buffers, then does all
        token-independent bookkeeping — position advance, count-based
        retirement planning, admission, cache-slot resets, next tick's
        prompt prestaging — while the device runs."""
        sched = self.scheduler
        cfg = self.sampler_cfg
        if self._pending is not None:
            toks_dev, emissions = self._pending
            self._pending = None
            toks = np.asarray(toks_dev).reshape(self.B, -1)[:, -1]
            sched.finalize(emissions, toks)
        active = sched.active_indices
        if not active:
            # cold start / wake-up: nothing was admitted under a running
            # step, so admit + reset inline.
            admitted = sched.admit()
            if not admitted:
                return False
            active = sched.active_indices
            self.cache_mgr.reset_slots(admitted, active)

        # fill: only slots not prestaged during the previous tick
        buf = self._overlap.rotate()
        skip = self._overlap.prestaged
        for i in active:
            if i in skip:
                continue
            s = sched.slots[i]
            buf.tokens[i, 0] = s.next_token()
            buf.positions[i] = s.pos
            if cfg.stochastic:
                buf.seeds[i] = cfg.request_seed(
                    s.req.rid, len(s.req.generated))

        # Chunked prefill rides the overlap seam: prefilling slots are
        # parked — excluded from tick_advance (their positions move
        # through the chunk's own bookkeeping) and flagged to the cache
        # manager so carried-state families alias them to the NULL
        # state row for this decode step.
        if self._prefill_fn is not None:
            gen = [i for i in active
                   if sched.slots[i].pos >= sched.slots[i].req.n_prompt]
            parked = [i for i in active if i not in set(gen)]
        else:
            gen = active
            parked = None

        toks_dev = self._dispatch(buf.tokens, buf.positions, buf.seeds,
                                  parked=parked)

        # -- bookkeeping for the next tick, under the running step -----------
        # The chunk dispatch is queued behind the decode step (so the
        # device never idles).
        if self._prefill_fn is not None:
            pf = sched.prefill_queue()
            if pf:
                self._prefill_tick(pf[0])
        emissions = sched.tick_advance(gen)
        self._pending = (toks_dev, emissions)
        admitted = sched.admit()                 # refills planned-free slots
        if admitted:
            self.cache_mgr.reset_slots(admitted, sched.active_indices)
        self._overlap.prestage(sched, cfg)
        return True

    def run(self, *, max_ticks: int = 10_000) -> list:
        """Drain queue + slots; returns finished requests.

        Raises :class:`TickBudgetExceeded` when ``max_ticks`` expires
        with requests still queued or mid-flight — each survivor is
        marked ``truncated`` first, so the caller can distinguish
        partial completions from real finishes.  (The old behavior
        returned ``finished`` silently, leaving in-flight slots active
        and queued requests unreported.)
        """
        for _ in range(max_ticks):
            if not self.step() and not self.queue:
                break
        else:
            sched = self.scheduler
            if sched.has_work():
                survivors = [s.req for s in sched.slots if s.active]
                survivors += list(sched.queue)
                for r in survivors:
                    r.truncated = True
                raise TickBudgetExceeded(
                    f"run(max_ticks={max_ticks}) exhausted its tick "
                    f"budget with {len(survivors)} request(s) unfinished "
                    f"({sum(1 for s in sched.slots if s.active)} in "
                    f"flight, {len(sched.queue)} queued); survivors "
                    f"marked truncated", survivors)
        return self.finished
