"""Slot-based continuous-batching decode engine, built at an OptLevel.

The serving counterpart of the paper's five-step ladder, with every step a
real, independently toggleable stage keyed by ``BestEffortConfig.level``:

  O1 data caching      — persistent device-resident cache with in-place
                         per-slot resets (``cache.CacheManager``); O0 falls
                         back to a per-request cache rebuild.
  O2 pipelining        — continuous batching: every active slot decodes in
                         ONE fused jitted step with sampling in-graph
                         (``sampler``), amortizing the pass over the
                         weights; O0/O1 run the un-pipelined loop — one
                         batch-1 model call per request per tick, host-side
                         sampling over that request's full-vocab logits.
  O3 PE duplication    — batch-axis sharding of cache + step across
                         devices when ``config.effective_pe > 1``
                         (``parallel.sharding`` on a 1-D data mesh).
  O4 double buffering  — host prestages next tick's token/position buffers
                         while the device runs this tick (``overlap``).
  O5 scratchpad reorg  — packed slot admission: all slots admitted in a
                         tick are zeroed by one fused donated call.
  O6 paged scratchpad  — the decode cache becomes a pool of fixed-size
                         KV blocks with per-request block tables
                         (``paged.PagedCacheManager``); the jitted step
                         gathers each slot's dense view from the pool and
                         scatters back the one block it wrote.  Admission
                         is gated on free blocks (queue, never reject).

Unified prefill/decode: every step feeds one token per active slot — a
slot still consuming its prompt feeds the next prompt token (its logits
are discarded), a generating slot feeds its last sampled token.  This
keeps one jitted step for all families (KV-cache transformers, RWKV/SSM
state models, enc-dec) and is exactly how slot-based TPU serving engines
handle heterogeneous request phases.

Admission, slot bookkeeping and retirement live in ``scheduler``; the
engine is only the tick loop that wires scheduler, cache manager, sampler
and overlap together under one config.
"""

from __future__ import annotations

import collections
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.optlevel import BestEffortConfig, OptLevel, Step
from repro.serving.cache import CacheManager
from repro.serving.overlap import HostOverlap
from repro.serving.paged import PagedCacheManager
from repro.serving.sampler import SamplerConfig, make_sampler
from repro.serving.scheduler import Request, Scheduler


def _last_logits(logits):
    """(B, V) or (B, 1, V) -> (B, V): the newest position's logits."""
    if logits.ndim == 3:
        return logits[:, -1, :]
    return logits


def _make_fused(model, sample):
    """The batched fused decode+sample step (O2+); one definition shared
    by the jit-cached path and the sharded-jit path so they can never
    drift apart."""
    def _fused(params, cache, tokens, positions, seeds):
        logits, new_cache = model.decode_step(
            params, cache, tokens, positions)
        return sample(_last_logits(logits), seeds), new_cache

    return _fused


def _make_paged_fused(model, sample, layout):
    """The O6 step: block-table gather -> the SAME decode_step the dense
    rungs run -> single-block scatter.  The dense view the model sees is
    bit-identical at every unmasked position (see ``paged`` docstring),
    so greedy tokens cannot drift from the contiguous path."""
    def _fused(params, pool, tables, tokens, positions, seeds):
        dense = layout.gather(pool, tables)
        logits, new_dense = model.decode_step(
            params, dense, tokens, positions)
        toks = sample(_last_logits(logits), seeds)
        return toks, layout.scatter(pool, tables, new_dense, positions)

    return _fused


# Jitted step functions are shared across engines of the same
# (model, sampler, fusion mode): every level from O2 up runs the *same*
# compiled decode program, so measured differences between ladder rungs
# come from the host-side mechanics each rung actually changes, not from
# per-engine jit-instance luck.  (Sharded O3+ engines build their own
# step: shardings are part of the program.)  LRU-bounded: each entry pins
# its model (the id() key must stay valid) and three compiled
# executables, so an unbounded cache would leak in any process that
# keeps constructing models.
_STEP_CACHE = collections.OrderedDict()
_STEP_CACHE_MAX = 8


def _shared_steps(model, sampler_cfg):
    key = (id(model), sampler_cfg)
    if key in _STEP_CACHE:
        _STEP_CACHE.move_to_end(key)
    else:
        sample = make_sampler(sampler_cfg)
        axes_tree = model.cache_axes()
        leaves_axes = jax.tree.leaves(
            axes_tree, is_leaf=lambda x: isinstance(x, tuple))
        batch_axes = [ax.index("batch") for ax in leaves_axes]

        def _single(params, cache, token, position, islot):
            """One request's decode step: slice slot ``islot``'s cache
            rows, run a batch-1 model step, write the rows back.  The
            un-pipelined serving loop — each request pays its own model
            call (and its own pass over the weights)."""
            leaves, treedef = jax.tree.flatten(cache)
            row = jax.tree.unflatten(treedef, [
                jax.lax.dynamic_slice_in_dim(leaf, islot, 1, axis=bax)
                for leaf, bax in zip(leaves, batch_axes)])
            logits, new_row = model.decode_step(
                params, row, token[None, None], position[None])
            row_leaves = jax.tree.leaves(new_row)
            new_cache = jax.tree.unflatten(treedef, [
                jax.lax.dynamic_update_slice_in_dim(leaf, new, islot,
                                                    axis=bax)
                for leaf, new, bax in zip(leaves, row_leaves, batch_axes)])
            return _last_logits(logits)[0], new_cache

        _STEP_CACHE[key] = {
            "model": model,   # keep the model alive while its id is a key
            "fused": jax.jit(_make_fused(model, sample),
                             donate_argnums=(1,)),
            "single": jax.jit(_single, donate_argnums=(1,)),
            "sample": jax.jit(sample),
        }
        if len(_STEP_CACHE) > _STEP_CACHE_MAX:
            _STEP_CACHE.popitem(last=False)
    return _STEP_CACHE[key]


class DecodeEngine:
    def __init__(self, model, params, *, batch_size: int, max_seq: int,
                 pad_id: int = 0, config: Optional[BestEffortConfig] = None,
                 sampler: Optional[SamplerConfig] = None,
                 policy: str = "fcfs", step_fn=None):
        self.model = model
        self.B = batch_size
        self.max_seq = max_seq
        self.pad_id = pad_id
        self.config = config or BestEffortConfig(level=OptLevel.O5)
        self.level = self.config.level
        self.sampler_cfg = sampler or SamplerConfig()
        self.scheduler = Scheduler(batch_size, max_seq, policy=policy)
        self.n_steps = 0

        # O6: paged KV blocks.  The pool's leading axis is blocks, not
        # slots, so the O3 batch-axis sharding plan does not apply
        # (block-axis sharding of the pool is future work) — paged
        # engines always build the unsharded paged step.
        self._paged = self.level.has(Step.PAGED_SCRATCHPAD)
        if self._paged and step_fn is not None:
            # A caller-supplied fused step has no block-table argument;
            # silently falling back to the contiguous cache would let an
            # operator believe they are measuring the paged rung.
            raise ValueError(
                "step_fn is incompatible with the paged O6 cache (the "
                "jitted step must thread block tables); build the engine "
                "at O5 or drop step_fn")

        # O3: PE duplication = batch-axis sharding across devices.
        self._shardings = None if self._paged else self._plan_pe_sharding()
        cache_sh = tok_sh = pos_sh = None
        if self._shardings is not None:
            cache_sh, tok_sh, pos_sh = self._shardings
            params = jax.device_put(params, self._repl)
        self.params = params
        if self._paged:
            self.cache_mgr = PagedCacheManager(
                model, batch_size, max_seq,
                block_size=self.config.kv_block_size,
                pool_blocks=self.config.kv_pool_blocks)
            # The scheduler drives the block lifecycle: admission is
            # gated on free blocks (a request that fits max_seq but not
            # the pool queues), admit allocates the reservation, retire
            # returns it before the next admission wave.
            self.scheduler.admission_gate = self.cache_mgr.can_admit
            self.scheduler.on_admit = self.cache_mgr.admit_slot
            self.scheduler.on_retire = self.cache_mgr.release_slot
        else:
            self.cache_mgr = CacheManager(model, batch_size, max_seq,
                                          self.level, shardings=cache_sh)

        self._fused = self.level.has(Step.PIPELINING) or step_fn is not None
        if step_fn is not None:
            # Back-compat hook: a caller-supplied fused step
            # (params, cache, tokens, positions) -> (tokens, cache).
            self._step_fn = lambda p, c, t, pos, seeds: step_fn(p, c, t, pos)
        elif self._paged:
            # Pool geometry is part of the program, so each paged engine
            # compiles its own step (like the sharded path).
            self._step_fn = jax.jit(
                _make_paged_fused(model, make_sampler(self.sampler_cfg),
                                  self.cache_mgr.layout),
                donate_argnums=(1,))
        elif self._shardings is not None:
            # Sharded PE duplication: shardings are part of the program,
            # so this engine compiles its own instance of the fused step.
            self._step_fn = jax.jit(
                _make_fused(model, make_sampler(self.sampler_cfg)),
                donate_argnums=(1,),
                in_shardings=(self._repl, cache_sh, tok_sh, pos_sh, pos_sh),
                out_shardings=(pos_sh, cache_sh))
        elif self._fused:
            self._step_fn = _shared_steps(model, self.sampler_cfg)["fused"]
        else:
            # O0/O1: the un-pipelined serving loop — each active request
            # runs its OWN batch-1 model call per tick (every request pays
            # a full pass over the weights; no continuous batching), and
            # sampling happens OUTSIDE the graph: greedy argmax runs on
            # the host over the request's transferred logits; stochastic
            # kinds run as a separate device dispatch (host RNG would
            # diverge from the fused path's bits).
            shared = _shared_steps(model, self.sampler_cfg)
            self._single_fn = shared["single"]
            self._sample_fn = shared["sample"]
            self._host_greedy = not self.sampler_cfg.stochastic

        # O4: host/device overlap via rotating prestaged buffers plus the
        # split-tick protocol (dispatch -> bookkeeping under the running
        # step -> finalize next tick).
        self._overlap = (HostOverlap(batch_size, pad_id,
                                     self.config.effective_buffers)
                         if self.level.has(Step.DOUBLE_BUFFERING) else None)
        self._pending = None        # (toks_future, emissions) of last tick

    # -- PE duplication -------------------------------------------------------
    def _plan_pe_sharding(self):
        """Shard the batch axis of cache/tokens/positions over a 1-D mesh
        of min(pe, devices) when the level enables PE duplication."""
        pe = self.config.effective_pe
        if pe <= 1:
            return None
        devs = jax.devices()
        n = min(pe, len(devs))
        while n > 1 and self.B % n:
            n -= 1
        if n <= 1:
            return None
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.parallel.sharding import Sharder

        mesh = Mesh(np.asarray(devs[:n]), ("data",))
        sharder = Sharder(mesh, {"batch": ("data",)})
        cache_specs = self.model.cache_spec(self.B, self.max_seq)
        cache_sh = sharder.tree_shardings(self.model.cache_axes(),
                                          cache_specs)
        tok_sh = NamedSharding(mesh, P("data", None))
        pos_sh = NamedSharding(mesh, P("data"))
        self._repl = NamedSharding(mesh, P())
        return cache_sh, tok_sh, pos_sh

    # -- public API -----------------------------------------------------------
    @property
    def cache(self):
        return self.cache_mgr.cache

    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def finished(self):
        return self.scheduler.finished

    @property
    def slots(self):
        return self.scheduler.slots

    def submit(self, req: Request) -> int:
        return self.scheduler.submit(req)

    def step(self) -> bool:
        """One engine tick: admit, run the batched decode step, retire."""
        if self._overlap is not None:
            return self._step_overlapped()
        return self._step_serial()

    def _dispatch(self, tokens_np, positions_np, seeds_np):
        """Run the batched fused device step; returns the (possibly still
        in-flight) sampled tokens and installs the new cache.  The paged
        step additionally threads the current block tables through the
        graph (values change at admission; the (B, nb) shape never does,
        so there is no retrace)."""
        if self._paged:
            toks_dev, new_cache = self._step_fn(
                self.params, self.cache_mgr.cache,
                jnp.asarray(self.cache_mgr.tables),
                jnp.asarray(tokens_np), jnp.asarray(positions_np),
                jnp.asarray(seeds_np))
        else:
            toks_dev, new_cache = self._step_fn(
                self.params, self.cache_mgr.cache, jnp.asarray(tokens_np),
                jnp.asarray(positions_np), jnp.asarray(seeds_np))
        self.cache_mgr.cache = new_cache
        self.n_steps += 1
        return toks_dev

    def _step_serial(self) -> bool:
        """O0..O3: admit -> fill -> dispatch -> wait -> retire, in order.

        Below O2 (no pipelining) each active request additionally runs its
        own batch-1 model call, one after another — the naive per-request
        loop a batched tick replaces.
        """
        sched = self.scheduler
        admitted = sched.admit()
        active = sched.active_indices
        self.cache_mgr.reset_slots(admitted, active)
        if not active:
            return False

        cfg = self.sampler_cfg
        slots = sched.slots
        if not self._fused:
            # O0/O1: one model call per request, host-side sampling.
            toks = np.zeros((self.B,), np.int32)
            for i in active:
                s = slots[i]
                logits, self.cache_mgr.cache = self._single_fn(
                    self.params, self.cache_mgr.cache,
                    jnp.int32(s.next_token()), jnp.int32(s.pos),
                    jnp.int32(i))
                if self._host_greedy:
                    toks[i] = int(np.asarray(logits).argmax())
                else:
                    seed = cfg.request_seed(s.req.rid, len(s.req.generated))
                    toks[i] = int(self._sample_fn(
                        jnp.asarray(logits)[None],
                        jnp.asarray([seed], jnp.int32))[0])
            self.n_steps += 1
            for i in active:
                sched.advance(i, toks[i])
            return True

        # O2/O3: one batched fused step for every active slot.
        tokens_np = np.asarray(
            [[s.next_token() if s.active else self.pad_id]
             for s in slots], np.int32)
        positions_np = np.asarray(
            [s.pos if s.active else 0 for s in slots], np.int32)
        seeds_np = (np.asarray(
            [cfg.request_seed(s.req.rid, len(s.req.generated))
             if s.active else 0 for s in slots], np.int32)
            if cfg.stochastic else np.zeros((self.B,), np.int32))

        toks_dev = self._dispatch(tokens_np, positions_np, seeds_np)
        toks = np.asarray(toks_dev).reshape(self.B, -1)[:, -1]
        for i in active:
            sched.advance(i, toks[i])
        return True

    def _step_overlapped(self) -> bool:
        """O4+: double-buffered schedule.  Each call finalizes the
        previous tick (its tokens have been computing since last call),
        dispatches this tick from mostly-prestaged buffers, then does all
        token-independent bookkeeping — position advance, count-based
        retirement planning, admission, cache-slot resets, next tick's
        prompt prestaging — while the device runs."""
        sched = self.scheduler
        cfg = self.sampler_cfg
        if self._pending is not None:
            toks_dev, emissions = self._pending
            self._pending = None
            toks = np.asarray(toks_dev).reshape(self.B, -1)[:, -1]
            sched.finalize(emissions, toks)
        active = sched.active_indices
        if not active:
            # cold start / wake-up: nothing was admitted under a running
            # step, so admit + reset inline.
            admitted = sched.admit()
            if not admitted:
                return False
            active = sched.active_indices
            self.cache_mgr.reset_slots(admitted, active)

        # fill: only slots not prestaged during the previous tick
        buf = self._overlap.rotate()
        skip = self._overlap.prestaged
        for i in active:
            if i in skip:
                continue
            s = sched.slots[i]
            buf.tokens[i, 0] = s.next_token()
            buf.positions[i] = s.pos
            if cfg.stochastic:
                buf.seeds[i] = cfg.request_seed(
                    s.req.rid, len(s.req.generated))

        toks_dev = self._dispatch(buf.tokens, buf.positions, buf.seeds)

        # -- bookkeeping for the next tick, under the running step -----------
        emissions = sched.tick_advance(active)
        self._pending = (toks_dev, emissions)
        admitted = sched.admit()                 # refills planned-free slots
        if admitted:
            self.cache_mgr.reset_slots(admitted, sched.active_indices)
        self._overlap.prestage(sched, cfg)
        return True

    def run(self, *, max_ticks: int = 10_000) -> list:
        """Drain queue + slots; returns finished requests."""
        for _ in range(max_ticks):
            if not self.step() and not self.queue:
                break
        return self.finished
