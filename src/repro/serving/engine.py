"""Slot-based continuous-batching decode engine.

The serving counterpart of the paper's ladder: a fixed pool of B slots (the
"PE duplication" — B sequences decode in lockstep on the sharded
serve_step), per-slot state caches staged on device (explicit data
caching), admission/retirement pipelined with compute (double buffering:
the host prepares next tokens while the device runs the step).

Unified prefill/decode: every step feeds one token per active slot — a
slot still consuming its prompt feeds the next prompt token (its logits
are discarded), a generating slot feeds its last sampled token.  This
keeps one jitted step for all families (KV-cache transformers, RWKV/SSM
state models, enc-dec) and is exactly how slot-based TPU serving engines
handle heterogeneous request phases.

Slot hygiene: on admission the slot's cache slice is zeroed (SSM/RWKV
states accumulate; KV caches are masked by position but zeroing keeps the
invariant uniform).  The batch axis of every cache leaf is located via the
model's ``cache_axes()`` logical names — no layout guessing.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Request:
    prompt: list
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    rid: int = -1
    # filled by the engine:
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def n_prompt(self):
        return len(self.prompt)


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0              # tokens consumed (prompt + generated)

    @property
    def active(self):
        return self.req is not None and not self.req.done

    def next_token(self) -> int:
        r = self.req
        if self.pos < r.n_prompt:
            return r.prompt[self.pos]
        return r.generated[-1]

    @property
    def prefilling(self) -> bool:
        # the step that consumes prompt token n_prompt-1 emits the first
        # generated token, so "prefilling" = pos < n_prompt - 1
        return self.pos < self.req.n_prompt - 1


class DecodeEngine:
    def __init__(self, model, params, *, batch_size: int, max_seq: int,
                 pad_id: int = 0, step_fn=None):
        self.model = model
        self.params = params
        self.B = batch_size
        self.max_seq = max_seq
        self.pad_id = pad_id
        self.cache = model.init_cache(batch_size, max_seq)
        self._batch_axis = self._find_batch_axes()
        self.slots = [_Slot() for _ in range(batch_size)]
        self.queue: collections.deque = collections.deque()
        self.finished: list = []
        self._rid = itertools.count()
        self.n_steps = 0

        if step_fn is None:
            def _step(params, cache, tokens, positions):
                logits, new_cache = model.decode_step(
                    params, cache, tokens, positions)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return nxt, new_cache
            step_fn = jax.jit(_step, donate_argnums=(1,))
        self.step_fn = step_fn

    # -- slot/cache bookkeeping ----------------------------------------------
    def _find_batch_axes(self):
        axes_tree = self.model.cache_axes()
        leaves_axes = jax.tree.leaves(
            axes_tree, is_leaf=lambda x: isinstance(x, tuple))
        leaves_cache = jax.tree.leaves(self.cache)
        assert len(leaves_axes) == len(leaves_cache), "cache axes drift"
        return [ax.index("batch") for ax in leaves_axes]

    def _zero_slot(self, i: int):
        leaves, treedef = jax.tree.flatten(self.cache)
        out = []
        for leaf, bax in zip(leaves, self._batch_axis):
            idx = [slice(None)] * leaf.ndim
            idx[bax] = i
            out.append(leaf.at[tuple(idx)].set(0))
        self.cache = jax.tree.unflatten(treedef, out)

    # -- public API ------------------------------------------------------------
    def submit(self, req: Request) -> int:
        req.rid = next(self._rid)
        self.queue.append(req)
        return req.rid

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            req = self.queue.popleft()
            assert req.n_prompt >= 1, "empty prompt"
            assert req.n_prompt + req.max_new_tokens <= self.max_seq, (
                "request exceeds engine max_seq")
            self.slots[i] = _Slot(req=req, pos=0)
            self._zero_slot(i)

    def step(self):
        """One engine tick: admit, run the batched decode step, retire."""
        self._admit()
        if not any(s.active for s in self.slots):
            return False

        tokens = np.full((self.B, 1), self.pad_id, np.int32)
        positions = np.zeros((self.B,), np.int32)
        for i, s in enumerate(self.slots):
            if s.active:
                tokens[i, 0] = s.next_token()
                positions[i] = s.pos

        nxt, self.cache = self.step_fn(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(positions))
        nxt = np.asarray(nxt).reshape(self.B, -1)[:, -1]
        self.n_steps += 1

        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            emitted = not s.prefilling
            s.pos += 1
            if emitted:
                r = s.req
                tok = int(nxt[i])
                r.generated.append(tok)
                hit_eos = r.eos_id is not None and tok == r.eos_id
                if (len(r.generated) >= r.max_new_tokens or hit_eos
                        or s.pos + 1 >= self.max_seq):
                    r.done = True
                    self.finished.append(r)
                    self.slots[i] = _Slot()
        return True

    def run(self, *, max_ticks: int = 10_000) -> list:
        """Drain queue + slots; returns finished requests."""
        for _ in range(max_ticks):
            if not self.step() and not self.queue:
                break
        return self.finished
