"""Quantized KV block storage: dtypes, scales, and the ladder contract.

The paged pool (``repro.serving.paged``) can store its KV blocks in a
narrow dtype — the bit-width-reduction refinement of the scratchpad
ladder.  Everything dtype-specific lives here so the allocator, both
attention paths (gather and block-table kernel), the prefill/verify
multi-token writers, and the test suite all agree on one definition of

  * the storable dtypes (``KV_DTYPES``) and their jnp types,
  * the per-(block x kv-head) absmax scale (``block_scale``),
  * the quantize/dequantize rounding (``quantize`` / ``dequantize``),
  * and the LADDER CONTRACT each dtype buys
    (``tolerance_contract``): bf16 pools stay bit-identical to the
    contiguous O5 reference; narrow pools trade bit-identity for a
    measured minimum token-prefix agreement.

Scale convention: one f32 scale per (pool block row, kv head), computed
as ``absmax / QMAX`` over the block's token and head-dim axes.  Zero
blocks get scale 1 so dequantizing an unwritten (all-zero) block yields
exactly 0 — matching the zero-initialized bf16 pool.  Quantization is
round-to-nearest and IDEMPOTENT through the bf16 compute dtype: for
int8, ``|q * s -> bf16 -> / s|`` perturbs by at most ``127 * 2^-9 <
0.5`` units, so re-quantizing an unmodified block with its stored scale
is exact — the property the windowed requant-on-append writers rely on.
"""

from __future__ import annotations

import jax.numpy as jnp

# Storable pool dtypes.  "bf16" is the identity (no scales, bit-exact
# ladder); the narrow pair store 1-byte words with per-block scales.
KV_DTYPES = ("bf16", "int8", "fp8")

# Largest representable magnitude per narrow dtype: int8 is symmetric
# [-127, 127] (we never emit -128 so negation round-trips); fp8 e4m3fn
# saturates at 448.
_QMAX = {"int8": 127.0, "fp8": 448.0}

_POOL_DTYPE = {
    "bf16": jnp.bfloat16,
    "int8": jnp.int8,
    "fp8": jnp.float8_e4m3fn,
}


def validate_kv_dtype(kv_dtype: str) -> str:
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"kv_dtype {kv_dtype!r}; choices: {KV_DTYPES}")
    return kv_dtype


def is_quantized(kv_dtype: str) -> bool:
    return validate_kv_dtype(kv_dtype) != "bf16"


def pool_dtype(kv_dtype: str):
    """The jnp dtype pool block leaves are stored in."""
    return _POOL_DTYPE[validate_kv_dtype(kv_dtype)]


def qmax(kv_dtype: str) -> float:
    return _QMAX[kv_dtype]


def block_scale(x, reduce_axes: tuple, kv_dtype: str):
    """Per-block absmax scale: f32, keepdims over ``reduce_axes`` (the
    block's token axis and head-dim axis), ``absmax / QMAX``; all-zero
    blocks get scale 1 so their dequantized value is exactly 0."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=reduce_axes,
                     keepdims=True)
    m = qmax(kv_dtype)
    return jnp.where(absmax > 0, absmax, m) / m


def quantize(x, scale, kv_dtype: str):
    """Round ``x`` (any float dtype) into the narrow dtype under
    ``scale`` (broadcastable f32).  Round-to-nearest; int8 clips to the
    symmetric [-127, 127] range."""
    scaled = x.astype(jnp.float32) / scale
    if kv_dtype == "int8":
        return jnp.clip(jnp.round(scaled), -127, 127).astype(jnp.int8)
    if kv_dtype == "fp8":
        return scaled.astype(jnp.float8_e4m3fn)
    raise ValueError(f"quantize: kv_dtype {kv_dtype!r} is not narrow")


def dequantize(q, scale, compute_dtype=jnp.bfloat16):
    """Widen a narrow block back to the compute dtype.  The f32
    multiply then single cast to ``compute_dtype`` is THE rounding site
    both attention paths share: the gather path dequantizes the dense
    view with it, and the block-table kernel applies the identical
    expression to each streamed block, so the two paged paths see
    bit-identical KV values."""
    return (q.astype(jnp.float32) * scale).astype(compute_dtype)


def scale_bytes_per_block(n_kv_heads: int) -> int:
    """Bytes of scale metadata stored per pool block row per K/V tensor
    (one f32 per kv head)."""
    return n_kv_heads * 4


def tolerance_contract(kv_dtype: str) -> dict:
    """The ladder contract a pool dtype buys, as data the differential
    fuzz and ``assert_tokens_match`` consume:

      * ``exact`` — greedy tokens must be BIT-IDENTICAL to the
        reference (bf16 pools: the PR-8 ladder invariant, unchanged).
      * ``min_agreement`` — for narrow pools: the minimum mean
        per-request token-prefix agreement vs the bf16/O5 reference.
        Quantization error compounds autoregressively (one flipped
        token reroutes the rest of that request), so the metric is the
        matched PREFIX fraction, averaged over the mix, gated well
        below what int8/fp8 per-block absmax measures on the smoke
        models (>= 0.9) but far above what a broken scale or rounding
        site produces (~1/vocab).
    """
    if not is_quantized(kv_dtype):
        return {"kv_dtype": kv_dtype, "exact": True, "min_agreement": 1.0}
    return {"kv_dtype": kv_dtype, "exact": False, "min_agreement": 0.45}


def token_agreement(ref: list, got: list) -> float:
    """Mean per-request matched-prefix fraction between two lists of
    token lists (the tolerance metric of ``tolerance_contract``)."""
    if not ref:
        return 1.0
    total = 0.0
    for r, g in zip(ref, got):
        n = max(len(r), len(g), 1)
        k = 0
        for a, b in zip(r, g):
            if a != b:
                break
            k += 1
        total += k / n
    return total / len(ref)


def assert_tokens_match(ref: list, got: list, contract: dict,
                        label: str = "") -> float:
    """Enforce a ``tolerance_contract`` between two per-request token
    lists and return the measured agreement.  Exact contracts (bf16)
    demand bit-identity with a first-divergence diagnostic; narrow
    contracts gate ``token_agreement`` on the contract floor.  This is
    THE assertion every ladder/differential test goes through, so the
    bit-vs-tolerance split lives in exactly one place."""
    if contract["exact"]:
        if ref != got:
            for i, (r, g) in enumerate(zip(ref, got)):
                if r != g:
                    raise AssertionError(
                        f"{label or 'tokens'}: exact contract "
                        f"({contract['kv_dtype']}) violated at request "
                        f"{i}: {r} != {g}")
            raise AssertionError(
                f"{label or 'tokens'}: exact contract "
                f"({contract['kv_dtype']}) violated: "
                f"{len(ref)} vs {len(got)} requests")
        return 1.0
    agreement = token_agreement(ref, got)
    if agreement < contract["min_agreement"]:
        raise AssertionError(
            f"{label or 'tokens'}: agreement {agreement:.3f} below the "
            f"{contract['kv_dtype']} contract floor "
            f"{contract['min_agreement']}")
    return agreement
