"""Host/device overlap for the decode loop — the double-buffering step.

The paper's 3-slot rotation (Fig. 4c/5c) overlaps load / compute / store of
adjacent iterations; ``runtime/overlap.py`` applies the same idea to the
cross-pod gradient sync.  Here it is applied to the serving hot loop: while
tick N's ``step_fn`` runs on the device, the host *prestages* tick N+1's
input buffers with everything already known — a slot still consuming its
prompt will feed ``prompt[pos + 1]`` next tick no matter what the device
returns, so its token/position entries can be written before the device
result arrives.  Only the slots whose next token IS the device's output
are filled after the sync point.

Below O4 the engine allocates fresh buffers every tick and fills them
entirely after the previous tick completes (the naive serial schedule);
at O4+ it rotates through ``n_buffers`` pre-allocated buffer sets.
"""

from __future__ import annotations

import numpy as np


class TickBuffers:
    """One set of host-side step inputs (tokens / positions / seeds)."""

    __slots__ = ("tokens", "positions", "seeds")

    def __init__(self, B: int, pad_id: int):
        self.tokens = np.full((B, 1), pad_id, np.int32)
        self.positions = np.zeros((B,), np.int32)
        self.seeds = np.zeros((B,), np.int32)


class HostOverlap:
    """Rotating pre-allocated buffer sets + the prestaged-slot ledger."""

    def __init__(self, B: int, pad_id: int, n_buffers: int = 3):
        self.pad_id = pad_id
        self._ring = [TickBuffers(B, pad_id) for _ in range(max(2, n_buffers))]
        self._k = 0
        self.prestaged: set = set()

    def rotate(self) -> TickBuffers:
        """Advance to the next buffer set (this tick's inputs).  Entries
        listed in ``self.prestaged`` were already written by last tick's
        ``prestage`` and must not be refilled."""
        self._k = (self._k + 1) % len(self._ring)
        return self._ring[self._k]

    def prestage(self, scheduler, sampler_cfg) -> TickBuffers:
        """Fill the NEXT tick's entries for slots whose input is already
        known, while the device computes this tick.

        Called after ``Scheduler.tick_advance`` (positions already point
        at the next token to consume): a slot with ``pos < n_prompt`` —
        still consuming its prompt, including slots admitted under the
        running step — will feed ``prompt[pos]`` no matter what the
        device returns.  Generating slots wait for the device's token and
        are filled after ``finalize``.  A prestaged slot cannot have
        emitted this tick (emission implies ``pos >= n_prompt``), so its
        seed input (derived from the emission count, which is position
        arithmetic) is already final too.
        """
        nxt = self._ring[(self._k + 1) % len(self._ring)]
        self.prestaged.clear()
        for i, s in enumerate(scheduler.slots):
            if not s.active:
                continue
            if s.pos < s.req.n_prompt:
                nxt.tokens[i, 0] = s.req.prompt[s.pos]
                nxt.positions[i] = s.pos
                if sampler_cfg.stochastic:
                    emitted = max(0, s.pos - s.req.n_prompt + 1)
                    nxt.seeds[i] = sampler_cfg.request_seed(
                        s.req.rid, emitted)
                self.prestaged.add(i)
        return nxt
