"""Paged decode-cache scratchpad — the serving ladder's O6 rung.

The contiguous ``cache.CacheManager`` reserves ``batch x max_seq`` cache
memory per slot no matter how short the requests are.  This module is the
vLLM-style alternative (scratchpad reorganization, level 2): every cache
leaf with a sequence axis is stored as a pool of fixed-size KV *blocks*,
and each slot owns a per-request *block table* mapping logical block
``j`` (positions ``j*T .. j*T+T-1``) to a physical pool block.  Capacity
is then the pool size over the *actual* per-request reservations
(``min(n_prompt + max_new_tokens, max_seq)`` tokens), so long-tail
prompt mixes admit more concurrent requests at equal memory.

Recurrent state (RWKV wkv, Mamba conv/ssm) has no sequence axis at all —
it is O(1) per slot — so per-position blocks are the wrong shape for it.
Those leaves get the *state pool* instead: a pool of per-slot state ROWS
with a slot -> row indirection map, no block tables.  One level of
indirection buys the same things block tables buy the KV leaves —
admit-without-reshape, pool-row sharding, defrag by row copy — at one
int per slot.  Hybrid models compose both pools (block tables for the
shared-attention KV, state rows for the mamba trunk); enc-dec stores its
fixed-length cross-attention KV as a state row too (cross attention is
unmasked, so the stale-positions-are-masked argument below never applies
to it — a whole-blob row swap does).

Layering (so the allocators are testable without jax):

  * :class:`BlockAllocator` — pure free-list arithmetic: allocate /
    append / release over integer block ids.  Block 0 is reserved as the
    NULL block: unallocated block-table entries point at it, it is never
    handed out, and its contents are write-garbage by design (see below).
  * :class:`PagedAllocator` — per-slot block tables + reservation-based
    admission on top of the free list.  Drives the scheduler's admission
    gate: a request whose reservation exceeds the free blocks *queues*
    (never raises) until retirements free blocks.
  * :class:`StatePool` — the state-row sibling: slot -> row map plus a
    row free list (row 0 reserved as the NULL row — the write-garbage
    sink for parked and inactive slots), with the same conservation
    invariants.
  * :class:`StatePagingPlan` — the jax layer for state leaves: pooled
    ``(rows, ...)`` storage, row gather/scatter, per-row byte
    accounting.  Sibling of :class:`BlockPagingPlan`, composed by the
    manager, never forked on inside the engine.
  * :class:`PagedCacheManager` — the jax layer: owns the pooled cache
    tree and presents the contiguous manager's ``reset_slots`` / cache
    interface to the engine; the jitted decode step threads the block
    table through a gather (pool -> dense per-slot view) and a scatter
    (the one block each slot wrote this tick -> pool), and the state
    rows through a row gather/scatter on the state leaves.

Bit-identity with the contiguous path (the ladder's O0..O6 contract)
rests on one invariant: a slot at position ``p`` has itself written every
cache entry at positions ``< p`` (blocks are reserved for the whole
request up front, and positions advance one per tick), position ``p`` is
written in-graph before attention reads it, and every position ``> p`` —
stale block contents, NULL-block garbage, neighbours' leftovers — is
masked to -1e30 before the softmax, where float32 ``exp`` underflows to
exactly 0.  Nothing unmasked can differ, so greedy argmax cannot either.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import kvquant

NULL_BLOCK = 0
NULL_ROW = 0


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` positions."""
    return -(-max(n_tokens, 0) // block_size)


class BlockAllocator:
    """Fixed pool of KV blocks with a LIFO free list.

    ``n_blocks`` is the number of *allocatable* blocks; physical pool
    storage has ``n_blocks + 1`` rows (row 0 is the reserved NULL block).
    ``defrag`` makes allocation take the lowest-numbered free blocks
    (keeps live blocks packed toward the pool's start after churn — the
    copy-on-admit compaction in :meth:`PagedCacheManager.compact` then
    has less to move).
    """

    def __init__(self, n_blocks: int, *, defrag: bool = False):
        if n_blocks < 1:
            raise ValueError(f"need at least one block (got {n_blocks})")
        self.n_blocks = n_blocks
        self.defrag = defrag
        self._free = list(range(n_blocks, 0, -1))   # pop() -> lowest id

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    def allocate(self, n: int) -> list:
        """Take ``n`` blocks off the free list; raises if short (callers
        gate on ``free_blocks`` first — the scheduler's admission gate)."""
        if n > len(self._free):
            raise RuntimeError(
                f"block pool exhausted: want {n}, free {len(self._free)} "
                f"of {self.n_blocks} (admission gate should have queued)")
        if self.defrag:
            self._free.sort(reverse=True)
        return [self._free.pop() for _ in range(n)]

    def append(self) -> int:
        """Grow a request by one block (the incremental-growth API; the
        engine reserves whole requests up front, tests exercise this)."""
        return self.allocate(1)[0]

    def release(self, blocks) -> None:
        live = set(self._free)
        for b in blocks:
            if b == NULL_BLOCK:
                continue
            if b in live or not (1 <= b <= self.n_blocks):
                raise RuntimeError(f"double/invalid free of block {b}")
            live.add(b)
            self._free.append(b)

    def rebuild(self, n_held: int) -> None:
        """Reset to the state where blocks ``1..n_held`` are held and the
        rest are free (the compacted layout) — keeps the free-list
        representation invariant in this class only."""
        self._free = list(range(self.n_blocks, n_held, -1))


class PagedAllocator:
    """Per-slot block tables over a :class:`BlockAllocator`.

    Pure host arithmetic (numpy tables, python free list) so the
    scheduler property tests can drive random admit/retire sequences
    against the real bookkeeping without touching jax.
    """

    def __init__(self, batch_size: int, max_seq: int, *,
                 block_size: int = 16, pool_blocks: int = 0,
                 defrag: bool = False):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1 (got {block_size})")
        self.B = batch_size
        self.max_seq = max_seq
        self.block_size = block_size
        self.blocks_per_seq = blocks_for(max_seq, block_size)
        # 0 = auto: equal worst-case capacity to the contiguous cache.
        # A pool SMALLER than one worst-case (max_seq) reservation is a
        # legitimate memory-saving config — real mixes rarely reserve the
        # full horizon — but it means some statically-valid requests can
        # NEVER be admitted; those are rejected per request at submit
        # time (``infeasible_reason``, wired to ``Scheduler.submit_gate``)
        # instead of being banned for the whole engine here.
        self.pool_blocks = pool_blocks or batch_size * self.blocks_per_seq
        if self.pool_blocks < 1:
            raise ValueError(
                f"pool_blocks must be >= 1 (got {self.pool_blocks})")
        self.allocator = BlockAllocator(self.pool_blocks, defrag=defrag)
        # tables[i, j] = physical block of slot i's logical block j
        self.tables = np.full((batch_size, self.blocks_per_seq),
                              NULL_BLOCK, np.int32)
        self._held = [0] * batch_size      # blocks held per slot

    # -- admission gate + lifecycle (wired to Scheduler callbacks) ----------
    def reserved_tokens(self, req) -> int:
        """Positions the request can ever write: the prompt is consumed
        one token per tick through the same cache, so the reservation is
        prompt + budget, clipped to the engine's max_seq horizon."""
        return min(req.n_prompt + req.max_new_tokens, self.max_seq)

    def blocks_needed(self, req) -> int:
        return blocks_for(self.reserved_tokens(req), self.block_size)

    def can_admit(self, req) -> bool:
        """The scheduler's admission gate: a request that fits max_seq but
        not the remaining free blocks queues (never raises)."""
        return self.blocks_needed(req) <= self.allocator.free_blocks

    def infeasible_reason(self, req):
        """The scheduler's SUBMIT gate: an error string when the
        request's reservation exceeds the TOTAL pool — no sequence of
        retirements can ever free enough blocks, so queuing it would
        gate out every admission wave forever and ``run()`` would spin
        its whole tick budget doing nothing.  None = feasible (it may
        still have to queue for the CURRENT free count, which is
        ``can_admit``'s job)."""
        need = self.blocks_needed(req)
        if need > self.pool_blocks:
            return (f"reservation of {need} KV blocks "
                    f"({self.reserved_tokens(req)} tokens at block size "
                    f"{self.block_size}) can never fit the total pool of "
                    f"{self.pool_blocks} blocks — shrink the request or "
                    f"enlarge kv_pool_blocks")
        return None

    def admit_slot(self, i: int, req) -> None:
        """Allocate the request's full reservation into slot ``i``'s
        table (up-front reservation = no mid-flight exhaustion)."""
        if self._held[i]:
            raise RuntimeError(f"slot {i} admitted while holding blocks")
        self.tables[i, :] = NULL_BLOCK
        self.grow_slot(i, self.reserved_tokens(req))

    def grow_slot(self, i: int, total_tokens: int) -> int:
        """Grow slot ``i``'s table to cover ``total_tokens`` positions,
        allocating exactly ``blocks_for(total) - held`` new blocks — the
        chunked-admission arithmetic: a chunk that ends mid-block shares
        its active block with the next chunk, so growing by totals (not
        by per-chunk ceil sums) never double-counts it.  Returns the
        number of blocks added (0 when the reservation already covers
        the total)."""
        want = blocks_for(min(total_tokens, self.max_seq), self.block_size)
        delta = want - self._held[i]
        if delta <= 0:
            return 0
        self.tables[i, self._held[i]:want] = self.allocator.allocate(delta)
        self._held[i] = want
        return delta

    def release_slot(self, i: int, req=None) -> None:
        n = self._held[i]
        if n:
            self.allocator.release(self.tables[i, :n].tolist())
        self.tables[i, :] = NULL_BLOCK
        self._held[i] = 0

    # -- accounting ---------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return self.allocator.free_blocks

    @property
    def held_blocks(self) -> list:
        """Blocks currently held per slot (the up-front reservation) —
        the per-slot upper bound on blocks a decode tick can touch; the
        kernel's actual per-tick walk is ``ceil(position + 1 / T)``."""
        return list(self._held)

    def slot_lengths(self, positions) -> list:
        """Per-slot valid KV lengths for a tick at ``positions`` (the
        engine's per-slot write positions): length = position + 1,
        clipped to the slot's reservation; slots holding nothing
        (inactive — every table entry NULL) report 0."""
        return [min(int(p) + 1, h * self.block_size) if h else 0
                for p, h in zip(positions, self._held)]

    @property
    def capacity_tokens(self) -> int:
        return self.pool_blocks * self.block_size

    def check_conservation(self) -> None:
        """allocated + free == total, and no block is in two places."""
        held = [b for row, n in zip(self.tables, self._held)
                for b in row[:n].tolist()]
        free = self.allocator._free
        assert len(held) + len(free) == self.pool_blocks, (held, free)
        assert not (set(held) & set(free)), "block both held and free"
        assert len(set(held)) == len(held), "block held twice"


class StatePool:
    """Slot -> state-row indirection for O(1)-per-slot cache leaves.

    The state-row sibling of :class:`PagedAllocator`: pure host
    bookkeeping (a numpy row map + python free list) so the scheduler
    property tests can drive random admit/retire traffic against the
    real invariants without touching jax.  Row 0 is the reserved NULL
    row — never handed out, aliased by parked and unoccupied slots, its
    contents write-garbage by design (the state-pool analogue of the
    NULL block).

    ``n_rows`` is the number of *allocatable* rows (default: one per
    engine slot, the capacity-parity configuration); physical pool
    storage has ``n_rows + 1`` rows.  Unlike blocks, a slot holds
    exactly ONE row for its whole lifetime — recurrent state does not
    grow with the sequence — so admission is a single pop and there is
    no reservation arithmetic.
    """

    def __init__(self, batch_size: int, *, n_rows: int = 0):
        self.B = batch_size
        self.n_rows = n_rows or batch_size
        if self.n_rows < 1:
            raise ValueError(f"need at least one row (got {self.n_rows})")
        # rows[i] = physical state row of slot i (NULL_ROW = unoccupied)
        self.rows = np.full((batch_size,), NULL_ROW, np.int32)
        self._free = list(range(self.n_rows, 0, -1))   # pop() -> lowest id

    @property
    def free_rows(self) -> int:
        return len(self._free)

    @property
    def used_rows(self) -> int:
        return self.n_rows - len(self._free)

    def can_admit(self, req=None) -> bool:
        return bool(self._free)

    def infeasible_reason(self, req=None):
        return None      # one row always fits a pool of >= 1 rows

    def admit_slot(self, i: int, req=None) -> None:
        if self.rows[i] != NULL_ROW:
            raise RuntimeError(f"slot {i} admitted while holding row "
                               f"{int(self.rows[i])}")
        if not self._free:
            raise RuntimeError(
                "state pool exhausted (admission gate should have queued)")
        self.rows[i] = self._free.pop()

    def release_slot(self, i: int, req=None) -> None:
        r = int(self.rows[i])
        if r == NULL_ROW:
            return                       # releasing an empty slot: no-op
        if r in self._free or not (1 <= r <= self.n_rows):
            raise RuntimeError(f"double/invalid free of state row {r}")
        self.rows[i] = NULL_ROW
        self._free.append(r)

    def compaction_moves(self) -> dict:
        """{old_row: new_row} packing the held rows into the lowest ids
        in slot order (the defrag plan — the manager applies the device
        copies, then calls :meth:`apply_moves`)."""
        held = [(i, int(r)) for i, r in enumerate(self.rows)
                if r != NULL_ROW]
        return {old: new for (_, old), new in
                zip(held, range(1, len(held) + 1)) if old != new}

    def apply_moves(self, moves: dict) -> None:
        for i in range(self.B):
            r = int(self.rows[i])
            if r in moves:
                self.rows[i] = moves[r]
        held = {int(r) for r in self.rows if r != NULL_ROW}
        self._free = [r for r in range(self.n_rows, 0, -1) if r not in held]

    def check_conservation(self) -> None:
        """held + free == total, and no row is in two places."""
        held = [int(r) for r in self.rows if r != NULL_ROW]
        assert len(set(held)) == len(held), "state row held twice"
        assert len(held) + len(self._free) == self.n_rows, (
            held, self._free)
        assert not (set(held) & set(self._free)), "row both held and free"
        assert all(1 <= r <= self.n_rows for r in held), held


# ---------------------------------------------------------------------------
# The jax layer: pooled cache tree + gather/scatter layout.
# ---------------------------------------------------------------------------


def _axes_leaves_with_paths(tree, prefix=()):
    """(path, axes-tuple) pairs in ``jax.tree.leaves`` order (dicts sort
    their keys) for the plain dict-of-tuples trees ``cache_axes`` returns.
    The path lets the layout classify leaves by *identity* (self- vs
    cross-attention cache), not by shape coincidence."""
    if isinstance(tree, tuple):
        return [(prefix, tree)]
    assert isinstance(tree, dict), f"unexpected cache_axes node {tree!r}"
    out = []
    for k in sorted(tree):
        out.extend(_axes_leaves_with_paths(tree[k], prefix + (k,)))
    return out


class BlockPagingPlan:
    """Per-leaf paging plan derived from the model's ``cache_axes()``.

    A leaf is paged iff its logical axes name both "batch" and "kv_seq",
    the sequence axis spans the engine's max_seq, and it is a *decode*
    cache — cross-attention caches (path contains "cross") pass through
    untouched, whatever their length: cross attention is unmasked, so
    the stale-positions-are-masked argument that makes paging safe does
    not apply to them.  Non-paged leaves — recurrent state (RWKV wkv,
    Mamba conv/ssm: no sequence axis, nothing to block-page) and the
    cross caches — are *state* leaves: with ``state_pooled=False``
    (direct construction, the legacy single-plan mode) they keep dense
    per-slot storage and scatter replaces them wholesale; with
    ``state_pooled=True`` (the manager composing this plan with a
    :class:`StatePagingPlan`) they pass through gather AND scatter
    untouched in their pooled row shape, and the state plan owns their
    row indirection.  In every paged leaf of every
    model family here the sequence axis sits immediately after the batch
    axis, which makes the (batch, seq) <-> (block, in-block) reshapes
    below pure metadata.
    """

    def __init__(self, model, batch_size: int, max_seq: int,
                 block_size: int, pool_blocks: int, *,
                 row_multiple: int = 1, kv_dtype: str = "bf16",
                 state_pooled: bool = False):
        self.B = batch_size
        self.max_seq = max_seq
        self.T = block_size
        self.nb = blocks_for(max_seq, block_size)
        self.state_pooled = state_pooled
        self.kv_dtype = kvquant.validate_kv_dtype(kv_dtype)
        self.quantized = kvquant.is_quantized(kv_dtype)
        self.store_dtype = kvquant.pool_dtype(kv_dtype)
        # + NULL block row; rounded up so a block-axis PlacementPlan can
        # shard the rows evenly (padding rows are never in any table, so
        # gather/scatter never touch them — pure dead memory).
        self.pool_rows = -(-(pool_blocks + 1) // row_multiple) * row_multiple
        axes_tree = model.cache_axes()
        paths_axes = _axes_leaves_with_paths(axes_tree)
        axes_flat = jax.tree.leaves(axes_tree,
                                    is_leaf=lambda x: isinstance(x, tuple))
        assert [ax for _, ax in paths_axes] == axes_flat, "leaf-order drift"
        specs = jax.tree.leaves(model.cache_spec(batch_size, max_seq))
        assert len(paths_axes) == len(specs), "cache axes drift"
        self.plans = []           # (bax, paged) per leaf
        self.scale_axes = []      # per leaf: scale reduce-axes or None
        self.compute_dtypes = []  # per leaf: the dense/compute dtype
        # Bytes-per-token accounting derives from the STORED pool dtype
        # (1 byte for int8/fp8), not the compute dtype — the `KV
        # bytes/tick` ladder column is about traffic actually moved.
        self.token_bytes = 0          # paged-leaf STORED bytes per token
        self.compute_token_bytes = 0  # dense-view bytes per token (bf16)
        self.scale_bytes_per_block = 0  # f32 scale bytes per pool row
        for (path, ax), spec in zip(paths_axes, specs):
            bax = ax.index("batch")
            cross = any("cross" in str(k) for k in path)
            paged = ("kv_seq" in ax and not cross
                     and spec.shape[ax.index("kv_seq")] == max_seq)
            sx = None
            if paged:
                assert ax.index("kv_seq") == bax + 1, (
                    f"paged leaf needs seq right after batch, got {ax}")
                n = 1
                for d in spec.shape:
                    n *= d
                per_tok = n // (batch_size * max_seq)
                item = jnp.dtype(spec.dtype).itemsize
                self.compute_token_bytes += per_tok * item
                self.token_bytes += per_tok * (
                    jnp.dtype(self.store_dtype).itemsize
                    if self.quantized else item)
                if self.quantized:
                    # One f32 scale per (block row x every named axis
                    # that isn't the sequence): reduce the block's token
                    # axis and the unnamed head-dim axes, keep layers /
                    # kv heads.
                    sx = tuple(i for i, name in enumerate(ax)
                               if name == "kv_seq" or name is None)
                    scale_elems = 1
                    for i, d in enumerate(spec.shape):
                        if i != bax and i not in sx:
                            scale_elems *= d
                    self.scale_bytes_per_block += scale_elems * 4
            self.plans.append((bax, paged))
            self.scale_axes.append(sx)
            self.compute_dtypes.append(spec.dtype)

    def init_pool(self, model) -> tuple:
        """(pool tree, treedef): paged leaves become
        (..., pool_rows, block_size, ...) zeros in the STORED dtype;
        recurrent leaves keep their contiguous per-slot shape."""
        dense = model.init_cache(self.B, self.max_seq)
        leaves, treedef = jax.tree.flatten(dense)
        out = []
        for leaf, (bax, paged) in zip(leaves, self.plans):
            if not paged:
                out.append(leaf)
                continue
            shape = list(leaf.shape)
            shape[bax] = self.pool_rows
            shape[bax + 1] = self.T
            dt = self.store_dtype if self.quantized else leaf.dtype
            out.append(jnp.zeros(tuple(shape), dt))
        return jax.tree.unflatten(treedef, out), treedef

    def scales_for_pool(self, pool):
        """Zero-initialized scale tree matching the pool treedef: paged
        leaves get their keepdims (..., pool_rows, 1, kv, 1) f32 scale
        array (zeros: an unwritten block dequantizes to exactly 0, like
        the zero bf16 pool); non-paged leaves get a scalar placeholder
        so the scale tree zips leaf-for-leaf with the pool tree."""
        leaves, treedef = jax.tree.flatten(pool)
        out = []
        for leaf, (bax, paged), sx in zip(leaves, self.plans,
                                          self.scale_axes):
            if sx is None:
                out.append(jnp.zeros((), jnp.float32))
                continue
            shape = tuple(1 if i in sx else d
                          for i, d in enumerate(leaf.shape))
            out.append(jnp.zeros(shape, jnp.float32))
        return jax.tree.unflatten(treedef, out)

    @property
    def geometry(self) -> dict:
        """Pool geometry for kernels / benchmarks / bytes accounting.
        ``pool_bytes`` counts the whole persistent footprint: stored
        block rows PLUS the per-block scale metadata."""
        pool_bytes = self.pool_rows * (self.T * self.token_bytes
                                       + self.scale_bytes_per_block)
        return {"block_size": self.T, "blocks_per_seq": self.nb,
                "pool_rows": self.pool_rows, "batch": self.B,
                "max_seq": self.max_seq, "token_bytes": self.token_bytes,
                "kv_dtype": self.kv_dtype,
                "scale_bytes_per_block": self.scale_bytes_per_block,
                "pool_bytes": pool_bytes,
                "pool_mb": pool_bytes / 2**20}

    # -- per-tick KV traffic estimates (the gather-vs-kernel delta) ----------
    def gather_bytes_per_tick(self) -> int:
        """KV bytes the GATHER step moves per decode tick: the pool is
        read in its STORED dtype (plus per-block scales when narrow),
        the dense compute-dtype view is written then read again by dense
        attention, and one block per slot is quantized and scattered
        back — O(B * max_seq) no matter how short the live requests.
        For ``kv_dtype=bf16`` this reduces exactly to the historical
        ``3 * dense + B * T * token_bytes``."""
        pool_read = self.B * self.nb * (self.T * self.token_bytes
                                        + self.scale_bytes_per_block)
        dense = self.B * self.nb * self.T * self.compute_token_bytes
        writeback = self.B * (self.T * self.token_bytes
                              + self.scale_bytes_per_block)
        return pool_read + 2 * dense + writeback

    def kernel_bytes_per_tick(self, lengths) -> int:
        """KV bytes the gather-free KERNEL step touches for the given
        per-slot valid lengths: only the blocks each slot's table
        references (streamed once, in the STORED dtype plus their
        scales), plus the per-slot append — one stored position for
        bf16; for narrow pools the append re-quantizes the tail block
        in place (read + write of one block row and its scale).
        For ``kv_dtype=bf16`` this reduces exactly to the historical
        ``(blocks * T + len(lengths)) * token_bytes``."""
        lengths = [int(x) for x in lengths]
        blocks = sum(blocks_for(x, self.T) for x in lengths)
        stream = blocks * (self.T * self.token_bytes
                           + self.scale_bytes_per_block)
        if self.quantized:
            append = len(lengths) * 2 * (self.T * self.token_bytes
                                         + self.scale_bytes_per_block)
        else:
            append = len(lengths) * self.token_bytes
        return stream + append

    def map_batch_axes(self, dense, fn):
        """Apply ``fn(leaf, batch_axis)`` to every leaf of a DENSE
        per-slot view (as produced by :meth:`gather`) — how the sharded
        paged step re-constrains the view onto the batch axis."""
        leaves, treedef = jax.tree.flatten(dense)
        return jax.tree.unflatten(treedef, [
            fn(leaf, bax) for leaf, (bax, _) in zip(leaves, self.plans)])

    # Both halves below are traced inside the jitted decode step.
    def gather(self, pool, tables, scales=None):
        """pool tree + tables (Bv, nb) -> dense per-slot cache view with
        a (possibly block-padded) sequence axis of nb*T >= max_seq.  Bv
        is usually the full batch; the chunked-prefill step passes one
        slot's table row (Bv == 1) to build a single-slot view.

        With ``scales`` (narrow pools), each gathered block is
        dequantized — ``kvquant.dequantize`` is THE shared rounding
        site, so this dense view is bit-identical to what the
        block-table kernel computes per streamed block."""
        Bv = tables.shape[0]
        leaves, treedef = jax.tree.flatten(pool)
        scale_leaves = (jax.tree.leaves(scales) if scales is not None
                        else [None] * len(leaves))
        flat = tables.reshape(-1)                     # (Bv*nb,)
        out = []
        for leaf, sleaf, (bax, paged), cdt in zip(
                leaves, scale_leaves, self.plans, self.compute_dtypes):
            if not paged:
                out.append(leaf)
                continue
            g = jnp.take(leaf, flat, axis=bax)        # bax: Bv*nb, bax+1: T
            if scales is not None:
                s = jnp.take(sleaf, flat, axis=bax)
                g = kvquant.dequantize(g, s, cdt)
            shape = (g.shape[:bax] + (Bv, self.nb * self.T)
                     + g.shape[bax + 2:])
            out.append(g.reshape(shape))
        return jax.tree.unflatten(treedef, out)

    def scatter_view(self, pool, tables, new_dense, scales=None,
                     lengths=None):
        """Write back EVERY block of the given slots' dense views — the
        chunked-prefill counterpart of :meth:`scatter` (a prompt chunk
        spans several blocks, so the whole per-slot view gathered this
        same tick is scattered back).  Untouched blocks rewrite their own
        just-gathered values and NULL table entries absorb the padded
        tail into the write-garbage NULL row.

        Narrow pools (``scales`` given) quantize each folded block with
        a fresh absmax scale; ``lengths`` (Bv,) masks positions at or
        beyond each slot's valid length to zero first, so stale-tenant
        garbage in the just-gathered view can never inflate a scale.
        Returns ``(pool, scales)`` in that mode, ``pool`` otherwise."""
        Bv, nb = tables.shape
        pool_leaves, treedef = jax.tree.flatten(pool)
        scale_leaves = (jax.tree.leaves(scales) if scales is not None
                        else [None] * len(pool_leaves))
        dense_leaves = jax.tree.leaves(new_dense)
        valid = None
        if scales is not None and lengths is not None:
            valid = (jnp.arange(nb * self.T)[None, :]
                     < lengths[:, None]).reshape(Bv * nb, self.T)
        out, out_s = [], []
        for leaf, sleaf, dense, (bax, paged), sx in zip(
                pool_leaves, scale_leaves, dense_leaves, self.plans,
                self.scale_axes):
            if not paged:
                # state_pooled: the StatePagingPlan row-scattered this
                # leaf already (or will) — keep the pool leaf untouched.
                # Legacy single-plan mode: whole-state replace.
                out.append(leaf if self.state_pooled else dense)
                out_s.append(sleaf)
                continue
            shape = (dense.shape[:bax] + (Bv * nb, self.T)
                     + dense.shape[bax + 2:])
            folded = dense.reshape(shape)
            sel = (slice(None),) * bax + (tables.reshape(-1),)
            if scales is None:
                out.append(leaf.at[sel].set(folded))
                out_s.append(sleaf)
                continue
            if valid is not None:
                vm = valid.reshape((1,) * bax + valid.shape
                                   + (1,) * (folded.ndim - bax - 2))
                folded = jnp.where(vm, folded, 0)
            s = kvquant.block_scale(folded, sx, self.kv_dtype)
            q = kvquant.quantize(folded, s, self.kv_dtype)
            out.append(leaf.at[sel].set(q))
            out_s.append(sleaf.at[sel].set(s))
        new_pool = jax.tree.unflatten(treedef, out)
        if scales is None:
            return new_pool
        return new_pool, jax.tree.unflatten(treedef, out_s)

    def scatter(self, pool, tables, new_dense, positions, scales=None):
        """Write back the ONE block each slot touched this tick.

        A decode tick writes exactly position ``positions[b]`` per slot,
        so only logical block ``positions[b] // T`` changed; the other
        nb-1 blocks still hold what the pool holds.  Inactive slots point
        at the NULL block, which absorbs their garbage chunk.

        Narrow pools (``scales`` given) mask positions beyond
        ``positions[b]`` to zero (not-yet-written garbage must not
        inflate the absmax), re-derive the block's scale, quantize, and
        write both the block row and its scale row; returns
        ``(pool, scales)`` in that mode, ``pool`` otherwise.  bf16 pools
        deliberately skip the masking so the write-back is the exact
        gathered bits (the round-trip test pins pool rows
        bit-identical)."""
        jb = positions // self.T                      # (B,) logical block
        pb = jnp.take_along_axis(tables, jb[:, None], axis=1)[:, 0]
        seq_idx = (jb * self.T)[:, None] + jnp.arange(self.T)[None]  # (B, T)
        valid = seq_idx <= positions[:, None]                        # (B, T)
        pool_leaves, treedef = jax.tree.flatten(pool)
        scale_leaves = (jax.tree.leaves(scales) if scales is not None
                        else [None] * len(pool_leaves))
        dense_leaves = jax.tree.leaves(new_dense)
        out, out_s = [], []
        for leaf, sleaf, dense, (bax, paged), sx in zip(
                pool_leaves, scale_leaves, dense_leaves, self.plans,
                self.scale_axes):
            if not paged:
                # state_pooled: the StatePagingPlan row-scattered this
                # leaf already (or will) — keep the pool leaf untouched.
                # Legacy single-plan mode: whole-state replace.
                out.append(leaf if self.state_pooled else dense)
                out_s.append(sleaf)
                continue
            idx = seq_idx.reshape(
                (1,) * bax + seq_idx.shape + (1,) * (dense.ndim - bax - 2))
            chunk = jnp.take_along_axis(dense, idx, axis=bax + 1)
            sel = (slice(None),) * bax + (pb,)
            if scales is None:
                out.append(leaf.at[sel].set(chunk))
                out_s.append(sleaf)
                continue
            vm = valid.reshape(
                (1,) * bax + valid.shape + (1,) * (chunk.ndim - bax - 2))
            chunk = jnp.where(vm, chunk, 0)
            s = kvquant.block_scale(chunk, sx, self.kv_dtype)
            q = kvquant.quantize(chunk, s, self.kv_dtype)
            out.append(leaf.at[sel].set(q))
            out_s.append(sleaf.at[sel].set(s))
        new_pool = jax.tree.unflatten(treedef, out)
        if scales is None:
            return new_pool
        return new_pool, jax.tree.unflatten(treedef, out_s)


class StatePagingPlan:
    """Row-pooled storage plan for the non-block leaves of a
    :class:`BlockPagingPlan` — recurrent state and cross-attention KV.

    State leaves trade their dense ``batch`` axis for a pool-row axis of
    ``total_rows = roundup(n_rows + 1, row_multiple)`` physical rows
    (row 0 = NULL, padding rows for even device sharding) at the SAME
    axis position ``bax``, so the sharding plan and the packed-zero
    helper work unchanged.  ``gather(tree, rows)`` takes each slot's row
    back out into a dense batch view; ``scatter(tree, rows, new_dense)``
    writes the dense view into the rows (duplicate NULL-row writes from
    parked/inactive slots collapse into the garbage sink).  Composes
    with the block plan in either order on disjoint leaves.
    """

    def __init__(self, block_plan: BlockPagingPlan, model,
                 batch_size: int, max_seq: int, *,
                 n_rows: int = 0, row_multiple: int = 1):
        self.n_rows = n_rows or batch_size
        self.total_rows = -(-(self.n_rows + 1) // row_multiple) \
            * row_multiple
        self.baxes = [bax for bax, _ in block_plan.plans]
        self.state = [not paged for _, paged in block_plan.plans]
        specs = jax.tree.leaves(model.cache_spec(batch_size, max_seq))
        # Per-row stored bytes across all state leaves (state is never
        # quantized — it is carried, not masked, and the tolerance
        # contract only covers attention reads).
        self.state_row_bytes = 0
        for spec, st, bax in zip(specs, self.state, self.baxes):
            if not st:
                continue
            n = 1
            for i, d in enumerate(spec.shape):
                if i != bax:
                    n *= d
            self.state_row_bytes += n * jnp.dtype(spec.dtype).itemsize

    @property
    def geometry(self) -> dict:
        return {"state_rows": self.total_rows,
                "state_row_bytes": self.state_row_bytes,
                "state_bytes": self.total_rows * self.state_row_bytes}

    def init_pool(self, pool):
        """Re-shape the state leaves of a freshly built pool tree from
        dense (batch at bax) to pooled (total_rows at bax) zeros."""
        leaves, treedef = jax.tree.flatten(pool)
        out = []
        for leaf, st, bax in zip(leaves, self.state, self.baxes):
            if not st:
                out.append(leaf)
                continue
            shape = list(leaf.shape)
            shape[bax] = self.total_rows
            out.append(jnp.zeros(tuple(shape), leaf.dtype))
        return jax.tree.unflatten(treedef, out)

    # Both halves below are traced inside the jitted decode step.
    def gather(self, tree, rows):
        """Pooled state leaves + rows (Bv,) -> dense per-slot view (the
        block leaves — already dense from the block gather, or absent —
        pass through untouched)."""
        leaves, treedef = jax.tree.flatten(tree)
        out = []
        for leaf, st, bax in zip(leaves, self.state, self.baxes):
            out.append(jnp.take(leaf, rows, axis=bax) if st else leaf)
        return jax.tree.unflatten(treedef, out)

    def scatter(self, tree, rows, new_dense):
        """Write each slot's dense state back into its pool row.  Slots
        whose row is NULL (parked mid-prefill, inactive) all land in row
        0 — the write-garbage sink — so their carried state is exactly
        NOT advanced, which is what makes chunked prefill safe for
        recurrent families (satellite: park via no-advance, not via
        degrading to token-by-token)."""
        leaves, treedef = jax.tree.flatten(tree)
        dense_leaves = jax.tree.leaves(new_dense)
        out = []
        for leaf, dense, st, bax in zip(leaves, dense_leaves,
                                        self.state, self.baxes):
            if not st:
                out.append(leaf)
                continue
            sel = (slice(None),) * bax + (rows,)
            out.append(leaf.at[sel].set(dense.astype(leaf.dtype)))
        return jax.tree.unflatten(treedef, out)


class PagedCacheManager(PagedAllocator):
    """Block-pooled drop-in for ``cache.CacheManager`` at O6.

    Same engine-facing surface — ``.cache`` (the pool tree),
    ``reset_slots(indices, live)``, ``step_extras()`` — plus the
    allocator lifecycle the scheduler drives through its
    ``admission_gate`` / ``on_admit`` / ``on_retire`` hooks.  Slot
    admission allocates the request's whole reservation (so
    ``reset_slots`` has nothing left to do: stale block contents are
    masked, not zeroed — see the module docstring), and retirement
    returns the blocks before the next admission wave runs.

    Families with state leaves (recurrent state, cross KV) additionally
    own a :class:`StatePool` + :class:`StatePagingPlan` pair: admission
    takes one state row per slot next to the block reservation (pure-
    state families skip block allocation entirely — no phantom
    reservations), retirement returns it, ``reset_slots`` zeroes the
    freshly assigned rows (state is carried, not masked), and
    ``insert_slot``/``compact`` move state through row indirection.

    Under a sharded :class:`~repro.parallel.sharding.PlacementPlan` the
    pool leaves are sharded on their BLOCK axis and the state leaves on
    their ROW axis (both padded to a device multiple by their plan);
    block tables and row maps stay replicated.
    """

    def __init__(self, model, batch_size: int, max_seq: int, *,
                 block_size: int = 16, pool_blocks: int = 0,
                 defrag: bool = False, placement=None,
                 kv_dtype: str = "bf16"):
        super().__init__(batch_size, max_seq, block_size=block_size,
                         pool_blocks=pool_blocks, defrag=defrag)
        self.model = model
        self.placement = placement
        row_mult = placement.n_devices if placement is not None else 1
        self.plan = BlockPagingPlan(
            model, batch_size, max_seq, self.block_size, self.pool_blocks,
            row_multiple=row_mult, kv_dtype=kv_dtype, state_pooled=True)
        self.has_blocks = any(paged for _, paged in self.plan.plans)
        # State leaves (recurrent state, cross KV) get the row pool;
        # pure-state families have no block leaves at all and their
        # admission runs entirely on state rows (no phantom block
        # reservations — the admit-without-reshape win).
        if all(paged for _, paged in self.plan.plans):
            self.state = None
            self.state_plan = None
        else:
            self.state = StatePool(batch_size)
            self.state_plan = StatePagingPlan(
                self.plan, model, batch_size, max_seq,
                n_rows=self.state.n_rows, row_multiple=row_mult)
        pool, self._treedef = self.plan.init_pool(model)
        if self.state_plan is not None:
            pool = self.state_plan.init_pool(pool)
        # Narrow pools carry their per-block scales as a sibling subtree
        # of the SAME treedef: ``.cache`` becomes {"pool", "scale"} and
        # the engine threads the bundle opaquely (it is just a pytree).
        if self.plan.quantized:
            self.cache = {"pool": pool,
                          "scale": self.plan.scales_for_pool(pool)}
        else:
            self.cache = pool
        if placement is not None and placement.sharded:
            self.cache = jax.device_put(self.cache,
                                        self.pool_shardings(placement))
        self._state_zero = None
        self._tables_dev = None     # cached device copy of the tables
        self._rows_dev = None       # cached device copy of the row map

    @property
    def kv_dtype(self) -> str:
        return self.plan.kv_dtype

    def _split_cache(self):
        """(pool tree, scale tree-or-None) view of ``.cache``."""
        if self.plan.quantized:
            return self.cache["pool"], self.cache["scale"]
        return self.cache, None

    def _join_cache(self, pool, scales) -> None:
        self.cache = ({"pool": pool, "scale": scales}
                      if self.plan.quantized else pool)

    # -- step inputs ---------------------------------------------------------
    @property
    def geometry(self) -> dict:
        """Pool geometry (block size / blocks-per-seq / pool rows /
        per-token bytes, plus the state-row pool when the family has
        state leaves) — what the KV-bytes accounting in
        ``benchmarks/serving_ladder.py`` and ad-hoc tooling consume
        instead of reaching into the plan.  ``pool_bytes`` covers the
        whole persistent footprint: block rows + scales + state rows."""
        g = dict(self.plan.geometry)
        if self.state_plan is not None:
            g.update(self.state_plan.geometry)
            g["pool_bytes"] += g["state_bytes"]
            g["pool_mb"] = g["pool_bytes"] / 2**20
        else:
            g.update({"state_rows": 0, "state_row_bytes": 0,
                      "state_bytes": 0})
        return g

    def pool_shardings(self, placement):
        """Sharding tree for the pool: every leaf sharded at its plan
        axis — the block-row axis for paged leaves, the state-row axis
        for state leaves (both sit at ``bax``).  Scale leaves
        shard on the same pool-row axis (their other dims are keepdims
        1s); the scalar placeholders stay replicated."""
        pool_sh = jax.tree.unflatten(self._treedef, [
            placement.axis(bax) for bax, _p in self.plan.plans])
        if not self.plan.quantized:
            return pool_sh
        scale_sh = jax.tree.unflatten(self._treedef, [
            placement.axis(bax) if sx is not None else placement.replicated
            for (bax, _p), sx in zip(self.plan.plans,
                                     self.plan.scale_axes)])
        return {"pool": pool_sh, "scale": scale_sh}

    def _put_host(self, arr):
        if self.placement is not None and self.placement.sharded:
            return jax.device_put(arr, self.placement.replicated)
        return jnp.asarray(arr)

    def step_extras(self, parked=None) -> tuple:
        """Per-tick step inputs beyond (params, cache, tokens, positions,
        seeds): the block tables (iff the family has block leaves) then
        the state rows (iff it has state leaves), as CACHED device
        arrays.  Tables/rows only change at admission / retirement /
        compaction — those paths invalidate — so steady-state decode
        ticks re-use one upload instead of paying a host->device
        transfer per tick.

        ``parked``: slot indices whose state row is aliased to the NULL
        row for THIS tick — the chunked-prefill park.  A parked slot's
        batched-decode read pulls NULL garbage (its output is discarded
        anyway; batch rows are independent in every family) and its
        state write lands in the garbage sink, so its real carried state
        advances only through the prefill chunks.  Block tables are NOT
        aliased: a parked slot's KV write at position p is rewritten by
        its next chunk — the standing stale-positions invariant."""
        out = []
        if self.has_blocks:
            if self._tables_dev is None:
                self._tables_dev = self._put_host(self.tables)
            out.append(self._tables_dev)
        if self.state is not None:
            if parked:
                rows = self.state.rows.copy()
                rows[list(parked)] = NULL_ROW
                out.append(self._put_host(rows))
            else:
                if self._rows_dev is None:
                    self._rows_dev = self._put_host(self.state.rows)
                out.append(self._rows_dev)
        return tuple(out)

    # -- admission: both pools must say yes -----------------------------------
    def blocks_needed(self, req) -> int:
        return super().blocks_needed(req) if self.has_blocks else 0

    def can_admit(self, req) -> bool:
        if self.has_blocks and not super().can_admit(req):
            return False
        return self.state is None or self.state.can_admit(req)

    def admit_slot(self, i: int, req) -> None:
        if self.has_blocks:
            super().admit_slot(i, req)
            self._tables_dev = None
        if self.state is not None:
            self.state.admit_slot(i, req)
            self._rows_dev = None

    def grow_slot(self, i: int, total_tokens: int) -> int:
        added = super().grow_slot(i, total_tokens)
        if added:
            self._tables_dev = None
        return added

    def release_slot(self, i: int, req=None) -> None:
        if self.has_blocks:
            super().release_slot(i, req)
            self._tables_dev = None
        if self.state is not None:
            self.state.release_slot(i, req)
            self._rows_dev = None

    def check_conservation(self) -> None:
        if self.has_blocks:
            super().check_conservation()
        if self.state is not None:
            self.state.check_conservation()

    def reset_slots(self, indices: list, live: list) -> None:
        """Admission reset under paging.

        Paged (sequence-axis) leaves need NO zeroing: the slots in
        ``indices`` had their tables rebuilt by ``admit_slot`` and every
        stale position is masked before the softmax.  Recurrent-STATE
        leaves (RWKV wkv / Mamba conv+ssm — per-slot, no sequence axis)
        are different: state is carried, not masked, so the previous
        tenant's state would leak straight into the new request's first
        step.  Their freshly allocated pool ROWS get the O5-style packed
        one-call zeroing (``admit_slot`` assigned the rows before this
        runs).
        """
        if not indices or self.state is None:
            return
        if self._state_zero is None:
            from repro.serving.cache import make_packed_zero

            self._state_zero = make_packed_zero(
                [bax for bax, _ in self.plan.plans],
                skip=[paged for _, paged in self.plan.plans])
        rows = [int(self.state.rows[i]) for i in indices]
        pool, scales = self._split_cache()
        pool = self._state_zero(pool, jnp.asarray(rows, jnp.int32))
        self._join_cache(pool, scales)

    def insert_slot(self, i: int, state) -> None:
        """Install an externally prefilled batch-1 DENSE cache tree into
        slot ``i``'s pool blocks (the INSERT phase of
        prefill->insert->generate).  Paged leaves pad their sequence axis
        to the table horizon (nb*T), fold it to (nb, T) and scatter
        through slot ``i``'s block table — ``place``/``admit`` rebuilt
        the table before this runs, and NULL entries past the reservation
        absorb the padded tail into the write-garbage NULL row.
        State leaves (recurrent state, cross KV) copy the batch-1 slice
        into slot ``i``'s pool row — cross-attention KV built offline
        (``encdec.build_cross_cache``) rides in through the same door.

        Narrow pools quantize each folded block with a fresh absmax
        scale (the dense prefill state is zero past the prompt, so no
        masking is needed) and install the scales alongside."""
        nb, T = self.plan.nb, self.plan.T
        row = jnp.asarray(self.tables[i], jnp.int32)        # (nb,)
        pool, scales = self._split_cache()
        leaves, treedef = jax.tree.flatten(pool)
        scale_leaves = (jax.tree.leaves(scales) if scales is not None
                        else [None] * len(leaves))
        st_leaves = jax.tree.leaves(state)
        assert len(leaves) == len(st_leaves), "prefill state tree drift"
        out, out_s = [], []
        for leaf, sleaf, st, (bax, paged), sx in zip(
                leaves, scale_leaves, st_leaves, self.plan.plans,
                self.plan.scale_axes):
            if not paged:
                st0 = jnp.take(st, 0, axis=bax).astype(leaf.dtype)
                sel = (slice(None),) * bax + (int(self.state.rows[i]),)
                out.append(leaf.at[sel].set(st0))
                out_s.append(sleaf)
                continue
            st0 = jnp.take(st, 0, axis=bax)
            pad = nb * T - st0.shape[bax]         # seq axis now at bax
            if pad:
                widths = [(0, 0)] * st0.ndim
                widths[bax] = (0, pad)
                st0 = jnp.pad(st0, widths)
            folded = st0.reshape(
                st0.shape[:bax] + (nb, T) + st0.shape[bax + 1:])
            sel = (slice(None),) * bax + (row,)
            if scales is None:
                out.append(leaf.at[sel].set(folded.astype(leaf.dtype)))
                out_s.append(sleaf)
                continue
            s = kvquant.block_scale(folded, sx, self.plan.kv_dtype)
            q = kvquant.quantize(folded, s, self.plan.kv_dtype)
            out.append(leaf.at[sel].set(q))
            out_s.append(sleaf.at[sel].set(s))
        new_scales = (jax.tree.unflatten(treedef, out_s)
                      if scales is not None else None)
        self._join_cache(jax.tree.unflatten(treedef, out), new_scales)
        self._tables_dev = None

    def compact(self) -> None:
        """Copy-on-admit defrag: relocate every held block to the lowest
        free ids, rewriting tables and physically copying pool rows.
        Optional — correctness never needs it (block ids are fully
        virtualized); it keeps the live set dense so a future pool-shrink
        or sequence-sharded gather touches a compact prefix."""
        held = sorted({b for row, n in zip(self.tables, self._held)
                       for b in row[:n].tolist()})
        want = list(range(1, len(held) + 1))
        moves = {old: new for old, new in zip(held, want) if old != new}
        smoves = (self.state.compaction_moves()
                  if self.state is not None else {})
        if not moves and not smoves:
            return
        src = jnp.asarray(list(moves.keys()) or [0], jnp.int32)
        dst = jnp.asarray(list(moves.values()) or [0], jnp.int32)
        ssrc = jnp.asarray(list(smoves.keys()) or [0], jnp.int32)
        sdst = jnp.asarray(list(smoves.values()) or [0], jnp.int32)
        pool, scales = self._split_cache()

        def move_rows(tree):
            # relocate pool rows — block rows by the block moves, state
            # rows by the state moves; scale rows ride along (same bax)
            # and scalar placeholders are left alone.  "or [0]" above
            # keeps an empty move set a NULL-row self-copy no-op.
            leaves, moved = jax.tree.leaves(tree), []
            for leaf, (bax, paged) in zip(leaves, self.plan.plans):
                if leaf.ndim == 0:
                    moved.append(leaf)
                    continue
                s, d = (src, dst) if paged else (ssrc, sdst)
                sel_src = (slice(None),) * bax + (s,)
                sel_dst = (slice(None),) * bax + (d,)
                moved.append(leaf.at[sel_dst].set(leaf[sel_src]))
            return jax.tree.unflatten(self._treedef, moved)

        pool = move_rows(pool)
        if scales is not None:
            scales = move_rows(scales)
        self._join_cache(pool, scales)
        if moves:
            remap = np.vectorize(lambda b: moves.get(int(b), int(b)))
            self.tables = remap(self.tables).astype(np.int32)
            self.allocator.rebuild(len(held))
            self._tables_dev = None
        if smoves:
            self.state.apply_moves(smoves)
            self._rows_dev = None
