"""Paged decode-cache scratchpad — the serving ladder's O6 rung.

The contiguous ``cache.CacheManager`` reserves ``batch x max_seq`` cache
memory per slot no matter how short the requests are.  This module is the
vLLM-style alternative (scratchpad reorganization, level 2): every cache
leaf with a sequence axis is stored as a pool of fixed-size KV *blocks*,
and each slot owns a per-request *block table* mapping logical block
``j`` (positions ``j*T .. j*T+T-1``) to a physical pool block.  Capacity
is then the pool size over the *actual* per-request reservations
(``min(n_prompt + max_new_tokens, max_seq)`` tokens), so long-tail
prompt mixes admit more concurrent requests at equal memory.

Layering (so the allocator is testable without jax):

  * :class:`BlockAllocator` — pure free-list arithmetic: allocate /
    append / release over integer block ids.  Block 0 is reserved as the
    NULL block: unallocated block-table entries point at it, it is never
    handed out, and its contents are write-garbage by design (see below).
  * :class:`PagedAllocator` — per-slot block tables + reservation-based
    admission on top of the free list.  Drives the scheduler's admission
    gate: a request whose reservation exceeds the free blocks *queues*
    (never raises) until retirements free blocks.
  * :class:`PagedCacheManager` — the jax layer: owns the pooled cache
    tree and presents the contiguous manager's ``reset_slots`` / cache
    interface to the engine; the jitted decode step threads the block
    table through a gather (pool -> dense per-slot view) and a scatter
    (the one block each slot wrote this tick -> pool).

Bit-identity with the contiguous path (the ladder's O0..O6 contract)
rests on one invariant: a slot at position ``p`` has itself written every
cache entry at positions ``< p`` (blocks are reserved for the whole
request up front, and positions advance one per tick), position ``p`` is
written in-graph before attention reads it, and every position ``> p`` —
stale block contents, NULL-block garbage, neighbours' leftovers — is
masked to -1e30 before the softmax, where float32 ``exp`` underflows to
exactly 0.  Nothing unmasked can differ, so greedy argmax cannot either.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import kvquant

NULL_BLOCK = 0


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` positions."""
    return -(-max(n_tokens, 0) // block_size)


class BlockAllocator:
    """Fixed pool of KV blocks with a LIFO free list.

    ``n_blocks`` is the number of *allocatable* blocks; physical pool
    storage has ``n_blocks + 1`` rows (row 0 is the reserved NULL block).
    ``defrag`` makes allocation take the lowest-numbered free blocks
    (keeps live blocks packed toward the pool's start after churn — the
    copy-on-admit compaction in :meth:`PagedCacheManager.compact` then
    has less to move).
    """

    def __init__(self, n_blocks: int, *, defrag: bool = False):
        if n_blocks < 1:
            raise ValueError(f"need at least one block (got {n_blocks})")
        self.n_blocks = n_blocks
        self.defrag = defrag
        self._free = list(range(n_blocks, 0, -1))   # pop() -> lowest id

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    def allocate(self, n: int) -> list:
        """Take ``n`` blocks off the free list; raises if short (callers
        gate on ``free_blocks`` first — the scheduler's admission gate)."""
        if n > len(self._free):
            raise RuntimeError(
                f"block pool exhausted: want {n}, free {len(self._free)} "
                f"of {self.n_blocks} (admission gate should have queued)")
        if self.defrag:
            self._free.sort(reverse=True)
        return [self._free.pop() for _ in range(n)]

    def append(self) -> int:
        """Grow a request by one block (the incremental-growth API; the
        engine reserves whole requests up front, tests exercise this)."""
        return self.allocate(1)[0]

    def release(self, blocks) -> None:
        live = set(self._free)
        for b in blocks:
            if b == NULL_BLOCK:
                continue
            if b in live or not (1 <= b <= self.n_blocks):
                raise RuntimeError(f"double/invalid free of block {b}")
            live.add(b)
            self._free.append(b)

    def rebuild(self, n_held: int) -> None:
        """Reset to the state where blocks ``1..n_held`` are held and the
        rest are free (the compacted layout) — keeps the free-list
        representation invariant in this class only."""
        self._free = list(range(self.n_blocks, n_held, -1))


class PagedAllocator:
    """Per-slot block tables over a :class:`BlockAllocator`.

    Pure host arithmetic (numpy tables, python free list) so the
    scheduler property tests can drive random admit/retire sequences
    against the real bookkeeping without touching jax.
    """

    def __init__(self, batch_size: int, max_seq: int, *,
                 block_size: int = 16, pool_blocks: int = 0,
                 defrag: bool = False):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1 (got {block_size})")
        self.B = batch_size
        self.max_seq = max_seq
        self.block_size = block_size
        self.blocks_per_seq = blocks_for(max_seq, block_size)
        # 0 = auto: equal worst-case capacity to the contiguous cache.
        # A pool SMALLER than one worst-case (max_seq) reservation is a
        # legitimate memory-saving config — real mixes rarely reserve the
        # full horizon — but it means some statically-valid requests can
        # NEVER be admitted; those are rejected per request at submit
        # time (``infeasible_reason``, wired to ``Scheduler.submit_gate``)
        # instead of being banned for the whole engine here.
        self.pool_blocks = pool_blocks or batch_size * self.blocks_per_seq
        if self.pool_blocks < 1:
            raise ValueError(
                f"pool_blocks must be >= 1 (got {self.pool_blocks})")
        self.allocator = BlockAllocator(self.pool_blocks, defrag=defrag)
        # tables[i, j] = physical block of slot i's logical block j
        self.tables = np.full((batch_size, self.blocks_per_seq),
                              NULL_BLOCK, np.int32)
        self._held = [0] * batch_size      # blocks held per slot

    # -- admission gate + lifecycle (wired to Scheduler callbacks) ----------
    def reserved_tokens(self, req) -> int:
        """Positions the request can ever write: the prompt is consumed
        one token per tick through the same cache, so the reservation is
        prompt + budget, clipped to the engine's max_seq horizon."""
        return min(req.n_prompt + req.max_new_tokens, self.max_seq)

    def blocks_needed(self, req) -> int:
        return blocks_for(self.reserved_tokens(req), self.block_size)

    def can_admit(self, req) -> bool:
        """The scheduler's admission gate: a request that fits max_seq but
        not the remaining free blocks queues (never raises)."""
        return self.blocks_needed(req) <= self.allocator.free_blocks

    def infeasible_reason(self, req):
        """The scheduler's SUBMIT gate: an error string when the
        request's reservation exceeds the TOTAL pool — no sequence of
        retirements can ever free enough blocks, so queuing it would
        gate out every admission wave forever and ``run()`` would spin
        its whole tick budget doing nothing.  None = feasible (it may
        still have to queue for the CURRENT free count, which is
        ``can_admit``'s job)."""
        need = self.blocks_needed(req)
        if need > self.pool_blocks:
            return (f"reservation of {need} KV blocks "
                    f"({self.reserved_tokens(req)} tokens at block size "
                    f"{self.block_size}) can never fit the total pool of "
                    f"{self.pool_blocks} blocks — shrink the request or "
                    f"enlarge kv_pool_blocks")
        return None

    def admit_slot(self, i: int, req) -> None:
        """Allocate the request's full reservation into slot ``i``'s
        table (up-front reservation = no mid-flight exhaustion)."""
        if self._held[i]:
            raise RuntimeError(f"slot {i} admitted while holding blocks")
        self.tables[i, :] = NULL_BLOCK
        self.grow_slot(i, self.reserved_tokens(req))

    def grow_slot(self, i: int, total_tokens: int) -> int:
        """Grow slot ``i``'s table to cover ``total_tokens`` positions,
        allocating exactly ``blocks_for(total) - held`` new blocks — the
        chunked-admission arithmetic: a chunk that ends mid-block shares
        its active block with the next chunk, so growing by totals (not
        by per-chunk ceil sums) never double-counts it.  Returns the
        number of blocks added (0 when the reservation already covers
        the total)."""
        want = blocks_for(min(total_tokens, self.max_seq), self.block_size)
        delta = want - self._held[i]
        if delta <= 0:
            return 0
        self.tables[i, self._held[i]:want] = self.allocator.allocate(delta)
        self._held[i] = want
        return delta

    def release_slot(self, i: int, req=None) -> None:
        n = self._held[i]
        if n:
            self.allocator.release(self.tables[i, :n].tolist())
        self.tables[i, :] = NULL_BLOCK
        self._held[i] = 0

    # -- accounting ---------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return self.allocator.free_blocks

    @property
    def held_blocks(self) -> list:
        """Blocks currently held per slot (the up-front reservation) —
        the per-slot upper bound on blocks a decode tick can touch; the
        kernel's actual per-tick walk is ``ceil(position + 1 / T)``."""
        return list(self._held)

    def slot_lengths(self, positions) -> list:
        """Per-slot valid KV lengths for a tick at ``positions`` (the
        engine's per-slot write positions): length = position + 1,
        clipped to the slot's reservation; slots holding nothing
        (inactive — every table entry NULL) report 0."""
        return [min(int(p) + 1, h * self.block_size) if h else 0
                for p, h in zip(positions, self._held)]

    @property
    def capacity_tokens(self) -> int:
        return self.pool_blocks * self.block_size

    def check_conservation(self) -> None:
        """allocated + free == total, and no block is in two places."""
        held = [b for row, n in zip(self.tables, self._held)
                for b in row[:n].tolist()]
        free = self.allocator._free
        assert len(held) + len(free) == self.pool_blocks, (held, free)
        assert not (set(held) & set(free)), "block both held and free"
        assert len(set(held)) == len(held), "block held twice"


# ---------------------------------------------------------------------------
# The jax layer: pooled cache tree + gather/scatter layout.
# ---------------------------------------------------------------------------


def _axes_leaves_with_paths(tree, prefix=()):
    """(path, axes-tuple) pairs in ``jax.tree.leaves`` order (dicts sort
    their keys) for the plain dict-of-tuples trees ``cache_axes`` returns.
    The path lets the layout classify leaves by *identity* (self- vs
    cross-attention cache), not by shape coincidence."""
    if isinstance(tree, tuple):
        return [(prefix, tree)]
    assert isinstance(tree, dict), f"unexpected cache_axes node {tree!r}"
    out = []
    for k in sorted(tree):
        out.extend(_axes_leaves_with_paths(tree[k], prefix + (k,)))
    return out


class BlockPagingPlan:
    """Per-leaf paging plan derived from the model's ``cache_axes()``.

    A leaf is paged iff its logical axes name both "batch" and "kv_seq",
    the sequence axis spans the engine's max_seq, and it is a *decode*
    cache — cross-attention caches (path contains "cross") pass through
    untouched, whatever their length: cross attention is unmasked, so
    the stale-positions-are-masked argument that makes paging safe does
    not apply to them.  Recurrent-state leaves (RWKV wkv, Mamba conv/ssm
    — no sequence axis) keep per-slot contiguous storage: there is
    nothing to page in O(1)-state families.  In every paged leaf of every
    model family here the sequence axis sits immediately after the batch
    axis, which makes the (batch, seq) <-> (block, in-block) reshapes
    below pure metadata.
    """

    def __init__(self, model, batch_size: int, max_seq: int,
                 block_size: int, pool_blocks: int, *,
                 row_multiple: int = 1, kv_dtype: str = "bf16"):
        self.B = batch_size
        self.max_seq = max_seq
        self.T = block_size
        self.nb = blocks_for(max_seq, block_size)
        self.kv_dtype = kvquant.validate_kv_dtype(kv_dtype)
        self.quantized = kvquant.is_quantized(kv_dtype)
        self.store_dtype = kvquant.pool_dtype(kv_dtype)
        # + NULL block row; rounded up so a block-axis PlacementPlan can
        # shard the rows evenly (padding rows are never in any table, so
        # gather/scatter never touch them — pure dead memory).
        self.pool_rows = -(-(pool_blocks + 1) // row_multiple) * row_multiple
        axes_tree = model.cache_axes()
        paths_axes = _axes_leaves_with_paths(axes_tree)
        axes_flat = jax.tree.leaves(axes_tree,
                                    is_leaf=lambda x: isinstance(x, tuple))
        assert [ax for _, ax in paths_axes] == axes_flat, "leaf-order drift"
        specs = jax.tree.leaves(model.cache_spec(batch_size, max_seq))
        assert len(paths_axes) == len(specs), "cache axes drift"
        self.plans = []           # (bax, paged) per leaf
        self.scale_axes = []      # per leaf: scale reduce-axes or None
        self.compute_dtypes = []  # per leaf: the dense/compute dtype
        # Bytes-per-token accounting derives from the STORED pool dtype
        # (1 byte for int8/fp8), not the compute dtype — the `KV
        # bytes/tick` ladder column is about traffic actually moved.
        self.token_bytes = 0          # paged-leaf STORED bytes per token
        self.compute_token_bytes = 0  # dense-view bytes per token (bf16)
        self.scale_bytes_per_block = 0  # f32 scale bytes per pool row
        for (path, ax), spec in zip(paths_axes, specs):
            bax = ax.index("batch")
            cross = any("cross" in str(k) for k in path)
            paged = ("kv_seq" in ax and not cross
                     and spec.shape[ax.index("kv_seq")] == max_seq)
            sx = None
            if paged:
                assert ax.index("kv_seq") == bax + 1, (
                    f"paged leaf needs seq right after batch, got {ax}")
                n = 1
                for d in spec.shape:
                    n *= d
                per_tok = n // (batch_size * max_seq)
                item = jnp.dtype(spec.dtype).itemsize
                self.compute_token_bytes += per_tok * item
                self.token_bytes += per_tok * (
                    jnp.dtype(self.store_dtype).itemsize
                    if self.quantized else item)
                if self.quantized:
                    # One f32 scale per (block row x every named axis
                    # that isn't the sequence): reduce the block's token
                    # axis and the unnamed head-dim axes, keep layers /
                    # kv heads.
                    sx = tuple(i for i, name in enumerate(ax)
                               if name == "kv_seq" or name is None)
                    scale_elems = 1
                    for i, d in enumerate(spec.shape):
                        if i != bax and i not in sx:
                            scale_elems *= d
                    self.scale_bytes_per_block += scale_elems * 4
            self.plans.append((bax, paged))
            self.scale_axes.append(sx)
            self.compute_dtypes.append(spec.dtype)

    def init_pool(self, model) -> tuple:
        """(pool tree, treedef): paged leaves become
        (..., pool_rows, block_size, ...) zeros in the STORED dtype;
        recurrent leaves keep their contiguous per-slot shape."""
        dense = model.init_cache(self.B, self.max_seq)
        leaves, treedef = jax.tree.flatten(dense)
        out = []
        for leaf, (bax, paged) in zip(leaves, self.plans):
            if not paged:
                out.append(leaf)
                continue
            shape = list(leaf.shape)
            shape[bax] = self.pool_rows
            shape[bax + 1] = self.T
            dt = self.store_dtype if self.quantized else leaf.dtype
            out.append(jnp.zeros(tuple(shape), dt))
        return jax.tree.unflatten(treedef, out), treedef

    def scales_for_pool(self, pool):
        """Zero-initialized scale tree matching the pool treedef: paged
        leaves get their keepdims (..., pool_rows, 1, kv, 1) f32 scale
        array (zeros: an unwritten block dequantizes to exactly 0, like
        the zero bf16 pool); non-paged leaves get a scalar placeholder
        so the scale tree zips leaf-for-leaf with the pool tree."""
        leaves, treedef = jax.tree.flatten(pool)
        out = []
        for leaf, (bax, paged), sx in zip(leaves, self.plans,
                                          self.scale_axes):
            if sx is None:
                out.append(jnp.zeros((), jnp.float32))
                continue
            shape = tuple(1 if i in sx else d
                          for i, d in enumerate(leaf.shape))
            out.append(jnp.zeros(shape, jnp.float32))
        return jax.tree.unflatten(treedef, out)

    @property
    def geometry(self) -> dict:
        """Pool geometry for kernels / benchmarks / bytes accounting.
        ``pool_bytes`` counts the whole persistent footprint: stored
        block rows PLUS the per-block scale metadata."""
        pool_bytes = self.pool_rows * (self.T * self.token_bytes
                                       + self.scale_bytes_per_block)
        return {"block_size": self.T, "blocks_per_seq": self.nb,
                "pool_rows": self.pool_rows, "batch": self.B,
                "max_seq": self.max_seq, "token_bytes": self.token_bytes,
                "kv_dtype": self.kv_dtype,
                "scale_bytes_per_block": self.scale_bytes_per_block,
                "pool_bytes": pool_bytes,
                "pool_mb": pool_bytes / 2**20}

    # -- per-tick KV traffic estimates (the gather-vs-kernel delta) ----------
    def gather_bytes_per_tick(self) -> int:
        """KV bytes the GATHER step moves per decode tick: the pool is
        read in its STORED dtype (plus per-block scales when narrow),
        the dense compute-dtype view is written then read again by dense
        attention, and one block per slot is quantized and scattered
        back — O(B * max_seq) no matter how short the live requests.
        For ``kv_dtype=bf16`` this reduces exactly to the historical
        ``3 * dense + B * T * token_bytes``."""
        pool_read = self.B * self.nb * (self.T * self.token_bytes
                                        + self.scale_bytes_per_block)
        dense = self.B * self.nb * self.T * self.compute_token_bytes
        writeback = self.B * (self.T * self.token_bytes
                              + self.scale_bytes_per_block)
        return pool_read + 2 * dense + writeback

    def kernel_bytes_per_tick(self, lengths) -> int:
        """KV bytes the gather-free KERNEL step touches for the given
        per-slot valid lengths: only the blocks each slot's table
        references (streamed once, in the STORED dtype plus their
        scales), plus the per-slot append — one stored position for
        bf16; for narrow pools the append re-quantizes the tail block
        in place (read + write of one block row and its scale).
        For ``kv_dtype=bf16`` this reduces exactly to the historical
        ``(blocks * T + len(lengths)) * token_bytes``."""
        lengths = [int(x) for x in lengths]
        blocks = sum(blocks_for(x, self.T) for x in lengths)
        stream = blocks * (self.T * self.token_bytes
                           + self.scale_bytes_per_block)
        if self.quantized:
            append = len(lengths) * 2 * (self.T * self.token_bytes
                                         + self.scale_bytes_per_block)
        else:
            append = len(lengths) * self.token_bytes
        return stream + append

    def map_batch_axes(self, dense, fn):
        """Apply ``fn(leaf, batch_axis)`` to every leaf of a DENSE
        per-slot view (as produced by :meth:`gather`) — how the sharded
        paged step re-constrains the view onto the batch axis."""
        leaves, treedef = jax.tree.flatten(dense)
        return jax.tree.unflatten(treedef, [
            fn(leaf, bax) for leaf, (bax, _) in zip(leaves, self.plans)])

    # Both halves below are traced inside the jitted decode step.
    def gather(self, pool, tables, scales=None):
        """pool tree + tables (Bv, nb) -> dense per-slot cache view with
        a (possibly block-padded) sequence axis of nb*T >= max_seq.  Bv
        is usually the full batch; the chunked-prefill step passes one
        slot's table row (Bv == 1) to build a single-slot view.

        With ``scales`` (narrow pools), each gathered block is
        dequantized — ``kvquant.dequantize`` is THE shared rounding
        site, so this dense view is bit-identical to what the
        block-table kernel computes per streamed block."""
        Bv = tables.shape[0]
        leaves, treedef = jax.tree.flatten(pool)
        scale_leaves = (jax.tree.leaves(scales) if scales is not None
                        else [None] * len(leaves))
        flat = tables.reshape(-1)                     # (Bv*nb,)
        out = []
        for leaf, sleaf, (bax, paged), cdt in zip(
                leaves, scale_leaves, self.plans, self.compute_dtypes):
            if not paged:
                out.append(leaf)
                continue
            g = jnp.take(leaf, flat, axis=bax)        # bax: Bv*nb, bax+1: T
            if scales is not None:
                s = jnp.take(sleaf, flat, axis=bax)
                g = kvquant.dequantize(g, s, cdt)
            shape = (g.shape[:bax] + (Bv, self.nb * self.T)
                     + g.shape[bax + 2:])
            out.append(g.reshape(shape))
        return jax.tree.unflatten(treedef, out)

    def scatter_view(self, pool, tables, new_dense, scales=None,
                     lengths=None):
        """Write back EVERY block of the given slots' dense views — the
        chunked-prefill counterpart of :meth:`scatter` (a prompt chunk
        spans several blocks, so the whole per-slot view gathered this
        same tick is scattered back).  Untouched blocks rewrite their own
        just-gathered values and NULL table entries absorb the padded
        tail into the write-garbage NULL row.

        Narrow pools (``scales`` given) quantize each folded block with
        a fresh absmax scale; ``lengths`` (Bv,) masks positions at or
        beyond each slot's valid length to zero first, so stale-tenant
        garbage in the just-gathered view can never inflate a scale.
        Returns ``(pool, scales)`` in that mode, ``pool`` otherwise."""
        Bv, nb = tables.shape
        pool_leaves, treedef = jax.tree.flatten(pool)
        scale_leaves = (jax.tree.leaves(scales) if scales is not None
                        else [None] * len(pool_leaves))
        dense_leaves = jax.tree.leaves(new_dense)
        valid = None
        if scales is not None and lengths is not None:
            valid = (jnp.arange(nb * self.T)[None, :]
                     < lengths[:, None]).reshape(Bv * nb, self.T)
        out, out_s = [], []
        for leaf, sleaf, dense, (bax, paged), sx in zip(
                pool_leaves, scale_leaves, dense_leaves, self.plans,
                self.scale_axes):
            if not paged:
                out.append(dense)                     # whole-state replace
                out_s.append(sleaf)
                continue
            shape = (dense.shape[:bax] + (Bv * nb, self.T)
                     + dense.shape[bax + 2:])
            folded = dense.reshape(shape)
            sel = (slice(None),) * bax + (tables.reshape(-1),)
            if scales is None:
                out.append(leaf.at[sel].set(folded))
                out_s.append(sleaf)
                continue
            if valid is not None:
                vm = valid.reshape((1,) * bax + valid.shape
                                   + (1,) * (folded.ndim - bax - 2))
                folded = jnp.where(vm, folded, 0)
            s = kvquant.block_scale(folded, sx, self.kv_dtype)
            q = kvquant.quantize(folded, s, self.kv_dtype)
            out.append(leaf.at[sel].set(q))
            out_s.append(sleaf.at[sel].set(s))
        new_pool = jax.tree.unflatten(treedef, out)
        if scales is None:
            return new_pool
        return new_pool, jax.tree.unflatten(treedef, out_s)

    def scatter(self, pool, tables, new_dense, positions, scales=None):
        """Write back the ONE block each slot touched this tick.

        A decode tick writes exactly position ``positions[b]`` per slot,
        so only logical block ``positions[b] // T`` changed; the other
        nb-1 blocks still hold what the pool holds.  Inactive slots point
        at the NULL block, which absorbs their garbage chunk.

        Narrow pools (``scales`` given) mask positions beyond
        ``positions[b]`` to zero (not-yet-written garbage must not
        inflate the absmax), re-derive the block's scale, quantize, and
        write both the block row and its scale row; returns
        ``(pool, scales)`` in that mode, ``pool`` otherwise.  bf16 pools
        deliberately skip the masking so the write-back is the exact
        gathered bits (the round-trip test pins pool rows
        bit-identical)."""
        jb = positions // self.T                      # (B,) logical block
        pb = jnp.take_along_axis(tables, jb[:, None], axis=1)[:, 0]
        seq_idx = (jb * self.T)[:, None] + jnp.arange(self.T)[None]  # (B, T)
        valid = seq_idx <= positions[:, None]                        # (B, T)
        pool_leaves, treedef = jax.tree.flatten(pool)
        scale_leaves = (jax.tree.leaves(scales) if scales is not None
                        else [None] * len(pool_leaves))
        dense_leaves = jax.tree.leaves(new_dense)
        out, out_s = [], []
        for leaf, sleaf, dense, (bax, paged), sx in zip(
                pool_leaves, scale_leaves, dense_leaves, self.plans,
                self.scale_axes):
            if not paged:
                out.append(dense)                     # whole-state replace
                out_s.append(sleaf)
                continue
            idx = seq_idx.reshape(
                (1,) * bax + seq_idx.shape + (1,) * (dense.ndim - bax - 2))
            chunk = jnp.take_along_axis(dense, idx, axis=bax + 1)
            sel = (slice(None),) * bax + (pb,)
            if scales is None:
                out.append(leaf.at[sel].set(chunk))
                out_s.append(sleaf)
                continue
            vm = valid.reshape(
                (1,) * bax + valid.shape + (1,) * (chunk.ndim - bax - 2))
            chunk = jnp.where(vm, chunk, 0)
            s = kvquant.block_scale(chunk, sx, self.kv_dtype)
            q = kvquant.quantize(chunk, s, self.kv_dtype)
            out.append(leaf.at[sel].set(q))
            out_s.append(sleaf.at[sel].set(s))
        new_pool = jax.tree.unflatten(treedef, out)
        if scales is None:
            return new_pool
        return new_pool, jax.tree.unflatten(treedef, out_s)


class PagedCacheManager(PagedAllocator):
    """Block-pooled drop-in for ``cache.CacheManager`` at O6.

    Same engine-facing surface — ``.cache`` (the pool tree),
    ``reset_slots(indices, live)``, ``step_extras()`` — plus the
    allocator lifecycle the scheduler drives through its
    ``admission_gate`` / ``on_admit`` / ``on_retire`` hooks.  Slot
    admission allocates the request's whole reservation (so
    ``reset_slots`` has nothing left to do: stale block contents are
    masked, not zeroed — see the module docstring), and retirement
    returns the blocks before the next admission wave runs.

    Under a sharded :class:`~repro.parallel.sharding.PlacementPlan` the
    pool leaves are sharded on their BLOCK axis (rows padded to a device
    multiple by the plan) and the recurrent-state leaves on their batch
    axis; block tables stay replicated.
    """

    def __init__(self, model, batch_size: int, max_seq: int, *,
                 block_size: int = 16, pool_blocks: int = 0,
                 defrag: bool = False, placement=None,
                 kv_dtype: str = "bf16"):
        super().__init__(batch_size, max_seq, block_size=block_size,
                         pool_blocks=pool_blocks, defrag=defrag)
        self.model = model
        self.placement = placement
        self.plan = BlockPagingPlan(
            model, batch_size, max_seq, self.block_size, self.pool_blocks,
            row_multiple=placement.n_devices if placement is not None else 1,
            kv_dtype=kv_dtype)
        pool, self._treedef = self.plan.init_pool(model)
        # Narrow pools carry their per-block scales as a sibling subtree
        # of the SAME treedef: ``.cache`` becomes {"pool", "scale"} and
        # the engine threads the bundle opaquely (it is just a pytree).
        if self.plan.quantized:
            self.cache = {"pool": pool,
                          "scale": self.plan.scales_for_pool(pool)}
        else:
            self.cache = pool
        if placement is not None and placement.sharded:
            self.cache = jax.device_put(self.cache,
                                        self.pool_shardings(placement))
        self._state_zero = None
        self._tables_dev = None     # cached device copy of the tables

    @property
    def kv_dtype(self) -> str:
        return self.plan.kv_dtype

    def _split_cache(self):
        """(pool tree, scale tree-or-None) view of ``.cache``."""
        if self.plan.quantized:
            return self.cache["pool"], self.cache["scale"]
        return self.cache, None

    def _join_cache(self, pool, scales) -> None:
        self.cache = ({"pool": pool, "scale": scales}
                      if self.plan.quantized else pool)

    # -- step inputs ---------------------------------------------------------
    @property
    def geometry(self) -> dict:
        """Pool geometry (block size / blocks-per-seq / pool rows /
        per-token bytes) — what the KV-bytes accounting in
        ``benchmarks/serving_ladder.py`` and ad-hoc tooling consume
        instead of reaching into the plan."""
        return self.plan.geometry

    def pool_shardings(self, placement):
        """Sharding tree for the pool: every leaf sharded at its plan
        axis — the pool-row axis for paged leaves, the batch axis for
        recurrent-state leaves (both sit at ``bax``).  Scale leaves
        shard on the same pool-row axis (their other dims are keepdims
        1s); the scalar placeholders stay replicated."""
        pool_sh = jax.tree.unflatten(self._treedef, [
            placement.axis(bax) for bax, _p in self.plan.plans])
        if not self.plan.quantized:
            return pool_sh
        scale_sh = jax.tree.unflatten(self._treedef, [
            placement.axis(bax) if sx is not None else placement.replicated
            for (bax, _p), sx in zip(self.plan.plans,
                                     self.plan.scale_axes)])
        return {"pool": pool_sh, "scale": scale_sh}

    def step_extras(self) -> tuple:
        """Per-tick step inputs beyond (params, cache, tokens, positions,
        seeds): the block tables, as a CACHED device array.  Tables only
        change at admission/retirement/compaction — those paths
        invalidate — so steady-state decode ticks re-use one upload
        instead of paying a host->device transfer per tick."""
        if self._tables_dev is None:
            if self.placement is not None and self.placement.sharded:
                self._tables_dev = jax.device_put(
                    self.tables, self.placement.replicated)
            else:
                self._tables_dev = jnp.asarray(self.tables)
        return (self._tables_dev,)

    def admit_slot(self, i: int, req) -> None:
        super().admit_slot(i, req)
        self._tables_dev = None

    def grow_slot(self, i: int, total_tokens: int) -> int:
        added = super().grow_slot(i, total_tokens)
        if added:
            self._tables_dev = None
        return added

    def release_slot(self, i: int, req=None) -> None:
        super().release_slot(i, req)
        self._tables_dev = None

    def reset_slots(self, indices: list, live: list) -> None:
        """Admission reset under paging.

        Paged (sequence-axis) leaves need NO zeroing: the slots in
        ``indices`` had their tables rebuilt by ``admit_slot`` and every
        stale position is masked before the softmax.  Recurrent-STATE
        leaves (RWKV wkv / Mamba conv+ssm — per-slot, no sequence axis)
        are different: state is carried, not masked, so the previous
        tenant's state would leak straight into the new request's first
        step.  Those leaves get the O5-style packed one-call zeroing.
        """
        if not indices or all(paged for _, paged in self.plan.plans):
            return
        if self._state_zero is None:
            from repro.serving.cache import make_packed_zero

            self._state_zero = make_packed_zero(
                [bax for bax, _ in self.plan.plans],
                skip=[paged for _, paged in self.plan.plans])
        pool, scales = self._split_cache()
        pool = self._state_zero(pool, jnp.asarray(indices, jnp.int32))
        self._join_cache(pool, scales)

    def insert_slot(self, i: int, state) -> None:
        """Install an externally prefilled batch-1 DENSE cache tree into
        slot ``i``'s pool blocks (the INSERT phase of
        prefill->insert->generate).  Paged leaves pad their sequence axis
        to the table horizon (nb*T), fold it to (nb, T) and scatter
        through slot ``i``'s block table — ``place``/``admit`` rebuilt
        the table before this runs, and NULL entries past the reservation
        absorb the padded tail into the write-garbage NULL row.
        Recurrent-state leaves copy the batch-1 slice over slot ``i``.

        Narrow pools quantize each folded block with a fresh absmax
        scale (the dense prefill state is zero past the prompt, so no
        masking is needed) and install the scales alongside."""
        nb, T = self.plan.nb, self.plan.T
        row = jnp.asarray(self.tables[i], jnp.int32)        # (nb,)
        pool, scales = self._split_cache()
        leaves, treedef = jax.tree.flatten(pool)
        scale_leaves = (jax.tree.leaves(scales) if scales is not None
                        else [None] * len(leaves))
        st_leaves = jax.tree.leaves(state)
        assert len(leaves) == len(st_leaves), "prefill state tree drift"
        out, out_s = [], []
        for leaf, sleaf, st, (bax, paged), sx in zip(
                leaves, scale_leaves, st_leaves, self.plan.plans,
                self.plan.scale_axes):
            if not paged:
                st0 = jnp.take(st, 0, axis=bax).astype(leaf.dtype)
                sel = (slice(None),) * bax + (i,)
                out.append(leaf.at[sel].set(st0))
                out_s.append(sleaf)
                continue
            st0 = jnp.take(st, 0, axis=bax)
            pad = nb * T - st0.shape[bax]         # seq axis now at bax
            if pad:
                widths = [(0, 0)] * st0.ndim
                widths[bax] = (0, pad)
                st0 = jnp.pad(st0, widths)
            folded = st0.reshape(
                st0.shape[:bax] + (nb, T) + st0.shape[bax + 1:])
            sel = (slice(None),) * bax + (row,)
            if scales is None:
                out.append(leaf.at[sel].set(folded.astype(leaf.dtype)))
                out_s.append(sleaf)
                continue
            s = kvquant.block_scale(folded, sx, self.plan.kv_dtype)
            q = kvquant.quantize(folded, s, self.plan.kv_dtype)
            out.append(leaf.at[sel].set(q))
            out_s.append(sleaf.at[sel].set(s))
        new_scales = (jax.tree.unflatten(treedef, out_s)
                      if scales is not None else None)
        self._join_cache(jax.tree.unflatten(treedef, out), new_scales)
        self._tables_dev = None

    def compact(self) -> None:
        """Copy-on-admit defrag: relocate every held block to the lowest
        free ids, rewriting tables and physically copying pool rows.
        Optional — correctness never needs it (block ids are fully
        virtualized); it keeps the live set dense so a future pool-shrink
        or sequence-sharded gather touches a compact prefix."""
        held = sorted({b for row, n in zip(self.tables, self._held)
                       for b in row[:n].tolist()})
        want = list(range(1, len(held) + 1))
        moves = {old: new for old, new in zip(held, want) if old != new}
        if not moves:
            return
        src = jnp.asarray(list(moves.keys()), jnp.int32)
        dst = jnp.asarray(list(moves.values()), jnp.int32)
        pool, scales = self._split_cache()

        def move_rows(tree):
            # relocate pool rows; scale rows ride along (same bax), and
            # non-paged leaves / scalar placeholders are left alone.
            leaves, moved = jax.tree.leaves(tree), []
            for leaf, (bax, paged) in zip(leaves, self.plan.plans):
                if not paged or leaf.ndim == 0:
                    moved.append(leaf)
                    continue
                sel_src = (slice(None),) * bax + (src,)
                sel_dst = (slice(None),) * bax + (dst,)
                moved.append(leaf.at[sel_dst].set(leaf[sel_src]))
            return jax.tree.unflatten(self._treedef, moved)

        pool = move_rows(pool)
        if scales is not None:
            scales = move_rows(scales)
        self._join_cache(pool, scales)
        remap = np.vectorize(lambda b: moves.get(int(b), int(b)))
        self.tables = remap(self.tables).astype(np.int32)
        self.allocator.rebuild(len(held))
        self._tables_dev = None
