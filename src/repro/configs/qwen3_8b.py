"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936, qk-norm. [hf:Qwen/Qwen3-8B; hf]"""
import dataclasses
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12288, vocab=151_936, head_dim=128, qk_norm=True,
)

def smoke() -> ArchConfig:
    return dataclasses.replace(
        FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, q_chunk=32, loss_chunk=32, remat=False)
