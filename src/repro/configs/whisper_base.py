"""whisper-base [audio]: 6L enc + 6L dec, d_model=512 8H d_ff=2048
vocab=51865, enc-dec; conv frontend STUBBED (input_specs supplies frame
embeddings). [arXiv:2212.04356; unverified]"""
import dataclasses
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51_865, frontend="audio_frames",
)

def smoke() -> ArchConfig:
    return dataclasses.replace(
        FULL, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab=256, q_chunk=32,
        loss_chunk=32, remat=False)
