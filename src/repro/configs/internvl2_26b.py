"""internvl2-26b [vlm]: InternLM2-20B backbone 48L d_model=6144 48H
(GQA kv=8) d_ff=16384 vocab=92553; InternViT frontend STUBBED
(input_specs supplies patch embeddings). [arXiv:2404.16821; hf]"""
import dataclasses
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92_553, head_dim=128,
    frontend="vision_patches", n_prefix=256,
)

def smoke() -> ArchConfig:
    return dataclasses.replace(
        FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, n_prefix=8, q_chunk=32, loss_chunk=32,
        remat=False)
