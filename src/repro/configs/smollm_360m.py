"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152 (llama-arch small). [hf:HuggingFaceTB/SmolLM-360M; hf]"""
import dataclasses
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_ff=2560, vocab=49_152,
)

def smoke() -> ArchConfig:
    return dataclasses.replace(
        FULL, n_layers=2, d_model=60, n_heads=3, n_kv_heads=1, head_dim=20,
        d_ff=128, vocab=256, q_chunk=32, loss_chunk=32, remat=False)
