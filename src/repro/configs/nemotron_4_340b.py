"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000, squared-ReLU MLP (no gating). [arXiv:2402.16819; unverified]"""
import dataclasses
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
    d_ff=73728, vocab=256_000, head_dim=192, mlp_kind="relu2",
    fsdp_over_pod=True,
)

def smoke() -> ArchConfig:
    return dataclasses.replace(
        FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, q_chunk=32, loss_chunk=32, remat=False,
        fsdp_over_pod=False)
