"""Config registry: ``--arch <id>`` resolution for all assigned archs."""

from repro.configs import (
    internvl2_26b,
    llama4_scout_17b_a16e,
    mamba2_2p7b,
    mistral_large_123b,
    nemotron_4_340b,
    qwen3_8b,
    qwen3_moe_30b_a3b,
    rwkv6_3b,
    smollm_360m,
    whisper_base,
    zamba2_2p7b,
)
from repro.configs.base import (
    ArchConfig,
    SHAPES,
    ShapeConfig,
    applicable_shapes,
    model_flops,
)

_MODULES = {
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "zamba2-2.7b": zamba2_2p7b,
    "rwkv6-3b": rwkv6_3b,
    "mamba2-2.7b": mamba2_2p7b,
    "mistral-large-123b": mistral_large_123b,
    "nemotron-4-340b": nemotron_4_340b,
    "smollm-360m": smollm_360m,
    "qwen3-8b": qwen3_8b,
    "whisper-base": whisper_base,
    "internvl2-26b": internvl2_26b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    return _MODULES[name].FULL


def get_smoke(name: str) -> ArchConfig:
    return _MODULES[name].smoke()
