"""rwkv6-3b [ssm] "Finch": 32L d_model=2560, attention-free WKV6 with
data-dependent decay, channel-mix d_ff=8960, vocab=65536.
[arXiv:2404.05892; hf]"""
import dataclasses
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=8960, vocab=65_536, rwkv_head_dim=64,
)

def smoke() -> ArchConfig:
    return dataclasses.replace(
        FULL, n_layers=2, d_model=64, d_ff=128, vocab=256,
        rwkv_head_dim=16, q_chunk=32, loss_chunk=32, remat=False)
