"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) expert d_ff=768
vocab=151936, MoE 128 experts top-8, qk-norm (Qwen3 family).
[hf:Qwen/Qwen3-30B-A3B; hf]"""
import dataclasses
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab=151_936,
    n_experts=128, top_k=8, expert_d_ff=768, qk_norm=True,
)

def smoke() -> ArchConfig:
    return dataclasses.replace(
        FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=48, vocab=256, n_experts=8, top_k=2, expert_d_ff=48,
        q_chunk=32, loss_chunk=32, remat=False)
