"""zamba2-2.7b [hybrid]: 54 Mamba2 layers d_model=2560 + shared attention
block (32H MHA, d_ff=10240) applied every 6 layers; ssm_state=64;
vocab=32000. [arXiv:2411.15242; hf]"""
import dataclasses
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32_000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, conv_width=4,
    attn_every=6,
)

def smoke() -> ArchConfig:
    return dataclasses.replace(
        FULL, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, ssm_state=16, ssm_head_dim=32, attn_every=2,
        q_chunk=32, loss_chunk=32, remat=False)
