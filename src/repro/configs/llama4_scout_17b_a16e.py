"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + shared expert (early-fusion family).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
import dataclasses
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202_048,
    n_experts=16, top_k=1, expert_d_ff=8192, shared_expert=True,
)

def smoke() -> ArchConfig:
    return dataclasses.replace(
        FULL, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=256, n_experts=4, expert_d_ff=96, q_chunk=32,
        loss_chunk=32, remat=False)
