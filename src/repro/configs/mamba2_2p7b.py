"""mamba2-2.7b [mamba]: pure SSD stack, 64L d_model=2560, head_dim=64,
ssm_state=128, expand=2 — attention-free, O(1) decode state per slot.
[arXiv:2405.21060; hf]"""
import dataclasses
from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="mamba2-2.7b", family="mamba",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50_288,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, conv_width=4,
)

def smoke() -> ArchConfig:
    return dataclasses.replace(
        FULL, n_layers=4, d_model=64, vocab=256,
        ssm_state=16, ssm_head_dim=32,
        q_chunk=32, loss_chunk=32, remat=False)
