"""Architecture + run configuration.

One ``ArchConfig`` per assigned architecture lives in ``repro/configs/<id>.py``
with the exact published dimensions; ``smoke()`` returns a reduced config of
the same family for CPU tests.  ``ShapeConfig`` captures the assigned
input-shape sets (train_4k / prefill_32k / decode_32k / long_500k).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.optlevel import BestEffortConfig


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | mamba | hybrid
                                 # | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                 # 0 => attention-free (rwkv)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // n_heads

    # MoE --------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0         # d_ff of each expert (d_ff then = shared/dense)
    shared_expert: bool = False  # llama4-style always-on shared expert
    capacity_factor: float = 1.25

    # SSM / hybrid -------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    attn_every: int = 0          # hybrid: shared attn block every N ssm layers

    # RWKV ---------------------------------------------------------------
    rwkv_head_dim: int = 64

    # Attention flavor -----------------------------------------------------
    qk_norm: bool = False
    mlp_kind: str = "swiglu"     # swiglu | relu2 (nemotron squared-ReLU)
    rope_theta: float = 10_000.0

    # Enc-dec (whisper) ----------------------------------------------------
    n_enc_layers: int = 0        # >0 => encoder-decoder backbone

    # Modality frontend stubs ----------------------------------------------
    frontend: str = "none"       # none | audio_frames | vision_patches
    n_prefix: int = 0            # vlm: patch tokens prepended to text

    # Numerics / memory ------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = ""       # "" => legacy `remat` flag; full|dots|none
    cast_params_once: bool = False  # cast f32 params -> compute dtype once
                                 # per step, BEFORE the FSDP gathers (halves
                                 # gather + per-layer weight-read bytes)
    scores_dtype: str = "float32"  # attention logits dtype; "bfloat16"
                                 # halves the S^2 score-tensor HBM traffic
                                 # (softmax still reduces in f32 internally)
    loss_chunk: int = 2048       # chunked cross-entropy (memory cap)
    q_chunk: int = 1024          # chunked attention query block (O1/O2 analog)

    # Distribution (see parallel/sharding.py) ---------------------------------
    moe_local_dispatch: bool = False  # per-DP-group MoE dispatch (a2a
                                 # combine instead of (T,d) all-reduce)
    microbatch: int = 0          # >1: grad-accumulation microbatches per
                                 # step (bounds activation memory; the
                                 # metric twin lowers microbatch=0 since
                                 # accumulation only reschedules the work)
    fsdp_over_pod: bool = False  # ZeRO the pod axis too (123B/340B class)
    seq_shard_decode: bool = True  # shard long KV/seq over `data` at decode

    # Cost-twin lowering (see launch/dryrun.py): unroll every loop so
    # XLA cost analysis counts true trip counts.
    unroll_layers: bool = False

    # Best-effort ladder (paper) ------------------------------------------
    best_effort: BestEffortConfig = dataclasses.field(
        default_factory=BestEffortConfig
    )

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived ----------------------------------------------------------
    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family in ("ssm", "mamba")

    @property
    def subquadratic(self) -> bool:
        return self.family in ("ssm", "mamba", "hybrid")

    def n_params(self) -> float:
        """Total parameter count (embedding + blocks + head)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        emb = 2 * V * d  # untied in/out
        if self.family == "ssm":   # rwkv6
            per = _rwkv6_block_params(self)
            return emb + L * per
        if self.family == "mamba":
            return emb + L * _mamba2_block_params(self)
        if self.family == "hybrid":
            return emb + _zamba2_params(self)
        per = _attn_params(self) + _mlp_params(self)
        if self.n_experts:
            per = _attn_params(self) + _moe_params(self)
        total = L * per
        if self.is_encdec:
            enc = self.n_enc_layers * (_attn_params(self) + _mlp_params(self))
            dec_cross = self.n_layers * _attn_params(self)  # cross-attn
            total = total + enc + dec_cross
        return emb + total

    def n_active_params(self) -> float:
        """Active params per token (= total for dense)."""
        if not self.n_experts:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        act_moe = self.top_k * 3 * d * self.expert_d_ff + d * self.n_experts
        if self.shared_expert:
            act_moe += 3 * d * self.d_ff
        per = _attn_params(self) + act_moe
        return 2 * self.vocab * d + L * per


def _attn_params(c: ArchConfig) -> float:
    dh = c.head_dim
    return (
        c.d_model * c.n_heads * dh            # q
        + 2 * c.d_model * c.n_kv_heads * dh   # k, v
        + c.n_heads * dh * c.d_model          # o
        + 2 * c.d_model                       # norms
    )


def _mlp_params(c: ArchConfig) -> float:
    if c.mlp_kind == "relu2":
        return 2 * c.d_model * c.d_ff
    return 3 * c.d_model * c.d_ff             # swiglu


def _moe_params(c: ArchConfig) -> float:
    per_exp = 3 * c.d_model * c.expert_d_ff
    total = c.n_experts * per_exp + c.d_model * c.n_experts  # + router
    if c.shared_expert:
        total += 3 * c.d_model * c.d_ff
    return total


def _rwkv6_block_params(c: ArchConfig) -> float:
    d = c.d_model
    tm = 5 * d * d + 6 * d + 2 * (d * 32 + 32 * 5 * d)  # r,k,v,g,o + ddlerp lora
    cm = 2 * d * c.d_ff + d * d                        # channel mix (k,v,r)
    return tm + cm + 4 * d


def _mamba2_block_params(c: ArchConfig) -> float:
    d = c.d_model
    d_in = c.ssm_expand * d
    nheads = d_in // c.ssm_head_dim
    return (
        d * (2 * d_in + 2 * c.ssm_state + nheads)  # in_proj
        + c.conv_width * (d_in + 2 * c.ssm_state)  # conv
        + 3 * nheads                               # A, D, dt_bias
        + d_in * d + 2 * d                         # out_proj + norms
    )


def _zamba2_params(c: ArchConfig) -> float:
    d = c.d_model
    per_mamba = _mamba2_block_params(c)
    n_apps = c.n_layers // max(1, c.attn_every)
    shared = _attn_params(c) + _mlp_params(c)
    proj = n_apps * (2 * d * d)  # per-application down-projections
    return c.n_layers * per_mamba + shared + proj


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper (LM shapes: seq_len x global_batch).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list:
    """The assigned shape cells for one arch (skips noted in DESIGN.md)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        names.append("long_500k")   # needs sub-quadratic attention
    return [SHAPES[n] for n in names]


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS per step: 6*N*D train (N_active for MoE), 2*N*D inference
    (+ attention context flops for decode against the cache)."""
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one new token per sequence + KV-context reads as flops
    tokens = shape.global_batch
    attn_ctx = 0.0
    if cfg.n_heads:
        attn_dim = cfg.n_heads * cfg.head_dim
        layers = cfg.n_layers if not cfg.is_encdec else cfg.n_layers * 2
        if cfg.family == "hybrid":
            layers = cfg.n_layers // max(1, cfg.attn_every)
        attn_ctx = 4.0 * layers * shape.seq_len * attn_dim * tokens
    return 2.0 * n_active * tokens + attn_ctx
