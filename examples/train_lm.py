"""End-to-end training example: a ~smoke-scale qwen3-family model for a
few hundred steps on CPU, with checkpointing and an injected fault to
demonstrate restore-and-replay.

  PYTHONPATH=src python examples/train_lm.py [--steps 200]

(The identical driver trains the full configs on a real mesh — see
``repro/launch/train.py``; this example keeps CPU wall time sane.)
"""

import argparse
import tempfile

from repro.configs import get_smoke
from repro.configs.base import ShapeConfig
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="smollm-360m")
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    shape = ShapeConfig("example", seq_len=128, global_batch=8,
                        kind="train")
    with tempfile.TemporaryDirectory() as d:
        out = train(cfg, shape, steps=args.steps, ckpt_dir=d,
                    ckpt_every=50, seed=0, log_every=10)
    losses = out["losses"]
    first, last = losses[0][1], losses[-1][1]
    print(f"\ntrained {out['steps']} steps in {out['wall_s']:.0f}s")
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first - 0.2 else 'check hyperparams'})")


if __name__ == "__main__":
    main()
