"""Serving example: continuous batching across mixed request lengths,
including mid-flight admission (requests arrive while others decode) —
and the serving ladder: the same engine built naive (O0) and fully
refined (O5) generates identical tokens, faster.

  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax

from repro.configs import get_smoke
from repro.core.optlevel import BestEffortConfig, OptLevel
from repro.models import get_model
from repro.serving import DecodeEngine, Request


def drive(engine):
    wave1 = [Request(prompt=[1, 2, 3], max_new_tokens=8),
             Request(prompt=[9, 8, 7, 6], max_new_tokens=5),
             Request(prompt=[4], max_new_tokens=10)]
    for r in wave1:
        engine.submit(r)

    # run a few ticks, then admit a second wave mid-flight
    for _ in range(4):
        engine.step()
    wave2 = [Request(prompt=[5, 5], max_new_tokens=6),
             Request(prompt=[2, 4, 6, 8, 10], max_new_tokens=4)]
    for r in wave2:
        engine.submit(r)

    t0 = time.time()
    finished = engine.run()
    return finished, time.time() - t0


def main():
    cfg = get_smoke("qwen3-8b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    results = {}
    for level in (OptLevel.O0, OptLevel.O5):
        engine = DecodeEngine(model, params, batch_size=4, max_seq=48,
                              config=BestEffortConfig(level=level))
        finished, wall = drive(engine)
        results[level] = {r.rid: r.generated for r in finished}
        print(f"O{int(level)}: {len(finished)} requests in "
              f"{engine.n_steps} ticks / {wall:.2f}s "
              f"(continuous batching, batch={engine.B})")

    same = results[OptLevel.O0] == results[OptLevel.O5]
    print(f"naive and refined engines generated identical tokens: {same}")
    for rid, toks in sorted(results[OptLevel.O5].items()):
        print(f"  req {rid}: -> {toks}")


if __name__ == "__main__":
    main()
