"""Serving example: continuous batching across mixed request lengths,
including mid-flight admission (requests arrive while others decode).

  PYTHONPATH=src python examples/serve_lm.py
"""

import jax

from repro.configs import get_smoke
from repro.models import get_model
from repro.serving import DecodeEngine, Request


def main():
    cfg = get_smoke("qwen3-8b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = DecodeEngine(model, params, batch_size=4, max_seq=48)

    wave1 = [Request(prompt=[1, 2, 3], max_new_tokens=8),
             Request(prompt=[9, 8, 7, 6], max_new_tokens=5),
             Request(prompt=[4], max_new_tokens=10)]
    for r in wave1:
        engine.submit(r)

    # run a few ticks, then admit a second wave mid-flight
    for _ in range(4):
        engine.step()
    wave2 = [Request(prompt=[5, 5], max_new_tokens=6),
             Request(prompt=[2, 4, 6, 8, 10], max_new_tokens=4)]
    for r in wave2:
        engine.submit(r)

    finished = engine.run()
    print(f"{len(finished)} requests finished in {engine.n_steps} ticks "
          f"(continuous batching, batch={engine.B})")
    for r in sorted(finished, key=lambda r: r.rid):
        print(f"  req {r.rid}: prompt={r.prompt} -> {r.generated}")


if __name__ == "__main__":
    main()
