"""Full MachSuite refinement demo, driven by the closed-loop autotuner:
every kernel tuned unattended (the paper's Fig. 12 as a table), the
communication-bound filter verdicts (Table 5), and the final
paper-vs-model comparison.

  PYTHONPATH=src python examples/machsuite_refine.py
"""

from repro.autotune import (KernelModelBackend, autotune, render_rounds,
                            render_summary)
from repro.core import costmodel
from repro.core.optlevel import OptLevel


def main():
    profiles = costmodel.MACHSUITE_PROFILES

    # The closed loop, per kernel: measure -> guideline -> apply -> repeat.
    results = {name: autotune(KernelModelBackend(prof))
               for name, prof in sorted(profiles.items())}

    print(f"{'kernel':10s} {'filter':8s} " +
          " ".join(f"{'O' + str(i):>10s}" for i in range(6)) +
          "   final vs CPU")
    print("-" * 92)
    for name, prof in sorted(profiles.items()):
        filt = "REJECT" if results[name].rejected else "accept"
        curve = costmodel.refinement_curve(prof)
        base = curve[0]["system_s"]
        cells = " ".join(
            f"{base / curve[i]['system_s']:>9.1f}x" for i in range(6))
        final = curve[5]["speedup_vs_cpu"]
        print(f"{name:10s} {filt:8s} {cells}   {final:8.1f}x")

    t = costmodel.paper_validation_table()
    agg = t.pop("_aggregate")
    print("\npaper abstract vs this model:")
    print(f"  naive slowdown   paper ~292.5x | model "
          f"{agg['gmean_naive_slowdown']:.1f}x (gmean)")
    mean_sp = sum(r['final_speedup'] for r in t.values()) / len(t)
    print(f"  final speedup    paper  ~34.4x | model {mean_sp:.1f}x (mean)")
    print(f"  improvement      paper 42~29030x | model "
          f"{agg['min_improvement']:.0f}~{agg['max_improvement']:.0f}x")

    print("\nclosed-loop verdicts (autotuner, paper Table 4/5 analog):")
    print(render_summary(list(results.values())))

    print("\nthe refinement *process* on NW (autotuned, round by round):")
    print(render_rounds(results["nw"].to_records()))


if __name__ == "__main__":
    main()
