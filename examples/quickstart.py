"""Quickstart: the paper's best-effort guideline in five minutes.

Walks one MachSuite kernel (AES, the paper's Fig. 4 example) up the
refinement ladder exactly as the paper does: measure the breakdown,
let the guideline pick the next step, apply it, repeat — then shows the
same ladder as *structurally different JAX programs* whose outputs are
identical.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import costmodel
from repro.core.optlevel import OptLevel
from repro.core.refine import refine_modelled
from repro.machsuite import aes


def main():
    print("=" * 72)
    print("1. The paper's refinement loop on AES (analytic FPGA model)")
    print("=" * 72)
    records = refine_modelled(costmodel.MACHSUITE_PROFILES["aes"])
    for r in records:
        b = r.breakdown
        print(f"  O{int(r.level)}: dram={b['dram_s']:.3g}s "
              f"compute={b['compute_s']:.3g}s "
              f"speedup_vs_naive={r.speedup_vs_baseline:8.1f}x")
        print(f"       guideline says -> {r.recommendation}")

    print()
    print("=" * 72)
    print("2. The same ladder as real JAX programs (outputs identical)")
    print("=" * 72)
    rng = np.random.default_rng(0)
    inp = aes.make_inputs(rng, scale=2048 / 64e6)   # 2 KB demo
    ref = aes.oracle(**inp)
    for lvl in OptLevel:
        if lvl > OptLevel.O5:
            break       # O6 (paged serving scratchpad) has no kernel analog
        out = np.asarray(aes.run(lvl, **inp))
        ok = "OK" if np.array_equal(out, ref) else "MISMATCH"
        print(f"  O{int(lvl)} ({lvl.name}): ciphertext[:8]="
              f"{out[:8].tolist()}  {ok}")
    print("\n  (All six levels encrypt identically — the steps are"
          " performance transforms, not semantic ones.)")


if __name__ == "__main__":
    main()
