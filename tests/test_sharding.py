"""Sharder unit tests (single device — spec construction is pure logic)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import Sharder, make_rules


def _mesh(shape, axes):
    return jax.sharding.Mesh(
        __import__("numpy").array(jax.devices() * int(
            __import__("numpy").prod(shape))).reshape(shape)[
                tuple(slice(0, s) for s in shape)], axes)


@pytest.fixture
def mesh44():
    import numpy as np
    devs = np.tile(np.array(jax.devices()[:1]), 16).reshape(4, 4)
    return jax.sharding.Mesh(devs, ("data", "model"))


@pytest.fixture
def mesh_pod():
    import numpy as np
    devs = np.tile(np.array(jax.devices()[:1]), 16).reshape(2, 2, 4)
    return jax.sharding.Mesh(devs, ("pod", "data", "model"))


def test_basic_specs(mesh44):
    s = Sharder(mesh44, make_rules(mesh44))
    assert s.spec(("batch", None), (8, 128)) == P("data", None)
    assert s.spec(("embed", "mlp"), (64, 128)) == P("data", "model")
    assert s.spec((None, None), (3, 5)) == P(None, None)


def test_divisibility_degradation_recorded(mesh44):
    s = Sharder(mesh44, make_rules(mesh44))
    # 15 heads not divisible by model=4 -> degrades to replicated
    assert s.spec(("heads",), (15,)) == P(None)
    assert any(d[0] == "heads" for d in s.degradations)
    # 16 heads fine
    assert s.spec(("heads",), (16,)) == P("model")


def test_pod_batch_mapping(mesh_pod):
    s = Sharder(mesh_pod, make_rules(mesh_pod))
    assert s.spec(("batch", None), (8, 16)) == P(("pod", "data"), None)


def test_fsdp_over_pod(mesh_pod):
    s = Sharder(mesh_pod, make_rules(mesh_pod, fsdp_over_pod=True))
    assert s.spec(("embed", "mlp"), (64, 64)) == P(("pod", "data"), "model")
    s2 = Sharder(mesh_pod, make_rules(mesh_pod, fsdp_over_pod=False))
    assert s2.spec(("embed", "mlp"), (64, 64)) == P("data", "model")


def test_no_axis_reuse_within_spec(mesh44):
    """A mesh axis may shard at most one tensor dim."""
    s = Sharder(mesh44, make_rules(mesh44))
    spec = s.spec(("embed", "embed"), (64, 64))
    flat = []
    for e in spec:
        if isinstance(e, tuple):
            flat += list(e)
        elif e is not None:
            flat.append(e)
    assert len(flat) == len(set(flat))


def test_partial_prefix_degradation(mesh_pod):
    """batch -> (pod, data) degrades to (pod,) when divisible by pod only."""
    s = Sharder(mesh_pod, make_rules(mesh_pod))
    # dim 2: divisible by pod=2, not by pod*data=4
    assert s.spec(("batch",), (2,)) == P("pod")


def test_constrain_rank_mismatch(mesh44):
    import jax.numpy as jnp
    s = Sharder(mesh44, make_rules(mesh44))
    with pytest.raises(ValueError):
        s.constrain(jnp.zeros((4, 4)), "batch")
