"""Pallas kernel sweeps: shapes x dtypes vs pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.optlevel import OptLevel
from repro.kernels.tiled_matmul.ops import matmul, pick_blocks
from repro.kernels.tiled_matmul.ref import matmul_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.paged_attention.ops import (paged_attention,
                                               paged_prefill_attention)
from repro.kernels.paged_attention.ref import (paged_attention_ref,
                                               paged_prefill_attention_ref)
from repro.kernels.rwkv6_wkv.ops import wkv
from repro.kernels.rwkv6_wkv.ref import wkv_ref
from repro.kernels.mamba2_ssd.ops import ssd
from repro.kernels.mamba2_ssd.ref import ssd_ref

KEYS = jax.random.split(jax.random.PRNGKey(42), 8)


# ---------------------------------------------------------------------------
# tiled matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(32, 32, 32), (64, 96, 128),
                                   (128, 64, 32), (48, 80, 112)])
@pytest.mark.parametrize("lvl", range(6))
def test_matmul_levels(shape, lvl):
    M, K, N = shape
    a = jax.random.normal(KEYS[0], (M, K), jnp.float32)
    b = jax.random.normal(KEYS[1], (K, N), jnp.float32)
    ref = matmul_ref(a, b)
    out = matmul(a, b, OptLevel(lvl))
    tol = 3e-2 if lvl >= 5 else 1e-5   # bf16 packing at O5
    rel = float(jnp.max(jnp.abs(out - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < tol, (shape, lvl, rel)


def test_matmul_explicit_blocks():
    a = jax.random.normal(KEYS[2], (64, 64), jnp.float32)
    b = jax.random.normal(KEYS[3], (64, 64), jnp.float32)
    ref = matmul_ref(a, b)
    for blocks in [(16, 16, 16), (32, 64, 16), (64, 64, 64)]:
        out = matmul(a, b, OptLevel.O3, blocks=blocks)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_pick_blocks_vmem_budget():
    from repro.kernels.tiled_matmul.ops import VMEM_BUDGET
    for level in (OptLevel.O2, OptLevel.O4):
        bm, bn, bk = pick_blocks(4096, 4096, 4096, level=level)
        n_buf = 2 if level >= OptLevel.O4 else 1
        assert n_buf * 4 * (bm * bk + bk * bn + bm * bn) <= VMEM_BUDGET
    # O4 blocks never exceed O2 blocks (double buffering halves the budget)
    o2 = pick_blocks(4096, 4096, 4096, level=OptLevel.O2)
    o4 = pick_blocks(4096, 4096, 4096, level=OptLevel.O4)
    assert all(x4 <= x2 for x4, x2 in zip(o4, o2))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def _attn_ref_gqa(q, k, v, causal):
    B, S, H, D = q.shape
    rep = H // k.shape[2]
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    tf = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    out = attention_ref(tf(q), tf(kr), tf(vr), causal=causal)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dims", [(1, 64, 2, 2, 16), (2, 128, 4, 2, 32),
                                  (1, 128, 3, 1, 64)])
def test_flash_attention(dims, causal):
    B, S, H, Hkv, D = dims
    q = jax.random.normal(KEYS[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(KEYS[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(KEYS[2], (B, S, Hkv, D), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    ref = _attn_ref_gqa(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("blocks", [(16, 64), (64, 16), (128, 128)])
def test_flash_attention_block_invariance(blocks):
    bq, bk = blocks
    B, S, H, D = 1, 128, 2, 16
    q = jax.random.normal(KEYS[3], (B, S, H, D), jnp.float32)
    k = jax.random.normal(KEYS[4], (B, S, H, D), jnp.float32)
    v = jax.random.normal(KEYS[5], (B, S, H, D), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    ref = _attn_ref_gqa(q, k, v, True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_flash_attention_bf16():
    B, S, H, D = 1, 64, 2, 32
    q = jax.random.normal(KEYS[0], (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(KEYS[1], (B, S, H, D), jnp.bfloat16)
    v = jax.random.normal(KEYS[2], (B, S, H, D), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    ref = _attn_ref_gqa(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), rtol=0.06, atol=0.03)


def test_flash_attention_gqa_no_repeat_bitwise_matches_repeated():
    """The GQA fix: the per-KV-head grid (k-block index maps pointing at
    the kv group's stream) must be BITWISE identical to feeding the
    kernel explicitly repeated K/V — same per-stream compute, minus the
    H/Hkv materialized copies the old wrapper paid before every call."""
    for B, S, H, Hkv, D in [(2, 64, 4, 2, 16), (1, 128, 6, 2, 32),
                            (2, 64, 4, 1, 16)]:
        q = jax.random.normal(KEYS[0], (B, S, H, D), jnp.float32)
        k = jax.random.normal(KEYS[1], (B, S, Hkv, D), jnp.float32)
        v = jax.random.normal(KEYS[2], (B, S, Hkv, D), jnp.float32)
        rep = H // Hkv
        out = flash_attention(q, k, v, block_q=32, block_k=32)
        ref = flash_attention(q, jnp.repeat(k, rep, 2),
                              jnp.repeat(v, rep, 2),
                              block_q=32, block_k=32)
        assert np.array_equal(np.asarray(out), np.asarray(ref)), (B, H, Hkv)


# ---------------------------------------------------------------------------
# paged decode attention (block-table-aware, gather-free)
# ---------------------------------------------------------------------------

def _paged_case(B, H, KV, D, T, nb, *, extra_rows=2, dtype=jnp.float32,
                seed=1, full_lengths=False):
    """Random pool/tables/lengths with real blocks covering each slot's
    valid prefix and NULL (row 0) entries past it — the allocator's
    invariant.  ``extra_rows`` leaves unreferenced pool rows (the padded
    rows a sharded placement adds) holding garbage that must not leak."""
    r = np.random.default_rng(seed)
    lengths = (np.full(B, nb * T) if full_lengths
               else r.integers(1, nb * T + 1, B))
    R = 1 + B * nb + extra_rows
    kp = r.normal(size=(R, T, KV, D)).astype(np.float32)
    vp = r.normal(size=(R, T, KV, D)).astype(np.float32)
    tables = np.zeros((B, nb), np.int32)
    free = list(range(1, R))
    r.shuffle(free)
    for b in range(B):
        for j in range(-(-int(lengths[b]) // T)):
            tables[b, j] = free.pop()
    q = r.normal(size=(B, H, D)).astype(np.float32)
    return (jnp.asarray(q, dtype), jnp.asarray(kp, dtype),
            jnp.asarray(vp, dtype), jnp.asarray(tables),
            jnp.asarray(lengths, jnp.int32))


@pytest.mark.parametrize("dims", [
    (3, 4, 2, 16, 4, 8),     # GQA, partial final blocks
    (2, 2, 2, 32, 8, 4),     # MHA
    (1, 3, 1, 16, 4, 3),     # single kv head, odd group
    (4, 8, 2, 16, 16, 2),    # wide groups, big blocks
])
def test_paged_attention_vs_ref(dims):
    q, kp, vp, tables, lengths = _paged_case(*dims)
    out = paged_attention(q, kp, vp, tables, lengths)
    ref = paged_attention_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_paged_attention_full_lengths_and_block_invariance():
    """Full sequences (no partial block) agree with the ref, and the
    same logical content paged at different block sizes agrees with
    itself (block size is layout, not math)."""
    q, kp, vp, tables, lengths = _paged_case(2, 4, 2, 16, 4, 8,
                                             full_lengths=True)
    out = paged_attention(q, kp, vp, tables, lengths)
    ref = paged_attention_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)

    # repage T=4 content into T=8 blocks: dense views identical
    B, nb, T = 2, 8, 4
    dense_k = np.asarray(kp)[np.asarray(tables)].reshape(B, nb * T, 2, 16)
    dense_v = np.asarray(vp)[np.asarray(tables)].reshape(B, nb * T, 2, 16)
    kp2 = np.concatenate([np.zeros((1, 8, 2, 16), np.float32),
                          dense_k.reshape(B * 4, 8, 2, 16)])
    vp2 = np.concatenate([np.zeros((1, 8, 2, 16), np.float32),
                          dense_v.reshape(B * 4, 8, 2, 16)])
    tables2 = np.arange(1, B * 4 + 1, dtype=np.int32).reshape(B, 4)
    out2 = paged_attention(q, jnp.asarray(kp2), jnp.asarray(vp2),
                           jnp.asarray(tables2), lengths)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(out),
                               rtol=2e-4, atol=2e-5)


def test_paged_attention_bf16():
    q, kp, vp, tables, lengths = _paged_case(3, 4, 2, 16, 4, 6,
                                             dtype=jnp.bfloat16)
    out = paged_attention(q, kp, vp, tables, lengths)
    ref = paged_attention_ref(q, kp, vp, tables, lengths)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=0.06, atol=0.03)


@pytest.mark.parametrize("kvd", ["int8", "fp8"])
def test_paged_attention_quantized_matches_dequantized_pool(kvd):
    """A narrow pool + (rows, KV) scale operands: the kernel's in-stream
    dequant applies the SAME expression the gather path uses on its
    dense view, so the output must be bitwise identical to calling the
    kernel on the explicitly pre-dequantized pool with no scales."""
    from repro.serving import kvquant

    q, kp, vp, tables, lengths = _paged_case(3, 4, 2, 16, 4, 6,
                                             dtype=jnp.bfloat16)
    ks = kvquant.block_scale(kp, (1, 3), kvd)
    vs = kvquant.block_scale(vp, (1, 3), kvd)
    kq = kvquant.quantize(kp, ks, kvd)
    vq = kvquant.quantize(vp, vs, kvd)
    out = paged_attention(q, kq, vq, tables, lengths,
                          k_scale=ks[:, 0, :, 0], v_scale=vs[:, 0, :, 0])
    wide = paged_attention(q, kvquant.dequantize(kq, ks),
                           kvquant.dequantize(vq, vs), tables, lengths)
    assert out.dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(out, np.float32),
                          np.asarray(wide, np.float32))
    # and it stays close to the full-precision pool's answer
    ref = paged_attention_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=0.1, atol=0.05)
    # scale operands are validated: wrong shape fails loudly
    with pytest.raises(ValueError, match="scale"):
        paged_attention(q, kq, vq, tables, lengths,
                        k_scale=ks[:, 0, :, 0].T, v_scale=vs[:, 0, :, 0])


def test_paged_attention_null_block_garbage_never_leaks():
    """Mutating the NULL block (row 0) and every unreferenced pool row
    must not change any output — the length mask plus the in-range block
    skip are what make paging safe."""
    q, kp, vp, tables, lengths = _paged_case(3, 4, 2, 16, 4, 6, seed=9)
    out = np.asarray(paged_attention(q, kp, vp, tables, lengths))
    referenced = set()
    for b in range(3):
        for j in range(-(-int(lengths[b]) // 4)):
            referenced.add(int(tables[b, j]))
    kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
    for row in range(kp2.shape[0]):
        if row not in referenced:
            kp2[row] = 1e3
            vp2[row] = -1e3
    out2 = np.asarray(paged_attention(q, jnp.asarray(kp2),
                                      jnp.asarray(vp2), tables, lengths))
    assert np.array_equal(out, out2)


def test_paged_attention_rejects_bad_shapes():
    q, kp, vp, tables, lengths = _paged_case(2, 3, 2, 16, 4, 4)
    with pytest.raises(ValueError, match="multiple"):
        paged_attention(q, kp, vp, tables, lengths)   # 3 heads, 2 kv
    q, kp, vp, tables, lengths = _paged_case(2, 4, 2, 16, 4, 4)
    with pytest.raises(ValueError, match="mismatch"):
        paged_attention(q, kp, vp[..., :8], tables, lengths)


# ---------------------------------------------------------------------------
# paged prefill attention (qlen > 1: the chunked-prefill query mode)
# ---------------------------------------------------------------------------

def _paged_prefill_case(B, H, KV, D, T, nb, Q, *, extra_rows=2,
                        dtype=jnp.float32, seed=3):
    """Random pool/tables with Q consecutive query tokens per slot whose
    K/V are already appended: lengths = start + Q with random starts, so
    final blocks are partially filled and earlier chunks' history is in
    the pool.  Real blocks cover each slot's valid prefix; NULL (row 0)
    past it; ``extra_rows`` unreferenced garbage rows."""
    r = np.random.default_rng(seed)
    starts = r.integers(0, nb * T - Q + 1, B)
    lengths = starts + Q
    R = 1 + B * nb + extra_rows
    kp = r.normal(size=(R, T, KV, D)).astype(np.float32)
    vp = r.normal(size=(R, T, KV, D)).astype(np.float32)
    tables = np.zeros((B, nb), np.int32)
    free = list(range(1, R))
    r.shuffle(free)
    for b in range(B):
        for j in range(-(-int(lengths[b]) // T)):
            tables[b, j] = free.pop()
    q = r.normal(size=(B, Q, H, D)).astype(np.float32)
    return (jnp.asarray(q, dtype), jnp.asarray(kp, dtype),
            jnp.asarray(vp, dtype), jnp.asarray(tables),
            jnp.asarray(lengths, jnp.int32))


@pytest.mark.parametrize("dims", [
    (3, 4, 2, 16, 4, 8, 5),    # GQA, Q coprime with T: rows cross blocks
    (2, 2, 2, 32, 8, 4, 8),    # MHA, Q == T
    (1, 3, 1, 16, 4, 3, 2),    # single kv head, odd group
    (2, 8, 2, 16, 16, 2, 11),  # big blocks, Q > T/2, partial final block
])
def test_paged_prefill_attention_vs_ref(dims):
    q, kp, vp, tables, lengths = _paged_prefill_case(*dims)
    out = paged_prefill_attention(q, kp, vp, tables, lengths)
    ref = paged_prefill_attention_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


from tests._hypothesis_compat import given, settings, st  # noqa: E402


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_paged_prefill_random_shapes(seed):
    """Random (qlen, kv_len, block size, GQA group) draws against the
    dense oracle — the shapes the chunked-prefill engine actually emits
    (arbitrary starts, partial final blocks, ragged per-slot lengths)."""
    r = np.random.default_rng(seed)
    B = int(r.integers(1, 4))
    KV = int(r.integers(1, 3))
    G = int(r.integers(1, 4))
    D = int(r.choice([8, 16]))
    T = int(r.integers(2, 9))
    nb = int(r.integers(2, 6))
    Q = int(r.integers(1, min(8, nb * T) + 1))
    q, kp, vp, tables, lengths = _paged_prefill_case(
        B, KV * G, KV, D, T, nb, Q, seed=seed + 1)
    out = paged_prefill_attention(q, kp, vp, tables, lengths)
    ref = paged_prefill_attention_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5,
        err_msg=f"B={B} KV={KV} G={G} D={D} T={T} nb={nb} Q={Q}")


def test_paged_prefill_qlen1_bitwise_matches_decode():
    """Q == 1 must degenerate BIT-EXACTLY to the decode kernel: the
    engine's bit-identity contract rides on the prefill path's final
    token computing the same floats the per-token path would."""
    for dims in [(3, 4, 2, 16, 4, 8), (2, 2, 2, 32, 8, 4),
                 (1, 3, 1, 16, 4, 3)]:
        q, kp, vp, tables, lengths = _paged_case(*dims, seed=5)
        dec = paged_attention(q, kp, vp, tables, lengths)
        pre = paged_prefill_attention(q[:, None], kp, vp, tables, lengths)
        assert np.array_equal(np.asarray(pre[:, 0]), np.asarray(dec)), dims


def test_paged_prefill_null_and_future_garbage_never_leaks():
    """Mutating every pool row outside each slot's valid prefix — NULL,
    unreferenced rows, AND positions past ``lengths`` inside referenced
    final blocks — must not change any output row: the per-row causal
    limit is what makes writing a whole chunk before reading it safe."""
    q, kp, vp, tables, lengths = _paged_prefill_case(3, 4, 2, 16, 4, 6, 5,
                                                     seed=11)
    out = np.asarray(paged_prefill_attention(q, kp, vp, tables, lengths))
    kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
    T = kp2.shape[1]
    referenced = {}
    for b in range(3):
        for j in range(-(-int(lengths[b]) // T)):
            row = int(tables[b, j])
            valid = min(int(lengths[b]) - j * T, T)
            referenced[row] = max(referenced.get(row, 0), valid)
    for row in range(kp2.shape[0]):
        vfrom = referenced.get(row, 0)
        kp2[row, vfrom:] = 1e3
        vp2[row, vfrom:] = -1e3
    out2 = np.asarray(paged_prefill_attention(q, jnp.asarray(kp2),
                                              jnp.asarray(vp2), tables,
                                              lengths))
    assert np.array_equal(out, out2)


def test_paged_prefill_bf16():
    q, kp, vp, tables, lengths = _paged_prefill_case(2, 4, 2, 16, 4, 6, 5,
                                                     dtype=jnp.bfloat16)
    out = paged_prefill_attention(q, kp, vp, tables, lengths)
    ref = paged_prefill_attention_ref(q, kp, vp, tables, lengths)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=0.06, atol=0.03)


def test_flash_attention_rectangular_prefill_offset():
    """S_kv > S (chunked prefill against a dense cache): the causal mask
    shifts by ``S_kv - S`` — query row qi attends kv positions
    <= offset + qi — and S_kv == S stays the plain square case."""
    B, H, Hkv, D = 2, 4, 2, 16
    for S_kv, S in [(64, 16), (48, 48), (96, 32)]:
        q = jax.random.normal(KEYS[0], (B, S, H, D), jnp.float32)
        k = jax.random.normal(KEYS[1], (B, S_kv, Hkv, D), jnp.float32)
        v = jax.random.normal(KEYS[2], (B, S_kv, Hkv, D), jnp.float32)
        out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
        # dense oracle with the shifted causal mask
        rep = H // Hkv
        kr = jnp.repeat(k, rep, axis=2).transpose(0, 2, 1, 3)
        vr = jnp.repeat(v, rep, axis=2).transpose(0, 2, 1, 3)
        qt = q.transpose(0, 2, 1, 3)
        s = jnp.einsum("bhqd,bhkd->bhqk", qt, kr) / (D ** 0.5)
        mask = (jnp.arange(S_kv)[None, :]
                <= (S_kv - S) + jnp.arange(S)[:, None])
        s = jnp.where(mask[None, None], s, -1e30)
        ref = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), vr)
        ref = ref.transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5,
                                   err_msg=f"S_kv={S_kv} S={S}")


# ---------------------------------------------------------------------------
# rwkv6 wkv
# ---------------------------------------------------------------------------

def _wkv_case(B, S, H, N, chunk, with_state, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(B * S + H + N), 6)
    r = (jax.random.normal(ks[0], (B, S, H, N)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, S, H, N)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (B, S, H, N)) * 0.5).astype(dtype)
    lw = (-jnp.abs(jax.random.normal(ks[3], (B, S, H, N))) * 0.3).astype(dtype)
    u = (jax.random.normal(ks[4], (H, N)) * 0.1).astype(dtype)
    s0 = (jax.random.normal(ks[5], (B, H, N, N)) * 0.2
          if with_state else jnp.zeros((B, H, N, N))).astype(jnp.float32)

    y, sf = wkv(r, k, v, lw, u, init_state=s0, chunk=chunk)
    flat = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, N)
    u_f = jnp.broadcast_to(u, (B, H, N)).reshape(B * H, N)
    yr, sr = wkv_ref(flat(r), flat(k), flat(v), flat(lw), u_f,
                     s0.reshape(B * H, N, N))
    yr = yr.reshape(B, H, S, N).transpose(0, 2, 1, 3)
    sr = sr.reshape(B, H, N, N)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("case", [
    (1, 32, 1, 8, 8, False), (2, 64, 3, 16, 16, True),
    (1, 64, 2, 16, 64, False),    # chunk == S
    (2, 48, 2, 8, 16, True),      # S % 32 != 0 path
])
def test_wkv_sweep(case):
    _wkv_case(*case)


def test_wkv_bf16():
    _wkv_case(1, 32, 2, 8, 8, False, dtype=jnp.bfloat16)


def test_wkv_matches_model_chunked():
    """Kernel == the model's chunked implementation (not just the oracle)."""
    from repro.models.rwkv6 import wkv_chunked
    B, S, H, N = 2, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    r = jax.random.normal(ks[0], (B, S, H, N)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, N)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, N)) * 0.5
    lw = -jnp.abs(jax.random.normal(ks[3], (B, S, H, N))) * 0.3
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    y1, s1 = wkv(r, k, v, lw, u, chunk=16)
    y2, s2 = wkv_chunked(r, k, v, lw, u, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# mamba2 ssd
# ---------------------------------------------------------------------------

def _ssd_case(B, S, H, P, N, chunk, with_state):
    ks = jax.random.split(jax.random.PRNGKey(B + S + H + P + N), 6)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bs = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cs = jax.random.normal(ks[4], (B, S, N)) * 0.5
    s0 = (jax.random.normal(ks[5], (B, H, P, N)) * 0.2
          if with_state else jnp.zeros((B, H, P, N))).astype(jnp.float32)
    y, sf = ssd(x, dt, A, Bs, Cs, init_state=s0, chunk=chunk)
    yr, sr = ssd_ref(x, dt, A, Bs, Cs, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr),
                               rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("case", [
    (1, 32, 2, 8, 8, 8, False), (2, 64, 4, 16, 8, 16, True),
    (1, 64, 1, 8, 16, 64, False),   # chunk == S
    (2, 40, 2, 8, 8, 8, True),      # odd chunk count
])
def test_ssd_sweep(case):
    _ssd_case(*case)


def test_ssd_matches_model_chunked():
    from repro.models.mamba2 import ssd_chunked
    B, S, H, P, N = 2, 64, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bs = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cs = jax.random.normal(ks[4], (B, S, N)) * 0.5
    y1, s1 = ssd(x, dt, A, Bs, Cs, chunk=16)
    y2, s2 = ssd_chunked(x, dt, A, Bs, Cs, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-3, atol=1e-4)
