"""Data pipeline: determinism, seek, prefetch ordering, modality extras."""

import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ShapeConfig
from repro.data.pipeline import Prefetcher, SyntheticLM, make_pipeline


def test_batch_at_deterministic():
    ds = SyntheticLM(vocab=1000, seq_len=64, global_batch=4, seed=3)
    a = ds.batch_at(17)
    b = ds.batch_at(17)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))


def test_different_steps_differ():
    ds = SyntheticLM(vocab=1000, seq_len=64, global_batch=4, seed=3)
    a = ds.batch_at(1)["tokens"]
    b = ds.batch_at(2)["tokens"]
    assert not np.array_equal(np.asarray(a), np.asarray(b))


def test_labels_are_shifted_tokens():
    ds = SyntheticLM(vocab=50, seq_len=16, global_batch=2, seed=0)
    b = ds.batch_at(0)
    t = np.asarray(b["tokens"])
    l = np.asarray(b["labels"])
    np.testing.assert_array_equal(l[:, :-1], t[:, 1:])


def test_row_sharding_independence():
    """Row r of the global batch is identical no matter which host range
    materializes it (the make_array_from_callback contract)."""
    ds = SyntheticLM(vocab=100, seq_len=32, global_batch=8, seed=5)
    full = ds._tokens_at(3, 0, 8)
    part = ds._tokens_at(3, 4, 8)
    np.testing.assert_array_equal(full[4:], part)


def test_prefetch_order_and_seek():
    ds = SyntheticLM(vocab=100, seq_len=16, global_batch=2, seed=1)
    pf = Prefetcher(ds, start_step=10, depth=3)
    try:
        for s in (10, 11, 12, 13):
            b = pf.get(s)
            np.testing.assert_array_equal(
                np.asarray(b["tokens"]),
                np.asarray(ds.batch_at(s)["tokens"]))
        with pytest.raises(RuntimeError):
            pf.get(99)   # out-of-order detection
    finally:
        pf.close()


def test_vlm_and_audio_extras():
    shape = ShapeConfig("t", 32, 2, "train")
    vlm = get_smoke("internvl2-26b")
    pipe = make_pipeline(vlm, shape, seed=0)
    b = pipe.get(0)
    pipe.close()
    assert "patches" in b
    assert b["patches"].shape == (2, vlm.n_prefix, vlm.d_model)
    assert b["tokens"].shape == (2, 32 - vlm.n_prefix)

    aud = get_smoke("whisper-base")
    pipe = make_pipeline(aud, shape, seed=0)
    b = pipe.get(0)
    pipe.close()
    assert "frames" in b
    assert b["frames"].shape == (2, 32, aud.d_model)
