"""End-to-end system tests on the host device: train loop with checkpoint
restart determinism, fault-injected recovery, and the serve driver."""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ShapeConfig
from repro.launch.train import train
from repro.launch.serve import serve_demo

SHAPE = ShapeConfig("smoke_train", 64, 4, "train")


@pytest.mark.slow
def test_train_loop_runs_and_loss_finite(tmp_path):
    cfg = get_smoke("smollm-360m")
    out = train(cfg, SHAPE, steps=5, ckpt_dir=str(tmp_path / "ck"),
                ckpt_every=2, seed=0)
    assert out["steps"] == 5
    losses = [l for _, l in out["losses"]]
    assert all(np.isfinite(l) for l in losses)


@pytest.mark.slow
def test_restart_is_bitwise_identical(tmp_path):
    """Stop at step 6, resume to 10 == one uninterrupted 10-step run."""
    cfg = get_smoke("smollm-360m")

    full = train(cfg, SHAPE, steps=10, ckpt_dir=None, seed=0)

    part = train(cfg, SHAPE, steps=6, ckpt_dir=str(tmp_path / "ck"),
                 ckpt_every=3, seed=0)
    resumed = train(cfg, SHAPE, steps=4, ckpt_dir=str(tmp_path / "ck"),
                    ckpt_every=3, seed=0)

    full_d = dict(full["losses"])
    res_d = dict(resumed["losses"])
    for step in res_d:
        assert step in full_d
        np.testing.assert_allclose(res_d[step], full_d[step], rtol=1e-5), \
            (step, res_d[step], full_d[step])


@pytest.mark.slow
def test_serve_demo_driver():
    cfg = get_smoke("qwen3-8b")
    out = serve_demo(cfg, batch_size=3, max_seq=32, n_requests=5, seed=0)
    assert len(out["finished"]) == 5
    assert out["tokens"] > 0
