"""MachSuite substrate: level-equivalence vs oracles + property tests.

The core claim of the faithful reproduction: every optimization level
O0..O5 of every kernel computes the SAME function (the paper's refinement
steps are performance transforms, not semantic ones)."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.machsuite import KERNELS, aes, bfs, gemm, kmp, nw, sort, spmv, viterbi
from repro.core.optlevel import OptLevel

# scaled-down inputs (seconds, not hours, per kernel on CPU)
SCALES = {
    "aes": 2048 / 64e6,
    "bfs": 16 / 4096,
    "gemm": 32 / 1024,
    "kmp": 4096 / 128e6,
    "nw": 1 / 4096,
    "sort": 64 / 262144 / 16,
    "spmv": 1 / 64,
    "viterbi": 1 / 62500,
}


def _check(name, mod, lvl, rng):
    inp = mod.make_inputs(rng, SCALES[name])
    ref = np.asarray(mod.oracle(**inp))
    out = np.asarray(mod.run(OptLevel(lvl), **inp))
    if out.dtype.kind == "f":
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=1e-5,
                                   err_msg=f"{name} O{lvl}")
    else:
        np.testing.assert_array_equal(out, ref, err_msg=f"{name} O{lvl}")


@pytest.mark.parametrize("lvl", range(6))
@pytest.mark.parametrize("name", sorted(KERNELS))
def test_level_equivalence(name, lvl, rng):
    _check(name, KERNELS[name], lvl, rng)


def test_second_seed(rng):
    rng2 = np.random.default_rng(1234)
    for name in ("aes", "nw", "kmp"):
        _check(name, KERNELS[name], 5, rng2)


# ---------------------------------------------------------------------------
# AES properties
# ---------------------------------------------------------------------------

def test_aes_fips197_c3():
    key = np.arange(32, dtype=np.uint8)
    pt = np.frombuffer(bytes.fromhex("00112233445566778899aabbccddeeff"),
                       np.uint8)
    ct = aes.encrypt_blocks_np(pt[None, :], aes.expand_key(key))[0]
    assert ct.tobytes().hex() == "8ea2b7ca516745bfeafc49904b496089"


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_aes_ecb_block_independence(seed):
    """ECB: identical plaintext blocks -> identical ciphertext blocks."""
    r = np.random.default_rng(seed)
    key = r.integers(0, 256, 32, dtype=np.uint8)
    blk = r.integers(0, 256, 16, dtype=np.uint8)
    data = np.tile(blk, 4)
    ct = aes.oracle(data, key).reshape(4, 16)
    assert (ct == ct[0]).all()
    # and it is not the identity map
    assert not np.array_equal(ct[0], blk)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_aes_key_sensitivity(seed):
    r = np.random.default_rng(seed)
    k1 = r.integers(0, 256, 32, dtype=np.uint8)
    k2 = k1.copy()
    k2[0] ^= 1
    data = r.integers(0, 256, 64, dtype=np.uint8)
    assert not np.array_equal(aes.oracle(data, k1), aes.oracle(data, k2))


# ---------------------------------------------------------------------------
# KMP properties
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 6))
def test_kmp_counts_overlapping(seed, m):
    r = np.random.default_rng(seed)
    text = r.integers(0, 2, 256, dtype=np.uint8)   # binary => many matches
    pattern = r.integers(0, 2, m, dtype=np.uint8)
    expect = sum(
        1 for i in range(len(text) - m + 1)
        if (text[i:i + m] == pattern).all())
    assert int(kmp.oracle(text, pattern)) == expect
    assert int(kmp.run(OptLevel.O3, text, pattern)) == expect


def test_kmp_dfa_matches_failure_automaton(rng):
    text = rng.integers(0, 3, 512, dtype=np.uint8)
    pattern = rng.integers(0, 3, 5, dtype=np.uint8)
    o0 = int(kmp.run(OptLevel.O0, text, pattern))
    o2 = int(kmp.run(OptLevel.O2, text, pattern))
    assert o0 == o2 == int(kmp.oracle(text, pattern))


# ---------------------------------------------------------------------------
# NW properties
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(4, 16))
def test_nw_properties(seed, L):
    r = np.random.default_rng(seed)
    a = r.integers(0, 4, (1, L), dtype=np.uint8)
    b = r.integers(0, 4, (1, L), dtype=np.uint8)
    s_ab = int(nw.oracle(a, b)[0])
    s_ba = int(nw.oracle(b, a)[0])
    assert s_ab == s_ba                       # symmetric scoring scheme
    assert s_ab <= L * nw.MATCH               # bounded by all-match
    assert int(nw.oracle(a, a)[0]) == L * nw.MATCH   # self-alignment


# ---------------------------------------------------------------------------
# SORT properties
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_sort_is_sorted_permutation(seed):
    r = np.random.default_rng(seed)
    chunk = 32
    data = r.integers(-1000, 1000, 4 * chunk, dtype=np.int32)
    out = np.asarray(sort.run(OptLevel.O3, data, chunk)).reshape(-1, chunk)
    src = data.reshape(-1, chunk)
    for c in range(4):
        assert (np.diff(out[c]) >= 0).all()
        assert np.array_equal(np.sort(src[c]), out[c])


# ---------------------------------------------------------------------------
# BFS properties
# ---------------------------------------------------------------------------

def test_bfs_triangle_inequality(rng):
    inp = bfs.make_inputs(rng, 32 / 4096)
    dist = np.asarray(bfs.run(OptLevel.O2, **inp))
    off, nbr = inp["offsets"], inp["neighbors"]
    n = len(off) - 1
    assert dist[inp["source"]] == 0
    for u in range(n):
        if dist[u] < 0:
            continue
        for v in nbr[off[u]:off[u + 1]]:
            assert dist[v] >= 0 and dist[v] <= dist[u] + 1


# ---------------------------------------------------------------------------
# SPMV / GEMM / VITERBI extra checks
# ---------------------------------------------------------------------------

def test_spmv_linearity(rng):
    inp = spmv.make_inputs(rng, 1 / 64)
    y1 = np.asarray(spmv.run(OptLevel.O3, **inp))
    y2 = np.asarray(spmv.run(OptLevel.O3, inp["vals"] * 2.0, inp["cols"],
                             inp["x"]))
    np.testing.assert_allclose(y2, 2.0 * y1, rtol=1e-5)


def test_gemm_identity(rng):
    n = gemm.TILE * 2
    a = rng.standard_normal((n, n)).astype(np.float32)
    eye = np.eye(n, dtype=np.float32)
    out = np.asarray(gemm.run(OptLevel.O3, a, eye))
    np.testing.assert_allclose(out, a, rtol=1e-5, atol=1e-6)


def test_viterbi_beats_random_paths(rng):
    inp = viterbi.make_inputs(rng, 1 / 62500)
    best = np.asarray(viterbi.run(OptLevel.O2, **inp))
    obs, init, trans, emit = (inp["obs"], inp["init"], inp["trans"],
                              inp["emit"])
    S = init.shape[0]
    c = 0
    for _ in range(50):   # random path cost >= viterbi cost
        path = rng.integers(0, S, obs.shape[1])
        cost = init[path[0]] + emit[path[0], obs[c, 0]]
        for t in range(1, obs.shape[1]):
            cost += trans[path[t - 1], path[t]] + emit[path[t], obs[c, t]]
        assert cost >= best[c] - 1e-3
