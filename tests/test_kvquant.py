"""Quantized KV storage primitives (`serving/kvquant.py`): dtype map,
per-block absmax scales, round-trip idempotence, and the per-dtype
ladder contract (`tolerance_contract` / `token_agreement` /
`assert_tokens_match`) every identity test goes through."""

import numpy as np

import jax
import jax.numpy as jnp
import pytest

from repro.serving import kvquant


def test_dtype_map_and_validation():
    assert kvquant.KV_DTYPES == ("bf16", "int8", "fp8")
    assert not kvquant.is_quantized("bf16")
    assert kvquant.is_quantized("int8") and kvquant.is_quantized("fp8")
    assert kvquant.pool_dtype("bf16") == jnp.bfloat16
    assert kvquant.pool_dtype("int8") == jnp.int8
    assert kvquant.pool_dtype("fp8") == jnp.float8_e4m3fn
    with pytest.raises(ValueError, match="kv_dtype"):
        kvquant.validate_kv_dtype("int4")
    with pytest.raises(ValueError, match="not narrow"):
        kvquant.quantize(jnp.ones(3), jnp.ones(3), "bf16")
    assert kvquant.scale_bytes_per_block(2) == 8      # one f32 per kv head


@pytest.mark.parametrize("kvd", ["int8", "fp8"])
def test_block_scale_shape_and_zero_blocks(kvd):
    """Scales are f32 keepdims absmax/QMAX over the reduce axes, and an
    all-zero block dequantizes to EXACTLY zero (scale 1, not 0/0) —
    matching the zero-initialized bf16 pool."""
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 4, 2, 8),
                          jnp.bfloat16)
    x = x.at[2].set(0)                                # one all-zero block
    s = kvquant.block_scale(x, (1, 3), kvd)
    assert s.shape == (5, 1, 2, 1) and s.dtype == jnp.float32
    assert np.all(np.asarray(s) > 0)
    assert float(np.asarray(s)[2].max()) == 1.0
    q = kvquant.quantize(x, s, kvd)
    back = kvquant.dequantize(q, s)
    assert back.dtype == jnp.bfloat16
    assert np.all(np.asarray(back[2], np.float32) == 0.0)
    # narrow words really are 1 byte
    assert q.dtype.itemsize == 1


@pytest.mark.parametrize("kvd", ["int8", "fp8"])
def test_quantize_roundtrip_idempotent(kvd):
    """Re-quantizing a dequantized block under its stored scale is
    exact — the property the windowed requant-on-append writers rely on
    (untouched positions of a partially rewritten block must not
    drift)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (7, 4, 2, 8),
                          jnp.bfloat16) * 3.0
    s = kvquant.block_scale(x, (1, 3), kvd)
    q = kvquant.quantize(x, s, kvd)
    q2 = kvquant.quantize(kvquant.dequantize(q, s), s, kvd)
    assert np.array_equal(np.asarray(q, np.float32),
                          np.asarray(q2, np.float32))


def test_int8_range_is_symmetric():
    """Saturating inputs clip to ±127 — never -128, so negating a block
    round-trips through the same representable set."""
    x = jnp.asarray([[-1e6, 1e6]], jnp.float32)
    q = kvquant.quantize(x, jnp.ones((1, 1), jnp.float32), "int8")
    assert np.asarray(q).tolist() == [[-127, 127]]


def test_tolerance_contract_poles():
    exact = kvquant.tolerance_contract("bf16")
    assert exact["exact"] and exact["min_agreement"] == 1.0
    for kvd in ("int8", "fp8"):
        tc = kvquant.tolerance_contract(kvd)
        assert not tc["exact"]
        assert 0.0 < tc["min_agreement"] < 1.0
        assert tc["kv_dtype"] == kvd


def test_token_agreement_is_mean_matched_prefix():
    assert kvquant.token_agreement([], []) == 1.0
    assert kvquant.token_agreement([[1, 2, 3]], [[1, 2, 3]]) == 1.0
    # divergence at position 1 of 4: prefix fraction 1/4
    assert kvquant.token_agreement([[1, 2, 3, 4]], [[1, 9, 3, 4]]) == 0.25
    # mean over requests; length mismatch counts against the prefix
    got = kvquant.token_agreement([[1, 2], [5, 6, 7, 8]],
                                  [[1, 2], [5, 6]])
    assert got == (1.0 + 0.5) / 2


def test_assert_tokens_match_enforces_both_contracts():
    exact = kvquant.tolerance_contract("bf16")
    tol = kvquant.tolerance_contract("int8")
    ref = [[1, 2, 3], [4, 5]]
    assert kvquant.assert_tokens_match(ref, ref, exact) == 1.0
    with pytest.raises(AssertionError, match="exact contract"):
        kvquant.assert_tokens_match(ref, [[1, 2, 9], [4, 5]], exact,
                                    "label")
    # tolerance: the same divergence passes (agreement 5/6 > floor) and
    # the measured agreement is returned
    got = kvquant.assert_tokens_match(ref, [[1, 2, 9], [4, 5]], tol)
    assert abs(got - (2 / 3 + 1.0) / 2) < 1e-9
    with pytest.raises(AssertionError, match="below the int8 contract"):
        kvquant.assert_tokens_match(ref, [[9, 9, 9], [9, 9]], tol)
