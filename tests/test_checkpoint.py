"""Checkpointing: roundtrip, atomicity, rotation, async, manifest."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, load_checkpoint,
                              save_checkpoint)
from repro.checkpoint.sharded import load_manifest


@pytest.fixture
def tree():
    return {
        "layers": {"w": jnp.arange(24.0).reshape(4, 6),
                   "b": jnp.ones((6,), jnp.bfloat16)},
        "step_scale": jnp.float32(0.5),
    }


def test_roundtrip(tmp_path, tree):
    path = save_checkpoint(str(tmp_path / "ck"), tree, step=7,
                           extra={"note": "hi"})
    restored, step, extra = load_checkpoint(path, tree)
    assert step == 7 and extra == {"note": "hi"}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_shape_mismatch_rejected(tmp_path, tree):
    path = save_checkpoint(str(tmp_path / "ck"), tree, step=0)
    bad = dict(tree)
    bad["step_scale"] = jnp.zeros((3,))
    with pytest.raises(ValueError):
        load_checkpoint(path, bad)


def test_missing_leaf_rejected(tmp_path, tree):
    path = save_checkpoint(str(tmp_path / "ck"), tree, step=0)
    bigger = dict(tree)
    bigger["extra_leaf"] = jnp.zeros((2,))
    with pytest.raises(KeyError):
        load_checkpoint(path, bigger)


def test_atomicity_no_tmp_left(tmp_path, tree):
    path = save_checkpoint(str(tmp_path / "ck"), tree, step=1)
    assert os.path.exists(path)
    assert not os.path.exists(path + ".tmp")
    # re-save over the same path works (tmp+rename)
    save_checkpoint(path, tree, step=2)
    _, step, _ = load_checkpoint(path, tree)
    assert step == 2


def test_manifest_is_json_with_shards(tmp_path, tree):
    path = save_checkpoint(str(tmp_path / "ck"), tree, step=3)
    man = load_manifest(path)
    assert man["step"] == 3
    assert "layers.w" in man["leaves"]
    rec = man["leaves"]["layers.w"]
    assert rec["shape"] == [4, 6]
    for sh in rec["shards"]:
        assert os.path.exists(os.path.join(path, sh["file"]))


def test_manager_rotation_and_latest(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path / "root"), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(tree, step=s)
    mgr.wait()
    assert mgr.all_steps() == [3, 4]
    restored, step, _ = mgr.restore_latest(tree)
    assert step == 4
    mgr.close()


def test_manager_async_snapshot_isolation(tmp_path):
    """Mutating (donating) the live tree after save_async must not corrupt
    the checkpoint — the save took a host snapshot."""
    mgr = CheckpointManager(str(tmp_path / "root"), keep=2)
    arr = jnp.arange(8.0)
    mgr.save_async({"a": arr}, step=1)
    arr = arr * 0 - 5.0    # simulate buffer reuse
    mgr.wait()
    restored, _, _ = mgr.restore_latest({"a": arr})
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(8.0))
    mgr.close()


def test_restore_empty_returns_none(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path / "empty"))
    assert mgr.restore_latest(tree) is None
    mgr.close()
