"""Property-based scheduler + block-allocator tests (satellite of the O6
paged-cache work): random admit/retire/eos traffic must preserve the
bookkeeping invariants the serving engine's correctness rests on —

  * no slot double-occupancy (an active request lives in exactly one slot);
  * admission order respects the policy (fcfs: arrival order, no
    head-of-line bypass even when the block gate queues the head; spf:
    the admitted request has the shortest prompt in the queue);
  * block free-list conservation under the paged path: held + free ==
    total, no block held twice or both held and free, retired slots hold
    nothing — across BOTH tick protocols (serial ``advance`` and the
    overlapped ``tick_advance``/``finalize`` split).

Runs through ``tests/_hypothesis_compat``: real hypothesis when the
environment has it, the deterministic fixed-seed fallback otherwise.
"""

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.serving import PagedAllocator, Request, Scheduler
from repro.serving.paged import BlockAllocator, blocks_for


# ---------------------------------------------------------------------------
# BlockAllocator: the free list itself
# ---------------------------------------------------------------------------

def test_block_allocator_basics():
    a = BlockAllocator(4)
    assert a.free_blocks == 4 and a.used_blocks == 0
    got = a.allocate(3)
    assert len(got) == len(set(got)) == 3
    assert all(1 <= b <= 4 for b in got)        # block 0 is NULL, reserved
    assert a.free_blocks == 1
    with pytest.raises(RuntimeError, match="exhausted"):
        a.allocate(2)
    a.release(got[:2])
    assert a.free_blocks == 3
    with pytest.raises(RuntimeError, match="free"):
        a.release([got[0]])                      # double free
    with pytest.raises(RuntimeError, match="free"):
        a.release([99])                          # out of range
    b = a.append()
    assert 1 <= b <= 4 and a.free_blocks == 2


def test_block_allocator_defrag_takes_lowest_ids():
    a = BlockAllocator(8, defrag=True)
    first = a.allocate(6)
    a.release(first)                             # free list now shuffled
    assert a.allocate(3) == [1, 2, 3]


def test_blocks_for_arithmetic():
    assert blocks_for(0, 4) == 0
    assert blocks_for(1, 4) == 1
    assert blocks_for(4, 4) == 1
    assert blocks_for(5, 4) == 2


def test_paged_allocator_small_pool_gates_at_submit_not_construction():
    """A pool smaller than one max_seq reservation is a legal config
    (real mixes rarely reserve the full horizon).  The never-fits check
    moved to the SUBMIT boundary: ``infeasible_reason`` names requests
    whose reservation exceeds the total pool, and a scheduler wired with
    it rejects them at submit() — feasible requests still queue/admit."""
    pa = PagedAllocator(2, 32, block_size=4, pool_blocks=7)
    sched = Scheduler(2, 32, policy="fcfs")
    sched.admission_gate = pa.can_admit
    sched.submit_gate = pa.infeasible_reason
    sched.on_admit = pa.admit_slot
    sched.on_retire = pa.release_slot
    # needs 8 blocks > 7 in the whole pool: rejected with a clear error
    with pytest.raises(ValueError, match="never fit the total pool"):
        sched.submit(Request(prompt=[1] * 16, max_new_tokens=16))
    assert not sched.queue and not sched.finished
    # 28-token reservation = 7 blocks = the whole pool: feasible
    sched.submit(Request(prompt=[2] * 20, max_new_tokens=8))
    assert sched.admit() == [0]
    _check_invariants(sched, pa)


def test_submit_without_gate_still_static_only():
    """No submit_gate wired (contiguous layout): only the static
    max_seq validation applies, exactly as before."""
    sched = Scheduler(2, 32)
    sched.submit(Request(prompt=[1] * 16, max_new_tokens=16))
    assert len(sched.queue) == 1


# ---------------------------------------------------------------------------
# Random traffic against the real Scheduler + PagedAllocator wiring
# ---------------------------------------------------------------------------

def _check_invariants(sched, pa):
    # no double occupancy: an active request sits in exactly one slot
    active = [s.req for s in sched.slots if s.active]
    assert len({id(r) for r in active}) == len(active)
    assert not any(r.done for r in active)
    # free-list conservation + table/held consistency
    pa.check_conservation()
    for i, s in enumerate(sched.slots):
        if not s.active:
            assert pa._held[i] == 0, f"retired slot {i} still holds blocks"
        else:
            assert pa._held[i] == pa.blocks_needed(s.req)


def _run_scenario(seed: int, policy: str, split_protocol: bool):
    rng = np.random.default_rng(seed)
    n_slots = int(rng.integers(1, 5))
    block_size = int(rng.integers(1, 6))
    max_seq = int(rng.integers(8, 33))
    per_seq = blocks_for(max_seq, block_size)
    # pool between "one max request" and "every slot maxed": small pools
    # force the admission gate to queue
    pool = int(rng.integers(per_seq, n_slots * per_seq + 1))
    pa = PagedAllocator(n_slots, max_seq, block_size=block_size,
                        pool_blocks=pool)
    sched = Scheduler(n_slots, max_seq, policy=policy)
    sched.admission_gate = pa.can_admit
    admitted_log = []

    def on_admit(i, req):
        pa.admit_slot(i, req)
        admitted_log.append(req)
        if policy == "fcfs":
            # no head-of-line bypass: everything still queued arrived later
            assert all(req.rid < q.rid for q in sched.queue)
        else:
            # spf with aging: nothing EFFECTIVELY shorter (prompt length
            # minus waves spent queued, rid tiebreak) was left behind
            key = sched.effective_prompt_len
            assert all((key(req), req.rid) <= (key(q), q.rid)
                       for q in sched.queue)

    sched.on_admit = on_admit
    sched.on_retire = pa.release_slot

    EOS = 7
    submitted = 0
    for _ in range(int(rng.integers(10, 40))):
        # random submissions (some degenerate / eos-bearing)
        for _ in range(int(rng.integers(0, 3))):
            plen = int(rng.integers(1, max_seq))
            new = int(rng.integers(0, max_seq - plen + 1))
            sched.submit(Request(
                prompt=[int(t) for t in rng.integers(1, 50, plen)],
                max_new_tokens=new,
                eos_id=EOS if rng.random() < 0.5 else None))
            submitted += 1
        sched.admit()
        _check_invariants(sched, pa)
        active = sched.active_indices
        toks = {i: int(rng.integers(1, 10)) for i in active}  # may hit EOS
        if split_protocol:
            emissions = sched.tick_advance(active)
            _check_invariants(sched, pa)          # freed under running step
            sched.admit()                         # overlapped refill
            _check_invariants(sched, pa)
            sched.finalize(emissions, toks)
        else:
            for i in active:
                sched.advance(i, toks[i])
        _check_invariants(sched, pa)

    # drain: every submitted request eventually finishes and every block
    # comes home
    for _ in range(10_000):
        if not sched.has_work():
            break
        sched.admit()
        active = sched.active_indices
        toks = {i: int(rng.integers(1, 10)) for i in active}
        if split_protocol:
            emissions = sched.tick_advance(active)
            sched.finalize(emissions, toks)
        else:
            for i in active:
                sched.advance(i, toks[i])
        _check_invariants(sched, pa)
    assert not sched.has_work(), "scenario failed to drain (deadlock?)"
    assert len(sched.finished) == submitted
    assert pa.free_blocks == pool, "blocks leaked after full drain"
    # fcfs admitted exactly in arrival order
    if policy == "fcfs":
        rids = [r.rid for r in admitted_log]
        assert rids == sorted(rids)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_random_traffic_fcfs_serial(seed):
    _run_scenario(seed, "fcfs", split_protocol=False)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_random_traffic_fcfs_split(seed):
    _run_scenario(seed, "fcfs", split_protocol=True)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_random_traffic_spf_serial(seed):
    _run_scenario(seed, "spf", split_protocol=False)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_random_traffic_spf_split(seed):
    _run_scenario(seed, "spf", split_protocol=True)


# ---------------------------------------------------------------------------
# Chunked prefill bookkeeping: random chunked-prefill + decode traffic
# through the exact protocol the engine drives (one chunk grant per tick
# to the head of ``prefill_queue``; the final chunk ends in ``advance``).
# ---------------------------------------------------------------------------

def _run_chunked_scenario(seed: int, policy: str):
    rng = np.random.default_rng(seed)
    n_slots = int(rng.integers(1, 5))
    block_size = int(rng.integers(1, 6))
    max_seq = int(rng.integers(8, 33))
    C = int(rng.integers(1, 9))                   # prefill chunk width
    per_seq = blocks_for(max_seq, block_size)
    pool = int(rng.integers(per_seq, n_slots * per_seq + 1))
    pa = PagedAllocator(n_slots, max_seq, block_size=block_size,
                        pool_blocks=pool)
    sched = Scheduler(n_slots, max_seq, policy=policy)
    sched.admission_gate = pa.can_admit
    sched.on_admit = pa.admit_slot
    sched.on_retire = pa.release_slot

    grants = {}          # rid -> prefill chunk grants received
    admit_tick = {}      # rid -> tick the slot was admitted
    first_emit = {}      # rid -> tick of the first generated token
    submitted = 0
    tick = 0

    def serve_one_tick():
        nonlocal tick
        tick += 1
        sched.admit()
        for i, s in enumerate(sched.slots):
            if s.active and s.req.rid not in admit_tick:
                admit_tick[s.req.rid] = tick
        _check_invariants(sched, pa)
        # prefill-queue ordering respects the admission policy
        pf = sched.prefill_queue()
        assert all(sched.slots[i].active
                   and sched.slots[i].pos < sched.slots[i].req.n_prompt
                   for i in pf)
        if policy == "fcfs":
            rids = [sched.slots[i].req.rid for i in pf]
            assert rids == sorted(rids), "fcfs prefill queue out of order"
        else:
            rem = [(sched.slots[i].req.n_prompt - sched.slots[i].pos,
                    sched.slots[i].req.rid) for i in pf]
            assert rem == sorted(rem), "spf prefill queue out of order"
        # one chunk grant to the head (the engine's _prefill_tick)
        if pf:
            i = pf[0]
            s = sched.slots[i]
            r = s.req
            grants[r.rid] = grants.get(r.rid, 0) + 1
            n = min(C, r.n_prompt - s.pos)
            if s.pos + n == r.n_prompt:
                sched.advance_chunk(i, n - 1)
                sched.advance(i, int(rng.integers(1, 10)))
                first_emit.setdefault(r.rid, tick)
            else:
                sched.advance_chunk(i, n)
        # decode tick for every generating slot (pos past the prompt)
        for i in sched.active_indices:
            s = sched.slots[i]
            if s.req is not None and s.pos >= s.req.n_prompt:
                sched.advance(i, int(rng.integers(1, 10)))
        _check_invariants(sched, pa)

    EOS = 7
    for _ in range(int(rng.integers(10, 40))):
        for _ in range(int(rng.integers(0, 3))):
            plen = int(rng.integers(1, max_seq))
            new = int(rng.integers(1, max_seq - plen + 1))
            sched.submit(Request(
                prompt=[int(t) for t in rng.integers(1, 50, plen)],
                max_new_tokens=new,
                eos_id=EOS if rng.random() < 0.5 else None))
            submitted += 1
        serve_one_tick()

    for _ in range(10_000):
        if not sched.has_work():
            break
        serve_one_tick()
    assert not sched.has_work(), "chunked scenario failed to drain"
    assert len(sched.finished) == submitted
    assert pa.free_blocks == pool, "blocks leaked after chunked drain"
    # the stall bound: a slot's prefill occupies EXACTLY
    # ceil(n_prompt / C) chunk grants — no slot re-enters the prefill
    # queue once generating, none is starved into extra grants
    by_rid = {r.rid: r for r in sched.finished}
    for rid, g in grants.items():
        P = by_rid[rid].n_prompt
        assert g == -(-P // C), (
            f"rid {rid}: {g} chunk grants for prompt {P} at chunk {C}")
    # every admitted slot emitted within (queue-serialized) bound: its
    # own grants plus every grant spent on other slots while it waited
    for rid, t0 in admit_tick.items():
        assert rid in first_emit, f"rid {rid} admitted but never emitted"


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_random_chunked_traffic_fcfs(seed):
    _run_chunked_scenario(seed, "fcfs")


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_random_chunked_traffic_spf(seed):
    _run_chunked_scenario(seed, "spf")


def test_advance_chunk_rejects_overrun():
    sched = Scheduler(1, 16)
    sched.submit(Request(prompt=[1, 2, 3], max_new_tokens=2))
    sched.admit()
    with pytest.raises(AssertionError, match="overruns"):
        sched.advance_chunk(0, 3)          # chunk may not consume token 2
    sched.advance_chunk(0, 2)
    assert sched.slots[0].pos == 2


def test_place_occupies_at_post_prompt_position():
    """``place`` (the insert phase) occupies a free slot at
    ``pos = n_prompt - 1`` — the next ``advance`` emits — fires
    ``on_admit`` exactly once, and refuses occupied slots."""
    pa = PagedAllocator(2, 16, block_size=4, pool_blocks=8)
    sched = Scheduler(2, 16)
    sched.on_admit = pa.admit_slot
    sched.on_retire = pa.release_slot
    req = Request(prompt=[1, 2, 3], max_new_tokens=2)
    req.rid = 0
    sched.place(req, 1)
    assert sched.slots[1].pos == 2 and pa.held_blocks == [0, 2]
    assert sched.prefill_queue() == [1]    # last prompt token pending
    sched.advance(1, 5)                    # emits the first token
    assert req.generated == [5] and sched.prefill_queue() == []
    with pytest.raises(ValueError, match="occupied"):
        sched.place(Request(prompt=[9], max_new_tokens=1, rid=1), 1)
    sched.advance(1, 6)                    # budget reached: retires
    assert req.done and pa.free_blocks == 8


# ---------------------------------------------------------------------------
# grow_slot: the chunked-admission block arithmetic
# ---------------------------------------------------------------------------

def test_grow_slot_never_double_counts_shared_block():
    """Growing by TOTALS: a chunk ending mid-block shares its active
    block with the next chunk, so consecutive grows allocate
    ``blocks_for(total) - held`` — never per-chunk ceil sums."""
    pa = PagedAllocator(1, 32, block_size=4, pool_blocks=8)
    assert pa.grow_slot(0, 6) == 2         # covers tokens 0..5
    assert pa.grow_slot(0, 7) == 0         # same final block: no alloc
    assert pa.grow_slot(0, 9) == 1         # one more block
    assert pa._held[0] == 3 and pa.free_blocks == 5
    assert pa.grow_slot(0, 9) == 0         # idempotent
    assert pa.grow_slot(0, 100) == 5       # clips to max_seq (32 tokens)
    assert pa._held[0] == 8
    pa.check_conservation()


def test_grow_slot_queue_then_admit_neither_leaks_nor_deadlocks():
    """Queue-then-admit under a constrained pool: a reservation the gate
    defers admits after retirements free blocks, and a full drain
    returns every block (the reservation arithmetic leaks nothing)."""
    pa = PagedAllocator(2, 16, block_size=4, pool_blocks=5)
    sched = Scheduler(2, 16, policy="fcfs")
    sched.admission_gate = pa.can_admit
    sched.on_admit = pa.admit_slot
    sched.on_retire = pa.release_slot
    sched.submit(Request(prompt=[1] * 10, max_new_tokens=2))  # 3 blocks
    sched.submit(Request(prompt=[2] * 10, max_new_tokens=2))  # must queue
    assert sched.admit() == [0] and sched.admit() == []
    # chunked prefill (C=4) on the admitted slot; the queued request
    # stays gated throughout
    C = 4
    for _ in range(20):
        pf = sched.prefill_queue()
        if pf:
            i = pf[0]
            s = sched.slots[i]
            n = min(C, s.req.n_prompt - s.pos)
            if s.pos + n == s.req.n_prompt:
                sched.advance_chunk(i, n - 1)
                sched.advance(i, 3)
            else:
                sched.advance_chunk(i, n)
        else:
            for i in sched.active_indices:
                sched.advance(i, 3)
        _check_invariants(sched, pa)
        sched.admit()
        if not sched.has_work():
            break
    assert not sched.has_work(), "constrained pool deadlocked"
    assert len(sched.finished) == 2
    assert pa.free_blocks == 5, "blocks leaked"


# ---------------------------------------------------------------------------
# The block-granularity admission gate (the satellite fix): a request that
# fits max_seq but not the free blocks queues — never raises — and admits
# once retirements free the pool.
# ---------------------------------------------------------------------------

def test_block_exhaustion_queues_instead_of_raising():
    pa = PagedAllocator(2, 16, block_size=4, pool_blocks=5)
    sched = Scheduler(2, 16, policy="fcfs")
    sched.admission_gate = pa.can_admit
    sched.on_admit = pa.admit_slot
    sched.on_retire = pa.release_slot

    # 12-token reservation = 3 blocks; two of them exceed the 5-block pool
    sched.submit(Request(prompt=[1] * 8, max_new_tokens=4))
    sched.submit(Request(prompt=[2] * 8, max_new_tokens=4))   # must queue
    assert sched.admit() == [0]
    assert len(sched.queue) == 1 and pa.free_blocks == 2
    assert sched.admit() == []                 # still gated, still queued
    # drain the first request; its retirement frees the blocks
    for _ in range(11):
        for i in sched.active_indices:
            sched.advance(i, 3)
    assert not sched.slots[0].active
    assert sched.admit() == [0]                # queued request admits now
    assert sched.queue == type(sched.queue)()
    pa.check_conservation()


def test_gate_preserves_fcfs_no_bypass():
    """A small request behind a gated big one must NOT jump the queue
    under fcfs."""
    pa = PagedAllocator(2, 16, block_size=4, pool_blocks=5)
    sched = Scheduler(2, 16, policy="fcfs")
    sched.admission_gate = pa.can_admit
    sched.on_admit = pa.admit_slot
    sched.on_retire = pa.release_slot
    sched.submit(Request(prompt=[1] * 8, max_new_tokens=4))   # 3 blocks
    sched.submit(Request(prompt=[2] * 8, max_new_tokens=4))   # gated head
    sched.submit(Request(prompt=[3], max_new_tokens=2))       # 1 block
    assert sched.admit() == [0]
    assert sched.admit() == []                 # head gated; no bypass
    assert [r.n_prompt for r in sched.queue] == [8, 1]


# ---------------------------------------------------------------------------
# spf aging (satellite fix): under sustained open-loop arrivals of short
# requests, pure shortest-prompt-first starves a long prompt FOREVER —
# every wave a fresh shorter request outranks it.  With aging, a queued
# request's effective length decays one token per admission wave, so every
# request is admitted within a bounded number of waves.
# ---------------------------------------------------------------------------

def _spf_starvation_scenario(seed: int) -> int:
    """One slot, adversarial traffic: every tick submits a fresh 1-token
    request (always the spf minimum by raw length) that completes in one
    advance.  Returns the number of waves until the long prompt admits —
    under pure spf this loop never terminates."""
    rng = np.random.default_rng(seed)
    max_seq = 64
    long_len = int(rng.integers(8, 32))
    sched = Scheduler(1, max_seq, policy="spf")
    long_req = Request(prompt=[9] * long_len, max_new_tokens=2)
    sched.submit(long_req)
    bound = long_len + 3     # aging decays one token per wave, + slack
    for wave in range(bound):
        sched.submit(Request(prompt=[int(rng.integers(1, 9))],
                             max_new_tokens=1))
        sched.admit()
        i = sched.active_indices[0]
        if sched.slots[i].req is long_req:
            return wave
        # the short admitted: drain it in one advance so the slot frees
        sched.advance(i, 3)
        assert not sched.slots[i].active
    raise AssertionError(
        f"long prompt ({long_len} tokens) starved for {bound} waves "
        f"(queue lengths: {[r.n_prompt for r in sched.queue]})")


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_spf_aging_prevents_starvation(seed):
    _spf_starvation_scenario(seed)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_spf_every_queued_request_eventually_admitted(seed):
    """The aging guarantee under random mixed traffic: run a bounded
    number of adversarial waves (fresh short arrivals each tick), then
    count that every request submitted in the FIRST wave has been
    admitted within n_prompt + queue-drain slack waves."""
    rng = np.random.default_rng(seed)
    max_seq = 32
    sched = Scheduler(2, max_seq, policy="spf")
    first_wave = [Request(prompt=[1] * int(rng.integers(2, max_seq - 2)),
                          max_new_tokens=1) for _ in range(3)]
    for r in first_wave:
        sched.submit(r)
    admitted = set()

    def on_admit(i, req):
        admitted.add(req.rid)

    sched.on_admit = on_admit
    # worst case: every first-wave request must out-age the adversarial
    # stream one after another, at one slot-free wave each
    bound = sum(r.n_prompt for r in first_wave) + 3 * len(first_wave)
    for _ in range(bound):
        sched.submit(Request(prompt=[2], max_new_tokens=1))
        sched.admit()
        for i in sched.active_indices:
            sched.advance(i, 3)          # max_new=1: retires immediately
        if all(r.rid in admitted for r in first_wave):
            break
    assert all(r.rid in admitted for r in first_wave), (
        f"first-wave requests starved after {bound} waves: "
        f"{[(r.rid, r.n_prompt) for r in first_wave if r.rid not in admitted]}")


def test_deadline_policy_admits_edf_order():
    """The deadline policy admits earliest-deadline-first regardless of
    arrival order; requests without a deadline sort last."""
    sched = Scheduler(1, 32, policy="deadline")
    a = Request(prompt=[1, 1], max_new_tokens=1)               # no deadline
    b = Request(prompt=[2, 2], max_new_tokens=1, deadline_s=50.0)
    c = Request(prompt=[3, 3], max_new_tokens=1, deadline_s=10.0)
    for r in (a, b, c):
        sched.submit(r)
    order = []
    sched.on_admit = lambda i, req: order.append(req)
    for _ in range(20):
        sched.admit()
        for i in sched.active_indices:
            sched.advance(i, 4)
            sched.advance(i, 4)
        if not sched.has_work():
            break
    assert order == [c, b, a]


def test_deadline_policy_prefill_queue_orders_by_deadline():
    sched = Scheduler(3, 32, policy="deadline")
    a = Request(prompt=[1] * 4, max_new_tokens=2)
    b = Request(prompt=[2] * 4, max_new_tokens=2, deadline_s=5.0)
    c = Request(prompt=[3] * 4, max_new_tokens=2, deadline_s=1.0)
    for r in (a, b, c):
        sched.submit(r)
    sched.admit()
    pf = sched.prefill_queue()
    assert [sched.slots[i].req for i in pf] == [c, b, a]


# ---------------------------------------------------------------------------
# StatePool: the state-row sibling of the block allocator (recurrent
# families).  Same conservation discipline, but a slot holds exactly one
# O(1) row for its whole lifetime — no reservation arithmetic.
# ---------------------------------------------------------------------------

from repro.serving.paged import NULL_ROW, StatePool  # noqa: E402


def test_state_pool_basics():
    pool = StatePool(3, n_rows=2)
    assert pool.free_rows == 2 and pool.used_rows == 0
    assert pool.can_admit()
    assert pool.infeasible_reason(Request(prompt=[1] * 30,
                                          max_new_tokens=100)) is None

    pool.admit_slot(0)
    pool.admit_slot(2)
    pool.check_conservation()
    assert pool.free_rows == 0 and pool.used_rows == 2
    assert not pool.can_admit()
    assert int(pool.rows[1]) == NULL_ROW
    # distinct real rows, handed out lowest-first
    assert sorted(int(r) for r in pool.rows if r != NULL_ROW) == [1, 2]

    with pytest.raises(RuntimeError, match="admitted while holding"):
        pool.admit_slot(0)
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.admit_slot(1)

    pool.release_slot(1)                     # releasing an empty slot: no-op
    assert pool.free_rows == 0
    pool.release_slot(0)
    pool.check_conservation()
    assert pool.free_rows == 1 and pool.used_rows == 1
    pool.admit_slot(0)

    # a corrupted alias (two slots claiming one row) must trip the
    # double-free guard on the second release
    pool.rows[1] = pool.rows[0]
    pool.release_slot(0)
    with pytest.raises(RuntimeError, match="double/invalid free"):
        pool.release_slot(1)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_state_pool_random_traffic_conserves_rows(seed):
    """held + free == total and no double-occupancy under random
    admit/retire/eos traffic driven through the scheduler hooks (the
    wiring the paged layout uses for recurrent families)."""
    rng = np.random.default_rng(seed)
    B = int(rng.integers(2, 5))
    pool = StatePool(B, n_rows=int(rng.integers(1, B + 1)))
    sched = Scheduler(B, 16)
    sched.admission_gate = pool.can_admit
    sched.on_admit = pool.admit_slot
    sched.on_retire = pool.release_slot

    for _ in range(60):
        if rng.random() < 0.5:
            sched.submit(Request(
                prompt=[1] * int(rng.integers(1, 6)),
                max_new_tokens=int(rng.integers(1, 6)), eos_id=0))
        for i in sched.admit():
            assert int(pool.rows[i]) != NULL_ROW
        pool.check_conservation()
        # every active slot holds exactly one real row; idle slots none
        for i, slot in enumerate(sched.slots):
            held = int(pool.rows[i]) != NULL_ROW
            assert held == slot.active, (i, slot)
        for i in list(sched.active_indices):
            # advance; sometimes force a surprise eos mid-generation
            tok = 0 if rng.random() < 0.1 else int(rng.integers(3, 9))
            sched.advance(i, tok)
        pool.check_conservation()
    assert pool.used_rows == sum(s.active for s in sched.slots)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_state_pool_defrag_packs_rows_and_preserves_mapping(seed):
    """compaction_moves packs held rows into the lowest ids in slot
    order; apply_moves rewrites the map consistently (bit-exactness of
    the device copies is covered by the serving differential tests —
    here we pin that the *plan* is a permutation the manager can apply)."""
    rng = np.random.default_rng(seed)
    B = int(rng.integers(2, 8))
    pool = StatePool(B)
    # random churn to fragment the row map
    for _ in range(40):
        i = int(rng.integers(0, B))
        if int(pool.rows[i]) == NULL_ROW and pool.can_admit():
            pool.admit_slot(i)
        elif rng.random() < 0.6:
            pool.release_slot(i)
    pool.check_conservation()
    before = {i: int(r) for i, r in enumerate(pool.rows) if r != NULL_ROW}

    moves = pool.compaction_moves()
    # valid plan for the manager's simultaneous snapshot copy
    # (``leaf.at[dst].set(leaf[src])``): sources held, destinations
    # distinct, and no destination clobbers a held row that is NOT
    # itself relocated by the same plan.
    held = set(before.values())
    assert set(moves) <= held
    assert len(set(moves.values())) == len(moves)
    assert not set(moves.values()) & (held - set(moves))
    pool.apply_moves(moves)
    pool.check_conservation()

    after = {i: int(r) for i, r in enumerate(pool.rows) if r != NULL_ROW}
    assert set(after) == set(before)          # same slots occupied
    n = len(after)
    assert sorted(after.values()) == list(range(1, n + 1))
    # slot order preserved: lower slot index -> lower packed row id
    packed = [after[i] for i in sorted(after)]
    assert packed == sorted(packed)
    # idempotent: a second plan is empty
    assert pool.compaction_moves() == {}
