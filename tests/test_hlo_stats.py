"""HLO collective parser against programs with known collectives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hlo_stats


def _compile_with_mesh(fn, specs_in, spec_out, mesh_shape=(1,),
                       axes=("data",)):
    devs = np.array(jax.devices()[:1] * int(np.prod(mesh_shape)))
    mesh = jax.sharding.Mesh(devs.reshape(mesh_shape), axes)
    from jax.sharding import NamedSharding
    in_sh = tuple(NamedSharding(mesh, s) for s in specs_in)
    out_sh = NamedSharding(mesh, spec_out)
    return jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)


def test_psum_produces_allreduce():
    from jax.sharding import PartitionSpec as P

    def fn(x):
        return jnp.sum(x * 2.0)

    jitted = _compile_with_mesh(fn, [P("data")], P())
    txt = jax.jit(fn).lower(jnp.zeros((8,))).compile().as_text()
    # single-device program has no collectives
    stats = hlo_stats.parse_hlo(txt)
    assert stats.collective_bytes == 0


def test_parse_synthetic_hlo_text():
    """Parser unit check against a handcrafted HLO snippet."""
    txt = """
HloModule test
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[512,256]{1,0} all-gather(%p0), dimensions={0}
  %ar = f32[512,256]{1,0} all-reduce(%ag), to_apply=add
  %rs = f32[128,256]{1,0} reduce-scatter(%ar), dimensions={0}
  %cp = f32[128,256]{1,0} collective-permute(%rs), source_target_pairs={{0,1}}
  %a2a = f32[128,256]{1,0} all-to-all(%cp), dimensions={0}
  ROOT t = (f32[128,256]) tuple(a2a)
}
"""
    stats = hlo_stats.parse_hlo(txt)
    kinds = set(stats.collectives)
    assert {"all-gather", "all-reduce", "reduce-scatter",
            "collective-permute", "all-to-all"} <= kinds
    # operand bytes: all-gather reads 128*256*4
    assert stats.collectives["all-gather"].operand_bytes == 128 * 256 * 4
    assert stats.collectives["all-reduce"].operand_bytes == 512 * 256 * 4
    assert stats.collective_bytes > 0


def test_bf16_and_multi_operand():
    txt = """
ENTRY main {
  %p0 = bf16[64]{0} parameter(0)
  %p1 = bf16[64]{0} parameter(1)
  %ar = (bf16[64], bf16[64]) all-reduce(%p0, %p1), to_apply=add
  ROOT r = bf16[64] get-tuple-element(ar), index=0
}
"""
    stats = hlo_stats.parse_hlo(txt)
    assert stats.collectives["all-reduce"].operand_bytes == 2 * 64 * 2


def test_op_census_counts_fusions():
    txt = """
ENTRY main {
  a = f32[4] add(x, y)
  b = f32[4] add(a, y)
  c = f32[4] multiply(b, b)
}
"""
    stats = hlo_stats.parse_hlo(txt)
    assert stats.op_census.get("add", 0) == 2
    assert stats.op_census.get("multiply", 0) == 1
