"""The best-effort guideline engine: bottleneck -> next step decisions,
the communication filter, and the modelled refinement walk."""

import pytest

from repro.core import costmodel
from repro.core.guideline import (COMM_BOUND_THRESHOLD, comm_bound_filter,
                                  recommend)
from repro.core.optlevel import (ALL_LEVELS, LADDER, BestEffortConfig,
                                 OptLevel, Step, STEP_ORDER)
from repro.core.refine import refine_modelled


def test_ladder_cumulative_semantics():
    assert OptLevel.O0.steps == ()
    assert OptLevel.O3.steps == STEP_ORDER[:3]
    assert OptLevel.O5.has(Step.SCRATCHPAD_REORG)
    assert not OptLevel.O2.has(Step.PE_DUPLICATION)
    assert OptLevel.O2.next_step == Step.PE_DUPLICATION
    # The serving extensions sit past the paper's five: O5's next move
    # is the paged-scratchpad rung, O6's the speculative rung; the full
    # ladder tops out at O7.
    assert OptLevel.O5.next_step == Step.PAGED_SCRATCHPAD
    assert OptLevel.O6.next_step == Step.SPECULATIVE
    assert OptLevel.O7.next_step is None
    assert OptLevel.O6.has(Step.PAGED_SCRATCHPAD)
    assert not OptLevel.O5.has(Step.PAGED_SCRATCHPAD)
    assert OptLevel.O7.has(Step.SPECULATIVE)
    assert not OptLevel.O6.has(Step.SPECULATIVE)
    assert STEP_ORDER == LADDER[:5]      # the paper's table is untouched


def test_paged_step_scoped_to_extended_universe():
    """The paper-scoped default universe never recommends the paged rung
    (kernel/LM walks stop at O5); the serving universe escalates to it
    after wide-word reorg, and stops only past O6."""
    five = set(STEP_ORDER)
    rec = recommend(applied=five, compute_s=1.0, memory_s=5.0)
    assert rec.stop and rec.step is None
    rec = recommend(applied=five, compute_s=1.0, memory_s=5.0, steps=LADDER)
    assert rec.step == Step.PAGED_SCRATCHPAD
    rec = recommend(applied=set(LADDER), compute_s=1.0, memory_s=5.0,
                    steps=LADDER)
    assert rec.stop and rec.step is None


def test_best_effort_config_gates():
    c = BestEffortConfig(level=OptLevel.O2, pe=16, n_buffers=3,
                         word_bits=512)
    assert c.effective_pe == 1          # PE dup not yet applied
    assert c.effective_buffers == 1
    c5 = c.with_level(OptLevel.O5)
    assert c5.effective_pe == 16
    assert c5.effective_buffers == 3
    assert c5.effective_word_bits == 512


def test_memory_bound_recommends_caching_first():
    rec = recommend(level=OptLevel.O0, compute_s=1.0, memory_s=5.0)
    assert rec.step == Step.DATA_CACHING


def test_memory_bound_after_caching_recommends_double_buffer():
    rec = recommend(level=OptLevel.O3, compute_s=1.0, memory_s=5.0)
    assert rec.step == Step.DOUBLE_BUFFERING
    rec = recommend(level=OptLevel.O4, compute_s=1.0, memory_s=5.0)
    assert rec.step == Step.SCRATCHPAD_REORG


def test_compute_bound_recommends_pipeline_then_pe():
    rec = recommend(level=OptLevel.O1, compute_s=9.0, memory_s=1.0)
    assert rec.step == Step.PIPELINING
    rec = recommend(level=OptLevel.O2, compute_s=9.0, memory_s=1.0)
    assert rec.step == Step.PE_DUPLICATION


def test_collective_bound_recommends_overlap_then_packing():
    rec = recommend(level=OptLevel.O3, compute_s=1.0, memory_s=1.0,
                    collective_s=9.0)
    assert rec.step == Step.DOUBLE_BUFFERING
    rec = recommend(level=OptLevel.O4, compute_s=1.0, memory_s=1.0,
                    collective_s=9.0)
    assert rec.step == Step.SCRATCHPAD_REORG


def test_all_applied_stops():
    rec = recommend(level=OptLevel.O5, compute_s=2.0, memory_s=1.0)
    assert rec.stop and rec.step is None


def test_comm_filter_matches_paper():
    assert comm_bound_filter(0.8, 1.0) is not None      # BFS
    assert comm_bound_filter(1.3, 1.0) is not None      # SPMV
    assert comm_bound_filter(0.059, 1.0) is None        # KMP
    assert comm_bound_filter(0.0022, 1.0) is None       # AES


def test_refine_walk_terminates_and_improves():
    for name in ("aes", "gemm", "nw"):
        records = refine_modelled(costmodel.MACHSUITE_PROFILES[name])
        assert records[-1].level == OptLevel.O5 or \
            "STOP" in records[-1].recommendation
        assert records[-1].speedup_vs_baseline > 30


def test_refine_walk_rejects_comm_bound():
    records = refine_modelled(costmodel.MACHSUITE_PROFILES["bfs"])
    assert "communication-bound" in records[0].recommendation
    assert len(records) == 1    # stopped before any step, like the paper
