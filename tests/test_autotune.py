"""Closed-loop autotuner: convergence, filter verdicts, trajectories, and
the shared measurement API (see also tests/test_machsuite.py for the full
O0..O5 output-equivalence matrix the tuner's candidates rely on)."""

import json

import numpy as np
import pytest

from repro.autotune import (CostTwinBackend, KernelModelBackend,
                            LM_STEP_OVERRIDES, ServingBackend, autotune,
                            read_trajectory, render_rounds, render_summary,
                            roofline_terms, write_trajectory)
from repro.autotune.trajectory import trajectory_path
from repro.core import costmodel
from repro.core.guideline import recommend
from repro.core.optlevel import STEP_ORDER, OptLevel, Step
from repro.core.refine import refine_modelled
from repro.machsuite import KERNELS

ACCEPTED = ("aes", "gemm", "kmp", "nw", "sort", "viterbi")
REJECTED = ("bfs", "spmv")   # paper Table 5: communication-bound


def tune(name, **kw):
    return autotune(
        KernelModelBackend(costmodel.MACHSUITE_PROFILES[name]), **kw)


# ---------------------------------------------------------------------------
# Convergence + stop conditions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ACCEPTED)
def test_modeled_time_monotone_non_increasing(name):
    totals = [r.measurement.total_s for r in tune(name).rounds]
    assert len(totals) >= 2
    for a, b in zip(totals, totals[1:]):
        assert b <= a * (1 + 1e-9), (name, totals)


@pytest.mark.parametrize("name", ACCEPTED)
def test_accepted_kernels_reach_o5_and_stop(name):
    res = tune(name)
    assert not res.rejected
    assert res.final_label == "O5"
    assert res.final.stop
    assert res.final_speedup > 100          # paper: orders of magnitude
    assert "all five steps applied" in res.final.recommendation


@pytest.mark.parametrize("name", REJECTED)
def test_comm_bound_kernels_rejected_before_any_step(name):
    res = tune(name)
    assert res.rejected
    assert len(res.rounds) == 1             # stopped at O0, like the paper
    assert res.steps_taken == []
    assert "communication-bound" in res.final.recommendation


def test_gemm_ladder_order_matches_paper():
    """Memory-bound start: caching before pipelining before PE duplication."""
    steps = tune("gemm").steps_taken
    assert steps[:3] == [Step.DATA_CACHING.value, Step.PIPELINING.value,
                         Step.PE_DUPLICATION.value]


def test_frontier_mode_no_worse_than_greedy():
    for name in ("gemm", "aes"):
        greedy = tune(name)
        frontier = tune(name, frontier=True)
        assert frontier.mode == "frontier"
        assert (frontier.final_total_s
                <= greedy.final_total_s * (1 + 1e-9)), name
        # every explored round logged its measured candidate frontier
        explored = [r for r in frontier.rounds if r.candidates]
        assert explored
        for r in explored:
            assert all(t > 0 for _, t in r.candidates)
        # on the cumulative ladder the frontier's minimal moves are one
        # level at a time — no O0->O5 jump that bundles five steps
        labels = [r.label for r in frontier.rounds]
        assert labels == [f"O{i}" for i in range(len(labels))], name


def test_max_rounds_budget_respected():
    res = tune("gemm", max_rounds=2)
    assert len(res.rounds) <= 3             # 2 diagnosed + final log round
    assert res.rounds[-1].stop


# ---------------------------------------------------------------------------
# Semantics: the tuner's chosen level computes the same function
# ---------------------------------------------------------------------------

SMALL_SCALES = {"aes": 512 / 64e6, "gemm": 32 / 1024, "kmp": 1024 / 128e6,
                "nw": 0.5 / 4096, "sort": 64 / 262144 / 16,
                "viterbi": 0.5 / 62500}


@pytest.mark.parametrize("name", sorted(SMALL_SCALES))
def test_autotuned_level_is_output_equivalent(name, rng):
    res = tune(name)
    level = OptLevel(res.final.measurement.meta["level"])
    mod = KERNELS[name]
    inp = mod.make_inputs(rng, SMALL_SCALES[name])
    ref = np.asarray(mod.oracle(**inp))
    out = np.asarray(mod.run(level, **inp))
    if out.dtype.kind == "f":
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=1e-5)
    else:
        np.testing.assert_array_equal(out, ref)


# ---------------------------------------------------------------------------
# Resource feedback (paper Table 6): shrink, re-measure, keep walking
# ---------------------------------------------------------------------------

def test_resource_conflict_shrinks_and_walk_continues():
    """A configuration that over-subscribes the BRAM fabric must not stop
    the walk: the backend shrinks cache/PE/width, re-measures, and still
    reaches O5 (the paper's §5.2 PEs-vs-width trade, automated)."""
    prof = costmodel.MACHSUITE_PROFILES["aes"]
    hw = costmodel.FPGA_2012
    # the paper's own infeasible point: 128 PEs x 512-bit x 3 buffers
    assert costmodel.bram_demand(
        prof, OptLevel.O5, hw, cache_bytes=64 * 1024, pe=128,
        word_bits=512) > hw.bram_blocks

    res = autotune(KernelModelBackend(prof, cache_bytes=64 * 1024, pe=128))
    assert res.final_label == "O5" and not res.rejected
    fit = res.final.measurement.meta["resource"]
    assert fit["shrunk"] is True
    assert fit["demand_blocks"] <= fit["budget_blocks"]
    # the requested (infeasible) config is recorded next to the fit
    assert fit["requested"]["demand_blocks"] > hw.bram_blocks
    # feasible rungs below O5 are untouched
    for r in res.rounds[:-1]:
        assert r.measurement.meta["resource"]["shrunk"] is False, r.label


def test_resource_fit_prefers_fastest_feasible():
    """The fit re-measures candidates rather than blindly halving: for AES
    (width-bound conflict) narrowing the scratchpad word keeps all 128 PEs
    instead of folding PEs, because that candidate measures faster."""
    prof = costmodel.MACHSUITE_PROFILES["aes"]
    fit = costmodel.fit_resources(prof, OptLevel.O5,
                                  cache_bytes=64 * 1024, pe=128)
    assert fit["shrunk"]
    assert fit["pe"] == 128                  # PEs kept
    assert fit["word_bits"] < 512            # width traded instead
    t_fit = costmodel.kernel_time(
        prof, OptLevel.O5, cache_bytes=fit["cache_bytes"], pe=fit["pe"],
        word_bits=fit["word_bits"])["system_s"]
    # strictly better than the naive halve-the-PEs resolution
    t_fold = costmodel.kernel_time(
        prof, OptLevel.O5, cache_bytes=64 * 1024, pe=64)["system_s"]
    assert t_fit < t_fold


def test_feasible_config_never_shrunk():
    prof = costmodel.MACHSUITE_PROFILES["gemm"]
    fit = costmodel.fit_resources(prof, OptLevel.O5,
                                  cache_bytes=64 * 1024, pe=128)
    assert fit["shrunk"] is False
    assert fit["cache_bytes"] == 64 * 1024 and fit["pe"] == 128
    # below O1 there are no on-chip buffers at all
    assert costmodel.bram_demand(prof, OptLevel.O0, costmodel.FPGA_2012,
                                 cache_bytes=64 * 1024, pe=128,
                                 word_bits=512) == 0


# ---------------------------------------------------------------------------
# ServingBackend: ladder state machine (no jax work — measure is exercised
# by the slow-tier walk below and by benchmarks/serving_ladder.py)
# ---------------------------------------------------------------------------

def test_serving_backend_ladder_state_machine():
    b = ServingBackend("qwen3-8b", repeats=1, n_requests=2)
    s = b.initial_state()
    assert b.name == "serve/qwen3-8b"
    assert b.describe(s) == "O0" and b.applied(s) == set()
    assert b.candidate_steps(s) == [Step.DATA_CACHING]
    s = b.apply(s, Step.DATA_CACHING)
    assert s == OptLevel.O1
    # the serving ladder continues past the paper's five to the paged
    # rung and then the speculative rung
    assert b.candidate_steps(OptLevel.O5) == [Step.PAGED_SCRATCHPAD]
    assert b.apply(OptLevel.O5, Step.PAGED_SCRATCHPAD) == OptLevel.O6
    assert b.candidate_steps(OptLevel.O6) == [Step.SPECULATIVE]
    assert b.apply(OptLevel.O6, Step.SPECULATIVE) == OptLevel.O7
    assert b.candidate_steps(OptLevel.O7) == []
    # paper-scoped backends still top out at O5
    kb = KernelModelBackend(costmodel.MACHSUITE_PROFILES["gemm"])
    assert kb.candidate_steps(OptLevel.O5) == []
    with pytest.raises(ValueError, match="paged_attn"):
        ServingBackend("qwen3-8b", paged_attn="flash")
    with pytest.raises(ValueError, match="draft_k"):
        ServingBackend("qwen3-8b", draft_k="huge")
    with pytest.raises(ValueError, match="draft_k"):
        ServingBackend("qwen3-8b", draft_k=-1)


def test_serving_backend_measures_paged_attn_by_race():
    """At the paged rung with ``paged_attn="auto"`` the backend measures
    BOTH the gather step and the gather-free kernel step on interleaved
    repeats, keeps the winner (gather on tie/loss), and records the race
    in meta — the AutoDSE keep-only-when-it-wins rule applied to the
    attention implementation knob."""
    b = ServingBackend("qwen3-8b", batch_size=2, max_seq=16, n_requests=3,
                       max_new=3, repeats=1, kv_block_size=4,
                       kv_dtype="bf16")
    m = b.measure(OptLevel.O6)
    walls = m.meta["paged_attn_walls"]
    assert set(walls) == {"gather", "kernel"}
    assert all(w > 0 for w in walls.values())
    assert m.meta["paged_attn"] in ("gather", "kernel")
    # the winner rule: kernel only displaces gather beyond the 1% floor
    if walls["kernel"] < 0.99 * walls["gather"]:
        assert m.meta["paged_attn"] == "kernel"
    else:
        assert m.meta["paged_attn"] == "gather"
    # total_s is the winning cell's floor; the chunked-prefill race may
    # displace it (prefill_chunk > 0) — otherwise it equals the
    # attn-race winner's wall (refined in place by the chunk race's
    # extra interleaved repeats)
    if m.meta["prefill_chunk"]:
        assert m.total_s == m.meta["prefill_chunk_walls"][m.meta["prefill_chunk"]]
    else:
        assert m.total_s == walls[m.meta["paged_attn"]]
    # below the paged rung there is no race and no race meta
    m5 = b.measure(OptLevel.O5)
    assert "paged_attn_walls" not in m5.meta

    # pinning the knob skips the race but still records the impl
    bk = ServingBackend("qwen3-8b", batch_size=2, max_seq=16, n_requests=3,
                        max_new=3, repeats=1, kv_block_size=4,
                        paged_attn="kernel", kv_dtype="bf16")
    mk = bk.measure(OptLevel.O6)
    assert mk.meta["paged_attn"] == "kernel"
    assert list(mk.meta["paged_attn_walls"]) == ["kernel"]
    assert mk.meta["generated"] == m.meta["generated"]

    # recurrent families race the kernel rung for real now: rwkv6's
    # paged step reads state through row indirection, so pinning
    # "kernel" runs the kernel path (no silent gather degrade) and the
    # meta records the state impl alongside
    br = ServingBackend("rwkv6-3b", batch_size=2, max_seq=16, n_requests=2,
                        max_new=3, repeats=1, kv_block_size=4,
                        paged_attn="kernel", kv_dtype="bf16")
    mr = br.measure(OptLevel.O6)
    assert mr.meta["paged_attn"] == "kernel"
    assert list(mr.meta["paged_attn_walls"]) == ["kernel"]
    assert mr.meta["state_impl"] == "rows"


def test_serving_backend_races_kv_dtype():
    """At the paged rung ``kv_dtype="auto"`` races the chosen bf16
    engine against an int8 twin holding EQUAL pool bytes (the saved
    token bytes buy extra blocks); narrow displaces bf16 only beyond
    the 1% noise floor, and meta records both walls plus the measured
    token agreement, which must clear the int8 tolerance contract."""
    from repro.serving.kvquant import tolerance_contract

    b = ServingBackend("qwen3-8b", batch_size=2, max_seq=16, n_requests=3,
                       max_new=3, repeats=1, kv_block_size=4,
                       paged_attn="gather", prefill_chunk=0)
    m = b.measure(OptLevel.O6)
    walls = m.meta["kv_dtype_walls"]
    assert set(walls) == {"bf16", "int8"}
    assert all(w > 0 for w in walls.values())
    assert m.meta["kv_agreement"] >= tolerance_contract("int8")[
        "min_agreement"]
    # the winner rule: narrow only displaces bf16 beyond the 1% floor,
    # and total_s is always the shipped engine's wall
    if walls["int8"] < 0.99 * walls["bf16"]:
        assert m.meta["kv_dtype"] == "int8"
        assert m.total_s == walls["int8"]
    else:
        assert m.meta["kv_dtype"] == "bf16"
        assert m.total_s == walls["bf16"]

    # below the paged rung there is no pool, hence no race
    m5 = b.measure(OptLevel.O5)
    assert "kv_dtype_walls" not in m5.meta
    assert m5.meta["kv_dtype"] == "bf16"

    # pinning int8 skips the keep-decision (narrow always ships) but
    # still measures and records both walls
    bq = ServingBackend("qwen3-8b", batch_size=2, max_seq=16, n_requests=3,
                        max_new=3, repeats=1, kv_block_size=4,
                        paged_attn="gather", prefill_chunk=0,
                        kv_dtype="int8")
    mq = bq.measure(OptLevel.O6)
    assert mq.meta["kv_dtype"] == "int8"
    assert set(mq.meta["kv_dtype_walls"]) == {"bf16", "int8"}
    assert mq.total_s == mq.meta["kv_dtype_walls"]["int8"]

    with pytest.raises(ValueError, match="kv_dtype"):
        ServingBackend("qwen3-8b", kv_dtype="int4")


@pytest.mark.slow
def test_serving_ladder_walk_identical_tokens():
    """The full measured O0->O7 serving walk: eight rounds, every level's
    generations bit-identical under greedy sampling — including the paged
    O6 rung at reduced pool capacity (forces queueing) and the
    speculative O7 rung (pinned K so the walk stays one engine per
    round)."""
    b = ServingBackend("qwen3-8b", batch_size=2, max_seq=24, n_requests=4,
                       max_new=4, repeats=1, kv_block_size=8,
                       kv_pool_blocks=5, draft_k=4, kv_dtype="bf16")
    res = autotune(b, ladder=True)
    assert res.mode == "ladder" and not res.rejected
    assert [r.label for r in res.rounds] == [f"O{i}" for i in range(8)]
    gens = [r.measurement.meta["generated"] for r in res.rounds]
    assert all(g == gens[0] for g in gens)
    assert all(r.measurement.total_s > 0 for r in res.rounds)
    caps = [r.measurement.meta["kv_capacity"] for r in res.rounds]
    assert caps[:6] == [2 * 24] * 6 and caps[6:] == [5 * 8] * 2
    assert res.rounds[7].measurement.meta["draft_k_walls"].keys() == {0, 4}


def test_serving_backend_races_draft_k():
    """At the speculative rung ``draft_k="auto"`` races K in {0,2,4,8} on
    interleaved repeats; the winner displaces the K=0 incumbent only
    beyond the 1% noise floor, and meta records every measured wall plus
    the chosen engine's acceptance telemetry."""
    b = ServingBackend("qwen3-8b", batch_size=2, max_seq=16, n_requests=3,
                       max_new=3, repeats=1, kv_block_size=4,
                       paged_attn="gather", prefill_chunk=0,
                       kv_dtype="bf16")
    m = b.measure(OptLevel.O7)
    walls = m.meta["draft_k_walls"]
    assert set(walls) == {0, 2, 4, 8}
    assert all(w > 0 for w in walls.values())
    best_k = min((k for k in walls if k), key=lambda k: walls[k])
    if walls[best_k] < 0.99 * walls[0]:
        assert m.meta["draft_k"] == best_k
        assert m.meta["spec_mode"] == "draft"
        assert m.total_s == walls[best_k]
    else:
        assert m.meta["draft_k"] == 0
        assert m.meta["spec_mode"] == "off"
        assert m.total_s == walls[0]
    assert 0.0 <= m.meta["accept_rate"] <= 1.0
    assert m.meta["eff_tok_per_step"] >= 0.0

    # pinning draft_k=0 disables the race (and speculation) entirely
    b0 = ServingBackend("qwen3-8b", batch_size=2, max_seq=16, n_requests=3,
                        max_new=3, repeats=1, kv_block_size=4,
                        paged_attn="gather", prefill_chunk=0, draft_k=0,
                        kv_dtype="bf16")
    m0 = b0.measure(OptLevel.O7)
    assert "draft_k_walls" not in m0.meta
    assert m0.meta["spec_mode"] == "off" and m0.meta["draft_k"] == 0
    assert m0.meta["generated"] == m.meta["generated"]

    # a family whose model cannot verify (no multi-token step) degrades
    # to plain decode — no race, no walls, spec_mode says so
    br = ServingBackend("rwkv6-3b", batch_size=2, max_seq=16, n_requests=2,
                        max_new=3, repeats=1, kv_block_size=4,
                        kv_dtype="bf16")
    mr = br.measure(OptLevel.O7)
    assert "draft_k_walls" not in mr.meta
    assert mr.meta["spec_mode"] == "off"


def test_ladder_mode_on_kernel_backend_measures_every_rung():
    res = autotune(KernelModelBackend(costmodel.MACHSUITE_PROFILES["gemm"]),
                   ladder=True)
    assert res.mode == "ladder"
    assert [r.label for r in res.rounds] == [f"O{i}" for i in range(6)]
    assert res.final.stop


# ---------------------------------------------------------------------------
# Guideline: explicit applied-set API (the LM frontier's entry point)
# ---------------------------------------------------------------------------

def test_recommend_applied_set_matches_level():
    by_level = recommend(level=OptLevel.O1, compute_s=9.0, memory_s=1.0)
    by_set = recommend(applied={Step.DATA_CACHING},
                       compute_s=9.0, memory_s=1.0)
    assert by_level.step == by_set.step == Step.PIPELINING


def test_recommend_applied_set_stop_and_ordering():
    rec = recommend(applied=set(STEP_ORDER), compute_s=1.0, memory_s=2.0)
    assert rec.stop and rec.step is None
    rec = recommend(applied=set(), compute_s=1.0, memory_s=5.0)
    assert rec.step == Step.DATA_CACHING    # caching strictly first
    rec = recommend(applied={Step.DATA_CACHING, Step.DOUBLE_BUFFERING},
                    compute_s=1.0, memory_s=5.0)
    assert rec.step == Step.SCRATCHPAD_REORG


def test_recommend_requires_level_or_applied():
    with pytest.raises(TypeError):
        recommend(compute_s=1.0, memory_s=1.0)


# ---------------------------------------------------------------------------
# Trajectories: JSONL round-trip + rendering
# ---------------------------------------------------------------------------

def test_trajectory_roundtrip_and_render(tmp_path):
    res = tune("gemm")
    path = write_trajectory(res, out_dir=str(tmp_path))
    assert path == trajectory_path("gemm", str(tmp_path))
    recs = read_trajectory(path)
    assert len(recs) == len(res.rounds)
    assert [r["label"] for r in recs] == [f"O{i}" for i in range(6)]
    for r in recs:
        assert r["target"] == "gemm" and r["mode"] == "greedy"
        assert set(r["measurement"]) >= {
            "compute_s", "memory_s", "total_s", "dominant"}
        json.dumps(r)                        # every row stays serializable
    table = render_rounds(recs)
    assert table.count("\n") == len(recs) + 1
    summary = render_summary([res, tune("bfs")])
    assert "REJECT (comm-bound)" in summary and "O5" in summary


def test_cli_kernel_mode(tmp_path, capsys):
    from repro.autotune.__main__ import main

    assert main(["--kernel", "gemm", "--out", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "VERDICT: O5" in out
    assert (tmp_path / "gemm.jsonl").exists()
    assert main(["--kernel", "spmv", "--out", str(tmp_path)]) == 0
    assert "REJECT" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# Shared measurement API
# ---------------------------------------------------------------------------

def test_refine_modelled_compat_matches_tuner():
    """core.refine's public record stream is now a view of the tuner."""
    records = refine_modelled(costmodel.MACHSUITE_PROFILES["gemm"])
    rounds = tune("gemm").rounds
    assert [int(r.level) for r in records] == \
        [r.measurement.meta["level"] for r in rounds]
    assert [r.recommendation for r in records] == \
        [r.recommendation for r in rounds]
    np.testing.assert_allclose(
        [r.speedup_vs_baseline for r in records],
        [r.speedup_vs_start for r in rounds])


def test_roofline_terms_arithmetic():
    rec = roofline_terms(197e12, 819e9 * 2, 50e9 / 2, chips=4,
                         model_flops=197e12 * 2)
    assert rec["compute_s"] == pytest.approx(1.0)
    assert rec["memory_s"] == pytest.approx(2.0)
    assert rec["collective_s"] == pytest.approx(0.5)
    assert rec["dominant"] == "memory"
    assert rec["step_time_s"] == pytest.approx(2.0)
    assert rec["roofline_fraction"] == pytest.approx(0.25)  # 2/(4*1)/2
    assert rec["useful_flops_fraction"] == pytest.approx(0.5)
    fused = roofline_terms(3 * 197e12, 4 * 819e9, 0.0,
                           fused_bytes_per_device=819e9)
    assert fused["dominant"] == "memory"
    assert fused["memory_fused_s"] == pytest.approx(1.0)
    assert fused["dominant_fused"] == "compute"   # fusion flips the verdict
    assert fused["step_time_fused_s"] == pytest.approx(3.0)


def test_cost_twin_backend_state_machine():
    """Override mapping + independent-step state (no compile involved)."""
    b = CostTwinBackend("qwen3-8b", "train_4k",
                        base_overrides={"loss_chunk": 64})
    s0 = b.initial_state()
    assert b.applied(s0) == set() and b.describe(s0) == "O0"
    assert b.overrides_for(s0) == {"loss_chunk": 64}
    s = b.apply(s0, Step.SCRATCHPAD_REORG)     # steps are independent:
    assert b.applied(s) == {Step.SCRATCHPAD_REORG}   # no ladder jump
    ov = b.overrides_for(s)
    assert ov["scores_dtype"] == "bfloat16" and ov["loss_chunk"] == 64
    s = b.apply(s, Step.DATA_CACHING)
    assert b.overrides_for(s)["cast_params_once"] is True
    assert set(b.candidate_steps(s)) == set(STEP_ORDER) - b.applied(s)
    # every declared step maps to overrides drawn from real ArchConfig fields
    from repro.configs.base import ArchConfig
    import dataclasses
    fields = {f.name for f in dataclasses.fields(ArchConfig)}
    for step, ov in LM_STEP_OVERRIDES.items():
        assert set(ov) <= fields, step
