"""Async traffic front end (launch/server.py): open-loop trace
generation, replay through :class:`AsyncServer`, streaming token
delivery, and the latency/goodput metric vocabulary — plus the core
contract that the async path's greedy tokens are BIT-IDENTICAL to the
synchronous ``submit()``/``run()`` path for the same admission order."""

import asyncio

import numpy as np

import jax
import pytest

from repro.configs import get_smoke
from repro.core.optlevel import BestEffortConfig, OptLevel
from repro.models import get_model
from repro.serving import DecodeEngine, Request
from repro.serving.kvquant import assert_tokens_match, tolerance_contract
from repro.launch.server import (AsyncServer, TokenEvent, latency_metrics,
                                 make_trace, replay_trace, serve_trace)

RNG = jax.random.PRNGKey(0)

_MODELS = {}


def _model(arch="qwen3-8b"):
    if arch not in _MODELS:
        cfg = get_smoke(arch)
        model = get_model(cfg)
        _MODELS[arch] = (cfg, model, model.init(RNG))
    return _MODELS[arch]


def _engine(arch="qwen3-8b", B=3, max_seq=32, **kw):
    cfg, model, params = _model(arch)
    return DecodeEngine(model, params, batch_size=B, max_seq=max_seq,
                        **kw), cfg


def _match(want, got, label, contract=tolerance_contract("bf16")):
    """Hold two {prompt: generated} maps to a ladder token contract."""
    assert set(want) == set(got), label
    keys = sorted(want)
    assert_tokens_match([want[k] for k in keys], [got[k] for k in keys],
                        contract, label)


# ---------------------------------------------------------------------------
# Traces (no model needed).
# ---------------------------------------------------------------------------

def test_trace_deterministic_per_seed():
    a = make_trace(n_requests=20, rate=10.0, seed=3)
    b = make_trace(n_requests=20, rate=10.0, seed=3)
    c = make_trace(n_requests=20, rate=10.0, seed=4)
    assert [(t.at_s, t.prompt, t.max_new_tokens) for t in a] \
        == [(t.at_s, t.prompt, t.max_new_tokens) for t in b]
    assert [t.prompt for t in a] != [t.prompt for t in c]


@pytest.mark.parametrize("pattern", ["poisson", "bursty"])
def test_trace_mean_rate_matches_target(pattern):
    rate = 25.0
    trace = make_trace(n_requests=400, rate=rate, seed=0, pattern=pattern)
    assert all(t.at_s > 0 for t in trace)
    assert all(b.at_s >= a.at_s for a, b in zip(trace, trace[1:]))
    measured = len(trace) / trace[-1].at_s
    assert 0.5 * rate < measured < 2.0 * rate, \
        f"{pattern} offered rate {measured:.1f}/s vs target {rate}/s"


def test_bursty_trace_actually_clumps():
    """The bursty pattern clumps: most gaps are short intra-burst spacing
    with rare long idles, so the median gap sits far below the mean —
    unlike poisson, where median/mean = ln 2.  That skew is its entire
    point (a burst of shorts convoying a long)."""
    kw = dict(n_requests=300, rate=10.0, seed=1)
    gaps = lambda tr: np.diff([0.0] + [t.at_s for t in tr])
    pois = gaps(make_trace(pattern="poisson", **kw))
    burs = gaps(make_trace(pattern="bursty", **kw))
    assert np.median(burs) / burs.mean() \
        < 0.7 * np.median(pois) / pois.mean()


def test_trace_rejects_bad_inputs():
    with pytest.raises(ValueError, match="pattern"):
        make_trace(n_requests=4, rate=1.0, pattern="carrier-pigeon")
    with pytest.raises(ValueError, match="rate"):
        make_trace(n_requests=4, rate=0.0)


def test_trace_deadline_slack_attached():
    trace = make_trace(n_requests=5, rate=10.0, deadline_slack_s=2.5)
    assert all(t.deadline_s == 2.5 for t in trace)


# ---------------------------------------------------------------------------
# Metrics (synthetic Request records; no model needed).
# ---------------------------------------------------------------------------

def _rec(*, arrival=0.0, first=0.1, finish=0.5, n_gen=5, truncated=False,
         deadline=None):
    r = Request(prompt=[1], max_new_tokens=n_gen, deadline_s=deadline)
    r.generated = list(range(n_gen))
    r.arrival_s, r.first_token_s, r.finish_s = arrival, first, finish
    r.truncated = truncated
    r.done = True
    return r


def test_latency_metrics_percentiles_and_goodput():
    fin = [
        _rec(arrival=0.0, first=0.1, finish=0.5, n_gen=5),    # good
        _rec(arrival=0.0, first=0.9, finish=1.0, n_gen=2),    # ttft miss
        _rec(arrival=0.0, first=0.1, finish=9.0, n_gen=5),    # tpot miss
        _rec(arrival=0.0, first=0.1, finish=0.2, n_gen=5,
             truncated=True),                                 # truncated
    ]
    m = latency_metrics(fin, makespan_s=2.0, ttft_slo_s=0.5, tpot_slo_s=0.2)
    assert m["requests"] == 4 and m["tokens"] == 17
    assert m["good_requests"] == 1
    assert m["goodput_rps"] == pytest.approx(0.5)
    assert m["goodput_frac"] == pytest.approx(0.25)
    assert m["ttft_p50_s"] == pytest.approx(0.1)
    assert m["ttft_p99_s"] <= 0.9 + 1e-9
    # tpot for the good record: (0.5 - 0.1) / 4 = 0.1
    assert m["tpot_p50_s"] == pytest.approx(0.1, abs=0.15)
    assert m["throughput_rps"] == pytest.approx(2.0)


def test_latency_metrics_deadline_miss_not_good():
    ok = _rec(arrival=0.0, first=0.1, finish=0.4, n_gen=3, deadline=1.0)
    late = _rec(arrival=0.0, first=0.1, finish=5.0, n_gen=3, deadline=1.0)
    late.finish_s = 5.0
    m = latency_metrics([ok, late], makespan_s=1.0, tpot_slo_s=10.0)
    assert m["good_requests"] == 1


def test_latency_metrics_empty():
    m = latency_metrics([], makespan_s=1.0)
    assert m["requests"] == 0 and m["goodput_frac"] == 0.0
    assert m["ttft_p50_s"] == 0.0


# ---------------------------------------------------------------------------
# AsyncServer integration (tiny smoke model; fast tier).
# ---------------------------------------------------------------------------

def _sync_tokens(prompts_and_lens, *, arch="qwen3-8b", B=3, max_seq=32,
                 **kw):
    """Reference completion via the synchronous submit()/run() path."""
    eng, _ = _engine(arch, B=B, max_seq=max_seq, **kw)
    for prompt, n in prompts_and_lens:
        eng.submit(Request(prompt=list(prompt), max_new_tokens=n))
    fin = eng.run()
    return {tuple(r.prompt): r.generated for r in fin}


def test_async_server_tokens_bit_identical_to_sync():
    jobs = [([5, 6, 7], 6), ([9, 3], 4), ([2, 2, 2, 2], 5), ([11], 3),
            ([4, 8], 7)]
    want = _sync_tokens(jobs)

    async def _run():
        eng, _ = _engine()
        async with AsyncServer(eng) as server:
            handles = [server.submit(p, max_new_tokens=n) for p, n in jobs]
            done = await asyncio.gather(*(h.done for h in handles))
        return {tuple(r.prompt): r.generated for r in done}

    got = asyncio.run(_run())
    _match(want, got, "async vs sync")


def test_async_server_streams_every_token_in_order():
    async def _run():
        eng, _ = _engine(B=2)
        events = []
        async with AsyncServer(eng) as server:
            h1 = server.submit([5, 6, 7], max_new_tokens=5,
                               on_token=events.append)
            h2 = server.submit([9, 3], max_new_tokens=4)
            streamed = [ev async for ev in h2.tokens()]
            r1, r2 = await h1.done, await h2.done
        return h1, h2, events, streamed, r1, r2

    h1, h2, events, streamed, r1, r2 = asyncio.run(_run())
    assert all(isinstance(ev, TokenEvent) for ev in events)
    # callback saw exactly h1's completion, in emission order
    assert [ev.token for ev in events] == r1.generated
    assert [ev.index for ev in events] == list(range(len(r1.generated)))
    assert all(ev.rid == h1.rid for ev in events)
    # async-iterated stream saw exactly h2's completion
    assert [ev.token for ev in streamed] == r2.generated
    assert len(r1.generated) == 5 and len(r2.generated) == 4


def test_async_server_concurrent_staggered_submits():
    """Arrivals landing WHILE the engine ticks still finish, and still
    match the sync reference for the same admission order."""
    jobs = [([5, 6, 7], 4), ([9, 3], 3), ([1, 2, 3, 4], 5), ([7], 3)]
    want = _sync_tokens(jobs, B=2)

    async def _run():
        eng, _ = _engine(B=2)
        async with AsyncServer(eng) as server:
            handles = []
            for p, n in jobs:
                handles.append(server.submit(p, max_new_tokens=n))
                # let the tick loop interleave between arrivals
                for _ in range(3):
                    await asyncio.sleep(0)
            done = await asyncio.gather(*(h.done for h in handles))
        return {tuple(r.prompt): r.generated for r in done}

    got = asyncio.run(_run())
    _match(want, got, "staggered async vs sync")


def test_async_server_degenerate_request_resolves_immediately():
    async def _run():
        eng, _ = _engine()
        async with AsyncServer(eng) as server:
            h = server.submit([1, 2], max_new_tokens=0)
            req = await h.done
            evs = [ev async for ev in h.tokens()]
        return req, evs

    req, evs = asyncio.run(_run())
    assert req.done and req.generated == [] and evs == []


def test_async_server_rejects_oversized_like_sync():
    async def _run():
        eng, _ = _engine(max_seq=16)
        async with AsyncServer(eng) as server:
            with pytest.raises(ValueError, match="max_seq"):
                server.submit([1] * 10, max_new_tokens=10)
            h = server.submit([1, 2], max_new_tokens=2)
            await h.done

    asyncio.run(_run())


def test_async_server_stop_fails_outstanding_futures():
    async def _run():
        eng, _ = _engine()
        server = await AsyncServer(eng, max_ticks=1).start()
        h = server.submit([5, 6, 7], max_new_tokens=8)
        with pytest.raises(RuntimeError, match="tick budget"):
            await h.done
        await server.stop()
        return h.request

    req = asyncio.run(_run())
    assert req.truncated


def test_serve_trace_end_to_end_metrics():
    eng, cfg = _engine(B=2)
    trace = make_trace(n_requests=6, rate=50.0, seed=0, vocab=cfg.vocab,
                       prompt_len=(2, 6), max_new=(2, 5))
    out = serve_trace(eng, trace, time_scale=0.0)   # fire ASAP
    assert len(out["finished"]) == 6
    assert out["ticks"] > 0
    m = latency_metrics(out["finished"], makespan_s=out["makespan_s"],
                        ttft_slo_s=60.0, tpot_slo_s=60.0)
    assert m["requests"] == 6
    assert m["good_requests"] == 6          # SLOs are generous
    assert m["tok_per_s"] > 0
    assert m["ttft_p50_s"] >= 0 and m["tpot_p50_s"] >= 0


def test_serve_trace_paged_engine_bit_identical():
    """The front end composes with the O6 paged engine, and its tokens
    still match the sync reference."""
    trace = make_trace(n_requests=5, rate=100.0, seed=2, vocab=64,
                       prompt_len=(2, 6), max_new=(2, 5))
    kw = dict(config=BestEffortConfig(level=OptLevel.O6, kv_block_size=4))
    jobs = [(t.prompt, t.max_new_tokens) for t in trace]
    want = _sync_tokens(jobs, **kw)
    eng, _ = _engine(**kw)
    out = serve_trace(eng, trace, time_scale=0.0)
    got = {tuple(r.prompt): r.generated for r in out["finished"]}
    _match(want, got, "trace paged vs sync")


def test_serve_trace_quantized_engine_within_contract():
    """The front end also composes with the int8 pool: the replayed
    trace's tokens are held to the narrow tolerance contract against
    the bf16 sync reference, not bit-identity."""
    trace = make_trace(n_requests=5, rate=100.0, seed=2, vocab=64,
                       prompt_len=(2, 6), max_new=(2, 5))
    jobs = [(t.prompt, t.max_new_tokens) for t in trace]
    want = _sync_tokens(jobs, config=BestEffortConfig(
        level=OptLevel.O6, kv_block_size=4))
    eng, _ = _engine(config=BestEffortConfig(
        level=OptLevel.O6, kv_block_size=4, kv_dtype="int8"))
    out = serve_trace(eng, trace, time_scale=0.0)
    got = {tuple(r.prompt): r.generated for r in out["finished"]}
    _match(want, got, "trace int8 vs sync bf16",
           contract=tolerance_contract("int8"))


# ---------------------------------------------------------------------------
# Nightly tier: the full traffic harness smoke (sweeps 3 rates, writes
# JSONL + markdown section, validates the required fields).
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_traffic_harness_smoke(tmp_path, monkeypatch):
    import benchmarks.traffic_harness as th

    monkeypatch.setattr(th, "OUT_DIR", str(tmp_path))
    monkeypatch.setattr(th, "MD_PATH", str(tmp_path / "ladder.md"))
    rows = th.main(["--arch", "qwen3-8b", "--rates", "5,20,80",
                    "--requests", "6", "--batch", "2", "--max-seq", "32",
                    "--no-md", "--smoke"])
    assert len(rows) == 3
    paths = list(tmp_path.glob("traffic__*.jsonl"))
    assert paths, "harness wrote no JSONL"
    th.check_jsonl(str(paths[0]))
