"""§Perf knobs are semantics-preserving: microbatch accumulation, remat
policies, cast_params_once, scores_dtype, and the merged-heads attention
layout all compute the same function."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import ShapeConfig
from repro.core import hlo_stats
from repro.launch import steps
from repro.launch.mesh import make_host_mesh
from repro.models import get_model, make_batch
from repro.optim import adamw
from repro.parallel.sharding import use_sharder

RNG = jax.random.PRNGKey(0)
SHAPE = ShapeConfig("t", 64, 8, "train")


def _one_step(cfg, params, opt, batch):
    art = steps.build_train(cfg, SHAPE, make_host_mesh())
    with art.sharder.mesh, use_sharder(art.sharder):
        copy = lambda t: jax.tree.map(lambda x: x + 0, t)
        return art.jit()(copy(params), copy(opt), batch)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("qwen3-8b")
    model = get_model(cfg)
    params = model.init(RNG)
    opt = adamw.init_state(adamw.AdamWConfig(), params)
    batch = make_batch(cfg, SHAPE, RNG)
    p0, o0, m0 = _one_step(cfg, params, opt, batch)
    return cfg, params, opt, batch, p0, float(m0["loss"])


@pytest.mark.parametrize("overrides", [
    {"microbatch": 2}, {"microbatch": 4},
    {"remat_policy": "dots"}, {"remat_policy": "none"},
    {"cast_params_once": True},
    {"microbatch": 4, "remat_policy": "dots", "cast_params_once": True},
])
def test_knob_equivalence(setup, overrides):
    cfg, params, opt, batch, p0, loss0 = setup
    cfg2 = dataclasses.replace(cfg, **overrides)
    p2, o2, m2 = _one_step(cfg2, params, opt, batch)
    assert abs(float(m2["loss"]) - loss0) < 5e-3, overrides
    delta = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        p0, p2)))
    assert delta < 5e-2, (overrides, delta)


def test_scores_dtype_close(setup):
    cfg, params, opt, batch, p0, loss0 = setup
    cfg2 = dataclasses.replace(cfg, scores_dtype="bfloat16")
    _, _, m2 = _one_step(cfg2, params, opt, batch)
    assert abs(float(m2["loss"]) - loss0) < 2e-2


def test_ce_loss_handles_unaligned_seq():
    """The internvl 32768-256 prefill regression: S not divisible by
    loss_chunk must still evaluate."""
    from repro.models.layers import chunked_cross_entropy, PDef, init_params
    B, S, d, V = 2, 28, 16, 64     # 28 % 8 != 0
    params = {"lm_head": jnp.ones((d, V), jnp.bfloat16) * 0.01}
    h = jnp.ones((B, S, d), jnp.bfloat16)
    labels = jnp.zeros((B, S), jnp.int32)
    loss = chunked_cross_entropy(h, params, labels, chunk=8)
    assert np.isfinite(float(loss))


# ---------------------------------------------------------------------------
# measurement infrastructure
# ---------------------------------------------------------------------------

def test_fused_bytes_counts_boundaries_only():
    txt = """
ENTRY main {
  %p0 = f32[128,128]{1,0} parameter(0)
  %c0 = bf16[128,128]{1,0} convert(%p0)
  %d = bf16[128,128]{1,0} dot(%c0, %c0), lhs_contracting_dims={1}
  %a = bf16[128,128]{1,0} add(%d, %d)
  %f = bf16[128,128]{1,0} fusion(%a), kind=kLoop, calls=%fc
}
"""
    fb = hlo_stats.fused_bytes(txt)
    n = 128 * 128
    # dot: 2 operands bf16 + result; fusion: operand + result.
    # convert/add are elementwise (fused on the TPU target) -> excluded.
    assert fb == (3 * 2 * n) + (2 * 2 * n)


def test_promoted_allreduce_counted_at_bf16_width():
    base = """
ENTRY main {{
  %p0 = f32[256]{{0}} parameter(0)
  %ar = f32[256]{{0}} all-reduce(%p0), to_apply=%add{suffix}
}}
"""
    plain = hlo_stats.parse_hlo(base.format(suffix=""))
    promoted = hlo_stats.parse_hlo(base.format(suffix=".clone_promoted"))
    assert plain.collective_bytes == 256 * 4
    assert promoted.collective_bytes == 256 * 2   # counted at bf16 width
