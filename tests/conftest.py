"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the single real CPU device (the 512-device override belongs to
launch/dryrun.py only; subprocess tests set their own env)."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    # Also registered in pyproject.toml; kept here so ad-hoc invocations
    # with an alternate rootdir still know the tiers.
    config.addinivalue_line(
        "markers", "slow: compile-heavy / long-running test "
        "(deselected by default; run with -m slow)")
    config.addinivalue_line(
        "markers", "dist: multi-device subprocess integration test "
        "(deselected by default; run with -m dist)")
