"""Faithful-reproduction checks: the analytic FPGA model against every
number range the paper prints (abstract, Tables 4/5, Figures 1/6/9/12)."""

import math

import pytest

from repro.core import costmodel
from repro.core.costmodel import (MACHSUITE_PROFILES, kernel_time,
                                  paper_validation_table, refinement_curve)
from repro.core.optlevel import OptLevel

# Paper Table 5 (PCIe transfer time / CPU runtime)
TABLE5 = {
    "aes": 2.2e-3, "bfs": 0.8, "gemm": 6.0e-4, "kmp": 5.9e-2,
    "nw": 1.5e-3, "sort": 4.9e-3, "spmv": 1.3, "viterbi": 1.4e-2,
}

# Paper Table 4 (pipelining speedup on computation)
TABLE4 = {
    "aes": 1.4, "bfs": 1.4, "gemm": 10.5, "kmp": 7.0,
    "nw": 8.8, "sort": 1.8, "spmv": 10.9, "viterbi": 3.2,
}


def test_pcie_ratios_match_table5():
    for name, prof in MACHSUITE_PROFILES.items():
        t = kernel_time(prof, OptLevel.O0)
        ratio = t["pcie_s"] / prof.cpu_time_s
        assert ratio == pytest.approx(TABLE5[name], rel=0.55), name


def test_comm_bound_kernels_rejected_like_paper():
    """BFS and SPMV (and only they) fail the Table 5 filter."""
    from repro.core.guideline import COMM_BOUND_THRESHOLD
    for name, prof in MACHSUITE_PROFILES.items():
        t = kernel_time(prof, OptLevel.O0)
        ratio = t["pcie_s"] / prof.cpu_time_s
        assert (ratio > COMM_BOUND_THRESHOLD) == (name in ("bfs", "spmv")), \
            name


def test_pipelining_speedups_match_table4():
    """O1 -> O2 computation speedup reproduces Table 4 (the II/latency
    parameters are independent inputs; the N*L -> N*ii + L formula does
    the rest)."""
    for name, prof in MACHSUITE_PROFILES.items():
        t1 = kernel_time(prof, OptLevel.O1)
        t2 = kernel_time(prof, OptLevel.O2)
        speedup = t1["compute_s"] / t2["compute_s"]
        assert speedup == pytest.approx(TABLE4[name], rel=0.30), (
            name, speedup)


def test_headline_numbers_in_paper_ranges():
    t = paper_validation_table()
    agg = t.pop("_aggregate")
    # abstract: naive accelerators average ~292.5x slowdown
    assert 150 <= agg["gmean_naive_slowdown"] <= 500
    # abstract: improvement 42x..29030x per kernel
    for name, row in t.items():
        assert 30 <= row["improvement"] <= 40_000, (name, row)
    # abstract: ~34.4x average speedup over the Xeon core
    mean_speedup = sum(r["final_speedup"] for r in t.values()) / len(t)
    assert 15 <= mean_speedup <= 70, mean_speedup
    # Fig. 12: except BFS/SPMV every kernel beats the CPU by >= 4.7x
    for name, row in t.items():
        if name not in ("bfs", "spmv"):
            assert row["final_speedup"] >= 4.0, (name, row)
    # paper conclusion: best kernel up to ~112.8x
    assert 40 <= max(r["final_speedup"] for r in t.values()) <= 250


def test_caching_size_insensitivity_fig6():
    """Fig. 6: 64KB / 1MB / infinite caching sizes perform alike; 2KB may
    differ but stays within ~2x (the burst-init amortization curve)."""
    for name, prof in MACHSUITE_PROFILES.items():
        t64k = kernel_time(prof, OptLevel.O5, cache_bytes=64 * 1024)
        t1m = kernel_time(prof, OptLevel.O5, cache_bytes=1024 * 1024)
        assert t1m["system_s"] == pytest.approx(t64k["system_s"], rel=0.10)
        t2k = kernel_time(prof, OptLevel.O5, cache_bytes=2 * 1024)
        assert t2k["system_s"] <= 2.5 * t64k["system_s"], name


def test_pe_scaling_fig9():
    """Near-linear compute scaling for fully-parallel kernels; sub-linear
    for SORT (tree reduce); inapplicable for BFS."""
    prof = MACHSUITE_PROFILES["nw"]
    c1 = kernel_time(prof, OptLevel.O3, pe=1)["compute_s"]
    c64 = kernel_time(prof, OptLevel.O3, pe=64)["compute_s"]
    assert c1 / c64 == pytest.approx(64, rel=0.05)

    sort_p = MACHSUITE_PROFILES["sort"]
    s1 = kernel_time(sort_p, OptLevel.O3, pe=1)["compute_s"]
    s64 = kernel_time(sort_p, OptLevel.O3, pe=64)["compute_s"]
    assert 2 < s1 / s64 < 40   # tree-reduce: much less than 64x

    bfs_p = MACHSUITE_PROFILES["bfs"]
    b1 = kernel_time(bfs_p, OptLevel.O3, pe=1)["compute_s"]
    b64 = kernel_time(bfs_p, OptLevel.O3, pe=64)["compute_s"]
    assert b1 == b64   # no parallel jobs


def test_refinement_curve_monotone_for_accelerable():
    """Walking O0 -> O5 never slows an accelerable kernel down much; total
    improvement matches Fig. 12's orders of magnitude."""
    for name, prof in MACHSUITE_PROFILES.items():
        curve = refinement_curve(prof)
        times = [curve[i]["system_s"] for i in range(6)]
        for a, b in zip(times, times[1:]):
            assert b <= a * 1.35, (name, times)   # small regressions only
        if name not in ("bfs", "spmv"):
            assert times[0] / times[-1] > 30, (name, times)


def test_double_buffer_bounded_gain():
    """Fig. 12: double buffering contributes <= ~2.1x."""
    for name, prof in MACHSUITE_PROFILES.items():
        t3 = kernel_time(prof, OptLevel.O3)
        t4 = kernel_time(prof, OptLevel.O4)
        gain = t3["kernel_s"] / t4["kernel_s"]
        assert 0.95 <= gain <= 2.3, (name, gain)
