"""Multi-device integration tests (subprocess: fresh jax with N host
devices, since device count locks at first jax init)."""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.dist   # distributed tier: opt in with -m dist

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, n_devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{n_devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=REPO)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_train_step_runs_on_4x2_mesh():
    """Real (executed, not just lowered) sharded train step for a dense and
    an MoE smoke arch on a (data=4, model=2) mesh."""
    out = run_py("""
        import jax, numpy as np
        from repro.configs import get_smoke
        from repro.configs.base import ShapeConfig
        from repro.launch import steps
        from repro.launch.mesh import make_host_mesh
        from repro.models import make_batch
        from repro.optim import adamw
        from repro.parallel.sharding import use_sharder

        mesh = make_host_mesh(data=4, model=2)
        shape = ShapeConfig("t", 64, 8, "train")
        for arch in ("qwen3-8b", "qwen3-moe-30b-a3b"):
            cfg = get_smoke(arch)
            art = steps.build_train(cfg, shape, mesh)
            with art.sharder.mesh, use_sharder(art.sharder):
                params = jax.jit(art.model.init,
                                 out_shardings=art.in_shardings[0])(
                    jax.random.PRNGKey(0))
                opt = jax.jit(lambda p: adamw.init_state(
                    adamw.AdamWConfig(), p),
                    out_shardings=art.in_shardings[1])(params)
                step = art.jit()
                batch = make_batch(cfg, shape, jax.random.PRNGKey(1))
                p2, o2, m = step(params, opt, batch)
                loss = float(m["loss"])
                assert np.isfinite(loss), arch
                print("OK", arch, loss)
    """)
    assert out.count("OK") == 2


@pytest.mark.slow
def test_serve_step_runs_on_mesh():
    out = run_py("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_smoke
        from repro.configs.base import ShapeConfig
        from repro.launch import steps
        from repro.launch.mesh import make_host_mesh
        from repro.parallel.sharding import use_sharder

        mesh = make_host_mesh(data=4, model=2)
        shape = ShapeConfig("d", 64, 8, "decode")
        for arch in ("qwen3-8b", "rwkv6-3b"):
            cfg = get_smoke(arch)
            art = steps.build_serve(cfg, shape, mesh)
            with art.sharder.mesh, use_sharder(art.sharder):
                params = jax.jit(art.model.init,
                                 out_shardings=art.in_shardings[0])(
                    jax.random.PRNGKey(0))
                cache = jax.jit(
                    lambda: art.model.init_cache(8, 64),
                    out_shardings=art.in_shardings[1])()
                step = art.jit()
                tok, cache = step(params, cache,
                                  jnp.ones((8, 1), jnp.int32),
                                  jnp.zeros((8,), jnp.int32))
                assert tok.shape == (8, 1)
                print("OK", arch)
    """)
    assert out.count("OK") == 2


@pytest.mark.slow
def test_pipeline_parallel_equivalence():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.parallel.pipeline import (pipeline_apply, split_stages,
                                             make_stage_fn)
        mesh = jax.make_mesh((4,), ("stage",))
        L, d = 8, 16
        key = jax.random.PRNGKey(0)
        W = jax.random.normal(key, (L, d, d)) * 0.3
        layer = lambda w, x: jnp.tanh(x @ w)
        x = jax.random.normal(key, (6, 4, d))
        out = pipeline_apply(split_stages(W, 4), x,
                             stage_fn=make_stage_fn(layer), mesh=mesh)
        h = x
        for l in range(L):
            h = layer(W[l], h)
        err = float(jnp.max(jnp.abs(out - h)))
        assert err < 1e-5, err
        print("OK", err)
    """, n_devices=4)
    assert "OK" in out


@pytest.mark.slow
def test_elastic_checkpoint_reshard():
    """Save params sharded on a (4,2) mesh, restore onto (2,4) and (1,1):
    bitwise-identical values under every target sharding."""
    out = run_py("""
        import os, tempfile, jax, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_checkpoint, load_checkpoint
        from repro.launch.mesh import make_host_mesh

        mesh_a = make_host_mesh(data=4, model=2)
        mesh_b = make_host_mesh(data=2, model=4)
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 32))
        tree = {"w": jax.device_put(
            x, NamedSharding(mesh_a, P("data", "model")))}

        d = tempfile.mkdtemp()
        path = save_checkpoint(os.path.join(d, "ck"), tree, step=5)

        spec = {"w": jax.ShapeDtypeStruct((16, 32), x.dtype)}
        for mesh, pspec in ((mesh_b, P("data", "model")),
                            (mesh_b, P(None, "model")),
                            (make_host_mesh(), P())):
            sh = {"w": NamedSharding(mesh, pspec)}
            restored, step, _ = load_checkpoint(path, spec, shardings=sh)
            np.testing.assert_array_equal(
                np.asarray(restored["w"]), np.asarray(x))
            assert step == 5
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_compressed_podwise_reduction():
    """int8 error-feedback all-reduce over a real pod axis (shard_map)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.runtime.compression import (int8_compress,
                                               int8_decompress)

        mesh = jax.make_mesh((4,), ("pod",))

        def reduce_compressed(g, err):
            target = g + err
            q, s = int8_compress(target)
            deq = int8_decompress(q, s)
            new_err = target - deq
            return jax.lax.pmean(deq, "pod"), new_err

        f = shard_map(reduce_compressed, mesh=mesh,
                      in_specs=(P("pod"), P("pod")),
                      out_specs=(P("pod"), P("pod")))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        err = jnp.zeros((8, 64))
        red, new_err = f(g, err)
        # per-pod rows of `red` hold the pod-mean (replicated math check)
        true_mean = np.asarray(g).reshape(4, 2, 64).mean(0)
        got = np.asarray(red).reshape(4, 2, 64)
        for p in range(4):
            np.testing.assert_allclose(got[p], true_mean, atol=0.06)
        # residual bounded by one quantization step
        scale = np.abs(np.asarray(g)).max() / 127
        assert float(jnp.max(jnp.abs(new_err))) <= scale * 0.51
        print("OK")
    """, n_devices=4)
    assert "OK" in out


@pytest.mark.slow
def test_mini_dryrun_8dev():
    """The dry-run machinery end-to-end on an 8-device production-shaped
    mesh (2,2,2): lower + compile + roofline terms for one cell."""
    out = run_py("""
        import jax
        from repro.configs import get_smoke
        from repro.configs.base import ShapeConfig
        from repro.core.analyzer import roofline_from_compiled
        from repro.launch import steps
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(data=2, model=2, pod=2)
        cfg = get_smoke("qwen3-8b")
        shape = ShapeConfig("t", 64, 8, "train")
        art = steps.build_train(cfg, shape, mesh)
        lowered = art.lower()
        compiled = lowered.compile()
        rf = roofline_from_compiled(
            compiled, arch="qwen3-8b", shape="t", mesh_name="host",
            chips=8, model_flops=1e9)
        assert rf.compute_s > 0 and rf.memory_s > 0
        assert rf.collective_bytes_per_device > 0, "expected collectives"
        print("OK", rf.dominant, sorted(rf.collective_breakdown))
    """)
    assert "OK" in out


def test_serving_pe_sharding_matches_single_device():
    """O3's PE duplication for serving: with pe>1 and multiple devices the
    engine shards the batch axis of cache+step; tokens must match the
    unsharded O2 engine bit for bit."""
    out = run_py("""
        import jax
        from repro.configs import get_smoke
        from repro.core.optlevel import BestEffortConfig, OptLevel
        from repro.models import get_model
        from repro.serving import DecodeEngine, Request

        assert jax.device_count() == 2
        cfg = get_smoke("qwen3-8b")
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        gens = {}
        for lvl in (OptLevel.O2, OptLevel.O3, OptLevel.O5):
            eng = DecodeEngine(model, params, batch_size=4, max_seq=32,
                               config=BestEffortConfig(level=lvl, pe=2))
            sharded = eng.placement.sharded
            assert sharded == (lvl >= OptLevel.O3), (lvl, sharded)
            for p in ([5, 6, 7], [9], [3, 1, 4, 1], [2, 2], [8, 8, 8]):
                eng.submit(Request(prompt=list(p), max_new_tokens=4))
            gens[int(lvl)] = {r.rid: r.generated for r in eng.run()}
        assert gens[2] == gens[3] == gens[5]
        print("OK sharded serving identical")
    """, n_devices=2)
    assert "OK" in out


def test_sharded_paged_serving_oracle():
    """The layout x placement composition cell: a paged engine with
    effective_pe > 1 on 4 devices must build a BLOCK-axis-sharded pool
    (tables replicated, dense view batch-sharded) and decode a random
    mix — mid-flight arrivals, a pool small enough that the block gate
    queues admissions — to greedy tokens bit-identical to the unsharded
    O6 and the contiguous (batch-sharded) O5 paths."""
    out = run_py("""
        import jax, numpy as np
        from repro.configs import get_smoke
        from repro.core.optlevel import BestEffortConfig, OptLevel
        from repro.models import get_model
        from repro.serving import DecodeEngine, Request

        assert jax.device_count() == 4
        cfg = get_smoke("qwen3-8b")
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(11)
        mix = [(rng.integers(1, cfg.vocab,
                             int(rng.integers(1, 9))).tolist(),
                int(rng.integers(1, 6))) for _ in range(10)]

        def run(config):
            eng = DecodeEngine(model, params, batch_size=4, max_seq=32,
                               config=config)
            rids = [eng.submit(Request(prompt=list(p), max_new_tokens=n))
                    for p, n in mix[:6]]
            for _ in range(2):          # mid-flight arrivals
                eng.step()
            rids += [eng.submit(Request(prompt=list(p), max_new_tokens=n))
                     for p, n in mix[6:]]
            fin = {r.rid: r.generated for r in eng.run()}
            return eng, [fin[rid] for rid in rids]

        # kv_pool_blocks=20 < 4 slots x 8 blocks/seq: the admission gate
        # queues under load (never rejects), on the sharded path too.
        e5, g5 = run(BestEffortConfig(level=OptLevel.O5, pe=4))
        e6, g6 = run(BestEffortConfig(level=OptLevel.O6, pe=1,
                                      kv_block_size=4, kv_pool_blocks=20))
        e6s, g6s = run(BestEffortConfig(level=OptLevel.O6, pe=4,
                                        kv_block_size=4,
                                        kv_pool_blocks=20))
        # the gather-free kernel on the SAME block-axis-sharded pool:
        # the step replicates the pool in-graph for the kernel call and
        # out_shardings re-shard the written pool onto the block axis
        e6k, g6k = run(BestEffortConfig(level=OptLevel.O6, pe=4,
                                        kv_block_size=4,
                                        kv_pool_blocks=20,
                                        paged_attn="kernel"))
        assert e5.placement.n_devices == 4 and e5.layout.name == \\
            "contiguous"
        assert e6.placement.n_devices == 1 and e6.layout.name == "paged"
        assert e6s.placement.n_devices == 4 and e6s.layout.name == "paged"
        assert e6k.placement.n_devices == 4 and \\
            e6k.layout.attn_impl == "kernel"
        # the pool really is sharded on its BLOCK axis, rows padded to a
        # device multiple — on the kernel cell too
        for eng in (e6s, e6k):
            leaves = jax.tree.leaves(eng.cache_mgr.cache)
            paged_leaf, (bax, _) = next(
                (leaf, plan) for leaf, plan
                in zip(leaves, eng.cache_mgr.plan.plans) if plan[1])
            assert paged_leaf.shape[bax] % 4 == 0, paged_leaf.shape
            assert paged_leaf.sharding.spec[bax] == "data", \\
                paged_leaf.sharding.spec
        assert g5 == g6 == g6s == g6k, "sharded-paged tokens diverged"
        print("OK sharded paged oracle", len(g6s))
    """, n_devices=4)
    assert "OK" in out
