"""Serving engine: continuous batching, slot hygiene, retirement."""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import get_model
from repro.serving import DecodeEngine, Request

RNG = jax.random.PRNGKey(0)


def _engine(arch="qwen3-8b", B=3, max_seq=32):
    cfg = get_smoke(arch)
    model = get_model(cfg)
    params = model.init(RNG)
    return DecodeEngine(model, params, batch_size=B, max_seq=max_seq), cfg


def test_all_requests_finish_exact_lengths():
    eng, _ = _engine()
    lens = [4, 2, 7, 1, 3]
    for i, n in enumerate(lens):
        eng.submit(Request(prompt=[i + 1, i + 2], max_new_tokens=n))
    fin = eng.run()
    assert sorted(len(r.generated) for r in fin) == sorted(lens)


def test_more_requests_than_slots():
    eng, _ = _engine(B=2)
    for i in range(7):
        eng.submit(Request(prompt=[1 + i], max_new_tokens=3))
    fin = eng.run()
    assert len(fin) == 7


def test_determinism_across_slot_reuse():
    """Same prompt gives the same completion whether it runs in a fresh
    engine or a reused slot (cache zeroing)."""
    for arch in ("qwen3-8b", "rwkv6-3b", "zamba2-2.7b"):
        eng, _ = _engine(arch, B=2, max_seq=24)
        eng.submit(Request(prompt=[5, 6, 7], max_new_tokens=4))
        first = eng.run()[-1].generated
        # occupy + retire slots with other traffic, then repeat
        eng.submit(Request(prompt=[9, 9], max_new_tokens=5))
        eng.submit(Request(prompt=[3, 1, 4, 1], max_new_tokens=2))
        eng.run()
        eng.submit(Request(prompt=[5, 6, 7], max_new_tokens=4))
        again = eng.run()[-1].generated
        assert first == again, arch


def test_batched_equals_solo():
    """A request decodes to the same tokens alone or batched with others
    (slots are independent)."""
    eng, _ = _engine(B=1, max_seq=24)
    eng.submit(Request(prompt=[2, 4, 6], max_new_tokens=5))
    solo = eng.run()[0].generated

    eng2, _ = _engine(B=3, max_seq=24)
    eng2.submit(Request(prompt=[2, 4, 6], max_new_tokens=5))
    eng2.submit(Request(prompt=[1, 1, 1, 1], max_new_tokens=3))
    eng2.submit(Request(prompt=[7], max_new_tokens=6))
    fin = eng2.run()
    batched = next(r for r in fin if r.prompt == [2, 4, 6]).generated
    assert solo == batched


def test_eos_stops_early():
    eng, cfg = _engine()
    # run once to find what token gets generated, then use it as EOS
    eng.submit(Request(prompt=[3, 5], max_new_tokens=6))
    toks = eng.run()[0].generated
    eos = toks[1]
    eng.submit(Request(prompt=[3, 5], max_new_tokens=6, eos_id=eos))
    out = eng.run()[-1]
    assert out.generated[-1] == eos
    assert len(out.generated) <= 2


def test_request_too_long_rejected():
    eng, _ = _engine(B=1, max_seq=8)
    eng.submit(Request(prompt=[1] * 6, max_new_tokens=6))
    with pytest.raises(AssertionError):
        eng.run()
